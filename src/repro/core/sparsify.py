"""Upstream Entity-Wise Top-K Sparsification (paper §III-C, Eq. 1-2).

Entity-wise (row-wise) sparsification: whole embedding rows are either sent
at full precision or not sent at all — never element-wise truncated.  That is
the paper's core departure from parameter-wise Top-K sparsification in
generic federated learning.

All functions here are jit-safe (static K); the federated simulation and the
TPU shard_map collective both build on them.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import eshard
from repro.kernels import ops as kernel_ops


def sparsity_k(num_entities: int, p: float) -> int:
    """K = N_c * p (Eq. 2), at least 1, at most N_c (0 when N_c == 0)."""
    return min(num_entities, max(1, int(round(num_entities * p))))


def change_scores(
    current: jnp.ndarray, history: jnp.ndarray, use_kernel: bool = True
) -> jnp.ndarray:
    """M = 1 - cos(E^t, E^h) per entity row (Eq. 1).

    current/history: (N, D).  Returns (N,) change scores in [0, 2].
    ``use_kernel`` routes through the fused Pallas kernel wrapper (which
    falls back to the jnp reference off-TPU).
    """
    if use_kernel:
        return kernel_ops.change_score(current, history)
    num = (current * history).sum(axis=-1)
    den = jnp.linalg.norm(current, axis=-1) * jnp.linalg.norm(history, axis=-1)
    return 1.0 - num / jnp.maximum(den, 1e-12)


def top_k_select(
    scores: jnp.ndarray, k: int, *, entity_axis: Optional[str] = None
) -> jnp.ndarray:
    """THE Top-K selection used by every engine (upload, download, and the
    ranked-key/sign variants): ``lax.top_k`` index order — descending score,
    ties toward the lower index — over the trailing axis.

    ``scores`` may have leading batch axes.  With ``entity_axis`` set the
    trailing axis is this shard's block of a row-sharded score vector and
    the returned indices are GLOBAL row ids, merged across shards via
    :func:`repro.core.eshard.merged_top_k` — bitwise identical to a global
    ``top_k`` of the concatenated scores.
    """
    return eshard.merged_top_k(scores, k, entity_axis)


def select_top_k(scores: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-K entity indices by change score + 0/1 sign vector.

    Returns (indices (k,) int32 in descending-score order, sign (N,) int8).
    """
    idx = top_k_select(scores, k)
    sign = jnp.zeros(scores.shape[0], dtype=jnp.int8).at[idx].set(1)
    return idx.astype(jnp.int32), sign


def upstream_sparsify(
    current: jnp.ndarray,
    history: jnp.ndarray,
    k: int,
    use_kernel: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full client-side upstream pass.

    Returns ``(indices (k,), values (k, D), sign (N,), new_history (N, D))``.
    ``new_history`` has the selected rows refreshed to ``current`` (paper:
    "updating corresponding embeddings in E_h for selected Top-K entities").
    """
    scores = change_scores(current, history, use_kernel=use_kernel)
    idx, sign = select_top_k(scores, k)
    values = current[idx]
    new_history = history.at[idx].set(values)
    return idx, values, sign, new_history


def quantize_rows(values: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise symmetric int8 quantization of selected embedding rows.

    Beyond-paper extension (EXPERIMENTS.md §Repro): the paper keeps selected
    rows at full precision; FedS+Q8 additionally quantizes the wire payload
    (int8 + one f32 scale per row = ~4x fewer bytes per selected row).
    Returns (q (k, D) int8, scale (k,) f32); dequantize with q * scale.
    """
    scale = jnp.max(jnp.abs(values), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(values / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_rows(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale[:, None]
