"""Universal-precision-reduction baseline: FedE-KD (co-distillation).

The paper's *negative finding* (§III-A, Table I, Appendix VI-A/B) is that
compressing ALL entity embeddings slows convergence enough that TOTAL
communication goes UP despite the smaller per-round payload.  Two baseline
families reproduce it:

* **FedE-KD** (this module): each client holds low- and high-dim embeddings;
  both train on local triples with mutual KL co-distillation (Eq. 6); only
  the low-dim table is communicated (FedE-style full exchange).  KD is a
  *model-side* compression — it changes what is trained, not just what is
  transmitted — so it genuinely needs this standalone host pipeline.
* **FedE-SVD / FedE-SVD+** — low-rank truncation of transmissions.  The
  standalone numpy pipeline that used to live here was absorbed into the
  ``lowrank`` wire codec (:mod:`repro.core.codecs.lowrank`), which runs the
  same per-row truncated-SVD math *inside* the compiled engines: drive it
  with ``run_federated(..., FederatedConfig(protocol="feds_nosync",
  sparsity_p=1.0, codec="lowrank:cols=8,rank=2"))`` for the full-exchange
  Table-I shape (every shared row transmitted low-rank every round).  SVD+'s
  factor fine-tuning retired with the host pipeline (EXPERIMENTS.md
  §Codecs documents the delta: the codec truncates transmitted *embeddings*
  where the retired pipeline truncated update deltas).

`benchmarks/table1_compression.py` runs both baselines against FedE.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import Upload, fede_aggregate
from repro.core.protocol import build_comm_views
from repro.data.partition import ClientData
from repro.federated.client import KGEClient
from repro.federated.comm import CommLedger
from repro.federated.metrics import weighted_average
from repro.kge.scoring import KGEModel, init_kge_params, kge_loss, score_triples
from repro.train.optimizer import adam_init, adam_update


# --------------------------------------------------------------------- KD
@functools.partial(jax.jit, static_argnames=("method", "gamma", "lr", "temp"))
def _kd_train_epoch(
    params_lo,
    params_hi,
    opt_lo,
    opt_hi,
    pos,
    neg_t,
    neg_h,
    method: str,
    gamma: float,
    lr: float,
    temp: float,
):
    """Joint low/high-dim training with mutual KL co-distillation (Eq. 6)."""

    def scores(params, p, nt, nh):
        h, r, t = p[:, 0], p[:, 1], p[:, 2]
        pos_s = score_triples(params, h, r, t, method, gamma)[:, None]
        neg_ts = score_triples(params, h, r, nt, method, gamma)
        neg_hs = score_triples(params, nh, r, t, method, gamma)
        return jnp.concatenate([pos_s, neg_ts, neg_hs], axis=-1)  # (B, 1+2N)

    def loss_fn(both, batch):
        p, nt, nh = batch
        l_lo = kge_loss(both["lo"], p, nt, nh, method, gamma, temp)
        l_hi = kge_loss(both["hi"], p, nt, nh, method, gamma, temp)
        s_lo = jax.nn.log_softmax(scores(both["lo"], p, nt, nh), axis=-1)
        s_hi = jax.nn.log_softmax(scores(both["hi"], p, nt, nh), axis=-1)
        kl_lh = jnp.sum(jnp.exp(s_lo) * (s_lo - s_hi), axis=-1).mean()
        kl_hl = jnp.sum(jnp.exp(s_hi) * (s_hi - s_lo), axis=-1).mean()
        # Adaptive weighting: co-distillation strengthens as supervised loss
        # shrinks (Eq. 6 denominator), gradients through the weight stopped.
        denom = jax.lax.stop_gradient(l_lo + l_hi) + 1e-6
        return l_lo + l_hi + (kl_lh + kl_hl) / denom

    both = {"lo": params_lo, "hi": params_hi}
    opt = {"lo": opt_lo, "hi": opt_hi}

    def step(carry, batch):
        both, opt = carry
        loss, grads = jax.value_and_grad(loss_fn)(both, batch)
        new_lo, opt_lo2 = adam_update(grads["lo"], opt["lo"], both["lo"], lr)
        new_hi, opt_hi2 = adam_update(grads["hi"], opt["hi"], both["hi"], lr)
        return ({"lo": new_lo, "hi": new_hi}, {"lo": opt_lo2, "hi": opt_hi2}), loss

    (both, opt), losses = jax.lax.scan(step, (both, opt), (pos, neg_t, neg_h))
    return both["lo"], both["hi"], opt["lo"], opt["hi"], losses.mean()


@dataclasses.dataclass
class CompressionConfig:
    strategy: str = "kd"  # only "kd" — svd/svdp absorbed into the lowrank codec
    method: str = "transe"
    dim: int = 256
    kd_low_dim: int = 192
    rounds: int = 100
    local_epochs: int = 3
    batch_size: int = 512
    num_negatives: int = 64
    lr: float = 1e-4
    gamma: float = 8.0
    eval_every: int = 5
    patience: int = 3
    max_eval_triples: int = 500
    seed: int = 0


def run_compression(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: CompressionConfig,
    verbose: bool = False,
):
    """Run FedE-KD; returns a FederatedResult-compatible record.

    The SVD strategies route through the real engines now — see the module
    docstring for the ``codec="lowrank"`` invocation.
    """
    from repro.federated.simulation import FederatedResult, FederatedConfig, _snapshot, _restore

    if cfg.strategy != "kd":
        raise ValueError(
            f"strategy {cfg.strategy!r} retired from the host pipeline; "
            "FedE-SVD now runs through the engines via "
            "FederatedConfig(protocol='feds_nosync', sparsity_p=1.0, "
            "codec='lowrank:cols=...,rank=...') — only 'kd' remains here"
        )

    clients = [
        KGEClient(
            d,
            method=cfg.method,
            dim=cfg.dim,
            gamma=cfg.gamma,
            batch_size=cfg.batch_size,
            num_negatives=cfg.num_negatives,
            lr=cfg.lr,
            seed=cfg.seed,
        )
        for d in clients_data
    ]
    views = build_comm_views([d.local_to_global for d in clients_data], num_global_entities)
    ledger = CommLedger()
    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None}
    declines, prev_mrr, rounds_run = 0, -1.0, 0

    lo_models = [
        KGEModel(method=cfg.method, num_entities=d.num_entities,  # type: ignore[arg-type]
                 num_relations=d.num_relations, dim=cfg.kd_low_dim)
        for d in clients_data
    ]
    params_lo = [
        init_kge_params(jax.random.PRNGKey(cfg.seed * 31 + i + 1), m)
        for i, m in enumerate(lo_models)
    ]
    opt_lo = [adam_init(p) for p in params_lo]
    per_entity = cfg.kd_low_dim

    for t in range(cfg.rounds):
        rounds_run = t + 1
        uploads = []
        for i, c in enumerate(clients):
            for _ in range(cfg.local_epochs):
                stacked = [b for b in c.loader.epoch()]
                pos = jnp.asarray(np.stack([b[0] for b in stacked]))
                nt = jnp.asarray(np.stack([b[1] for b in stacked]))
                nh = jnp.asarray(np.stack([b[2] for b in stacked]))
                params_lo[i], c.params, opt_lo[i], c.opt_state, _ = _kd_train_epoch(
                    params_lo[i], c.params, opt_lo[i], c.opt_state,
                    pos, nt, nh, cfg.method, cfg.gamma, cfg.lr, 1.0,
                )
            v = views[i]
            uploads.append(Upload(
                client_id=i,
                entity_ids=v.shared_global.astype(np.int64),
                values=np.asarray(params_lo[i]["entity"])[v.shared_local],
            ))
            ledger.params_transmitted += v.num_shared * per_entity
            ledger.bytes_int8_signs += v.num_shared * per_entity * 4
        mean, _ = fede_aggregate(uploads, num_global_entities)
        for i, v in enumerate(views):
            params_lo[i]["entity"] = (
                params_lo[i]["entity"]
                .at[jnp.asarray(v.shared_local)]
                .set(jnp.asarray(mean[v.shared_global]))
            )
            ledger.params_transmitted += v.num_shared * per_entity
            ledger.bytes_int8_signs += v.num_shared * per_entity * 4
        ledger.end_round()

        if (t + 1) % cfg.eval_every == 0:
            val = weighted_average(
                [c.evaluate("valid", cfg.max_eval_triples) for c in clients]
            )
            eval_history.append((t + 1, val["mrr"], val["hits10"]))
            if verbose:
                print(f"[{cfg.strategy}] round {t+1:4d} val MRR {val['mrr']:.4f}")
            if val["mrr"] > best["mrr"]:
                best = {"mrr": val["mrr"], "round": t + 1, "snap": _snapshot(clients)}
            declines = declines + 1 if val["mrr"] < prev_mrr else 0
            prev_mrr = val["mrr"]
            if declines >= cfg.patience:
                break

    if best["snap"] is not None:
        _restore(clients, best["snap"])
    test = weighted_average([c.evaluate("test", cfg.max_eval_triples) for c in clients])
    fed_cfg = FederatedConfig(method=cfg.method, protocol=f"fede_{cfg.strategy}",
                              dim=cfg.dim, rounds=cfg.rounds,
                              local_epochs=cfg.local_epochs, lr=cfg.lr, seed=cfg.seed)
    return FederatedResult(
        config=fed_cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )
