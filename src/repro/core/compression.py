"""Universal-precision-reduction baselines: FedE-KD, FedE-SVD, FedE-SVD+.

These implement the paper's *negative finding* (§III-A, Table I, Appendix
VI-A/B): compressing ALL entity embeddings — co-distillation to a lower
dimension, or low-rank truncation of the update matrices — slows convergence
enough that TOTAL communication goes UP despite the smaller per-round
payload.  They exist as first-class baselines so Table I is reproducible.

* FedE-KD: each client holds low- and high-dim embeddings; both train on
  local triples with mutual KL co-distillation (Eq. 6); only the low-dim
  table is communicated (FedE-style full exchange).
* FedE-SVD: per-entity embedding *updates* are reshaped to (m, n) and
  truncated to the top ``r`` singular values before transmission, both
  directions.
* FedE-SVD+: additionally retrains the factors (U, s, V) on the local loss
  with an orthogonality regularizer (Eq. 7) before truncation.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import Upload, fede_aggregate
from repro.core.protocol import ClientCommView, build_comm_views
from repro.data.partition import ClientData
from repro.federated.client import KGEClient, _train_epoch
from repro.federated.comm import CommLedger
from repro.federated.metrics import weighted_average
from repro.kge.scoring import KGEModel, init_kge_params, kge_loss, score_triples
from repro.train.optimizer import adam_init, adam_update

# --------------------------------------------------------------------- SVD


def svd_compress(updates: np.ndarray, n_cols: int, rank: int):
    """Truncated per-entity SVD of update rows.

    updates (N, D) -> factors (U (N, m, r), s (N, r), V (N, n, r)) with
    D = m * n_cols.  Transmitted parameter count per entity:
    m*r + r + n*r (Appendix VI-B).
    """
    n_rows, dim = updates.shape
    m = dim // n_cols
    mat = updates.reshape(n_rows, m, n_cols)
    u, s, vt = np.linalg.svd(mat, full_matrices=False)
    return u[:, :, :rank], s[:, :rank], np.transpose(vt[:, :rank, :], (0, 2, 1))


def svd_restore(u: np.ndarray, s: np.ndarray, v: np.ndarray, dim: int) -> np.ndarray:
    """Inverse of :func:`svd_compress` (lossy)."""
    mat = np.einsum("nmr,nr,nkr->nmk", u, s, v)
    return mat.reshape(mat.shape[0], dim)


def svd_params_per_entity(dim: int, n_cols: int, rank: int) -> int:
    m = dim // n_cols
    return m * rank + rank + n_cols * rank


# ------------------------------------------------------------------- SVD+
@functools.partial(jax.jit, static_argnames=("method", "gamma", "lr", "alpha", "steps"))
def _svdp_refine(
    base_entity,  # (N, D) embeddings at round start
    u,  # (N, m, r)
    s,  # (N, r)
    v,  # (N, n, r)
    relation,  # (R, Dr)
    pos,
    neg_t,
    neg_h,
    method: str,
    gamma: float,
    lr: float,
    alpha: float,
    steps: int,
):
    """Final-epoch factor training with orthogonality regularization (Eq. 7)."""
    n, dim = base_entity.shape
    r = s.shape[-1]

    def entity_of(f):
        delta = jnp.einsum("nmr,nr,nkr->nmk", f["u"], f["s"], f["v"]).reshape(n, dim)
        return base_entity + delta

    def loss_fn(f, batch):
        p, nt, nh = batch
        params = {"entity": entity_of(f), "relation": relation}
        l_kge = kge_loss(params, p, nt, nh, method, gamma)
        eye = jnp.eye(r)
        ortho = (
            jnp.mean(jnp.sum((jnp.einsum("nmr,nms->nrs", f["u"], f["u"]) - eye) ** 2, (-2, -1)))
            + jnp.mean(jnp.sum((jnp.einsum("nkr,nks->nrs", f["v"], f["v"]) - eye) ** 2, (-2, -1)))
        ) / (r * r)
        return l_kge + alpha * ortho

    factors = {"u": u, "s": s, "v": v}
    opt = adam_init(factors)

    def step_fn(carry, batch):
        f, opt = carry
        _, grads = jax.value_and_grad(loss_fn)(f, batch)
        f, opt = adam_update(grads, opt, f, lr)
        return (f, opt), 0.0

    nb = pos.shape[0]
    take = min(steps, nb)
    (factors, _), _ = jax.lax.scan(
        step_fn, (factors, opt), (pos[:take], neg_t[:take], neg_h[:take])
    )
    return factors["u"], factors["s"], factors["v"]


# --------------------------------------------------------------------- KD
@functools.partial(jax.jit, static_argnames=("method", "gamma", "lr", "temp"))
def _kd_train_epoch(
    params_lo,
    params_hi,
    opt_lo,
    opt_hi,
    pos,
    neg_t,
    neg_h,
    method: str,
    gamma: float,
    lr: float,
    temp: float,
):
    """Joint low/high-dim training with mutual KL co-distillation (Eq. 6)."""

    def scores(params, p, nt, nh):
        h, r, t = p[:, 0], p[:, 1], p[:, 2]
        pos_s = score_triples(params, h, r, t, method, gamma)[:, None]
        neg_ts = score_triples(params, h, r, nt, method, gamma)
        neg_hs = score_triples(params, nh, r, t, method, gamma)
        return jnp.concatenate([pos_s, neg_ts, neg_hs], axis=-1)  # (B, 1+2N)

    def loss_fn(both, batch):
        p, nt, nh = batch
        l_lo = kge_loss(both["lo"], p, nt, nh, method, gamma, temp)
        l_hi = kge_loss(both["hi"], p, nt, nh, method, gamma, temp)
        s_lo = jax.nn.log_softmax(scores(both["lo"], p, nt, nh), axis=-1)
        s_hi = jax.nn.log_softmax(scores(both["hi"], p, nt, nh), axis=-1)
        kl_lh = jnp.sum(jnp.exp(s_lo) * (s_lo - s_hi), axis=-1).mean()
        kl_hl = jnp.sum(jnp.exp(s_hi) * (s_hi - s_lo), axis=-1).mean()
        # Adaptive weighting: co-distillation strengthens as supervised loss
        # shrinks (Eq. 6 denominator), gradients through the weight stopped.
        denom = jax.lax.stop_gradient(l_lo + l_hi) + 1e-6
        return l_lo + l_hi + (kl_lh + kl_hl) / denom

    both = {"lo": params_lo, "hi": params_hi}
    opt = {"lo": opt_lo, "hi": opt_hi}

    def step(carry, batch):
        both, opt = carry
        loss, grads = jax.value_and_grad(loss_fn)(both, batch)
        new_lo, opt_lo2 = adam_update(grads["lo"], opt["lo"], both["lo"], lr)
        new_hi, opt_hi2 = adam_update(grads["hi"], opt["hi"], both["hi"], lr)
        return ({"lo": new_lo, "hi": new_hi}, {"lo": opt_lo2, "hi": opt_hi2}), loss

    (both, opt), losses = jax.lax.scan(step, (both, opt), (pos, neg_t, neg_h))
    return both["lo"], both["hi"], opt["lo"], opt["hi"], losses.mean()


@dataclasses.dataclass
class CompressionConfig:
    strategy: str = "svd"  # kd | svd | svdp
    method: str = "transe"
    dim: int = 256
    kd_low_dim: int = 192
    svd_cols: int = 8
    svd_rank: int = 5
    svdp_alpha: float = 0.05
    svdp_steps: int = 8
    rounds: int = 100
    local_epochs: int = 3
    batch_size: int = 512
    num_negatives: int = 64
    lr: float = 1e-4
    gamma: float = 8.0
    eval_every: int = 5
    patience: int = 3
    max_eval_triples: int = 500
    seed: int = 0


def run_compression(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: CompressionConfig,
    verbose: bool = False,
):
    """Run FedE-{KD,SVD,SVD+}; returns a FederatedResult-compatible record."""
    from repro.federated.simulation import FederatedResult, FederatedConfig, _snapshot, _restore

    clients = [
        KGEClient(
            d,
            method=cfg.method,
            dim=cfg.dim,
            gamma=cfg.gamma,
            batch_size=cfg.batch_size,
            num_negatives=cfg.num_negatives,
            lr=cfg.lr,
            seed=cfg.seed,
        )
        for d in clients_data
    ]
    views = build_comm_views([d.local_to_global for d in clients_data], num_global_entities)
    ledger = CommLedger()
    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None}
    declines, prev_mrr, rounds_run = 0, -1.0, 0

    if cfg.strategy == "kd":
        lo_models = [
            KGEModel(method=cfg.method, num_entities=d.num_entities,  # type: ignore[arg-type]
                     num_relations=d.num_relations, dim=cfg.kd_low_dim)
            for d in clients_data
        ]
        params_lo = [
            init_kge_params(jax.random.PRNGKey(cfg.seed * 31 + i + 1), m)
            for i, m in enumerate(lo_models)
        ]
        opt_lo = [adam_init(p) for p in params_lo]
        per_entity = cfg.kd_low_dim
    else:
        per_entity = svd_params_per_entity(cfg.dim, cfg.svd_cols, cfg.svd_rank)

    for t in range(cfg.rounds):
        rounds_run = t + 1
        uploads = []
        if cfg.strategy == "kd":
            for i, c in enumerate(clients):
                for _ in range(cfg.local_epochs):
                    stacked = [b for b in c.loader.epoch()]
                    pos = jnp.asarray(np.stack([b[0] for b in stacked]))
                    nt = jnp.asarray(np.stack([b[1] for b in stacked]))
                    nh = jnp.asarray(np.stack([b[2] for b in stacked]))
                    params_lo[i], c.params, opt_lo[i], c.opt_state, _ = _kd_train_epoch(
                        params_lo[i], c.params, opt_lo[i], c.opt_state,
                        pos, nt, nh, cfg.method, cfg.gamma, cfg.lr, 1.0,
                    )
                v = views[i]
                uploads.append(Upload(
                    client_id=i,
                    entity_ids=v.shared_global.astype(np.int64),
                    values=np.asarray(params_lo[i]["entity"])[v.shared_local],
                ))
                ledger.params_transmitted += v.num_shared * per_entity
                ledger.bytes_int8_signs += v.num_shared * per_entity * 4
            mean, _ = fede_aggregate(uploads, num_global_entities)
            for i, v in enumerate(views):
                params_lo[i]["entity"] = (
                    params_lo[i]["entity"]
                    .at[jnp.asarray(v.shared_local)]
                    .set(jnp.asarray(mean[v.shared_global]))
                )
                ledger.params_transmitted += v.num_shared * per_entity
                ledger.bytes_int8_signs += v.num_shared * per_entity * 4
        else:  # svd / svdp
            bases = [np.asarray(c.params["entity"]) for c in clients]
            for i, c in enumerate(clients):
                c.train_local(cfg.local_epochs)
                v = views[i]
                delta = np.asarray(c.params["entity"])[v.shared_local] - bases[i][v.shared_local]
                u, s, vv = svd_compress(delta, cfg.svd_cols, cfg.svd_cols)  # full rank first
                if cfg.strategy == "svdp":
                    stacked = [b for b in c.loader.epoch()]
                    pos = jnp.asarray(np.stack([b[0] for b in stacked]))
                    nt = jnp.asarray(np.stack([b[1] for b in stacked]))
                    nh = jnp.asarray(np.stack([b[2] for b in stacked]))
                    # refine factors of the shared rows only
                    u_j, s_j, v_j = _svdp_refine(
                        jnp.asarray(bases[i][v.shared_local]),
                        jnp.asarray(u), jnp.asarray(s), jnp.asarray(vv),
                        c.params["relation"], pos, nt, nh,
                        cfg.method, cfg.gamma, cfg.lr, cfg.svdp_alpha, cfg.svdp_steps,
                    )
                    u, s, vv = np.asarray(u_j), np.asarray(s_j), np.asarray(v_j)
                u, s, vv = u[:, :, : cfg.svd_rank], s[:, : cfg.svd_rank], vv[:, :, : cfg.svd_rank]
                restored = svd_restore(u, s, vv, cfg.dim)
                uploads.append(Upload(
                    client_id=i,
                    entity_ids=v.shared_global.astype(np.int64),
                    values=restored.astype(np.float32),
                ))
                ledger.params_transmitted += v.num_shared * per_entity
                ledger.bytes_int8_signs += v.num_shared * per_entity * 4
            mean_update, _ = fede_aggregate(uploads, num_global_entities)
            for i, v in enumerate(views):
                # Server re-compresses the aggregated update before download.
                upd = mean_update[v.shared_global]
                u, s, vv = svd_compress(upd, cfg.svd_cols, cfg.svd_rank)
                upd_lossy = svd_restore(u, s, vv, cfg.dim)
                new_rows = bases[i][v.shared_local] + upd_lossy
                clients[i].set_entity_rows(v.shared_local, new_rows)
                ledger.params_transmitted += v.num_shared * per_entity
                ledger.bytes_int8_signs += v.num_shared * per_entity * 4
        ledger.end_round()

        if (t + 1) % cfg.eval_every == 0:
            val = weighted_average(
                [c.evaluate("valid", cfg.max_eval_triples) for c in clients]
            )
            eval_history.append((t + 1, val["mrr"], val["hits10"]))
            if verbose:
                print(f"[{cfg.strategy}] round {t+1:4d} val MRR {val['mrr']:.4f}")
            if val["mrr"] > best["mrr"]:
                best = {"mrr": val["mrr"], "round": t + 1, "snap": _snapshot(clients)}
            declines = declines + 1 if val["mrr"] < prev_mrr else 0
            prev_mrr = val["mrr"]
            if declines >= cfg.patience:
                break

    if best["snap"] is not None:
        _restore(clients, best["snap"])
    test = weighted_average([c.evaluate("test", cfg.max_eval_triples) for c in clients])
    fed_cfg = FederatedConfig(method=cfg.method, protocol=f"fede_{cfg.strategy}",
                              dim=cfg.dim, rounds=cfg.rounds,
                              local_epochs=cfg.local_epochs, lr=cfg.lr, seed=cfg.seed)
    return FederatedResult(
        config=fed_cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )
