"""Entity-axis sharding primitives (the ``entities`` mesh axis).

The federation engines historically assumed every padded row-major table —
``(C, E_max, D)`` entity embeddings, the matching Adam moments, and the
``(C, Ns_max, D)`` upload history / error-feedback residuals — fits on one
device.  This module provides the cross-shard building blocks that let the
same programs run with those tables block-sharded along their row axis over
a second mesh axis (``launch/mesh.py:make_federation_mesh(...,
entity_devices=n)``), while staying **bitwise identical** to the unsharded
programs:

* :func:`merged_top_k` — per-shard ``lax.top_k`` + one ``all_gather`` of the
  ``(K, score)`` candidates + a two-key ``lax.sort`` merge.  ``lax.top_k``
  breaks score ties toward the lower index; sorting the merged candidates on
  ``(-score, global_index)`` reproduces exactly that order, so the selected
  index sequence equals a global ``top_k`` bit for bit (scores are
  canonicalized with ``+ 0.0`` so a stray ``-0.0`` cannot invert a tie that
  ``top_k``'s ``>`` comparison would treat as equal).
* :func:`dist_take_rows` / :func:`dist_take_vec` — exact distributed gather:
  every shard contributes its local candidate rows, one ``all_gather``, then
  a select-by-owner ``take``.  No floating-point reduction is involved (a
  masked ``psum`` could turn ``-0.0`` into ``+0.0``), so the gathered rows
  are the unsharded rows, not merely numerically close.
* :func:`own_local` / :func:`scatter_rows` / :func:`scatter_add_rows` —
  ownership tests and drop-mode local scatters.  A shard scatters exactly
  the contributions whose destination row it owns, in the order they appear
  in the full index list, so per-row accumulation order matches the
  unsharded scatter.

Everything here is shape-polymorphic over a leading batch axis via ``vmap``
(collectives batch correctly under ``vmap`` inside ``shard_map``); callers
in :mod:`repro.core.engine` / :mod:`repro.core.state` /
:mod:`repro.core.evaluation` pass ``entity_axis=None`` to stay on the
unsharded fast path, which is compiled out entirely.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def axis_size(axis_name: str) -> int:
    """Static mesh-axis size inside shard_map, on either jax generation.

    (Defined here rather than imported from :mod:`repro.core.engine` —
    ``engine`` imports :mod:`repro.core.sparsify`, which imports this
    module for the shard-aware Top-K.)
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)  # constant-folded on jax <= 0.4.x


def shard_offset(axis_name: str, block: int) -> jnp.ndarray:
    """First global row index of this shard's block."""
    return jax.lax.axis_index(axis_name) * block


def pad_rows(n: int, shards: int, multiple: int = 1) -> int:
    """Round ``n`` up so it splits into ``shards`` equal blocks, each a
    multiple of ``multiple`` rows (``32`` aligns eval filter words)."""
    unit = shards * multiple
    return max(unit, -(-int(n) // unit) * unit)


def own_local(idx: jnp.ndarray, base: jnp.ndarray, block: int):
    """Ownership mask + local index for global row ids against one block.

    Returns ``(own (bool), local (int32))``; ``local`` is only meaningful
    where ``own`` — callers route non-owned ids to a drop sentinel.
    """
    loc = idx.astype(jnp.int32) - base
    own = (loc >= 0) & (loc < block)
    return own, loc


def merged_top_k(
    scores: jnp.ndarray,  # (C, n_blk) this shard's score block
    k: int,
    axis_name: Optional[str],
) -> jnp.ndarray:
    """Global ``lax.top_k`` indices over row-sharded scores, bitwise.

    With ``axis_name=None`` this IS ``lax.top_k`` over the full scores.
    Sharded: each shard's local top-``min(k, n_blk)`` candidates (their
    global indices and scores) are all-gathered and merged with a stable
    two-key sort on ``(-score, global_index)`` — every global top-``k`` row
    is somewhere in the candidate pool, and the sort reproduces ``top_k``'s
    descending-score / ascending-index order exactly.
    """
    if axis_name is None:
        _, idx = jax.lax.top_k(scores, k)
        return idx.astype(jnp.int32)
    n_blk = scores.shape[-1]
    base = shard_offset(axis_name, n_blk)
    k_loc = min(k, n_blk)
    v, i = jax.lax.top_k(scores + 0.0, k_loc)  # +0.0: canonicalize -0.0
    gi = i.astype(jnp.int32) + base
    v = jax.lax.all_gather(v, axis_name, axis=-1, tiled=True)
    gi = jax.lax.all_gather(gi, axis_name, axis=-1, tiled=True)
    _, idx = jax.lax.sort((-v, gi), num_keys=2, dimension=-1)
    return jax.lax.slice_in_dim(idx, 0, k, axis=-1)


def _take_rows_one(table: jnp.ndarray, idx: jnp.ndarray, axis_name: str):
    """(n_blk, ...) block + (m,) global ids -> (m, ...) exact rows."""
    n_blk = table.shape[0]
    shards = axis_size(axis_name)
    base = shard_offset(axis_name, n_blk)
    own, loc = own_local(idx, base, n_blk)
    cand = jnp.take(table, jnp.clip(loc, 0, n_blk - 1), axis=0)
    gathered = jax.lax.all_gather(cand, axis_name)  # (S, m, ...)
    owner = jnp.clip(idx.astype(jnp.int32) // n_blk, 0, shards - 1)
    owner = owner.reshape(owner.shape + (1,) * (cand.ndim - 1))
    out = jnp.take_along_axis(jnp.moveaxis(gathered, 0, 1), owner[:, None], axis=1)
    return out[:, 0]


def dist_take_rows(
    table: jnp.ndarray,  # (C, n_blk, D) row-sharded blocks
    idx: jnp.ndarray,  # (C, m) global row ids (out-of-range ids yield junk
    #                    rows the caller must mask, like a clipped take)
    axis_name: Optional[str],
) -> jnp.ndarray:
    """Exact batched distributed row gather; ``== take_along_axis`` unsharded."""
    if axis_name is None:
        return jnp.take_along_axis(table, idx[:, :, None], axis=1)
    return jax.vmap(functools.partial(_take_rows_one, axis_name=axis_name))(
        table, idx
    )


def dist_take_vec(
    vec: jnp.ndarray,  # (C, n_blk) row-sharded scalar-per-row blocks
    idx: jnp.ndarray,  # (C, m) global row ids
    axis_name: Optional[str],
) -> jnp.ndarray:
    """Exact batched distributed gather of per-row scalars."""
    if axis_name is None:
        return jnp.take_along_axis(vec, idx, axis=1)
    out = jax.vmap(functools.partial(_take_rows_one, axis_name=axis_name))(
        vec[:, :, None], idx
    )
    return out[..., 0]


def _local_idx(idx: jnp.ndarray, axis_name: Optional[str], block: int):
    """Global ids -> local ids with a drop sentinel for non-owned rows."""
    if axis_name is None:
        return idx
    base = shard_offset(axis_name, block)
    own, loc = own_local(idx, base, block)
    return jnp.where(own, loc, block)


def scatter_rows(
    table: jnp.ndarray,  # (C, n_blk, D) row-sharded blocks
    idx: jnp.ndarray,  # (C, m) global row ids (sentinel >= n_total drops)
    rows: jnp.ndarray,  # (C, m, D)
    axis_name: Optional[str],
) -> jnp.ndarray:
    """Set rows by global id; each shard writes only the rows it owns."""
    block = table.shape[1]
    loc = _local_idx(idx, axis_name, block)
    return jax.vmap(lambda t, i, r: t.at[i].set(r, mode="drop"))(table, loc, rows)


def scatter_add_rows(
    table: jnp.ndarray,  # (C, n_blk, D) row-sharded blocks
    idx: jnp.ndarray,  # (C, m) global row ids
    rows: jnp.ndarray,  # (C, m, D)
    axis_name: Optional[str],
) -> jnp.ndarray:
    """Add rows by global id, owned rows only, in full-list order."""
    block = table.shape[1]
    loc = _local_idx(idx, axis_name, block)
    return jax.vmap(lambda t, i, r: t.at[i].add(r, mode="drop"))(table, loc, rows)


def scatter_add_vec(
    vec: jnp.ndarray,  # (C, n_blk) row-sharded per-row scalars
    idx: jnp.ndarray,  # (C, m) global row ids
    vals: jnp.ndarray,  # (C, m)
    axis_name: Optional[str],
) -> jnp.ndarray:
    block = vec.shape[1]
    loc = _local_idx(idx, axis_name, block)
    return jax.vmap(lambda t, i, v: t.at[i].add(v, mode="drop"))(vec, loc, vals)


def local_block(full: jnp.ndarray, axis_name: Optional[str], block: int, axis: int = 1):
    """Slice this shard's block out of a replicated full-width array."""
    if axis_name is None:
        return full
    base = shard_offset(axis_name, block)
    return jax.lax.dynamic_slice_in_dim(full, base, block, axis=axis)


def all_blocks(blk: jnp.ndarray, axis_name: Optional[str], axis: int = 1):
    """Concatenate every shard's block back into the full-width array."""
    if axis_name is None:
        return blk
    return jax.lax.all_gather(blk, axis_name, axis=axis, tiled=True)
