"""Back-compat shim: the codec layer moved to :mod:`repro.core.codecs`.

PR 1 introduced this module with two hard-coded codecs; PR 4 grew it into a
registry-backed package (``core/codecs/``) with four codecs and optional
device-resident error-feedback residual state.  Import from
:mod:`repro.core.codecs` in new code; this shim re-exports the public
surface so existing imports keep working.
"""
from __future__ import annotations

from repro.core.codecs import (
    IdentityCodec,
    Int8RowCodec,
    LowRankCodec,
    TopKDimsCodec,
    WireCodec,
    codec_usage,
    get_codec,
    parse_codec_spec,
    registered_codecs,
)

__all__ = [
    "WireCodec",
    "IdentityCodec",
    "Int8RowCodec",
    "LowRankCodec",
    "TopKDimsCodec",
    "codec_usage",
    "get_codec",
    "parse_codec_spec",
    "registered_codecs",
]
