"""Pluggable wire codecs for FedS protocol payloads.

A :class:`WireCodec` owns BOTH sides of putting selected embedding rows on
the wire:

* the value transform — ``roundtrip`` is encode+decode fused, i.e. "the rows
  as the receiver sees them".  It is jit-safe (pure jnp) so the batched
  :class:`repro.core.engine.RoundEngine` can apply it inside the compiled
  round, and the numpy reference path can apply it to ragged per-client
  payloads.
* the :class:`repro.federated.comm.CommLedger` accounting for both protocol
  legs, so the byte/parameter math for a codec lives in exactly one place
  instead of inline branches in the simulation loop.

Ledger conventions (match the paper's Eq. 5 accounting): ``params`` are
float-equivalent parameter counts (an int8 element counts as 1/4 parameter);
``bytes`` are realistic wire bytes with int8 sign vectors.  The per-entity
sign vector is transmitted on every leg, including empty downloads — the
receiver cannot know the download was empty without it.

Codecs only ever see **sparse** rounds: under the ISM schedule
(:mod:`repro.core.sync`) the one-in-``s+1`` sync rounds are full FedE
exchanges accounted at full precision directly by the ledger
(``log_full_exchange``), which is what makes Eq. 5's ``p*s + 1`` numerator
shape.  The device engines apply ``roundtrip`` inside their compiled
programs (per round for :class:`~repro.core.state.CycleEngine`, inside the
scanned span for :class:`~repro.core.state.SuperstepEngine`) and replay the
per-leg accounting calls at eval-boundary ledger flushes.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax.numpy as jnp

from repro.core.sparsify import dequantize_rows, quantize_rows

if TYPE_CHECKING:  # avoid a core -> federated import cycle at runtime
    from repro.federated.comm import CommLedger


class WireCodec:
    """Interface: value round-trip + per-leg ledger accounting."""

    name = "abstract"
    # False when roundtrip is the identity — lets ragged host paths skip the
    # per-message device round-trip entirely.
    transforms_values = True

    def roundtrip(self, values: jnp.ndarray) -> jnp.ndarray:
        """(k, D) rows -> (k, D) rows as decoded by the receiver (jit-safe)."""
        raise NotImplementedError

    def log_upload(self, ledger: CommLedger, k: int, dim: int, num_shared: int) -> None:
        """Account one client's upstream leg (k selected rows)."""
        raise NotImplementedError

    def log_download(self, ledger: CommLedger, k: int, dim: int, num_shared: int) -> None:
        """Account one client's downstream leg (k aggregated rows)."""
        raise NotImplementedError


class IdentityCodec(WireCodec):
    """Full-precision f32 rows on the wire — the paper's FedS protocol."""

    name = "identity"
    transforms_values = False

    def roundtrip(self, values: jnp.ndarray) -> jnp.ndarray:
        return values

    def log_upload(self, ledger: CommLedger, k: int, dim: int, num_shared: int) -> None:
        ledger.log_upload_sparse(k, dim, num_shared)

    def log_download(self, ledger: CommLedger, k: int, dim: int, num_shared: int) -> None:
        ledger.log_download_sparse(k, dim, num_shared)


class Int8RowCodec(WireCodec):
    """FedS+Q8: row-wise symmetric int8 payloads + one f32 scale per row.

    Beyond-paper extension (EXPERIMENTS.md §Repro): precision is reduced only
    on the wire, never in the training state.  Upstream leg: int8 values
    (dim/4 param-equivalents per row) + f32 scale + i32 index per row + the
    (num_shared,) sign vector.  Downstream leg additionally carries the f32
    priority count per row.
    """

    name = "int8-rows"

    def roundtrip(self, values: jnp.ndarray) -> jnp.ndarray:
        return dequantize_rows(*quantize_rows(values))

    def log_upload(self, ledger: CommLedger, k: int, dim: int, num_shared: int) -> None:
        ledger.params_transmitted += k * dim / 4 + k + num_shared
        ledger.bytes_int8_signs += k * dim + k * 4 + num_shared + k * 4

    def log_download(self, ledger: CommLedger, k: int, dim: int, num_shared: int) -> None:
        ledger.params_transmitted += k * dim / 4 + 2 * k + num_shared
        # int8 values + (scale, priority) f32 pair + i32 index per row + sign
        ledger.bytes_int8_signs += k * (dim + 8) + k * 4 + num_shared


def get_codec(name: str) -> WireCodec:
    """Codec registry for config-level selection."""
    codecs = {c.name: c for c in (IdentityCodec, Int8RowCodec)}
    if name not in codecs:
        raise ValueError(f"unknown wire codec {name!r}; known: {sorted(codecs)}")
    return codecs[name]()
