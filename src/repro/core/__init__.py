"""FedS core: Entity-Wise Top-K Sparsification for federated KGE.

This package is the paper's contribution:

* :mod:`repro.core.sparsify` — upstream entity-wise Top-K selection (Eq. 1-2)
* :mod:`repro.core.aggregate` — downstream personalized aggregation with
  priority weights (Eq. 3-4)
* :mod:`repro.core.sync` — Intermittent Synchronization Mechanism (§III-E)
* :mod:`repro.core.protocol` — FedE / FedEP / FedEPL / FedS round logic
* :mod:`repro.core.compression` — the FedE-KD co-distillation baseline (the
  paper's negative finding, Table I; the SVD baseline lives in the
  ``lowrank`` codec now)
* :mod:`repro.core.engine` — the unified jitted round: batched client state,
  shared host/SPMD implementation (RoundEngine)
* :mod:`repro.core.codecs` — the registry-backed wire-codec subsystem
  (identity / int8 / lowrank / topk-dims) owning payload encode/decode +
  ledger accounting, with optional device-resident error-feedback residual
  state (``repro.core.codec`` is a back-compat shim)
* :mod:`repro.core.distributed` — TPU-native sparse-sync collective
  (shard_map + lax collectives, static-K masked buffers)
"""
from repro.core.sparsify import (
    change_scores,
    select_top_k,
    upstream_sparsify,
    sparsity_k,
)
from repro.core.aggregate import (
    Upload,
    Download,
    personalized_aggregate,
    fede_aggregate,
)
from repro.core.codecs import (
    IdentityCodec,
    Int8RowCodec,
    LowRankCodec,
    TopKDimsCodec,
    WireCodec,
    codec_usage,
    get_codec,
    parse_codec_spec,
    registered_codecs,
)
from repro.core.engine import RoundEngine
from repro.core.sync import is_sync_round, comm_ratio_worst_case

__all__ = [
    "RoundEngine",
    "WireCodec",
    "IdentityCodec",
    "Int8RowCodec",
    "LowRankCodec",
    "TopKDimsCodec",
    "codec_usage",
    "get_codec",
    "parse_codec_spec",
    "registered_codecs",
    "change_scores",
    "select_top_k",
    "upstream_sparsify",
    "sparsity_k",
    "Upload",
    "Download",
    "personalized_aggregate",
    "fede_aggregate",
    "is_sync_round",
    "comm_ratio_worst_case",
]
