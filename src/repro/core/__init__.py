"""FedS core: Entity-Wise Top-K Sparsification for federated KGE.

This package is the paper's contribution:

* :mod:`repro.core.sparsify` — upstream entity-wise Top-K selection (Eq. 1-2)
* :mod:`repro.core.aggregate` — downstream personalized aggregation with
  priority weights (Eq. 3-4)
* :mod:`repro.core.sync` — Intermittent Synchronization Mechanism (§III-E)
* :mod:`repro.core.protocol` — FedE / FedEP / FedEPL / FedS round logic
* :mod:`repro.core.compression` — FedE-KD / FedE-SVD / FedE-SVD+ baselines
  (the paper's negative finding, Table I)
* :mod:`repro.core.engine` — the unified jitted round: batched client state,
  shared host/SPMD implementation (RoundEngine)
* :mod:`repro.core.codec` — pluggable wire codecs (identity / int8 rows)
  owning payload transform + ledger accounting
* :mod:`repro.core.distributed` — TPU-native sparse-sync collective
  (shard_map + lax collectives, static-K masked buffers)
"""
from repro.core.sparsify import (
    change_scores,
    select_top_k,
    upstream_sparsify,
    sparsity_k,
)
from repro.core.aggregate import (
    Upload,
    Download,
    personalized_aggregate,
    fede_aggregate,
)
from repro.core.codec import IdentityCodec, Int8RowCodec, WireCodec, get_codec
from repro.core.engine import RoundEngine
from repro.core.sync import is_sync_round, comm_ratio_worst_case

__all__ = [
    "RoundEngine",
    "WireCodec",
    "IdentityCodec",
    "Int8RowCodec",
    "get_codec",
    "change_scores",
    "select_top_k",
    "upstream_sparsify",
    "sparsity_k",
    "Upload",
    "Download",
    "personalized_aggregate",
    "fede_aggregate",
    "is_sync_round",
    "comm_ratio_worst_case",
]
