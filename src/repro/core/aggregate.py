"""Downstream Personalized Entity-Wise Top-K Sparsification (paper §III-D).

Server-side logic.  The federated *simulation* runs this host-side in numpy
(clients have heterogeneous entity sets and counts, which is naturally a
ragged problem); the SPMD/TPU deployment path uses
:mod:`repro.core.distributed`, which implements the same semantics with
static-K masked buffers + segment_sum and is property-tested against this
module.

Key semantics (Eq. 3-4):
* aggregation for client c over entity e sums the uploads of the OTHER
  clients that uploaded e this round (c's own upload excluded),
* priority weight P_{c,e} = |C_{c,e}| = number of other clients that uploaded
  e,
* per-client Top-K by priority, random tie-break, K = N_c * p,
* if fewer than K entities have any aggregate, send all available.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sparsify import sparsity_k


@dataclasses.dataclass(frozen=True)
class Upload:
    """One client's upstream message (global entity id space).

    Frozen: protocol messages are immutable once constructed — wire
    transforms (e.g. the int8 codec) must build a new message with
    ``dataclasses.replace`` instead of overwriting the payload another
    consumer may still reference.
    """

    client_id: int
    entity_ids: np.ndarray  # (k,) int — GLOBAL ids of uploaded entities
    values: np.ndarray  # (k, D) float32 embeddings


@dataclasses.dataclass(frozen=True)
class Download:
    """Server -> client message for one client (immutable, like Upload)."""

    client_id: int
    entity_ids: np.ndarray  # (k',) int GLOBAL ids (k' <= K)
    agg_values: np.ndarray  # (k', D) summed embeddings A (Eq. 3)
    priority: np.ndarray  # (k',) int counts |C_{c,e}|


def personalized_aggregate(
    uploads: list[Upload],
    client_entities: list[np.ndarray],  # per client: GLOBAL ids of its shared entities
    sparsity_p: float,
    rng: np.random.Generator,
) -> list[Download]:
    """Run the server's downstream pass for every client."""
    num_clients = len(uploads)
    dim = uploads[0].values.shape[1]

    # Index uploads once: entity -> list of (client, row).
    by_entity: dict[int, list[tuple[int, int]]] = {}
    for up in uploads:
        for row, e in enumerate(up.entity_ids.tolist()):
            by_entity.setdefault(e, []).append((up.client_id, row))

    downloads: list[Download] = []
    for c in range(num_clients):
        ents = client_entities[c]
        k = sparsity_k(len(ents), sparsity_p)
        cand_ids: list[int] = []
        cand_pri: list[int] = []
        for e in ents.tolist():
            contributors = [x for x in by_entity.get(e, ()) if x[0] != c]
            if contributors:
                cand_ids.append(e)
                cand_pri.append(len(contributors))
        if not cand_ids:
            downloads.append(
                Download(
                    client_id=c,
                    entity_ids=np.zeros(0, dtype=np.int64),
                    agg_values=np.zeros((0, dim), dtype=np.float32),
                    priority=np.zeros(0, dtype=np.int64),
                )
            )
            continue
        cand_ids_arr = np.asarray(cand_ids, dtype=np.int64)
        cand_pri_arr = np.asarray(cand_pri, dtype=np.int64)
        if len(cand_ids_arr) > k:
            # Top-K by priority, random tie-break (paper: "a random strategy").
            tie = rng.random(len(cand_ids_arr))
            order = np.lexsort((tie, -cand_pri_arr))
            sel = order[:k]
        else:
            sel = np.arange(len(cand_ids_arr))
        sel_ids = cand_ids_arr[sel]
        sel_pri = cand_pri_arr[sel]
        agg = np.zeros((len(sel_ids), dim), dtype=np.float32)
        for i, e in enumerate(sel_ids.tolist()):
            for cl, row in by_entity[e]:
                if cl != c:
                    agg[i] += np.asarray(uploads[cl].values[row], dtype=np.float32)
        downloads.append(
            Download(client_id=c, entity_ids=sel_ids, agg_values=agg, priority=sel_pri)
        )
    return downloads


def apply_download(
    local_emb: np.ndarray,  # (N_c, D) client's full local entity table (LOCAL ids)
    global_to_local: dict[int, int],
    down: Download,
) -> np.ndarray:
    """Eq. 4: E^{t+1}_e = (A_e + E^t_e) / (1 + P_e) on selected rows."""
    out = local_emb.copy()
    for i, e in enumerate(down.entity_ids.tolist()):
        li = global_to_local[e]
        out[li] = (down.agg_values[i] + local_emb[li]) / (1.0 + down.priority[i])
    return out


def fede_aggregate(
    uploads: list[Upload],
    num_global_entities: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Standard FedE full aggregation (used on synchronization rounds).

    Returns (global_table (E, D) mean over owning clients, count (E,)).
    Entities uploaded by no client keep zero rows (count 0).
    """
    dim = uploads[0].values.shape[1]
    total = np.zeros((num_global_entities, dim), dtype=np.float32)
    count = np.zeros(num_global_entities, dtype=np.int64)
    for up in uploads:
        np.add.at(total, up.entity_ids, up.values.astype(np.float32))
        np.add.at(count, up.entity_ids, 1)
    mean = total / np.maximum(count, 1)[:, None]
    return mean, count
