"""Round-level protocol primitives for FedE / FedEP / FedS.

These functions are the glue between the jit-level primitives
(:mod:`repro.core.sparsify`, :mod:`repro.kernels.ops`) and the federated
simulation loop (:mod:`repro.federated.simulation`).  Everything here operates
on one communication round.

Protocol variants (paper §IV-B):

* ``single`` — no communication at all (local KGE only).
* ``fedep``  — personalized FedE: full exchange every round, evaluation on
  the personalized (local) embeddings.  ``FedEPL`` is ``fedep`` at a reduced
  embedding dimension (Eq. 5-matched), selected purely via config.
* ``feds``   — the paper: upstream/downstream entity-wise Top-K rounds with
  intermittent full synchronization every ``s`` rounds.
* ``feds_nosync`` — ablation (Fig. 2): FedS without the synchronization
  mechanism.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import Upload
from repro.core.sparsify import sparsity_k, upstream_sparsify
from repro.kernels import ops as kernel_ops


@dataclasses.dataclass
class ClientCommView:
    """Static communication-relevant view of one client.

    ``shared_local``: local ids of entities shared with >=1 other client.
    ``shared_global``: their global ids (same order).
    """

    client_id: int
    shared_local: np.ndarray  # (Ns,) int32
    shared_global: np.ndarray  # (Ns,) int32
    global_to_row: dict  # global id -> row index in the shared arrays

    @property
    def num_shared(self) -> int:
        return int(self.shared_local.shape[0])


def build_comm_views(clients_local_to_global: list[np.ndarray], num_global: int):
    """Compute each client's shared-entity view (paper: exclusive entities
    are never communicated)."""
    count = np.zeros(num_global, dtype=np.int64)
    for l2g in clients_local_to_global:
        count[l2g] += 1
    shared = count >= 2
    views = []
    for cid, l2g in enumerate(clients_local_to_global):
        mask = shared[l2g]
        local_ids = np.nonzero(mask)[0].astype(np.int32)
        global_ids = l2g[local_ids].astype(np.int32)
        views.append(
            ClientCommView(
                client_id=cid,
                shared_local=local_ids,
                shared_global=global_ids,
                global_to_row={int(g): i for i, g in enumerate(global_ids)},
            )
        )
    return views


# ------------------------------------------------------------------ upstream
def sparse_upload(
    entity_table: jnp.ndarray,  # client's full (N_c, D) table
    history: jnp.ndarray,  # (Ns, D) history of SHARED rows
    view: ClientCommView,
    p: float,
) -> tuple[Upload, jnp.ndarray]:
    """Upstream Entity-Wise Top-K (paper §III-C).

    Returns (Upload in global id space, refreshed history).
    """
    cur = entity_table[jnp.asarray(view.shared_local)]
    k = sparsity_k(view.num_shared, p)
    idx, values, _sign, new_history = upstream_sparsify(cur, history, k)
    idx_np = np.asarray(idx)
    return (
        Upload(
            client_id=view.client_id,
            entity_ids=view.shared_global[idx_np].astype(np.int64),
            values=np.asarray(values, dtype=np.float32),
        ),
        new_history,
    )


def sparse_upload_coded(
    entity_table: jnp.ndarray,
    history: jnp.ndarray,
    view: ClientCommView,
    p: float,
    codec,  # repro.core.codecs.WireCodec
    residual: np.ndarray | None = None,  # (Ns, D) error-feedback bank
) -> tuple[Upload, jnp.ndarray, np.ndarray | None]:
    """:func:`sparse_upload` with the wire codec applied host-side.

    The ragged numpy twin of the upstream leg of
    :func:`repro.core.engine.batched_sparse_round`: selected rows cross the
    wire through ``codec.roundtrip``, and with an error-feedback codec
    (``codec.has_residual``) each uploaded row is corrected by its banked
    residual before encoding and the fresh encode error is banked after —
    ``corrected = row + res``, ``res' = corrected - roundtrip(corrected)``
    on uploaded rows, untouched elsewhere.  Returns
    ``(Upload, new_history, new_residual)``; the paper-faithful oracle for
    ``ef=1`` device runs.
    """
    up, new_history = sparse_upload(entity_table, history, view, p)
    if not codec.transforms_values:
        return up, new_history, residual
    idx = np.asarray(
        [view.global_to_row[int(g)] for g in up.entity_ids], dtype=np.int32
    )
    values = np.asarray(up.values, np.float32)
    if codec.has_residual:
        if residual is None:
            raise ValueError(
                f"codec {codec!r} carries error-feedback residual state; "
                "pass the (Ns, D) residual bank"
            )
        corrected = values + residual[idx]
        wire = np.asarray(codec.roundtrip(jnp.asarray(corrected)), np.float32)
        residual = residual.copy()
        residual[idx] = corrected - wire
    else:
        wire = np.asarray(codec.roundtrip(jnp.asarray(values)), np.float32)
    return dataclasses.replace(up, values=wire), new_history, residual


def full_upload(
    entity_table: jnp.ndarray, view: ClientCommView
) -> tuple[Upload, jnp.ndarray]:
    """Synchronization-round upload: every shared entity, history refreshed."""
    cur = entity_table[jnp.asarray(view.shared_local)]
    return (
        Upload(
            client_id=view.client_id,
            entity_ids=view.shared_global.astype(np.int64),
            values=np.asarray(cur, dtype=np.float32),
        ),
        cur,
    )


# ---------------------------------------------------------------- downstream
def apply_sparse_download(
    entity_table: jnp.ndarray,
    view: ClientCommView,
    down_entity_ids: np.ndarray,  # (k',) global ids
    down_values: np.ndarray,  # (k', D) aggregated sums A
    down_priority: np.ndarray,  # (k',) counts P
) -> jnp.ndarray:
    """Eq. 4 on the selected rows, through the fused masked-row kernel."""
    ns = view.num_shared
    dim = entity_table.shape[1]
    rows = np.asarray([view.global_to_row[int(g)] for g in down_entity_ids], dtype=np.int32)
    agg = jnp.zeros((ns, dim), dtype=jnp.float32)
    pri = jnp.zeros((ns,), dtype=jnp.float32)
    sign = jnp.zeros((ns,), dtype=jnp.int8)
    if rows.size:
        agg = agg.at[rows].set(jnp.asarray(down_values, dtype=jnp.float32))
        pri = pri.at[rows].set(jnp.asarray(down_priority, dtype=jnp.float32))
        sign = sign.at[rows].set(1)
    shared_rows = entity_table[jnp.asarray(view.shared_local)]
    updated = kernel_ops.sparse_apply(shared_rows, agg, pri, sign)
    return entity_table.at[jnp.asarray(view.shared_local)].set(
        updated.astype(entity_table.dtype)
    )


def apply_full_download(
    entity_table: jnp.ndarray,
    view: ClientCommView,
    global_mean: np.ndarray,  # (E, D) FedE-aggregated global table
    count: np.ndarray | None = None,  # (E,) contributor counts
) -> jnp.ndarray:
    """FedE / sync-round download: replace shared rows with the global mean.

    With ``count`` (the :func:`repro.core.aggregate.fede_aggregate` second
    return), rows whose entity received zero contributions this round keep
    their local values instead of taking the clamped-denominator zero row —
    the reference twin of the zero-participant guard in
    :func:`repro.core.engine.batched_sync_round`.  Without faults every
    shared entity has at least its own upload, so omitting ``count``
    (the historical call shape) is equivalent.
    """
    rows = jnp.asarray(global_mean[view.shared_global], dtype=entity_table.dtype)
    loc = jnp.asarray(view.shared_local)
    if count is not None:
        keep = jnp.asarray(count[view.shared_global] > 0)
        rows = jnp.where(keep[:, None], rows, entity_table[loc])
    return entity_table.at[loc].set(rows)
