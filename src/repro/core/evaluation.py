"""Device-resident batched filtered-ranking evaluation (the eval subsystem).

Evaluation used to be the last host-bound subsystem: every eval boundary
pulled all padded entity tables back into per-client host objects
(``CycleEngine.sync_clients``) and ranked with per-client dense ``(B, E)``
bool numpy filter masks in 256-row jitted chunks.  The paper's
convergence-speed claims (MRR/Hits@K *versus bytes transmitted*) are
measured at exactly these boundaries, so eval cost polluted every
communication-efficiency benchmark.  This module makes evaluation a
device-resident batched program over the same padded ``(C, ...)`` state
layout the engines already share:

* :class:`EvalBank` — one split's device-resident evaluation state, built
  ONCE at simulation construction: padded ``(C, B_max, 3)`` eval triple
  banks, filtered-setting masks bit-packed to ``(C, B_max, W)`` uint32
  words with ``W = ceil(E_max/32)`` (a ~32x memory cut over the per-client
  ``(B, E)`` bool masks), and per-client true row counts.
* :class:`BatchedEvaluator` — a single jitted (host) / ``shard_map`` (pod)
  program that scores every client's full candidate set at once, E-dim
  chunked via ``lax.scan`` over the scoring ops of
  :mod:`repro.kernels.ops` so the ``(C, B_max, E_max)`` score tensor is
  never materialized, applies the packed filters with bitwise ops, and
  reduces filtered ranks to a per-client ``(mrr, hits@1, hits@3, hits@10,
  count)`` block on device — the host reads back only
  ``(C, EVAL_BLOCK_COLS)`` scalars per boundary.  Under an entity-sharded
  2-D mesh (:func:`repro.launch.mesh.make_federation_mesh` with
  ``entity_devices > 1``) each shard scans only its own candidate block and
  the integer beat counts ``psum`` exactly, so ranks stay bitwise equal to
  the unsharded scan.

Exactness contract: on the default (ref) scoring dispatch the integer
filtered ranks (both head and tail legs) are **exactly equal** to the
numpy-oracle ranks of ``repro.federated.client.KGEClient.ranks`` —
candidate scores are computed with the same :mod:`repro.kge.scoring`
functions on the same rows, the gold candidate is excluded explicitly (so
a last-ulp difference in the separately computed gold score can never flip
its own comparison), and padding candidates/rows are masked.
``tests/test_evaluation.py`` property-tests rank equality over randomized
heterogeneous federations.  On TPU/interpret, candidate scores route
through the family-tagged eval kernels
(:attr:`repro.kge.scoring.ScoringSpec.family`): the distance family
(TransE/RotatE/pRotatE) through the tiled ``dist_cand_score_pallas``
VPU kernel and the bilinear family (ComplEx/DistMult) through the
matmul-style ``bilinear_cand_score_pallas`` MXU kernel.  Both are
tolerance-tested (~1e-4) rather than bitwise against the scoring
functions — a near-tie candidate within that tolerance of the gold
score may shift its integer rank by one there.

The bit-packed filter builders (:func:`build_known_index`,
:func:`pack_filter_rows`, :func:`unpack_filter_words`) are shared with the
host oracle, so ``KGEClient`` no longer holds dense bool masks either.

:class:`repro.core.state.SuperstepEngine` composes
:attr:`BatchedEvaluator.eval_core` into its scanned plans as ``"eval"``
segments (:data:`repro.core.sync.PLAN_KINDS`), so a whole ISM span
*including its eval round* compiles into one program with zero
intermediate ``sync_clients`` host round-trips.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eshard, telemetry
from repro.data.partition import ClientData
from repro.kernels import ops as kernel_ops
from repro.kge import scoring as kge_scoring

#: Bits per packed filter word.
WORD_BITS = 32

#: Hits@K cutoffs in the metric block, lowest first.  The paper's protocol
#: reports Hits@10; @1/@3 ride along in the same on-device reduction.
HITS_LEVELS = (1, 3, 10)

#: Hits@K cutoff used by the paper's headline protocol.
HITS_AT = 10

#: Columns of the per-client metric block: [mrr, hits@1, hits@3, hits@10,
#: count] — see :func:`repro.federated.metrics.aggregate_eval_block`.
EVAL_BLOCK_COLS = 2 + len(HITS_LEVELS)


# ------------------------------------------------------------- filter packing
def build_known_index(*triple_arrays: np.ndarray) -> dict:
    """Filtered-setting lookup over all known triples.

    Maps ``("t", h, r) -> {tails}`` and ``("h", r, t) -> {heads}`` — the
    standard KGE filtered protocol index, shared by the host oracle
    (``KGEClient``) and the packed-bank builders here.
    """
    known: dict = {}
    for arr in triple_arrays:
        for h, r, t in np.asarray(arr).tolist():
            known.setdefault(("t", h, r), set()).add(t)
            known.setdefault(("h", r, t), set()).add(h)
    return known


def num_filter_words(num_entities: int) -> int:
    """``W = ceil(E / 32)`` packed words per eval row (at least 1)."""
    return max(1, (int(num_entities) + WORD_BITS - 1) // WORD_BITS)


def pack_filter_rows(
    triples: np.ndarray,  # (B, 3) local-id eval triples
    known: dict,  # build_known_index output
    num_words: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Bit-packed filtered-setting masks for a block of eval triples.

    Returns ``(ft_words, fh_words)``, each ``(B, num_words)`` uint32; bit
    ``e`` of row ``i`` is set iff entity ``e`` is a known tail (resp. head)
    for triple ``i`` *other than the gold answer itself* — exactly the mask
    the oracle used to hold as a dense ``(B, E)`` bool array.
    """
    b = int(triples.shape[0])
    ft = np.zeros((b, num_words), np.uint32)
    fh = np.zeros((b, num_words), np.uint32)
    for i, (h, r, t) in enumerate(np.asarray(triples).tolist()):
        for e in known.get(("t", h, r), ()):
            if e != t:
                ft[i, e >> 5] |= np.uint32(1 << (e & 31))
        for e in known.get(("h", r, t), ()):
            if e != h:
                fh[i, e >> 5] |= np.uint32(1 << (e & 31))
    return ft, fh


def unpack_filter_words(words: jnp.ndarray, num_entities: int) -> jnp.ndarray:
    """(B, W) packed words -> (B, num_entities) bool mask (jit-safe).

    The host oracle's ``_rank_batch`` unpacks on device, so packed words are
    the only resident representation anywhere.
    """
    e = jnp.arange(num_entities, dtype=jnp.int32)
    bits = words[:, e >> 5] >> (e & 31).astype(jnp.uint32)
    return (bits & 1).astype(bool)


# ------------------------------------------------------------------ the bank
class EvalBank(NamedTuple):
    """One split's device-resident eval state; every leaf leads with the
    client axis, so one ``PartitionSpec('clients')`` shards the bundle."""

    triples: jnp.ndarray  # (C, B_max, 3) int32, zero-padded rows
    count: jnp.ndarray  # (C,) int32 true eval-triple counts
    ft_words: jnp.ndarray  # (C, B_max, W) uint32 packed tail filters
    fh_words: jnp.ndarray  # (C, B_max, W) uint32 packed head filters
    num_ent: jnp.ndarray  # (C,) int32 local entity counts (candidate bound)


def build_eval_bank(
    datas: Sequence[ClientData],
    split: str,
    max_triples: int,
    e_max: int,
    known: Optional[Sequence[dict]] = None,
    num_words: Optional[int] = None,
) -> EvalBank:
    """Pad one split's eval triples + packed filters across the federation.

    ``known`` may pass pre-built per-client :func:`build_known_index` dicts
    (e.g. shared with ``KGEClient``); otherwise they are built here from
    each client's train/valid/test.  ``num_words`` may widen the word axis
    beyond ``ceil(e_max/32)`` (the evaluator sizes it to the padded
    candidate range so chunk word-slices never run off the end).
    """
    c_n = len(datas)
    w = num_words if num_words is not None else num_filter_words(e_max)
    caps = [min(int(getattr(d, split).shape[0]), int(max_triples)) for d in datas]
    b_max = max(1, max(caps, default=0))
    triples = np.zeros((c_n, b_max, 3), np.int32)
    ft = np.zeros((c_n, b_max, w), np.uint32)
    fh = np.zeros((c_n, b_max, w), np.uint32)
    for c, d in enumerate(datas):
        n = caps[c]
        if n == 0:
            continue
        tri = np.asarray(getattr(d, split))[:n]
        triples[c, :n] = tri
        kn = known[c] if known is not None else build_known_index(
            d.train, d.valid, d.test
        )
        ft[c, :n], fh[c, :n] = pack_filter_rows(tri, kn, w)
    return EvalBank(
        triples=jnp.asarray(triples),
        count=jnp.asarray(np.asarray(caps, np.int32)),
        ft_words=jnp.asarray(ft),
        fh_words=jnp.asarray(fh),
        num_ent=jnp.asarray(
            np.asarray([d.num_entities for d in datas], np.int32)
        ),
    )


# ----------------------------------------------------------------- evaluator
class BatchedEvaluator:
    """Compiled filtered-ranking evaluation over padded federation params.

    Built once per federation; owns one :class:`EvalBank` per split and the
    compiled metric programs.  ``mesh=None`` compiles a single-device jit;
    with a 1-D client mesh the same core runs under ``shard_map`` (the
    reduction is fully per-client, so no collective is needed).

    ``eval_core(params, bank) -> (C, EVAL_BLOCK_COLS)`` is the pure program
    body — the
    :class:`repro.core.state.SuperstepEngine` inlines it as the ``"eval"``
    plan segment of a scanned superstep, which is what makes "one host
    dispatch per superstep" true through eval boundaries.
    """

    def __init__(
        self,
        datas: Sequence[ClientData],
        *,
        method: str,
        gamma: float,
        e_max: int,
        max_triples: int = 2000,
        splits: Sequence[str] = ("valid", "test"),
        chunk: int = 512,
        known: Optional[Sequence[dict]] = None,
        mesh=None,
        axis_name: str = "clients",
        entity_axis: Optional[str] = None,
    ):
        # fail fast with the registry's self-describing error rather than at
        # first compiled eval dispatch
        kge_scoring.get_scoring(method)
        self.method = method
        self.gamma = float(gamma)
        self.e_max = int(e_max)
        if max(int(d.num_entities) for d in datas) > self.e_max:
            raise ValueError(
                "e_max smaller than the largest client entity count; the "
                "bank's packed filter words would truncate"
            )
        # candidate chunk: scores live as (C, B_max, chunk) tiles inside the
        # scan, never (C, B_max, E_max).  Rounded to whole 32-bit filter
        # words so each scan step slices the chunk's packed words once and
        # expands bits in-register, instead of gathering one word per
        # candidate (32x the bandwidth of the packed representation).
        chunk = max(1, min(int(chunk), self.e_max))
        self.chunk = -(-chunk // WORD_BITS) * WORD_BITS
        self._eaxis = entity_axis if mesh is not None else None
        if self._eaxis is not None and self._eaxis not in dict(mesh.shape):
            raise ValueError(
                f"entity_axis {self._eaxis!r} not in mesh axes {tuple(mesh.shape)}"
            )
        n_e = int(dict(mesh.shape)[self._eaxis]) if self._eaxis else 1
        self.n_eshards = n_e
        if self._eaxis is not None:
            # entity-sharded: the candidate axis must mirror the engine's
            # padded state layout (pad_rows(e_max, n_e, 32)) so the entity
            # table blocks AND the packed filter word axis split evenly;
            # each shard then scans its own block span, chunk-padded
            # locally, and the integer beat counts psum exactly.
            self.e_pad = eshard.pad_rows(self.e_max, n_e, WORD_BITS)
        else:
            self.e_pad = -(-self.e_max // self.chunk) * self.chunk
        self.banks: Dict[str, EvalBank] = {
            s: build_eval_bank(datas, s, max_triples, self.e_max, known=known,
                               num_words=self.e_pad // WORD_BITS)
            for s in splits
        }
        self.eval_core = self._make_eval_core()
        self._rank_core = self._make_rank_core()
        if mesh is None:
            self._eval = jax.jit(self.eval_core)
            self._ranks = jax.jit(self._rank_core)
        else:
            from repro.core.engine import shard_map  # jax-version shim

            p = jax.sharding.PartitionSpec(axis_name)
            pp = self._params_spec(axis_name)
            pb = self._bank_spec(axis_name)
            self._eval = jax.jit(shard_map(
                self.eval_core, mesh=mesh, in_specs=(pp, pb), out_specs=p,
            ))
            self._ranks = jax.jit(shard_map(
                self._rank_core, mesh=mesh, in_specs=(pp, pb), out_specs=(p, p),
            ))

    # --------------------------------------------------------------- specs
    def _params_spec(self, axis_name: str):
        """PartitionSpec pytree for the padded params dict under the mesh."""
        p = jax.sharding.PartitionSpec(axis_name)
        if self._eaxis is None:
            return p
        return {
            "entity": jax.sharding.PartitionSpec(axis_name, self._eaxis),
            "relation": p,
        }

    def _bank_spec(self, axis_name: str):
        """:class:`EvalBank` spec — filter words shard on the word axis."""
        p = jax.sharding.PartitionSpec(axis_name)
        if self._eaxis is None:
            return p
        pw = jax.sharding.PartitionSpec(axis_name, None, self._eaxis)
        return EvalBank(triples=p, count=p, ft_words=pw, fh_words=pw, num_ent=p)

    # ------------------------------------------------------- program bodies
    def _make_rank_core(self):
        method, gamma = self.method, self.gamma
        chunk, e_pad = self.chunk, self.e_pad
        eaxis = self._eaxis

        def rank_core(params, bank: EvalBank):
            """Filtered ranks ``(rank_t, rank_h)``, each (C, B_max) int32.

            Entity-sharded (``eaxis`` set): ``params['entity']`` and the
            bank's packed filter words arrive as per-shard blocks; each
            shard scans its own chunk-padded candidate span with global
            candidate ids ``base + local``, masks candidates past its block
            edge, and the integer beat counts ``psum`` exactly — rank
            output is bitwise identical to the unsharded scan because only
            whole-boolean counts cross the shard boundary.
            """
            ent = params["entity"]  # (C, E_blk, D) block (full when unsharded)
            c_n, e_blk, _d = ent.shape
            if eaxis is None:
                span, base = e_pad, 0
            else:
                span = -(-e_blk // chunk) * chunk
                base = eshard.shard_offset(eaxis, e_blk)
            ent_p = jnp.pad(ent, ((0, 0), (0, span - e_blk), (0, 0)))
            ftw, fhw = bank.ft_words, bank.fh_words
            if span > e_blk and eaxis is not None:
                pw = ((0, 0), (0, 0), (0, (span - e_blk) // WORD_BITS))
                ftw, fhw = jnp.pad(ftw, pw), jnp.pad(fhw, pw)
            tri = bank.triples
            h, r, t = tri[..., 0], tri[..., 1], tri[..., 2]
            h_e = eshard.dist_take_rows(ent, h, eaxis)  # (C, B, D)
            t_e = eshard.dist_take_rows(ent, t, eaxis)
            r_e = jnp.take_along_axis(params["relation"], r[:, :, None], axis=1)
            # the gold triple's score — shared by both legs; the gold
            # CANDIDATE is excluded from the counts below, so rank equality
            # with the oracle never hinges on this value's last ulp
            gold = kernel_ops.kge_score_rows(h_e, r_e, t_e, method, gamma)
            zero = jnp.zeros(h.shape, jnp.int32)
            c_b = h.shape[:2]
            n_words = chunk // WORD_BITS  # chunk is a whole-word multiple
            bit = jnp.arange(WORD_BITS, dtype=jnp.uint32)

            def unpack_chunk(words, w0):
                """Slice the chunk's packed words ONCE and expand bits
                in-register: (C, B, W) -> (C, B, chunk) 0/1."""
                wc = jax.lax.dynamic_slice_in_dim(words, w0, n_words, axis=2)
                return ((wc[..., None] >> bit) & 1).reshape(c_b + (chunk,))

            def step(carry, e0):
                cnt_t, cnt_h = carry
                cand_loc = e0 + jnp.arange(chunk, dtype=jnp.int32)  # (Ec,)
                cand = base + cand_loc  # global candidate ids
                ce = jax.lax.dynamic_slice_in_dim(ent_p, e0, chunk, axis=1)
                # both legs' candidate scores, (C, B, Ec) tiles
                ts, hs = kernel_ops.kge_cand_scores(
                    h_e, r_e, t_e, ce, method, gamma
                )
                w0 = e0 // WORD_BITS
                fb_t = unpack_chunk(ftw, w0)
                fb_h = unpack_chunk(fhw, w0)
                ok = cand[None, :] < bank.num_ent[:, None]  # (C, Ec)
                if eaxis is not None:
                    # span-padding candidates would alias the NEXT shard's
                    # global ids — mask past this shard's block edge
                    ok = ok & (cand_loc[None, :] < e_blk)
                beat_t = (
                    (ts > gold[:, :, None])
                    & (fb_t == 0)
                    & ok[:, None, :]
                    & (cand[None, None, :] != t[:, :, None])
                )
                beat_h = (
                    (hs > gold[:, :, None])
                    & (fb_h == 0)
                    & ok[:, None, :]
                    & (cand[None, None, :] != h[:, :, None])
                )
                return (
                    cnt_t + beat_t.sum(-1).astype(jnp.int32),
                    cnt_h + beat_h.sum(-1).astype(jnp.int32),
                ), None

            (cnt_t, cnt_h), _ = jax.lax.scan(
                step, (zero, zero),
                jnp.arange(0, span, chunk, dtype=jnp.int32),
            )
            if eaxis is not None:
                cnt_t = jax.lax.psum(cnt_t, eaxis)
                cnt_h = jax.lax.psum(cnt_h, eaxis)
            return cnt_t + 1, cnt_h + 1

        return rank_core

    def _make_eval_core(self):
        rank_core = self._make_rank_core()

        def eval_core(params, bank: EvalBank):
            """(C, 5) per-client ``[mrr, hits@1, hits@3, hits@10, count]``
            scalar block (column order fixed by :data:`HITS_LEVELS`)."""
            rank_t, rank_h = rank_core(params, bank)
            b_max = rank_t.shape[1]
            valid = jnp.arange(b_max)[None, :] < bank.count[:, None]
            rt = rank_t.astype(jnp.float32)
            rh = rank_h.astype(jnp.float32)
            recip = jnp.where(valid, 1.0 / rt + 1.0 / rh, 0.0).sum(axis=1)
            hits = [
                jnp.where(
                    valid,
                    (rank_t <= lvl).astype(jnp.float32)
                    + (rank_h <= lvl).astype(jnp.float32),
                    0.0,
                ).sum(axis=1)
                for lvl in HITS_LEVELS
            ]
            denom = jnp.maximum(2.0 * bank.count.astype(jnp.float32), 1.0)
            return jnp.stack(
                [recip / denom]
                + [h / denom for h in hits]
                + [bank.count.astype(jnp.float32)],
                axis=1,
            )

        return eval_core

    # --------------------------------------------------------------- driving
    def evaluate(self, params: dict, split: str) -> np.ndarray:
        """Run the compiled program; returns the (C, EVAL_BLOCK_COLS) block
        as numpy — the ONLY host transfer an eval boundary performs."""
        with telemetry.span("eval", split=split):
            return np.asarray(self._eval(params, self.banks[split]))

    def ranks(self, params: dict, split: str) -> tuple[np.ndarray, np.ndarray]:
        """Integer filtered ranks (tail leg, head leg), each (C, B_max) —
        padded rows carry garbage; mask with ``bank.count``.  Test/debug
        path: production reads only the block of :meth:`evaluate`."""
        rt, rh = self._ranks(params, self.banks[split])
        return np.asarray(rt), np.asarray(rh)
