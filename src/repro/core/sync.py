"""Intermittent Synchronization Mechanism (paper §III-E) + Eq. 5 analysis.

Both clients and the server check whether the distance from the last
synchronization round has reached the predefined interval ``s``; if so, the
round is a full-exchange (standard FedE) round, otherwise a sparsified round.
With the convention used in the paper's Eq. 5 a *cycle* is ``s`` sparsified
rounds followed by 1 synchronization round (s+1 rounds total).

This module is the single source of truth for the ISM **round schedule**:
:func:`is_sync_round` decides sync-vs-sparse for the FedS protocol,
:func:`round_kind` maps any (round, protocol) pair to one of the three round
kinds, and :func:`compress_schedule` run-length-encodes a span of rounds into
the static plan segments the :class:`repro.core.state.SuperstepEngine`
compiles into a single scanned program.
"""
from __future__ import annotations

from typing import Iterable, Tuple

#: The three kinds of federated round, as scheduled by the ISM:
#: ``"sparse"`` — entity-wise Top-K upload + personalized download (Eq. 1-4);
#: ``"sync"``   — full FedE-style mean synchronization of shared entities;
#: ``"none"``   — local training only (the no-communication baseline).
ROUND_KINDS = ("sparse", "sync", "none")

#: Superstep *plan* segments are round kinds plus two zero-round markers:
#: ``"eval"`` — a device-resident filtered-ranking evaluation
#: (:class:`repro.core.evaluation.BatchedEvaluator`) folded into the same
#: scanned program — and ``"prefetch"`` — a host-tier staging point where
#: the :class:`repro.core.store.HostTieredStore` driver refreshes the
#: device hot-row cache from the host-resident table before the following
#: rounds run.  Neither is ever emitted by :func:`round_kind` (they consume
#: no round of the schedule): :meth:`repro.core.state.SuperstepEngine.
#: superstep_with_eval` appends ``"eval"`` so an ISM span and its boundary
#: eval compile together, and the tiered driver inserts ``"prefetch"``
#: via :func:`insert_prefetch`.  Compiled engine programs skip
#: ``"prefetch"`` segments (a no-op on device), so plans with and without
#: them produce bitwise-identical state.
PLAN_KINDS = ROUND_KINDS + ("eval", "prefetch")


def is_sync_round(round_idx: int, interval: int) -> bool:
    """True if ``round_idx`` is a full-synchronization round.

    Round 0 is the first sparsified round; rounds s, 2(s+1)-? ... — we use the
    cycle convention: rounds ``s, 2s+1, 3s+2, ...`` i.e.
    ``(round_idx + 1) % (interval + 1) == 0``: every cycle has exactly
    ``interval`` sparse rounds then one sync round, matching Eq. 5's
    accounting of ``s`` sparse + 1 full exchange per cycle.
    """
    if interval <= 0:
        return True  # degenerate: sync every round == plain FedE
    return (round_idx + 1) % (interval + 1) == 0


def round_kind(round_idx: int, protocol: str, interval: int) -> str:
    """The ISM round schedule: what kind of round ``round_idx`` is.

    * ``feds``        — ``interval`` sparse rounds then one sync round per
      cycle (:func:`is_sync_round`), the paper's full protocol;
    * ``feds_nosync`` — sparse every round (Fig. 2 ablation);
    * ``fedep``       — sync every round (full-exchange FedE/FedEP baseline);
    * ``single``      — ``"none"``: local training, no communication.
    """
    if protocol == "single":
        return "none"
    if protocol == "fedep":
        return "sync"
    if protocol == "feds_nosync":
        return "sparse"
    if protocol == "feds":
        return "sync" if is_sync_round(round_idx, interval) else "sparse"
    raise ValueError(f"unknown protocol {protocol!r}")


def compress_schedule(kinds: Iterable[str]) -> Tuple[Tuple[str, int], ...]:
    """Run-length-encode a per-round kind sequence into plan segments.

    ``("sparse","sparse","sync") -> (("sparse", 2), ("sync", 1))`` — the
    static superstep plan :class:`repro.core.state.SuperstepEngine` compiles
    (one ``lax.scan`` per segment, all segments in one program).  Hashable,
    so compiled programs are cached per distinct plan.  Accepts the full
    :data:`PLAN_KINDS` vocabulary — ``"eval"`` segments mark in-program
    evaluation points, not rounds.
    """
    plan: list[tuple[str, int]] = []
    for k in kinds:
        if k not in PLAN_KINDS:
            raise ValueError(f"unknown round kind {k!r}; expected {PLAN_KINDS}")
        if plan and plan[-1][0] == k:
            plan[-1] = (k, plan[-1][1] + 1)
        else:
            plan.append((k, 1))
    return tuple(plan)


def insert_prefetch(
    plan: Tuple[Tuple[str, int], ...], every: int
) -> Tuple[Tuple[str, int], ...]:
    """Insert ``("prefetch", 1)`` staging markers into a compressed plan.

    Splits round-consuming segments so a marker lands before every
    ``every``-th round of the span (and one before round 0) — the points
    where a host-tiered driver re-stages its device cache.  Zero-round
    segments (``"eval"``, existing ``"prefetch"``) pass through untouched
    and do not advance the round counter.  ``every <= 0`` returns the plan
    unchanged.  Engines treat ``"prefetch"`` as a no-op, so the expanded
    plan is schedule-equivalent to the input.
    """
    if every <= 0:
        return plan
    out: list[tuple[str, int]] = []
    t = 0  # rounds consumed so far
    for kind, n in plan:
        if kind not in ROUND_KINDS:
            out.append((kind, n))
            continue
        while n > 0:
            if t % every == 0:
                out.append(("prefetch", 1))
            take = min(n, every - (t % every))
            out.append((kind, take))
            t += take
            n -= take
    # re-merge adjacent same-kind segments the splitting may have created
    return tuple(_merge(out))


def _merge(segs):
    merged: list[tuple[str, int]] = []
    for kind, n in segs:
        if merged and merged[-1][0] == kind and kind != "prefetch":
            merged[-1] = (kind, merged[-1][1] + n)
        else:
            merged.append((kind, n))
    return merged


def comm_ratio_worst_case(p: float, s: int, dim: int) -> float:
    """Eq. 5: ratio of parameters transmitted by FedS vs full-exchange FKGE.

    R = (p*s + 1 + (2+p)*s / (2D)) / (s + 1)

    Worst case (every client always finds K downstream candidates; sign
    vectors accounted at full dtype width, as the paper does).
    """
    return (p * s + 1.0 + (2.0 + p) * s / (2.0 * dim)) / (s + 1.0)


def cycle_params_feds(n_entities: int, dim: int, p: float, s: int) -> float:
    """Absolute per-cycle parameter count transmitted by FedS for one client.

    2*(N*D*p*s + N*D) swapped embeddings + 2*N*s sign vectors + N*p*s priority
    entries (numerator of Eq. 5).
    """
    k = n_entities * p
    return 2 * (k * dim * s + n_entities * dim) + 2 * n_entities * s + k * s


def cycle_params_full(n_entities: int, dim: int, s: int) -> float:
    """Per-cycle parameter count for a full-exchange method (denominator)."""
    return 2 * n_entities * dim * (s + 1)
