"""Declarative, seeded fault injection for the federated engines.

The paper (like FedR and PFedEG) simulates a perfectly reliable federation:
every client participates in every round and every message arrives.  This
module makes client unreliability a first-class *input* to the engines — a
:class:`FaultSchedule` describes, declaratively:

* **partial participation** — each client joins round ``t`` with Bernoulli
  probability ``participation``;
* **message drops** — an upload (resp. download) sent by a participating
  client is lost in flight with probability ``drop_upload``
  (``drop_download``);
* **stragglers** — a static set of clients whose uploads always arrive
  ``lag`` sparse rounds late (buffered on device, folded into Eq. 3 on
  arrival).

and :func:`draw_round_faults` turns it into the per-round ``(C,)`` masks the
round functions consume.  The draw is a *pure function of the absolute round
index*: ``fold_in(PRNGKey(seed), t)`` keyed per leg — so the host ledger
replay, the numpy reference oracle, and the device programs (where ``t`` is
a traced scan carry) all see bit-identical masks without any cross-path
state.  ``threefry`` is deterministic across host/device, which is what
keeps ``reference == batched == fused == superstep`` an equivalence
contract *under any schedule*.

Mask semantics (shared by every engine path):

* ``part[c]``       — client ``c`` participates: it trains' upload is
  *computed* (history / EF residuals refresh, upload bytes are logged) and
  it is served a download (download bytes are logged).
* ``part * up_ok``  — the upload is *delivered*: it enters the Eq. 3
  aggregate.  A dropped upload still refreshed the sender's history and
  residual bank (the client cannot know the message was lost), which
  realistically poisons error feedback.
* ``part * dn_ok``  — the download is *received*: Eq. 4 applies.  The
  server still selected and sent the rows (bytes are logged on ``part``).

Eq. 3's existence weights become ``existence x participation``; a round in
which nobody participates degrades to a no-op with a ledger entry — the
zero-contributor guard in :func:`repro.core.engine.batched_sync_round`
keeps all-absent entity rows out of the mean instead of dividing by the
clamped zero count.

The *trivial* schedule (all-present, no drops, no stragglers) is detected
statically: engines given a trivial schedule compile exactly the pre-fault
programs, so the all-present case is bitwise identical to an unfaulted run
by construction.  ``force=True`` is a testing hook that keeps the fault
machinery in the compiled program even when the schedule is trivial (all
drawn masks are then deterministically all-ones — ``bernoulli(key, 1.0)``
is always True), which is how the chaos property harness asserts that the
mask plumbing itself is bitwise neutral.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class RoundFaults(NamedTuple):
    """Per-round ``(C,)`` float32 0/1 masks, one draw per leg."""

    part: jnp.ndarray  # 1.0 -> client participates this round
    up_ok: jnp.ndarray  # 1.0 -> its upload survives the wire
    dn_ok: jnp.ndarray  # 1.0 -> its download survives the wire


class FaultArrays(NamedTuple):
    """Device-resident fault state; every leaf leads with the client axis.

    Carried inside :class:`repro.core.state.StateArrays` so it rides the
    same scan/donation/checkpoint plumbing as the model state.  The
    straggler queue holds in-flight upload messages (selected slot indices,
    wire-coded values, delivery masks) for ``lag`` sparse rounds; it is
    zero-width (``L = 0``) when the schedule has no stragglers, so
    straggler-free runs pay no carry traffic for it.
    """

    age: jnp.ndarray  # (C,) int32 rounds since the client last participated
    q_idx: jnp.ndarray  # (C, L, k_max) int32 selected slot indices
    q_val: jnp.ndarray  # (C, L, k_max, D) f32 wire-coded upload values
    q_msk: jnp.ndarray  # (C, L, k_max) f32 delivery mask (0 = empty/lost)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Declarative, seeded description of federation unreliability."""

    participation: float = 1.0  # per-round Bernoulli keep probability
    drop_upload: float = 0.0  # P(lose an upload in flight)
    drop_download: float = 0.0  # P(lose a download in flight)
    stragglers: Tuple[int, ...] = ()  # client ids with delayed uploads
    lag: int = 0  # sparse rounds a straggler upload is delayed by
    seed: int = 0  # fault PRNG seed (independent of the training key)
    force: bool = False  # keep fault machinery compiled in even if trivial

    def __post_init__(self):
        if not (0.0 < self.participation <= 1.0):
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}"
            )
        for name in ("drop_upload", "drop_download"):
            v = getattr(self, name)
            if not (0.0 <= v < 1.0):
                raise ValueError(f"{name} must be in [0, 1), got {v}")
        ids = tuple(int(c) for c in self.stragglers)
        if len(set(ids)) != len(ids) or any(c < 0 for c in ids):
            raise ValueError(f"stragglers must be unique non-negative ids, got {ids}")
        object.__setattr__(self, "stragglers", tuple(sorted(ids)))
        if self.stragglers and self.lag < 1:
            raise ValueError("stragglers given but lag < 1")
        if not self.stragglers and self.lag:
            raise ValueError("lag given without stragglers")

    @property
    def trivial(self) -> bool:
        """True when the schedule cannot change any trajectory (and is not
        forced): engines then compile the exact pre-fault programs."""
        return (
            not self.force
            and self.participation >= 1.0
            and self.drop_upload == 0.0
            and self.drop_download == 0.0
            and not self.stragglers
        )

    @property
    def has_stragglers(self) -> bool:
        return bool(self.stragglers)

    def validate_clients(self, num_clients: int) -> None:
        bad = [c for c in self.stragglers if c >= num_clients]
        if bad:
            raise ValueError(
                f"straggler ids {bad} out of range for {num_clients} clients"
            )

    def straggler_mask(self, num_clients: int) -> np.ndarray:
        """(C,) float32 1.0 indicator of the static straggler set."""
        m = np.zeros((num_clients,), np.float32)
        if self.stragglers:
            m[np.asarray(self.stragglers, np.int64)] = 1.0
        return m


_SPEC_KEYS = ("p", "drop_up", "drop_down", "stragglers", "lag", "seed", "force")
_SPEC_GRAMMAR = (
    "fault spec grammar: comma-separated key=value pairs over "
    f"{_SPEC_KEYS}, e.g. 'p=0.5,drop_up=0.1,stragglers=0:2,lag=2,seed=7' "
    "(straggler ids are colon-separated)"
)


def parse_fault_spec(spec: str) -> FaultSchedule:
    """Parse the ``--faults`` spec string into a :class:`FaultSchedule`.

    An empty string means "no faults" and returns the trivial schedule.
    """
    spec = (spec or "").strip()
    kw: dict = {}
    seen: set = set()
    if spec:
        for item in spec.split(","):
            if "=" not in item:
                raise ValueError(f"bad fault spec item {item!r}; {_SPEC_GRAMMAR}")
            key, val = (s.strip() for s in item.split("=", 1))
            if key not in _SPEC_KEYS:
                raise ValueError(f"unknown fault spec key {key!r}; {_SPEC_GRAMMAR}")
            if key in seen:
                raise ValueError(f"duplicate fault spec key {key!r}")
            seen.add(key)
            try:
                if key == "p":
                    kw["participation"] = float(val)
                elif key in ("drop_up", "drop_down"):
                    kw["drop_upload" if key == "drop_up" else "drop_download"] = (
                        float(val)
                    )
                elif key == "stragglers":
                    kw["stragglers"] = tuple(
                        int(c) for c in val.split(":") if c != ""
                    )
                elif key in ("lag", "seed"):
                    kw[key] = int(val)
                else:  # force
                    kw["force"] = bool(int(val))
            except ValueError as e:
                if "fault spec" in str(e):
                    raise
                raise ValueError(
                    f"bad value {val!r} for fault spec key {key!r}; "
                    f"{_SPEC_GRAMMAR}"
                ) from None
    return FaultSchedule(**kw)


def draw_round_faults(
    sched: FaultSchedule, t, num_clients: int
) -> RoundFaults:
    """The per-round masks, as a pure function of the absolute round index.

    jit-safe: ``t`` may be a traced int32 (inside the superstep scan) or a
    concrete python int (host ledger replay, the reference oracle) — the
    threefry draw is bit-identical either way, which is what lets every
    engine path agree on the schedule without shared state.
    """
    base = jax.random.fold_in(jax.random.PRNGKey(sched.seed), t)

    def leg(i: int, p_keep: float) -> jnp.ndarray:
        # bernoulli(key, 1.0) is deterministically all-True (uniform < 1.0),
        # so force-trivial schedules draw all-ones through the same machinery
        return jax.random.bernoulli(
            jax.random.fold_in(base, i), p_keep, (num_clients,)
        ).astype(jnp.float32)

    return RoundFaults(
        part=leg(0, sched.participation),
        up_ok=leg(1, 1.0 - sched.drop_upload),
        dn_ok=leg(2, 1.0 - sched.drop_download),
    )


def host_round_faults(
    sched: FaultSchedule, t: int, num_clients: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host twin of :func:`draw_round_faults`: ``(part, up_ok, dn_ok)`` as
    numpy bool arrays, bit-identical to the device draw at round ``t``."""
    rf = draw_round_faults(sched, int(t), num_clients)
    return (
        np.asarray(rf.part) > 0.5,
        np.asarray(rf.up_ok) > 0.5,
        np.asarray(rf.dn_ok) > 0.5,
    )


def init_fault_arrays(
    sched: "FaultSchedule | None",
    num_clients: int,
    k_max: int,
    dim: int,
) -> FaultArrays:
    """Fresh device fault state: zero ages, an empty straggler queue.

    The queue depth is ``lag`` when the (active) schedule has stragglers and
    0 otherwise — a zero-width placeholder exactly like the EF residual
    bank, so fault-free runs carry no dead weight through the scans.
    """
    depth = sched.lag if (sched is not None and sched.has_stragglers) else 0
    return FaultArrays(
        age=jnp.zeros((num_clients,), jnp.int32),
        q_idx=jnp.zeros((num_clients, depth, k_max), jnp.int32),
        q_val=jnp.zeros((num_clients, depth, k_max, dim), jnp.float32),
        q_msk=jnp.zeros((num_clients, depth, k_max), jnp.float32),
    )
