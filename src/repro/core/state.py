"""Device-resident federation state and the fused train+communicate cycle.

:class:`repro.core.engine.RoundEngine` (PR 1) made the FedS communication
round one compiled program, but the simulation still paid host costs every
round: each client's entity table was gathered/scattered through numpy, and
local training re-stacked numpy batches per epoch in front of a per-client
jit.  This module removes both:

* :class:`FederationState` holds the WHOLE federation on device across
  rounds — padded ``(C, E_max, D)`` entity tables, ``(C, R, Rd)`` relation
  tables, the stacked Adam state, the ``(C, Ns_max, D)`` upload history, the
  ``(C, Ns_max, D)`` codec error-feedback residuals (see
  :mod:`repro.core.codecs`), and a threaded ``jax.random`` key (replacing
  the host-side numpy jitter RNG).
  It is built once from the per-client state and only scattered back to the
  clients at eval/snapshot boundaries (:meth:`CycleEngine.sync_clients`).
* :class:`CycleEngine` compiles one *cycle* — ``local_epochs`` of the
  training ``lax.scan`` with all batches pre-sampled on device, followed by
  the FedS sparse/sync round of :mod:`repro.core.engine` — as ONE ``jax.jit``
  program (host) or one ``shard_map`` program over the client axis (pod).
* :class:`SuperstepEngine` (PR 3) goes one level up: a whole Intermittent
  Synchronization Mechanism period — ``s`` sparse rounds then one dense sync
  round, as scheduled by :func:`repro.core.sync.round_kind` — is
  ``lax.scan``-ned into a SINGLE program per superstep, carrying the
  federation state, the threaded PRNG key, and device-side ledger
  accumulators (per-round download counts) through the scan.  One host
  touch-point per ``s+1`` rounds instead of one per round.

Client heterogeneity is expressed with static shapes throughout: triples are
padded to ``T_max`` (samplers draw indices below the true count), batches to
``B_max`` with zero-weight rows in the loss, scan steps to
``local_epochs * S_max`` with pass-through optimizer steps
(:func:`repro.train.optimizer.masked_adam_update`), and shared-entity rows to
``Ns_max`` exactly as in the round engine.

The per-round oracle path (``engine="batched"`` in the simulation) runs the
SAME ``train_core`` / ``comm_core`` functions as two separate jits per round,
so the property tests can assert that fusing them into one program changes
nothing (same seeds -> same eval trajectory and ledger totals).  See
EXPERIMENTS.md §Cycle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eshard
from repro.core.codecs import IdentityCodec, WireCodec
from repro.core.engine import (
    batched_sparse_round,
    batched_sync_round,
    build_padded_views,
    shard_map,
)
from repro.core.evaluation import EvalBank
from repro.core.faults import (
    FaultArrays,
    FaultSchedule,
    RoundFaults,
    draw_round_faults,
    init_fault_arrays,
)
from repro.core.sync import compress_schedule
from repro.core.telemetry import (
    NUM_SCORE_BUCKETS,
    RoundTelemetry,
    TelemetryArrays,
    init_telemetry_arrays,
    nonfinite_count,
    record_spec,
    residual_mass,
    shared_divergence,
    telemetry_spec,
    update_norm,
)
from repro.data.loader import stack_padded_triples
from repro.kge.scoring import get_scoring, loss_from_scores, per_sample_losses
from repro.train.optimizer import AdamState, adam_update, masked_adam_update

if TYPE_CHECKING:  # core never imports federated at runtime (layering)
    from repro.federated.client import KGEClient


class StateArrays(NamedTuple):
    """Device-resident pytree; every leaf leads with the client axis, so one
    ``PartitionSpec('clients')`` prefix shards the whole bundle."""

    params: dict  # {"entity": (C, E_max, D), "relation": (C, R, Rd)}
    opt: AdamState  # step (C,), mu/nu mirroring params
    hist: jnp.ndarray  # (C, Ns_max, D) upload history of shared rows
    res: jnp.ndarray  # (C, Ns_max, D) codec error-feedback residuals,
    #                   cleared by sync rounds; (C, 0, D) empty placeholder
    #                   when the codec carries no residual, so non-EF runs
    #                   pay no scan-carry traffic for it
    faults: FaultArrays  # per-client staleness counters + the straggler
    #                      in-flight message queue (repro.core.faults);
    #                      zero-width queue when the schedule has no
    #                      stragglers, passed through untouched when the
    #                      engine has no active fault schedule at all
    tel: Optional[TelemetryArrays] = None  # flight-recorder overlap carry
    #                      (repro.core.telemetry): the previous round's sent
    #                      upload selection.  None — zero pytree leaves —
    #                      with telemetry off, so untelemetered runs compile
    #                      exactly the historical programs (the same static
    #                      gating as trivial fault schedules)


class CycleConsts(NamedTuple):
    """Static per-federation device constants.

    Client-axis leading like the state, and passed as explicit program
    arguments (NOT closed over) so ``shard_map`` slices them per shard."""

    cids: jnp.ndarray  # (C,) global client index, for per-client key folding
    triples: jnp.ndarray  # (C, T_max, 3) padded local training triples
    num_train: jnp.ndarray  # (C,) true triple counts
    num_ent: jnp.ndarray  # (C,) local entity counts (negative-sampling bound)
    sample_w: jnp.ndarray  # (C, B_max) f32 0/1 padded-batch-row weights
    step_mask: jnp.ndarray  # (C, L) valid scan steps
    gather_idx: jnp.ndarray  # (C, Ns_max) local row per shared slot (0 padded)
    scatter_idx: jnp.ndarray  # (C, Ns_max) same, E_max sentinel on padding
    gid: jnp.ndarray  # (C, Ns_max) global entity ids (num_global padded)
    valid: jnp.ndarray  # (C, Ns_max) shared-slot validity
    k: jnp.ndarray  # (C,) per-client upstream/downstream K
    straggler: jnp.ndarray  # (C,) f32 static straggler-set indicator


@dataclasses.dataclass
class FederationState:
    """The whole federation, on device, between host touch-points."""

    arrays: StateArrays
    key: jax.Array  # threaded PRNG key: one 3-way split per cycle


class CycleEngine:
    """Compiled train+communicate cycles over :class:`FederationState`.

    Built once per federation from the clients (hyper-parameters must be
    homogeneous).  ``mesh=None`` compiles single-device jits; with a 1-D mesh
    the same programs run under ``shard_map`` over the client axis (C must be
    divisible by the mesh size), the only collective being the round's
    all-gather / psum.
    """

    def __init__(
        self,
        clients: Sequence["KGEClient"],
        views: Sequence,  # list[repro.core.protocol.ClientCommView]
        num_global_entities: int,
        *,
        sparsity_p: float,
        local_epochs: int,
        codec: Optional[WireCodec] = None,
        mesh=None,
        axis_name: str = "clients",
        entity_axis: Optional[str] = None,
        faults: Optional[FaultSchedule] = None,
        telemetry: bool = False,
    ):
        self.views = list(views)
        self.num_global = int(num_global_entities)
        self.num_clients = len(clients)
        # static, like the trivial-schedule gate: telemetry=False builds the
        # exact historical programs (no record outputs, no overlap carry)
        self._tel = bool(telemetry)
        # a trivial schedule compiles EXACTLY the fault-free programs — the
        # all-present case is bitwise pre-fault by construction, not by test
        self._sched = (
            faults if (faults is not None and not faults.trivial) else None
        )
        if self._sched is not None:
            self._sched.validate_clients(self.num_clients)
        if self.num_clients != len(self.views):
            raise ValueError("one comm view per client required")
        c0 = clients[0]
        self.method = c0.method
        self.gamma = float(c0.gamma)
        self.lr = float(c0.lr)
        self.temp = float(c0.temp)
        self.dim = int(c0.model.dim)
        self.rel_dim = int(c0.model.rel_dim)
        self.num_relations = int(c0.model.num_relations)
        self.local_epochs = int(local_epochs)
        self.num_negatives = int(c0.loader.num_negatives)
        self.codec = codec if codec is not None else IdentityCodec()
        for c in clients:
            if (
                c.method != self.method
                or c.model.dim != self.dim
                or c.model.num_relations != self.num_relations
                or c.loader.num_negatives != self.num_negatives
                or (float(c.gamma), float(c.lr), float(c.temp))
                != (self.gamma, self.lr, self.temp)
            ):
                raise ValueError(
                    "CycleEngine requires homogeneous model/loader hyper-parameters"
                )

        gid, valid, self.k_per_client, self.ns_max, self.k_max = build_padded_views(
            self.views, self.num_global, sparsity_p
        )

        # entity-axis sharding: every row-major table — entity embeddings and
        # Adam moments along E, upload history / EF residuals along Ns — is
        # block-sharded over the mesh's second axis.  Row counts pad up so
        # the blocks split evenly (E additionally to whole 32-entity filter
        # words, so the eval word axis shards evenly too); the padding slots
        # are invalid/zero rows the round and trainer masks already ignore.
        self._eaxis = entity_axis if mesh is not None else None
        if self._eaxis is not None and self._eaxis not in dict(mesh.shape):
            raise ValueError(
                f"entity_axis {self._eaxis!r} not in mesh axes {tuple(mesh.shape)}"
            )
        n_e = int(dict(mesh.shape)[self._eaxis]) if self._eaxis else 1
        self.n_eshards = n_e
        self.ns_pad = eshard.pad_rows(self.ns_max, n_e) if n_e > 1 else self.ns_max
        if self.ns_pad > self.ns_max:
            pad = self.ns_pad - self.ns_max
            gid = np.pad(gid, ((0, 0), (0, pad)), constant_values=self.num_global)
            valid = np.pad(valid, ((0, 0), (0, pad)))

        self.num_entities = np.asarray(
            [c.model.num_entities for c in clients], np.int32
        )
        self.e_max = int(self.num_entities.max())
        self.e_pad = (
            eshard.pad_rows(self.e_max, n_e, 32) if n_e > 1 else self.e_max
        )
        triples, counts = stack_padded_triples([c.data.train for c in clients])
        batch_sizes = np.asarray([c.loader.batch_size for c in clients], np.int32)
        steps = np.asarray([c.loader.batches_per_epoch for c in clients], np.int32)
        self.b_max = int(batch_sizes.max())
        self.s_max = int(steps.max())
        self.scan_len = self.local_epochs * self.s_max
        sample_w = (
            np.arange(self.b_max)[None, :] < batch_sizes[:, None]
        ).astype(np.float32)
        # step i of the flattened epochs*S_max scan belongs to epoch-position
        # i % S_max; clients with fewer batches-per-epoch pass through.
        step_mask = (
            np.tile(np.arange(self.s_max), self.local_epochs)[None, :]
            < steps[:, None]
        )
        # Static fast paths: when every client has the same batches-per-epoch
        # (resp. batch size) the masks are all-ones and the per-step
        # pass-through selects / per-sample weights — full-table-sized
        # ``where``s — are dead weight, so they are compiled out entirely.
        self._uniform_steps = bool(step_mask.all())
        self._uniform_batches = bool((sample_w == 1.0).all())
        gather_idx = np.zeros((self.num_clients, self.ns_pad), np.int32)
        scatter_idx = np.full((self.num_clients, self.ns_pad), self.e_pad, np.int32)
        for c, v in enumerate(self.views):
            gather_idx[c, : v.num_shared] = v.shared_local
            scatter_idx[c, : v.num_shared] = v.shared_local
        self.consts = CycleConsts(
            cids=jnp.arange(self.num_clients, dtype=jnp.int32),
            triples=jnp.asarray(triples),
            num_train=jnp.asarray(counts),
            num_ent=jnp.asarray(self.num_entities),
            sample_w=jnp.asarray(sample_w),
            step_mask=jnp.asarray(step_mask),
            gather_idx=jnp.asarray(gather_idx),
            scatter_idx=jnp.asarray(scatter_idx),
            gid=jnp.asarray(gid),
            valid=jnp.asarray(valid),
            k=jnp.asarray(self.k_per_client),
            straggler=jnp.asarray(
                self._sched.straggler_mask(self.num_clients)
                if self._sched is not None
                else np.zeros((self.num_clients,), np.float32)
            ),
        )

        self._axis = axis_name if mesh is not None else None
        self._mesh = mesh
        train_core = self._make_train_core()
        comm_core = self._make_comm_core()
        # kept for SuperstepEngine, which re-composes the same cores into
        # multi-round scanned programs (the equivalence contract depends on
        # every engine mode running these exact functions)
        self._train_core_fn = train_core
        self._comm_core_fn = comm_core

        tel = self._tel

        def comm_sparse(arrays, jitter, consts):
            return comm_core(arrays, jitter, consts, do_sync=False)

        def comm_sync(arrays, consts):
            return comm_core(arrays, None, consts, do_sync=True)

        def fused(arrays, kb, kj, consts, do_sync):
            arrays, jitter, loss = train_core(arrays, kb, kj, consts)
            if tel:
                arrays, down, rec = comm_core(
                    arrays, jitter, consts, do_sync=do_sync
                )
                return arrays, down, loss, rec
            arrays, down = comm_core(arrays, jitter, consts, do_sync=do_sync)
            return arrays, down, loss

        fused_sparse = functools.partial(fused, do_sync=False)
        fused_sync = functools.partial(fused, do_sync=True)

        # fault-schedule variants: same cores, plus the absolute round index
        # t as a (traced) program input — the per-round masks are drawn
        # INSIDE the program as a pure function of t (repro.core.faults), so
        # host replay / the reference oracle / the scanned superstep all see
        # bit-identical schedules.  The full (C,) draw is replicated across
        # shards; consts.cids slices each shard's clients.
        sched = self._sched

        def round_faults_of(consts, t):
            rf = draw_round_faults(sched, t, self.num_clients)
            return RoundFaults(
                part=rf.part[consts.cids],
                up_ok=rf.up_ok[consts.cids],
                dn_ok=rf.dn_ok[consts.cids],
            )

        self._round_faults = round_faults_of if sched is not None else None

        def comm_sparse_f(arrays, jitter, consts, t):
            return comm_core(
                arrays, jitter, consts, do_sync=False,
                rf=round_faults_of(consts, t),
            )

        def comm_sync_f(arrays, consts, t):
            return comm_core(
                arrays, None, consts, do_sync=True,
                rf=round_faults_of(consts, t),
            )

        def fused_f(arrays, kb, kj, consts, t, do_sync):
            arrays, jitter, loss = train_core(arrays, kb, kj, consts)
            out = comm_core(
                arrays, jitter, consts, do_sync=do_sync,
                rf=round_faults_of(consts, t),
            )
            if tel:
                arrays, down, rec = out
                return arrays, down, loss, rec
            arrays, down = out
            return arrays, down, loss

        fused_sparse_f = functools.partial(fused_f, do_sync=False)
        fused_sync_f = functools.partial(fused_f, do_sync=True)

        if mesh is None:
            # State flows linearly cycle-to-cycle, so the big resident
            # buffers (entity tables, Adam moments, history) are donated —
            # XLA updates them in place instead of allocating fresh ones.
            self._train = jax.jit(train_core, donate_argnums=(0,))
            self._comm_sparse = jax.jit(comm_sparse, donate_argnums=(0,))
            self._comm_sync = jax.jit(comm_sync, donate_argnums=(0,))
            self._fused_sparse = jax.jit(fused_sparse, donate_argnums=(0,))
            self._fused_sync = jax.jit(fused_sync, donate_argnums=(0,))
            if sched is not None:
                self._comm_sparse_f = jax.jit(comm_sparse_f, donate_argnums=(0,))
                self._comm_sync_f = jax.jit(comm_sync_f, donate_argnums=(0,))
                self._fused_sparse_f = jax.jit(fused_sparse_f, donate_argnums=(0,))
                self._fused_sync_f = jax.jit(fused_sync_f, donate_argnums=(0,))
        else:
            n_c = int(dict(mesh.shape)[axis_name])
            if self.num_clients % n_c != 0:
                raise ValueError(
                    f"{self.num_clients} clients not divisible by "
                    f"{n_c} client-axis mesh devices"
                )
            pa = self._arrays_spec()  # StateArrays-shaped (or plain prefix)
            p = jax.sharding.PartitionSpec(axis_name)
            r = jax.sharding.PartitionSpec()
            # record leaves are all client-axis-leading and psum-replicated
            # over any entity axis, so one client-only spec covers the pytree
            comm_out = (pa, p, record_spec(p)) if tel else (pa, p)
            fused_out = (pa, p, p, record_spec(p)) if tel else (pa, p, p)
            self._train = jax.jit(shard_map(
                train_core, mesh=mesh, in_specs=(pa, r, r, p), out_specs=(pa, p, p),
            ), donate_argnums=(0,))
            self._comm_sparse = jax.jit(shard_map(
                comm_sparse, mesh=mesh, in_specs=(pa, p, p), out_specs=comm_out,
            ), donate_argnums=(0,))
            self._comm_sync = jax.jit(shard_map(
                comm_sync, mesh=mesh, in_specs=(pa, p), out_specs=comm_out,
            ), donate_argnums=(0,))
            self._fused_sparse = jax.jit(shard_map(
                fused_sparse, mesh=mesh, in_specs=(pa, r, r, p),
                out_specs=fused_out,
            ), donate_argnums=(0,))
            self._fused_sync = jax.jit(shard_map(
                fused_sync, mesh=mesh, in_specs=(pa, r, r, p),
                out_specs=fused_out,
            ), donate_argnums=(0,))
            if sched is not None:
                self._comm_sparse_f = jax.jit(shard_map(
                    comm_sparse_f, mesh=mesh, in_specs=(pa, p, p, r),
                    out_specs=comm_out,
                ), donate_argnums=(0,))
                self._comm_sync_f = jax.jit(shard_map(
                    comm_sync_f, mesh=mesh, in_specs=(pa, p, r),
                    out_specs=comm_out,
                ), donate_argnums=(0,))
                self._fused_sparse_f = jax.jit(shard_map(
                    fused_sparse_f, mesh=mesh, in_specs=(pa, r, r, p, r),
                    out_specs=fused_out,
                ), donate_argnums=(0,))
                self._fused_sync_f = jax.jit(shard_map(
                    fused_sync_f, mesh=mesh, in_specs=(pa, r, r, p, r),
                    out_specs=fused_out,
                ), donate_argnums=(0,))

    def _arrays_spec(self):
        """PartitionSpec pytree for :class:`StateArrays` under the mesh.

        Client-only sharding keeps the historical single-spec prefix; with
        an entity axis the row-sharded leaves (entity table + its Adam
        moments, history, residuals) get the 2-D ``(clients, entities)``
        spec while relation tables and step counts stay client-only.
        """
        p = jax.sharding.PartitionSpec(self._axis)
        if self._eaxis is None:
            return p
        pe = jax.sharding.PartitionSpec(self._axis, self._eaxis)
        ent_like = {"entity": pe, "relation": p}
        return StateArrays(
            params=ent_like,
            opt=AdamState(step=p, mu=dict(ent_like), nu=dict(ent_like)),
            hist=pe,
            res=pe,
            # fault state is small and per-client (queue values are gathered
            # full rows, already entity-replicated) — client-only sharding
            faults=FaultArrays(age=p, q_idx=p, q_val=p, q_msk=p),
            # overlap carry is (C, k_max) slot indices — client-only too
            tel=telemetry_spec(p) if self._tel else None,
        )

    def _bank_spec(self):
        """PartitionSpec pytree for :class:`EvalBank` under the mesh —
        packed filter words row-shard on the word axis (32 rows per word,
        and every entity block is 32-aligned, so the split is exact)."""
        p = jax.sharding.PartitionSpec(self._axis)
        if self._eaxis is None:
            return p
        pw = jax.sharding.PartitionSpec(self._axis, None, self._eaxis)
        return EvalBank(triples=p, count=p, ft_words=pw, fh_words=pw, num_ent=p)

    # ------------------------------------------------------- program bodies
    def _make_train_core(self):
        scan_len, b_max, n_neg = self.scan_len, self.b_max, self.num_negatives
        method, gamma, lr, temp = self.method, self.gamma, self.lr, self.temp
        ns_max, ns_pad = self.ns_max, self.ns_pad
        uniform_steps = self._uniform_steps
        uniform_batches = self._uniform_batches
        eaxis, n_eshards = self._eaxis, self.n_eshards

        def sample_one(cid, tri, t_c, e_c, kb):
            """Pre-sample the whole cycle's batches for one client on device."""
            kc = jax.random.fold_in(kb, cid)
            pi = jax.random.randint(
                jax.random.fold_in(kc, 1), (scan_len, b_max), 0, t_c
            )
            pos = jnp.take(tri, pi, axis=0)  # (L, B, 3)
            neg_t = jax.random.randint(
                jax.random.fold_in(kc, 2), (scan_len, b_max, n_neg), 0, e_c
            )
            neg_h = jax.random.randint(
                jax.random.fold_in(kc, 3), (scan_len, b_max, n_neg), 0, e_c
            )
            return pos, neg_t, neg_h

        # registry-routed scoring: the spec's jit-safe score piece plus the
        # family-tagged loss weighting inside per_sample_losses below
        score = get_scoring(self.method).score

        def scores_of(rows, rel, cb):
            """Scores from ONE gathered row block ``[h; t; neg_t; neg_h]``."""
            h_e, t_e = rows[:cb], rows[cb : 2 * cb]
            nt_e = rows[2 * cb : (2 + n_neg) * cb].reshape(cb, n_neg, -1)
            nh_e = rows[(2 + n_neg) * cb :].reshape(cb, n_neg, -1)
            pos_score = score(h_e, rel, t_e, gamma)
            neg_t_score = score(h_e[:, None, :], rel[:, None, :], nt_e, gamma)
            neg_h_score = score(nh_e, rel[:, None, :], t_e[:, None, :], gamma)
            return pos_score, jnp.concatenate([neg_t_score, neg_h_score], -1)

        # Both trainers below compute gradients with respect to the GATHERED
        # rows and scatter-add the cotangents back ONCE: differentiating the
        # table-indexing loss directly materializes a dense (E, D) cotangent
        # per gather (six of them), which at FB15k scale costs ~20x the batch
        # math itself.  Same gradient, summation order aside.

        # ---- flat fast path: the client axis folds into the row axis, so
        # every gather/scatter is a fast single-level op (a batched scatter
        # under vmap falls off XLA:CPU's fast path).  Valid whenever all
        # clients share batches-per-epoch; per-client Adam bias correction
        # then reduces to one shared step count (taken from client 0, all
        # equal by construction).
        def train_flat(params, opt, pos, neg_t, neg_h, s_w):
            c_n, e_m, d = params["entity"].shape
            r_n, r_d = params["relation"].shape[1:]
            cb = c_n * b_max
            flat = lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:])  # noqa: E731
            params_f = jax.tree.map(flat, params)
            opt_f = AdamState(
                step=opt.step[0],
                mu=jax.tree.map(flat, opt.mu),
                nu=jax.tree.map(flat, opt.nu),
            )
            roff = jnp.arange(c_n, dtype=jnp.int32) * r_n
            # objective = sum over clients of each client's (weighted) mean
            # loss — cross-client gradients are disjoint, so one backward
            # pass yields every client's own-mean gradient.
            if uniform_batches:
                wn = jnp.full((c_n, b_max), 1.0 / b_max, jnp.float32)
            else:
                wn = s_w / jnp.maximum(s_w.sum(axis=1, keepdims=True), 1.0)

            # client id of every row of the flattened [h; t; neg_t; neg_h]
            # gather list — the entity-sharded gather/scatter keys on
            # (client, entity) pairs instead of pre-folded flat indices
            cid_rows = jnp.concatenate(
                [jnp.repeat(jnp.arange(c_n, dtype=jnp.int32), b_max)] * 2
                + [jnp.repeat(jnp.arange(c_n, dtype=jnp.int32), b_max * n_neg)] * 2
            )

            def gather_rows(table, e_idx):
                """rows ``table[c * E + e]`` with E row-sharded; exact."""
                if eaxis is None:
                    return table[cid_rows * e_m + e_idx]
                base = jax.lax.axis_index(eaxis) * e_m  # e_m == local block
                loc = jnp.clip(e_idx - base, 0, e_m - 1)
                cand = table[cid_rows * e_m + loc]
                g = jax.lax.all_gather(cand, eaxis)  # (S, M, d)
                owner = jnp.clip(e_idx // e_m, 0, n_eshards - 1)
                out = jnp.take_along_axis(
                    jnp.moveaxis(g, 0, 1), owner[:, None, None], axis=1
                )
                return out[:, 0]

            def scatter_grads(table, e_idx, g_rows):
                """Drop-mode scatter-add of owned contributions, full-list
                order — per-row accumulation order matches unsharded."""
                if eaxis is None:
                    return jnp.zeros_like(table).at[cid_rows * e_m + e_idx].add(g_rows)
                base = jax.lax.axis_index(eaxis) * e_m
                loc = e_idx - base
                own = (loc >= 0) & (loc < e_m)
                flat = jnp.where(own, cid_rows * e_m + loc, c_n * e_m)
                return jnp.zeros_like(table).at[flat].add(g_rows, mode="drop")

            def step_fn(carry, x):
                params_f, opt_f = carry
                p, nt, nh = x  # (C, B, 3), (C, B, N)
                r = (p[:, :, 1] + roff[:, None]).reshape(-1)
                e_idx = jnp.concatenate([
                    p[:, :, 0].reshape(-1), p[:, :, 2].reshape(-1),
                    nt.reshape(-1), nh.reshape(-1),
                ])

                def loss_fn(rows, rel):
                    pos_s, neg_s = scores_of(rows, rel, cb)
                    per = per_sample_losses(pos_s, neg_s, method, temp)
                    loss_c = (per.reshape(c_n, b_max) * wn).sum(axis=1) / 2.0
                    return loss_c.sum(), loss_c

                (_, loss_c), (g_rows, g_rel) = jax.value_and_grad(
                    loss_fn, argnums=(0, 1), has_aux=True
                )(gather_rows(params_f["entity"], e_idx), params_f["relation"][r])
                grads = {
                    "entity": scatter_grads(params_f["entity"], e_idx, g_rows),
                    "relation": jnp.zeros_like(params_f["relation"]).at[r].add(g_rel),
                }
                params_f, opt_f = adam_update(grads, opt_f, params_f, lr)
                return (params_f, opt_f), loss_c

            (params_f, opt_f), losses = jax.lax.scan(
                step_fn, (params_f, opt_f),
                (jnp.moveaxis(pos, 0, 1), jnp.moveaxis(neg_t, 0, 1),
                 jnp.moveaxis(neg_h, 0, 1)),
            )
            params = {
                "entity": params_f["entity"].reshape(c_n, e_m, d),
                "relation": params_f["relation"].reshape(c_n, r_n, r_d),
            }
            unflat = lambda t_: {  # noqa: E731
                "entity": t_["entity"].reshape(c_n, e_m, d),
                "relation": t_["relation"].reshape(c_n, r_n, r_d),
            }
            new_opt = AdamState(
                step=jnp.broadcast_to(opt_f.step, (c_n,)),
                mu=unflat(opt_f.mu),
                nu=unflat(opt_f.nu),
            )
            return params, new_opt, losses.mean(axis=0)

        # ---- heterogeneous fallback: vmap over clients with masked steps
        def batch_grads(params, p, nt, nh, weight):
            ent, rel_tab = params["entity"], params["relation"]
            h, r, t = p[:, 0], p[:, 1], p[:, 2]
            idx = jnp.concatenate([h, t, nt.reshape(-1), nh.reshape(-1)])

            def loss_fn(rows, rel):
                pos_s, neg_s = scores_of(rows, rel, b_max)
                return loss_from_scores(pos_s, neg_s, method, temp, weight)

            if eaxis is None:
                rows_in = ent[idx]
            else:  # collectives batch under the client vmap (one per shard)
                rows_in = eshard._take_rows_one(ent, idx, eaxis)
            loss, (g_rows, g_rel) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                rows_in, rel_tab[r]
            )
            if eaxis is None:
                g_ent = jnp.zeros_like(ent).at[idx].add(g_rows)
            else:
                e_blk = ent.shape[0]
                loc = idx - jax.lax.axis_index(eaxis) * e_blk
                own = (loc >= 0) & (loc < e_blk)
                g_ent = jnp.zeros_like(ent).at[
                    jnp.where(own, loc, e_blk)
                ].add(g_rows, mode="drop")
            grads = {
                "entity": g_ent,
                "relation": jnp.zeros_like(rel_tab).at[r].add(g_rel),
            }
            return loss, grads

        def train_one(params, opt, pos, neg_t, neg_h, s_w, s_mask):
            weight = None if uniform_batches else s_w

            def step(carry, x):
                params, opt = carry
                p, nt, nh, ok = x
                loss, grads = batch_grads(params, p, nt, nh, weight)
                params, opt = masked_adam_update(grads, opt, params, lr, ok)
                return (params, opt), jnp.where(ok, loss, 0.0)

            (params, opt), losses = jax.lax.scan(
                step, (params, opt), (pos, neg_t, neg_h, s_mask)
            )
            mean_loss = losses.sum() / jnp.maximum(s_mask.sum(), 1)
            return params, opt, mean_loss

        def train_core(arrays, kb, kj, consts):
            pos, neg_t, neg_h = jax.vmap(sample_one, in_axes=(0, 0, 0, 0, None))(
                consts.cids, consts.triples, consts.num_train, consts.num_ent, kb
            )
            if uniform_steps:
                params, opt, loss = train_flat(
                    arrays.params, arrays.opt, pos, neg_t, neg_h, consts.sample_w
                )
            else:
                params, opt, loss = jax.vmap(train_one)(
                    arrays.params, arrays.opt, pos, neg_t, neg_h,
                    consts.sample_w, consts.step_mask,
                )
            # Downstream tie-break jitter for the round that follows; computed
            # here so the per-round oracle consumes bit-identical noise.
            # Always drawn at the LOGICAL ns_max shape — the draw shape feeds
            # the PRNG, so padding must happen after, not in the draw.
            jitter = jax.vmap(
                lambda cid: jax.random.uniform(jax.random.fold_in(kj, cid), (ns_max,))
            )(consts.cids)
            if ns_pad > ns_max:
                jitter = jnp.pad(jitter, ((0, 0), (0, ns_pad - ns_max)))
            return (
                StateArrays(
                    params, opt, arrays.hist, arrays.res, arrays.faults,
                    arrays.tel,
                ),
                jitter,
                loss,
            )

        return train_core

    def _make_comm_core(self):
        k_max, num_global = self.k_max, self.num_global
        codec, axis = self.codec, self._axis
        eaxis, ns_blk = self._eaxis, self.ns_pad // self.n_eshards
        has_stragglers = self._sched is not None and self._sched.has_stragglers
        tel = self._tel

        def comm_core(arrays, jitter, consts, do_sync, rf=None):
            fa = arrays.faults
            new_tel = arrays.tel
            rec = None
            ent = arrays.params["entity"]
            # device-side gather of shared rows; padding slots zeroed exactly
            # like RoundEngine.gather so the round functions see identical
            # inputs to the per-round engine path.  Entity-sharded, this is
            # the exact distributed gather at full Ns_pad width — the round
            # then works on this shard's slot block while the cheap per-slot
            # vectors (gid / valid / jitter) stay replicated.
            emb = eshard.dist_take_rows(ent, consts.gather_idx, eaxis)
            emb = jnp.where(consts.valid[:, :, None], emb, 0.0)
            emb = eshard.local_block(emb, eaxis, ns_blk)
            if do_sync:
                rows, pre = batched_sync_round(
                    emb, consts.gid, consts.valid,
                    num_global=num_global, axis_name=axis, entity_axis=eaxis,
                    faults=rf,
                )
                down = jnp.zeros((rows.shape[0],), jnp.int32)
                if rf is None:
                    hist = pre
                    # the full exchange transmits exact values: nothing was
                    # dropped, and stale residuals would re-inject pre-sync
                    # error into freshly-repaired rows — the bank clears
                    res = (
                        jnp.zeros_like(arrays.res)
                        if codec.has_residual else arrays.res
                    )
                else:
                    # only participating clients uploaded: their history
                    # refreshes to the pre-sync rows and their residual
                    # banks clear; absent clients keep both and recover at
                    # the next sync they attend.  The full exchange also
                    # obsoletes a present straggler's in-flight sparse
                    # messages — its queue entries are masked out.
                    sent = rf.part[:, None, None] > 0.5
                    hist = jnp.where(sent, pre, arrays.hist)
                    res = (
                        jnp.where(sent, 0.0, arrays.res)
                        if codec.has_residual else arrays.res
                    )
                    partb = rf.part > 0.5
                    fa = fa._replace(
                        age=jnp.where(partb, 0, fa.age + 1),
                        q_msk=jnp.where(partb[:, None, None], 0.0, fa.q_msk),
                    )
                if tel:
                    cl = rows.shape[0]
                    if rf is None:
                        onesf = jnp.ones((cl,), jnp.float32)
                        partf = up_okf = dn_okf = onesf
                    else:
                        partf, up_okf, dn_okf = rf.part, rf.up_ok, rf.dn_ok
                    # the full exchange bills num_shared rows on each leg for
                    # every participating client; overlap and change scores
                    # are sparse-round signals and record as zeros (the
                    # overlap carry passes through untouched — a dense
                    # exchange is not a Top-K selection)
                    billed = jnp.where(
                        partf > 0.5,
                        consts.valid.sum(axis=1).astype(jnp.int32),
                        0,
                    )
                    # health probes on the post-sync rows, full width so the
                    # divergence segment sums keep the unsharded summation
                    # order; a fault-free sync collapses div_* to exact zero
                    post_full = eshard.all_blocks(rows, eaxis)
                    div_mean, div_max = shared_divergence(
                        post_full, consts.gid, consts.valid, num_global,
                        axis_name=axis,
                    )
                    rec = RoundTelemetry(
                        up_rows=billed,
                        dn_rows=billed,
                        overlap=jnp.zeros((cl,), jnp.int32),
                        res_mass=residual_mass(res, entity_axis=eaxis),
                        part=partf,
                        up_ok=up_okf,
                        dn_ok=dn_okf,
                        age=fa.age,
                        score_hist=jnp.zeros(
                            (cl, NUM_SCORE_BUCKETS), jnp.int32
                        ),
                        div_mean=div_mean,
                        div_max=div_max,
                        upd_norm=update_norm(
                            post_full, eshard.all_blocks(emb, eaxis),
                            consts.valid,
                        ),
                        nonfinite=nonfinite_count(post_full, consts.valid),
                    )
            else:
                # halve after the f32 cast (mirrors RoundEngine.sparse_round)
                j = jnp.asarray(jitter, jnp.float32) * 0.5
                prev = (
                    (arrays.tel.prev_idx, arrays.tel.prev_msk) if tel else None
                )
                if rf is None:
                    out = batched_sparse_round(
                        emb, arrays.hist, consts.gid, consts.valid, consts.k,
                        j, k_max=k_max, num_global=num_global, codec=codec,
                        axis_name=axis, res=arrays.res, entity_axis=eaxis,
                        prev=prev,
                    )
                    rows, hist, down, res = out[:4]
                else:
                    q = (
                        (fa.q_idx, fa.q_val, fa.q_msk)
                        if has_stragglers else None
                    )
                    out = batched_sparse_round(
                        emb, arrays.hist, consts.gid, consts.valid, consts.k,
                        j, k_max=k_max, num_global=num_global, codec=codec,
                        axis_name=axis, res=arrays.res, entity_axis=eaxis,
                        faults=rf,
                        straggler=consts.straggler if has_stragglers else None,
                        queue=q,
                        prev=prev,
                    )
                    rows, hist, down, res = out[:4]
                    partb = rf.part > 0.5
                    fa = fa._replace(age=jnp.where(partb, 0, fa.age + 1))
                    if q is not None:
                        nq = out[4]
                        fa = fa._replace(
                            q_idx=nq[0], q_val=nq[1], q_msk=nq[2]
                        )
                if tel:
                    # (rec, prev') ride LAST on the round's output tuple;
                    # the engine's age field is a placeholder — the
                    # post-update staleness counters live here
                    rec, new_prev = out[-2], out[-1]
                    rec = rec._replace(age=fa.age)
                    new_tel = TelemetryArrays(
                        prev_idx=new_prev[0], prev_msk=new_prev[1]
                    )
            rows_full = eshard.all_blocks(rows, eaxis)
            ent = eshard.scatter_rows(ent, consts.scatter_idx, rows_full, eaxis)
            params = dict(arrays.params, entity=ent)
            new_arrays = StateArrays(
                params, arrays.opt, hist, res, fa, new_tel
            )
            if tel:
                return new_arrays, down, rec
            return new_arrays, down

        return comm_core

    # ------------------------------------------------------- state plumbing
    def init_state(self, clients: Sequence["KGEClient"], seed: int = 0) -> FederationState:
        """Stack per-client params / optimizer state into padded device arrays."""
        c_n, d = self.num_clients, self.dim
        # e_pad / ns_pad == e_max / ns_max unless entity-sharded (then rows
        # are padded so the tables split into equal per-shard blocks)
        ent = np.zeros((c_n, self.e_pad, d), np.float32)
        rel = np.zeros((c_n, self.num_relations, self.rel_dim), np.float32)
        mu_e, nu_e = np.zeros_like(ent), np.zeros_like(ent)
        mu_r, nu_r = np.zeros_like(rel), np.zeros_like(rel)
        step = np.zeros((c_n,), np.int32)
        hist = np.zeros((c_n, self.ns_pad, d), np.float32)
        for c, cl in enumerate(clients):
            n = cl.model.num_entities
            ent[c, :n] = np.asarray(cl.params["entity"], np.float32)
            rel[c] = np.asarray(cl.params["relation"], np.float32)
            step[c] = int(cl.opt_state.step)
            mu_e[c, :n] = np.asarray(cl.opt_state.mu["entity"], np.float32)
            nu_e[c, :n] = np.asarray(cl.opt_state.nu["entity"], np.float32)
            mu_r[c] = np.asarray(cl.opt_state.mu["relation"], np.float32)
            nu_r[c] = np.asarray(cl.opt_state.nu["relation"], np.float32)
            v = self.views[c]
            if v.num_shared:
                hist[c, : v.num_shared] = ent[c][v.shared_local]
        if self._uniform_steps and len(set(step.tolist())) > 1:
            # the flat trainer shares one Adam step count across clients
            # (valid because equal batches-per-epoch keeps them in lockstep);
            # clients arriving with unequal counts would silently get client
            # 0's bias correction.
            raise ValueError(
                "clients have unequal Adam step counts "
                f"({step.tolist()}); the flat trainer requires lockstep steps"
            )
        arrays = StateArrays(
            params={"entity": jnp.asarray(ent), "relation": jnp.asarray(rel)},
            opt=AdamState(
                step=jnp.asarray(step),
                mu={"entity": jnp.asarray(mu_e), "relation": jnp.asarray(mu_r)},
                nu={"entity": jnp.asarray(nu_e), "relation": jnp.asarray(nu_r)},
            ),
            hist=jnp.asarray(hist),
            # error-feedback residual bank: starts all-zero (nothing dropped
            # yet); zero-width placeholder when the codec banks nothing
            res=jnp.zeros(
                (c_n, self.ns_pad if self.codec.has_residual else 0, d),
                jnp.float32,
            ),
            # staleness counters + straggler queue; zero-width queue (and a
            # pure pass-through in the programs) without an active schedule
            faults=init_fault_arrays(self._sched, c_n, self.k_max, d),
            # flight-recorder overlap carry: round 0 has no previous upload
            tel=(
                init_telemetry_arrays(c_n, self.k_max)
                if self._tel else None
            ),
        )
        return FederationState(arrays=arrays, key=jax.random.PRNGKey(seed))

    def sync_clients(self, state: FederationState, clients: Sequence["KGEClient"]) -> None:
        """Scatter the device-resident tables back into per-client params.

        The ONLY host transfer of entity tables in the device-engine paths —
        since the batched evaluator (:mod:`repro.core.evaluation`) took over
        eval boundaries, the simulation calls this exactly once, at the
        terminal best-snapshot materialization.  Optimizer state stays on
        device (clients' own opt_state is not consulted again after
        ``init_state``).
        """
        ent = np.asarray(state.arrays.params["entity"])
        rel = np.asarray(state.arrays.params["relation"])
        for c, cl in enumerate(clients):
            n = cl.model.num_entities
            cl.params = {
                "entity": jnp.asarray(ent[c, :n]),
                "relation": jnp.asarray(rel[c]),
            }

    # --------------------------------------------------------------- cycles
    @staticmethod
    def _advance(key):
        key, kb, kj = jax.random.split(key, 3)
        return key, kb, kj

    def train_cycle(self, state: FederationState):
        """``local_epochs`` of device training.  Returns (state', jitter, loss).

        Used by the ``engine="batched"`` oracle (followed by
        :meth:`comm_round`) and by the no-communication ``single`` protocol;
        the jitter output feeds the sparse round so the two-program path
        consumes the same random stream as the fused program.
        """
        key, kb, kj = self._advance(state.key)
        arrays, jitter, loss = self._train(state.arrays, kb, kj, self.consts)
        return FederationState(arrays, key), jitter, loss

    def _require_t(self, t):
        if t is None:
            raise ValueError(
                "this engine has an active FaultSchedule; communication "
                "rounds need the absolute round index t to draw the masks"
            )
        return jnp.int32(t)

    def comm_round(self, state: FederationState, jitter, sync: bool, t=None):
        """One communication round on resident state.  Returns (state', down),
        plus the round's :class:`~repro.core.telemetry.RoundTelemetry` when
        the engine was built with ``telemetry=True``.

        With an active fault schedule, ``t`` (the absolute round index) is
        required — the round's participation/drop masks are drawn from it
        inside the program.
        """
        if self._sched is not None:
            tt = self._require_t(t)
            if sync:
                out = self._comm_sync_f(state.arrays, self.consts, tt)
            else:
                out = self._comm_sparse_f(
                    state.arrays, jitter, self.consts, tt
                )
        elif sync:
            out = self._comm_sync(state.arrays, self.consts)
        else:
            out = self._comm_sparse(state.arrays, jitter, self.consts)
        if self._tel:
            arrays, down, rec = out
            return FederationState(arrays, state.key), down, rec
        arrays, down = out
        return FederationState(arrays, state.key), down

    def fused_cycle(self, state: FederationState, sync: bool, t=None):
        """One fused train+communicate cycle as a single compiled program.

        Returns ``(state', down_count (C,) device array, loss (C,))`` — the
        down counts stay on device so the caller can defer ledger accounting
        to eval boundaries — plus the round's device-resident
        :class:`~repro.core.telemetry.RoundTelemetry` when the engine was
        built with ``telemetry=True``.  ``t`` as in :meth:`comm_round`.
        """
        key, kb, kj = self._advance(state.key)
        if self._sched is not None:
            fn = self._fused_sync_f if sync else self._fused_sparse_f
            out = fn(state.arrays, kb, kj, self.consts, self._require_t(t))
        else:
            fn = self._fused_sync if sync else self._fused_sparse
            out = fn(state.arrays, kb, kj, self.consts)
        if self._tel:
            arrays, down, loss, rec = out
            return FederationState(arrays, key), down, loss, rec
        arrays, down, loss = out
        return FederationState(arrays, key), down, loss


class SuperstepEngine(CycleEngine):
    """Whole ISM supersteps — ``s`` sparse rounds + 1 sync round — as ONE
    compiled program.

    :class:`CycleEngine` fused train+communicate into one program *per
    round*, but the host loop still re-entered python between rounds: one
    eager PRNG split plus one program dispatch per round, ``s+1`` times per
    ISM period.  A *superstep* ``lax.scan``-s the whole period (in general:
    any span of the round schedule, run-length-encoded by
    :func:`repro.core.sync.compress_schedule` into static ``(kind, n)``
    segments) inside a single ``jax.jit`` (host) or a single ``shard_map``
    program over the client axis (pod).  The scan carries
    ``(StateArrays, PRNG key)`` and stacks the per-round download counts and
    losses as device-side ledger accumulators, so the host touches the
    device ONCE per superstep instead of once per round.
    :meth:`superstep_with_eval` extends the plan vocabulary with ``"eval"``
    segments (:data:`repro.core.sync.PLAN_KINDS`) running the batched
    evaluator (:mod:`repro.core.evaluation`) in-program, so an ISM span AND
    its boundary eval are one dispatch returning a ``(C, 5)`` metric block.

    Equivalence contract: each scan step performs *exactly* the per-cycle
    key schedule (one 3-way ``jax.random.split``) and runs the same
    ``train_core`` / ``comm_core`` functions as :meth:`fused_cycle`, so a
    superstep over ``kinds`` is trajectory- and ledger-bitwise-identical to
    the same rounds driven one :meth:`fused_cycle` call at a time
    (tests/test_state.py property-tests this).

    Compiled programs are cached per distinct plan; with a periodic ISM
    schedule and eval-aligned supersteps only a handful of plans ever occur.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._superstep_cache: dict = {}

    # ------------------------------------------------------------ compiling
    def _compile_superstep(self, plan, eval_core=None):
        """Compile one plan into one program.

        ``plan`` is the :func:`repro.core.sync.compress_schedule` RLE of a
        span; ``("eval", n)`` segments (requiring ``eval_core``) run the
        batched evaluator's program body in place, on the state as of that
        point in the span — the program then additionally takes the
        :class:`repro.core.evaluation.EvalBank` as its last argument and
        returns the stacked ``(C, 5)`` metric blocks.
        """
        train_core = self._train_core_fn
        comm_core = self._comm_core_fn
        sched = self._sched
        tel = self._tel
        round_faults_of = self._round_faults
        has_eval = any(kind == "eval" for kind, _ in plan)
        if has_eval and eval_core is None:
            raise ValueError("plan contains eval segments but no eval_core")

        def prog(arrays, key, consts, *extra):
            # with an active fault schedule the program takes the span's
            # absolute starting round t0 right after consts and carries the
            # round index through the scan — every round (including "none"
            # rounds, which consume a round index but draw no masks)
            # advances it, eval segments do not
            if sched is not None:
                t0, eval_args = extra[0], extra[1:]
            else:
                t0, eval_args = None, extra

            def seg_step(kind):
                def step(carry, _):
                    if sched is not None:
                        arrays, key, t = carry
                    else:
                        arrays, key = carry
                    # identical key schedule to CycleEngine._advance
                    key, kb, kj = jax.random.split(key, 3)
                    arrays, jitter, loss = train_core(arrays, kb, kj, consts)
                    rf = (
                        round_faults_of(consts, t)
                        if sched is not None and kind != "none" else None
                    )
                    rec = None
                    if kind == "sync":
                        out = comm_core(
                            arrays, jitter, consts, do_sync=True, rf=rf
                        )
                        if tel:
                            arrays, down, rec = out
                        else:
                            arrays, down = out
                    elif kind == "sparse":
                        out = comm_core(
                            arrays, jitter, consts, do_sync=False, rf=rf
                        )
                        if tel:
                            arrays, down, rec = out
                        else:
                            arrays, down = out
                    else:  # "none": local training only
                        down = (loss * 0).astype(jnp.int32)
                    ys = (down, loss) if rec is None else (down, loss, rec)
                    if sched is not None:
                        return (arrays, key, t + 1), ys
                    return (arrays, key), ys

                return step

            downs, losses, recs, blocks = [], [], [], []
            carry = (
                (arrays, key, t0) if sched is not None else (arrays, key)
            )
            for kind, n in plan:
                if kind == "prefetch":
                    # host-store staging marker (repro.core.store): a pure
                    # scheduling hint consumed by the tiered driver; the
                    # device program has nothing to stage
                    continue
                if kind == "eval":
                    # in-program evaluation on the state as of this point —
                    # no state/key mutation, only the (C, 5) metric block
                    blocks.extend(
                        eval_core(carry[0].params, eval_args[0])
                        for _ in range(n)
                    )
                    continue
                # unrolling removes the while-loop carry copies XLA:CPU
                # inserts around the big resident buffers (~3% per-round at
                # FB15k scale); capped so pathological eval spans don't
                # explode compile time
                carry, ys = jax.lax.scan(
                    seg_step(kind), carry, None, length=n,
                    unroll=min(n, 8),
                )
                if tel and kind != "none":
                    d, l, rc = ys
                    # per-round record pytrees sliced INSIDE the program,
                    # mirroring the download counts below
                    recs.extend(
                        jax.tree.map(lambda a, i=i: a[i], rc)
                        for i in range(n)
                    )
                else:
                    d, l = ys[0], ys[1]
                if kind == "sparse":
                    # per-round (C,) rows sliced INSIDE the program, so the
                    # host never dispatches per-round slice ops
                    downs.extend(d[i] for i in range(n))
                losses.append(l)
            out = (carry[0], carry[1], tuple(downs), tuple(losses))
            if tel:
                out = out + (tuple(recs),)
            return out + (tuple(blocks),) if has_eval else out

        n_sparse = sum(n for kind, n in plan if kind == "sparse")
        n_eval = sum(n for kind, n in plan if kind == "eval")
        if self._mesh is None:
            return jax.jit(prog, donate_argnums=(0,))
        pa = self._arrays_spec()  # StateArrays-shaped (or plain prefix)
        p = jax.sharding.PartitionSpec(self._axis)
        r = jax.sharding.PartitionSpec()
        # per-segment loss stacks rounds on axis 0; clients stay on axis 1
        seg = tuple(
            jax.sharding.PartitionSpec(None, self._axis)
            for kind, _ in plan if kind not in ("eval", "prefetch")
        )
        in_specs = (pa, r, p) + ((r,) if sched is not None else ())
        in_specs = in_specs + ((self._bank_spec(),) if has_eval else ())
        out_specs = (pa, r, (p,) * n_sparse, seg)
        if tel:
            n_rec = sum(n for kind, n in plan if kind in ("sparse", "sync"))
            out_specs = out_specs + ((record_spec(p),) * n_rec,)
        if has_eval:
            out_specs = out_specs + ((p,) * n_eval,)
        return jax.jit(
            shard_map(
                prog, mesh=self._mesh, in_specs=in_specs, out_specs=out_specs,
            ),
            donate_argnums=(0,),
        )

    # -------------------------------------------------------------- driving
    def superstep(self, state: FederationState, kinds: Sequence[str], t0=None):
        """Run ``len(kinds)`` rounds as one compiled program.

        ``kinds`` is the per-round ISM schedule for the span (each entry one
        of :data:`repro.core.sync.ROUND_KINDS`), e.g. a full FedS period
        ``("sparse",) * s + ("sync",)``.  Returns
        ``(state', per_round, losses)`` where ``per_round`` aligns with
        ``kinds`` as ``(kind, down_count | None)`` pairs — down counts are
        device-resident ``(C,)`` slices of the scanned accumulator, so the
        caller can defer ledger flushing to eval boundaries exactly like the
        per-cycle path — and ``losses`` is one ``(n, C)`` device array per
        plan segment.
        """
        plan = compress_schedule(kinds)
        if any(kind == "eval" for kind, _ in plan):
            raise ValueError(
                "superstep() takes round kinds only; use superstep_with_eval "
                "to fold an eval segment into the program"
            )
        fn = self._superstep_cache.get(plan)
        if fn is None:
            fn = self._superstep_cache[plan] = self._compile_superstep(plan)
        args = (state.arrays, state.key, self.consts)
        if self._sched is not None:
            args = args + (self._require_t(t0),)
        if self._tel:
            arrays, key, downs, losses, recs = fn(*args)
            per_round = self._align(kinds, downs, recs)
        else:
            arrays, key, downs, losses = fn(*args)
            per_round = self._align(kinds, downs)
        return FederationState(arrays, key), per_round, losses

    def superstep_with_eval(
        self,
        state: FederationState,
        kinds: Sequence[str],
        evaluator,  # repro.core.evaluation.BatchedEvaluator
        split: str = "valid",
        t0=None,
    ):
        """Run ``len(kinds)`` rounds PLUS the boundary evaluation as one
        compiled program.

        The plan is ``kinds`` with an ``"eval"`` segment appended
        (:data:`repro.core.sync.PLAN_KINDS`), so the filtered-ranking eval
        of :class:`repro.core.evaluation.BatchedEvaluator` runs on-device
        inside the same scanned program as the rounds — the host never
        syncs entity tables at the boundary, it reads back one ``(C, 5)``
        metric block.  Returns ``(state', per_round, losses, block)`` with
        the first three exactly as :meth:`superstep`.
        """
        plan = compress_schedule(tuple(kinds) + ("eval",))
        # the evaluator is part of the key: its eval_core closes over
        # method/gamma/chunk, so two evaluators sharing a plan+split must
        # not reuse each other's compiled program
        cache_key = (plan, split, evaluator)
        fn = self._superstep_cache.get(cache_key)
        if fn is None:
            fn = self._superstep_cache[cache_key] = self._compile_superstep(
                plan, eval_core=evaluator.eval_core
            )
        args = (state.arrays, state.key, self.consts)
        if self._sched is not None:
            args = args + (self._require_t(t0),)
        if self._tel:
            arrays, key, downs, losses, recs, blocks = fn(
                *args, evaluator.banks[split]
            )
            per_round = self._align(kinds, downs, recs)
        else:
            arrays, key, downs, losses, blocks = fn(
                *args, evaluator.banks[split]
            )
            per_round = self._align(kinds, downs)
        return (
            FederationState(arrays, key),
            per_round,
            losses,
            blocks[0],
        )

    @staticmethod
    def _align(kinds, downs, recs=None):
        """Zip per-round kinds with their device-resident download counts.

        Without telemetry: ``(kind, down | None)`` pairs, as always.  With
        telemetry (``recs`` given): ``(kind, down | None, rec | None)``
        triples — comm rounds carry their :class:`RoundTelemetry`, ``"none"``
        rounds carry ``None``.
        """
        down_iter = iter(downs)
        if recs is None:
            return [
                (kind, next(down_iter) if kind == "sparse" else None)
                for kind in kinds
            ]
        rec_iter = iter(recs)
        return [
            (
                kind,
                next(down_iter) if kind == "sparse" else None,
                next(rec_iter) if kind != "none" else None,
            )
            for kind in kinds
        ]
