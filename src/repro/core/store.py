"""Host-tiered embedding store: device hot-row cache over host-resident tables.

Every other engine in the repo (:mod:`repro.core.state`) keeps the whole
padded ``(C, E_pad, D)`` entity table — plus Adam moments, ~3x that — device
resident, which makes the largest trainable graph a function of accelerator
memory.  Entity-axis sharding (``entity_axis`` on the engines) divides that
footprint by the mesh size; this module removes E from the device footprint
altogether, the way the large-scale KGE stacks train web-scale graphs: the
full tables live in **host** memory and only the rows a cycle actually
touches are staged into a fixed-size device cache.

The tier boundary is row-granular and exact:

* :class:`HostTieredStore` — host numpy tables (entity embeddings + Adam
  ``mu``/``nu``) plus the cache directory: slot occupancy, per-slot
  *temperature*, LRU clocks, and dirty bits.  Slots ``[0, ns_pad)`` pin the
  shared-entity rows (the FedS protocol reads/writes them every round);
  the remaining slots hold the training working set.  Eviction picks the
  coldest non-pinned slot, where temperature is an EMA of the paper's Eq. 1
  change score ``1 - cos(row_after_cycle, row_before_cycle)`` — the same
  signal the upload sparsifier ranks rows by, reused as cache admission
  policy (rows that are still moving stay resident).
* :class:`TieredCycleEngine` — the cycle driver.  Each cycle it (1) runs
  the same device batch-sampling program as
  :class:`repro.core.state.CycleEngine` (indices only — no embedding
  traffic), (2) splits the training scan into **stage segments** of
  ``stage_steps`` steps each: per segment, the unique touched rows are
  computed on host, misses staged into the cache (dirty evictees flushed
  to the host tier first), and one compiled program trains over the
  fixed-width **working view** ``W = ns_pad + stage_steps*B*(2+2N)`` and
  scatters it back, and (3) runs the FedS round on the pinned prefix —
  the shared rows are always resident, so communication (same
  :func:`repro.core.engine.batched_sparse_round` / ``batched_sync_round``
  bodies, codecs and EF residuals included) never touches the host tier.
  ``stage_steps``, not E, sets the device working-set width: a full epoch
  touches nearly every entity, so whole-cycle staging would degenerate to
  ``W ~ E``; per-segment staging is what makes the device footprint a
  config value.

Contracts (tests/test_store.py):

* **Cache-size transparency**: the compiled program only ever sees the
  working view, whose width and contents are independent of the cache
  capacity ``H`` — so trajectories are **bitwise identical** across cache
  sizes; ``H`` only changes how often a touched row is already resident
  (the hit rate / host<->device traffic the scale benchmark measures).
* **Sparse-Adam semantics**: rows outside a cycle's working view receive
  no moment decay that cycle (the dense engines decay every row every
  step), so the tiered trajectory is intentionally NOT bitwise equal to
  :class:`repro.core.state.CycleEngine` — it is the standard semantics of
  every host-tiered KGE trainer, and the convergence benchmarks treat it
  as its own engine family.

``"prefetch"`` plan segments (:data:`repro.core.sync.PLAN_KINDS`) mark the
points of a superstep plan where this driver re-stages the cache; compiled
engine programs skip them, so plans with and without markers are
schedule-equivalent.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codecs import IdentityCodec, WireCodec
from repro.core.engine import (
    batched_sparse_round,
    batched_sync_round,
    build_padded_views,
)
from repro.core.sparsify import change_scores
from repro.core.telemetry import (
    NUM_SCORE_BUCKETS,
    RoundTelemetry,
    TelemetryArrays,
    init_telemetry_arrays,
    nonfinite_count,
    residual_mass,
    shared_divergence,
    span as telemetry_span,
    update_norm,
)
from repro.data.loader import stack_padded_triples
from repro.kge.scoring import get_scoring, per_sample_losses
from repro.train.optimizer import AdamState, adam_update


class DeviceCache(NamedTuple):
    """The device-resident hot tier: ``H`` row slots per client."""

    ent: jnp.ndarray  # (C, H, D) embedding rows
    mu: jnp.ndarray  # (C, H, D) Adam first moments
    nu: jnp.ndarray  # (C, H, D) Adam second moments


class TieredState(NamedTuple):
    """Device-resident state of the tiered driver (everything but the cold
    entity rows, which live in :class:`HostTieredStore`)."""

    cache: DeviceCache
    rel: jnp.ndarray  # (C, R, Dr) relation tables (fully resident — small)
    rel_mu: jnp.ndarray
    rel_nu: jnp.ndarray
    step: jnp.ndarray  # () int32 shared Adam step (lockstep clients)
    hist: jnp.ndarray  # (C, Ns, D) upload history
    res: jnp.ndarray  # (C, Ns | 0, D) EF residual bank
    key: jnp.ndarray  # cycle PRNG key
    tel: Optional[TelemetryArrays] = None  # flight-recorder overlap carry
    #                   (repro.core.telemetry); None with telemetry off


@jax.jit
def _cache_gather(cache: DeviceCache, ci, si):
    return cache.ent[ci, si], cache.mu[ci, si], cache.nu[ci, si]


@jax.jit
def _cache_scatter(cache: DeviceCache, ci, si, ent, mu, nu):
    return DeviceCache(
        ent=cache.ent.at[ci, si].set(ent),
        mu=cache.mu.at[ci, si].set(mu),
        nu=cache.nu.at[ci, si].set(nu),
    )


class HostTieredStore:
    """Host tier + cache directory.  All device arrays flow functionally
    through :meth:`stage` / :meth:`flush`; the store itself holds only host
    numpy state and bookkeeping."""

    def __init__(
        self,
        ent: np.ndarray,  # (C, E, D) host entity tables (padded rows zero)
        mu: np.ndarray,
        nu: np.ndarray,
        pinned: Sequence[np.ndarray],  # per-client local row ids, pinned
        cache_slots: int,
        ns_pad: int,
        temp_beta: float = 0.9,
    ):
        self.ent, self.mu, self.nu = ent, mu, nu
        self.c_n, self.e_rows, self.dim = ent.shape
        self.ns_pad = int(ns_pad)
        self.h = int(cache_slots)
        if self.h <= self.ns_pad:
            raise ValueError(
                f"cache_slots={self.h} leaves no dynamic slots beyond the "
                f"{self.ns_pad} pinned shared-row slots"
            )
        self.temp_beta = float(temp_beta)
        # directory: slot -> host row (-1 free), row -> slot (dynamic only)
        self.slot_row = np.full((self.c_n, self.h), -1, np.int64)
        self.row_slot: list[dict] = [dict() for _ in range(self.c_n)]
        self.pin_pos: list[dict] = []
        self.temp = np.zeros((self.c_n, self.h), np.float32)
        self.clock = np.zeros((self.c_n, self.h), np.int64)
        self.dirty = np.zeros((self.c_n, self.h), bool)
        self._free: list[list[int]] = [
            list(range(self.h - 1, self.ns_pad - 1, -1)) for _ in range(self.c_n)
        ]
        self._tick = 0
        self.stats = {
            "hits": 0, "misses": 0, "evictions": 0,
            "h2d_bytes": 0, "d2h_bytes": 0, "cycles": 0,
        }
        for c, rows in enumerate(pinned):
            rows = np.asarray(rows, np.int64)
            self.slot_row[c, : len(rows)] = rows
            self.pin_pos.append({int(e): i for i, e in enumerate(rows)})

    # ------------------------------------------------------------- tiering
    def seed_cache(self) -> DeviceCache:
        """Fresh cache with the pinned shared rows staged."""
        cache = DeviceCache(
            ent=jnp.zeros((self.c_n, self.h, self.dim), jnp.float32),
            mu=jnp.zeros((self.c_n, self.h, self.dim), jnp.float32),
            nu=jnp.zeros((self.c_n, self.h, self.dim), jnp.float32),
        )
        ci, si = np.nonzero(self.slot_row >= 0)
        rows = self.slot_row[ci, si]
        return _cache_scatter(
            cache, jnp.asarray(ci), jnp.asarray(si),
            jnp.asarray(self.ent[ci, rows]),
            jnp.asarray(self.mu[ci, rows]),
            jnp.asarray(self.nu[ci, rows]),
        )

    def stage(
        self, cache: DeviceCache, touched: Sequence[np.ndarray]
    ) -> tuple[DeviceCache, list[np.ndarray]]:
        """Make each client's ``touched`` (unique, non-pinned) rows resident.

        Flushes dirty evictees to the host tier, stages the misses from it,
        and returns the per-client slot arrays aligned with ``touched``.
        Values are exact row copies both ways, which is what makes the
        trajectory independent of the cache capacity.
        """
        self._tick += 1
        slot_lists: list[np.ndarray] = []
        pendings: list[list[int]] = []
        victims: list[list[int]] = []
        ev_c: list[int] = []
        ev_s: list[int] = []
        ev_rows: list[int] = []
        # pass 1: hits + victim selection (directory untouched so far)
        for c, rows in enumerate(touched):
            rs = self.row_slot[c]
            slots = np.full(len(rows), -1, np.int64)
            pending = []  # indices into `rows` that missed
            held = set()  # slots this cycle must not evict
            for i, e in enumerate(rows):
                s = rs.get(int(e), -1)
                if s >= 0:
                    slots[i] = s
                    held.add(s)
                else:
                    pending.append(i)
            self.stats["hits"] += len(rows) - len(pending)
            self.stats["misses"] += len(pending)
            vics: list[int] = []
            n_evict = max(0, len(pending) - len(self._free[c]))
            if n_evict:
                cand = [
                    s for s in range(self.ns_pad, self.h)
                    if self.slot_row[c, s] >= 0 and s not in held
                ]
                if len(cand) < n_evict:
                    raise ValueError(
                        f"cache overflow: client {c} touches "
                        f"{len(rows)} rows but only "
                        f"{self.h - self.ns_pad} dynamic slots exist"
                    )
                order = np.lexsort((self.clock[c, cand], self.temp[c, cand]))
                vics = [cand[j] for j in order[:n_evict]]
                for s in vics:
                    if self.dirty[c, s]:
                        ev_c.append(c)
                        ev_s.append(s)
                        ev_rows.append(int(self.slot_row[c, s]))
            slot_lists.append(slots)
            pendings.append(pending)
            victims.append(vics)
        # flush dirty evictees device -> host BEFORE their slots are reused
        if ev_c:
            ent, mu, nu = _cache_gather(
                cache, jnp.asarray(np.asarray(ev_c)),
                jnp.asarray(np.asarray(ev_s)),
            )
            ec, er = np.asarray(ev_c), np.asarray(ev_rows)
            self.ent[ec, er] = np.asarray(ent)
            self.mu[ec, er] = np.asarray(mu)
            self.nu[ec, er] = np.asarray(nu)
            self.stats["d2h_bytes"] += int(len(ev_c)) * self.dim * 4 * 3
        # pass 2: retire victims, assign miss slots
        miss_c: list[int] = []
        miss_s: list[int] = []
        miss_rows: list[int] = []
        for c, rows in enumerate(touched):
            rs = self.row_slot[c]
            free = self._free[c]
            for s in victims[c]:
                del rs[int(self.slot_row[c, s])]
                self.slot_row[c, s] = -1
                self.dirty[c, s] = False
                free.append(s)
            self.stats["evictions"] += len(victims[c])
            slots = slot_lists[c]
            for i in pendings[c]:
                s = free.pop()
                e = int(rows[i])
                slots[i] = s
                rs[e] = s
                self.slot_row[c, s] = e
                self.temp[c, s] = 0.0
                miss_c.append(c)
                miss_s.append(s)
                miss_rows.append(e)
        if miss_c:
            ci = jnp.asarray(np.asarray(miss_c))
            si = jnp.asarray(np.asarray(miss_s))
            rows = np.asarray(miss_rows)
            mc = np.asarray(miss_c)
            cache = _cache_scatter(
                cache, ci, si,
                jnp.asarray(self.ent[mc, rows]),
                jnp.asarray(self.mu[mc, rows]),
                jnp.asarray(self.nu[mc, rows]),
            )
            self.stats["h2d_bytes"] += int(rows.size) * self.dim * 4 * 3
        return cache, slot_lists

    def after_segment(self, view: np.ndarray, temp_sig: np.ndarray) -> None:
        """Fold a segment's change-score signal into slot temperatures and
        mark the view's slots dirty.  ``view``/``temp_sig`` are (C, W)."""
        b = self.temp_beta
        for c in range(self.c_n):
            m = view[c] < self.h
            s = view[c][m]
            self.temp[c, s] = b * self.temp[c, s] + (1.0 - b) * temp_sig[c][m]
            self.clock[c, s] = self._tick
            self.dirty[c, s] = True

    def mark_pinned_dirty(self) -> None:
        """Flag the pinned prefix for write-back (a comm round mutated it).

        Unoccupied pinned padding slots flip too, but :meth:`flush` masks on
        slot occupancy so they never reach the host tier."""
        self.dirty[:, : self.ns_pad] = True

    def flush(self, cache: DeviceCache) -> None:
        """Write every dirty resident slot back to the host tier."""
        ci, si = np.nonzero(self.dirty & (self.slot_row >= 0))
        if not len(ci):
            return
        ent, mu, nu = _cache_gather(cache, jnp.asarray(ci), jnp.asarray(si))
        rows = self.slot_row[ci, si]
        self.ent[ci, rows] = np.asarray(ent)
        self.mu[ci, rows] = np.asarray(mu)
        self.nu[ci, rows] = np.asarray(nu)
        self.dirty[ci, si] = False
        self.stats["d2h_bytes"] += int(len(ci)) * self.dim * 4 * 3

    # --------------------------------------------------------------- stats
    @property
    def hit_rate(self) -> float:
        n = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / n if n else 1.0

    def device_bytes(self) -> int:
        """Resident device footprint of the hot tier (cache slots x 3)."""
        return self.c_n * self.h * self.dim * 4 * 3

    def host_bytes(self) -> int:
        """Host-tier footprint (full tables x 3)."""
        return self.c_n * self.e_rows * self.dim * 4 * 3


class TieredCycleEngine:
    """Train+communicate cycles over :class:`HostTieredStore` state.

    Same federation inputs as :class:`repro.core.state.CycleEngine`
    (homogeneous clients — the tiered trainer supports only the lockstep
    flat path), but device memory holds ``cache_slots`` rows per client
    instead of ``E_max``.  Training runs as stage segments over the fixed
    working view ``W = ns_pad + t_cap``, whose width is set by the batch
    plan (``t_cap`` bounds a SEGMENT's unique non-pinned rows), NOT by the
    cache size — which is what makes trajectories cache-size transparent.
    """

    def __init__(
        self,
        clients: Sequence,
        views: Sequence,
        num_global_entities: int,
        *,
        sparsity_p: float,
        local_epochs: int,
        codec: Optional[WireCodec] = None,
        cache_slots: int = 0,
        stage_steps: int = 0,
        temp_beta: float = 0.9,
        telemetry: bool = False,
    ):
        self.views = list(views)
        self.num_global = int(num_global_entities)
        self.num_clients = len(clients)
        self._tel = bool(telemetry)
        c0 = clients[0]
        self.method = c0.method
        self.gamma = float(c0.gamma)
        self.lr = float(c0.lr)
        self.temp = float(c0.temp)
        self.dim = int(c0.model.dim)
        self.rel_dim = int(c0.model.rel_dim)
        self.num_relations = int(c0.model.num_relations)
        self.local_epochs = int(local_epochs)
        self.num_negatives = int(c0.loader.num_negatives)
        self.codec = codec if codec is not None else IdentityCodec()
        gid, valid, self.k_per_client, self.ns_max, self.k_max = (
            build_padded_views(self.views, self.num_global, sparsity_p)
        )
        self.ns_pad = self.ns_max
        self.num_entities = np.asarray(
            [c.model.num_entities for c in clients], np.int32
        )
        self.e_max = int(self.num_entities.max())
        triples, counts = stack_padded_triples([c.data.train for c in clients])
        batch_sizes = np.asarray([c.loader.batch_size for c in clients])
        steps = np.asarray([c.loader.batches_per_epoch for c in clients])
        if len(set(batch_sizes.tolist())) > 1 or len(set(steps.tolist())) > 1:
            raise ValueError(
                "TieredCycleEngine supports only lockstep clients "
                "(equal batch size and batches-per-epoch)"
            )
        self.b_max = int(batch_sizes.max())
        self.s_max = int(steps.max())
        self.scan_len = self.local_epochs * self.s_max
        self.stage_steps = (
            self.scan_len if stage_steps <= 0
            else min(int(stage_steps), self.scan_len)
        )
        # worst-case unique non-pinned rows one STAGE SEGMENT can touch —
        # this, not E, sets the device working-set width
        self.t_cap = int(min(
            self.e_max,
            self.stage_steps * self.b_max * (2 + 2 * self.num_negatives),
        ))
        self.w = self.ns_pad + self.t_cap
        self.cache_slots = max(int(cache_slots), self.w)
        self.temp_beta = float(temp_beta)
        self._gid = jnp.asarray(gid)
        self._valid = jnp.asarray(valid)
        self._k = jnp.asarray(self.k_per_client)
        self._cids = jnp.arange(self.num_clients, dtype=jnp.int32)
        self._triples = jnp.asarray(triples)
        self._num_train = jnp.asarray(counts)
        self._num_ent = jnp.asarray(self.num_entities)
        self._plan = self._make_plan()
        self._jitter_fn = self._make_jitter()
        self._train_seg = jax.jit(self._make_train_seg(), donate_argnums=(0,))
        comm = self._make_comm()
        self._comm = {
            kind: jax.jit(
                functools.partial(comm, do_sync=kind == "sync"),
                donate_argnums=(0,),
            )
            for kind in ("sparse", "sync")
        }

    # ----------------------------------------------------- device programs
    def _make_plan(self):
        scan_len, b_max, n_neg = self.scan_len, self.b_max, self.num_negatives

        def sample_one(cid, tri, t_c, e_c, kb):
            # EXACT copy of CycleEngine's sampler: same fold_in sequence and
            # draw shapes -> same batches for the same cycle key
            kc = jax.random.fold_in(kb, cid)
            pi = jax.random.randint(
                jax.random.fold_in(kc, 1), (scan_len, b_max), 0, t_c
            )
            pos = jnp.take(tri, pi, axis=0)
            neg_t = jax.random.randint(
                jax.random.fold_in(kc, 2), (scan_len, b_max, n_neg), 0, e_c
            )
            neg_h = jax.random.randint(
                jax.random.fold_in(kc, 3), (scan_len, b_max, n_neg), 0, e_c
            )
            return pos, neg_t, neg_h

        def plan(kb):
            return jax.vmap(sample_one, in_axes=(0, 0, 0, 0, None))(
                self._cids, self._triples, self._num_train, self._num_ent, kb
            )

        return jax.jit(plan)

    def _make_jitter(self):
        ns_max = self.ns_max

        def jit_jitter(kj):
            return jax.vmap(
                lambda cid: jax.random.uniform(
                    jax.random.fold_in(kj, cid), (ns_max,)
                )
            )(self._cids)

        return jax.jit(jit_jitter)

    def _make_train_seg(self):
        """One stage segment: gather working view -> train scan -> scatter
        back.  ``pos``/``neg_*`` carry the segment's steps; the program
        retraces once per distinct segment length (at most two: the body
        and a shorter tail)."""
        c_n, w, d = self.num_clients, self.w, self.dim
        r_n, r_d = self.num_relations, self.rel_dim
        b_max, n_neg = self.b_max, self.num_negatives
        method, gamma, lr, temp = self.method, self.gamma, self.lr, self.temp
        score = get_scoring(method).score
        cb = c_n * b_max

        def scores_of(rows, rel):
            h_e, t_e = rows[:cb], rows[cb : 2 * cb]
            nt_e = rows[2 * cb : (2 + n_neg) * cb].reshape(cb, n_neg, -1)
            nh_e = rows[(2 + n_neg) * cb :].reshape(cb, n_neg, -1)
            pos_s = score(h_e, rel, t_e, gamma)
            neg_t_s = score(h_e[:, None, :], rel[:, None, :], nt_e, gamma)
            neg_h_s = score(nh_e, rel[:, None, :], t_e[:, None, :], gamma)
            return pos_s, jnp.concatenate([neg_t_s, neg_h_s], -1)

        cid_rows = jnp.concatenate(
            [jnp.repeat(jnp.arange(c_n, dtype=jnp.int32), b_max)] * 2
            + [jnp.repeat(jnp.arange(c_n, dtype=jnp.int32), b_max * n_neg)] * 2
        )
        roff = jnp.arange(c_n, dtype=jnp.int32) * r_n

        def train_seg(cache, rel, rel_mu, rel_nu, step, view, pos, neg_t, neg_h):
            h_slots = cache.ent.shape[1]
            sent = view >= h_slots  # (C, W) sentinel (unused view tail)
            vi = jnp.where(sent, 0, view)
            live = (~sent)[:, :, None]
            take = lambda t: jnp.where(  # noqa: E731
                live, jnp.take_along_axis(t, vi[:, :, None], axis=1), 0.0
            )
            ent_w, mu_w, nu_w = take(cache.ent), take(cache.mu), take(cache.nu)
            old_ent = ent_w
            params_f = {
                "entity": ent_w.reshape(c_n * w, d),
                "relation": rel.reshape(c_n * r_n, r_d),
            }
            opt_f = AdamState(
                step=step,
                mu={"entity": mu_w.reshape(c_n * w, d),
                    "relation": rel_mu.reshape(c_n * r_n, r_d)},
                nu={"entity": nu_w.reshape(c_n * w, d),
                    "relation": rel_nu.reshape(c_n * r_n, r_d)},
            )
            wn = jnp.full((c_n, b_max), 1.0 / b_max, jnp.float32)

            def step_fn(carry, x):
                params_f, opt_f = carry
                p, nt, nh = x  # view-space indices, (C, B, 3) / (C, B, N)
                r = (p[:, :, 1] + roff[:, None]).reshape(-1)
                e_idx = cid_rows * w + jnp.concatenate([
                    p[:, :, 0].reshape(-1), p[:, :, 2].reshape(-1),
                    nt.reshape(-1), nh.reshape(-1),
                ])

                def loss_fn(rows, rel_rows):
                    pos_s, neg_s = scores_of(rows, rel_rows)
                    per = per_sample_losses(pos_s, neg_s, method, temp)
                    loss_c = (per.reshape(c_n, b_max) * wn).sum(axis=1) / 2.0
                    return loss_c.sum(), loss_c

                (_, loss_c), (g_rows, g_rel) = jax.value_and_grad(
                    loss_fn, argnums=(0, 1), has_aux=True
                )(params_f["entity"][e_idx], params_f["relation"][r])
                grads = {
                    "entity": jnp.zeros_like(params_f["entity"])
                    .at[e_idx].add(g_rows),
                    "relation": jnp.zeros_like(params_f["relation"])
                    .at[r].add(g_rel),
                }
                params_f, opt_f = adam_update(grads, opt_f, params_f, lr)
                return (params_f, opt_f), loss_c

            (params_f, opt_f), losses = jax.lax.scan(
                step_fn, (params_f, opt_f),
                (jnp.moveaxis(pos, 0, 1), jnp.moveaxis(neg_t, 0, 1),
                 jnp.moveaxis(neg_h, 0, 1)),
            )
            ent_w = params_f["entity"].reshape(c_n, w, d)
            mu_w = opt_f.mu["entity"].reshape(c_n, w, d)
            nu_w = opt_f.nu["entity"].reshape(c_n, w, d)
            # -------- Eq. 1 change score of the segment = slot temperature
            temp_sig = change_scores(
                ent_w.reshape(c_n * w, d), old_ent.reshape(c_n * w, d)
            ).reshape(c_n, w)
            temp_sig = jnp.where(sent, 0.0, temp_sig)
            # -------------------- scatter the view back into the cache
            cw = jnp.broadcast_to(
                jnp.arange(c_n, dtype=view.dtype)[:, None], (c_n, w)
            )
            flat = jnp.where(
                sent, c_n * h_slots, cw * h_slots + view
            ).reshape(-1)
            put = lambda t, v: (  # noqa: E731
                t.reshape(-1, d).at[flat].set(v.reshape(-1, d), mode="drop")
                .reshape(c_n, h_slots, d)
            )
            cache = DeviceCache(
                ent=put(cache.ent, ent_w),
                mu=put(cache.mu, mu_w),
                nu=put(cache.nu, nu_w),
            )
            return (
                cache,
                params_f["relation"].reshape(c_n, r_n, r_d),
                opt_f.mu["relation"].reshape(c_n, r_n, r_d),
                opt_f.nu["relation"].reshape(c_n, r_n, r_d),
                opt_f.step, losses, temp_sig,
            )

        return train_seg

    def _make_comm(self):
        """The FedS round over the pinned prefix — the shared rows are
        always cache-resident at slots ``[0, ns_pad)``, so communication
        never touches the host tier."""
        c_n, ns_pad, k_max = self.num_clients, self.ns_pad, self.k_max
        num_global, codec = self.num_global, self.codec
        tel = self._tel

        def comm(cache, hist, res, jitter, gid, valid, k, prev=None, *, do_sync):
            rec = None
            new_prev = prev
            emb = jnp.where(valid[:, :, None], cache.ent[:, :ns_pad], 0.0)
            if do_sync:
                rows, hist = batched_sync_round(
                    emb, gid, valid, num_global=num_global, axis_name=None,
                )
                down = jnp.zeros((c_n,), jnp.int32)
                # full exchange transmits exact values; stale residuals would
                # re-inject pre-sync error (matches CycleEngine comm_core)
                res = jnp.zeros_like(res) if codec.has_residual else res
                if tel:
                    # dense exchange: num_shared rows billed each leg, no
                    # Top-K signals; the overlap carry passes through
                    onesf = jnp.ones((c_n,), jnp.float32)
                    billed = valid.sum(axis=1).astype(jnp.int32)
                    div_mean, div_max = shared_divergence(
                        rows, gid, valid, num_global
                    )
                    rec = RoundTelemetry(
                        up_rows=billed,
                        dn_rows=billed,
                        overlap=jnp.zeros((c_n,), jnp.int32),
                        res_mass=residual_mass(res),
                        part=onesf,
                        up_ok=onesf,
                        dn_ok=onesf,
                        age=jnp.zeros((c_n,), jnp.int32),
                        score_hist=jnp.zeros(
                            (c_n, NUM_SCORE_BUCKETS), jnp.int32
                        ),
                        div_mean=div_mean,
                        div_max=div_max,
                        upd_norm=update_norm(rows, emb, valid),
                        nonfinite=nonfinite_count(rows, valid),
                    )
            else:
                # halve after the f32 cast (mirrors RoundEngine.sparse_round)
                j = jnp.asarray(jitter, jnp.float32) * 0.5
                out = batched_sparse_round(
                    emb, hist, gid, valid, k, j,
                    k_max=k_max, num_global=num_global, codec=codec,
                    axis_name=None, res=res, prev=prev,
                )
                rows, hist, down, res = out[:4]
                if tel:
                    rec, new_prev = out[-2], out[-1]
            ent = cache.ent.at[:, :ns_pad].set(
                jnp.where(valid[:, :, None], rows, cache.ent[:, :ns_pad])
            )
            new_cache = DeviceCache(ent, cache.mu, cache.nu)
            if tel:
                return new_cache, hist, res, down, rec, new_prev
            return new_cache, hist, res, down

        return comm

    # ------------------------------------------------------ state plumbing
    def init_state(
        self, clients: Sequence, seed: int = 0
    ) -> tuple[HostTieredStore, TieredState]:
        c_n, d = self.num_clients, self.dim
        ent = np.zeros((c_n, self.e_max, d), np.float32)
        mu = np.zeros_like(ent)
        nu = np.zeros_like(ent)
        rel = np.zeros((c_n, self.num_relations, self.rel_dim), np.float32)
        rel_mu, rel_nu = np.zeros_like(rel), np.zeros_like(rel)
        hist = np.zeros((c_n, self.ns_pad, d), np.float32)
        steps = set()
        for c, cl in enumerate(clients):
            n = cl.model.num_entities
            ent[c, :n] = np.asarray(cl.params["entity"], np.float32)
            rel[c] = np.asarray(cl.params["relation"], np.float32)
            mu[c, :n] = np.asarray(cl.opt_state.mu["entity"], np.float32)
            nu[c, :n] = np.asarray(cl.opt_state.nu["entity"], np.float32)
            rel_mu[c] = np.asarray(cl.opt_state.mu["relation"], np.float32)
            rel_nu[c] = np.asarray(cl.opt_state.nu["relation"], np.float32)
            steps.add(int(cl.opt_state.step))
            v = self.views[c]
            if v.num_shared:
                hist[c, : v.num_shared] = ent[c][v.shared_local]
        if len(steps) > 1:
            raise ValueError(
                "clients have unequal Adam step counts; the tiered trainer "
                "requires lockstep steps"
            )
        store = HostTieredStore(
            ent, mu, nu,
            pinned=[np.asarray(v.shared_local) for v in self.views],
            cache_slots=self.cache_slots, ns_pad=self.ns_pad,
            temp_beta=self.temp_beta,
        )
        state = TieredState(
            cache=store.seed_cache(),
            rel=jnp.asarray(rel),
            rel_mu=jnp.asarray(rel_mu),
            rel_nu=jnp.asarray(rel_nu),
            step=jnp.asarray(steps.pop() if steps else 0, jnp.int32),
            hist=jnp.asarray(hist),
            res=jnp.zeros(
                (c_n, self.ns_pad if self.codec.has_residual else 0, d),
                jnp.float32,
            ),
            key=jax.random.PRNGKey(seed),
            tel=(
                init_telemetry_arrays(c_n, self.k_max)
                if self._tel else None
            ),
        )
        return store, state

    def run_cycle(
        self, store: HostTieredStore, state: TieredState, kind: str
    ) -> tuple[TieredState, np.ndarray, np.ndarray]:
        """One ``local_epochs``-train + ``kind``-round cycle.

        Training runs as ``ceil(scan_len / stage_steps)`` stage segments —
        host remap + cache staging, then the compiled segment program —
        followed by the communication round on the always-resident pinned
        prefix.  Returns ``(state', down_counts (C,), loss (C,))``, plus the
        round's :class:`~repro.core.telemetry.RoundTelemetry` (``None`` for
        ``kind="none"``) when the engine was built with ``telemetry=True``.
        The per-cycle key schedule matches
        :class:`repro.core.state.CycleEngine` (one 3-way split; ``kb``
        feeds the batch plan, ``kj`` the jitter).
        """
        key, kb, kj = jax.random.split(state.key, 3)
        pos, neg_t, neg_h = self._plan(kb)
        pos_h = np.asarray(pos)
        nt_h = np.asarray(neg_t)
        nh_h = np.asarray(neg_h)
        cache, rel, rel_mu, rel_nu, step = (
            state.cache, state.rel, state.rel_mu, state.rel_nu, state.step
        )
        losses = []
        for s0 in range(0, self.scan_len, self.stage_steps):
            sl = slice(s0, min(s0 + self.stage_steps, self.scan_len))
            with telemetry_span("stage"):
                cache, view, pos_v, nt_v, nh_v = self._stage(
                    store, cache, pos_h[:, sl], nt_h[:, sl], nh_h[:, sl]
                )
            cache, rel, rel_mu, rel_nu, step, seg_loss, temp_sig = (
                self._train_seg(
                    cache, rel, rel_mu, rel_nu, step, jnp.asarray(view),
                    jnp.asarray(pos_v), jnp.asarray(nt_v), jnp.asarray(nh_v),
                )
            )
            store.after_segment(view, np.asarray(temp_sig))
            losses.append(np.asarray(seg_loss))
        hist, res = state.hist, state.res
        new_tel = state.tel
        rec = None
        if kind == "none":
            down = np.zeros((self.num_clients,), np.int32)
        else:
            jitter = (
                self._jitter_fn(kj) if kind == "sparse"
                else jnp.zeros((self.num_clients, self.ns_pad), jnp.float32)
            )
            if self._tel:
                cache, hist, res, down, rec, new_prev = self._comm[kind](
                    cache, hist, res, jitter, self._gid, self._valid,
                    self._k, (state.tel.prev_idx, state.tel.prev_msk),
                )
                new_tel = TelemetryArrays(
                    prev_idx=new_prev[0], prev_msk=new_prev[1]
                )
            else:
                cache, hist, res, down = self._comm[kind](
                    cache, hist, res, jitter, self._gid, self._valid, self._k
                )
            store.mark_pinned_dirty()
            down = np.asarray(down)
        store.stats["cycles"] += 1
        new_state = TieredState(
            cache=cache, rel=rel, rel_mu=rel_mu, rel_nu=rel_nu, step=step,
            hist=hist, res=res, key=key, tel=new_tel,
        )
        out = new_state, down, np.concatenate(losses, axis=0).mean(axis=0)
        return out + (rec,) if self._tel else out

    def _stage(self, store, cache, pos_h, nt_h, nh_h):
        """Touched-row discovery + cache staging + view-space remap for one
        segment's ``(C, seg, B, ...)`` index slices."""
        c_n = self.num_clients
        view = np.full((c_n, self.w), store.h, np.int32)
        view[:, : self.ns_pad] = np.arange(self.ns_pad)
        pos_v = pos_h.copy()
        nt_v = np.empty_like(nt_h)
        nh_v = np.empty_like(nh_h)
        touched: list[np.ndarray] = []
        remaps = []
        for c in range(c_n):
            rows_all = np.concatenate([
                pos_h[c, :, :, 0].ravel(), pos_h[c, :, :, 2].ravel(),
                nt_h[c].ravel(), nh_h[c].ravel(),
            ])
            uniq, inv = np.unique(rows_all, return_inverse=True)
            pin = store.pin_pos[c]
            vp = np.empty(len(uniq), np.int64)
            nonshared = []
            for j, e in enumerate(uniq.tolist()):
                p = pin.get(e, -1)
                if p >= 0:
                    vp[j] = p
                else:
                    vp[j] = self.ns_pad + len(nonshared)
                    nonshared.append(e)
            touched.append(np.asarray(nonshared, np.int64))
            remaps.append((uniq, inv, vp, len(nonshared)))
        cache, slot_lists = store.stage(cache, touched)
        for c in range(c_n):
            _uniq, inv, vp, n_ns = remaps[c]
            if n_ns:
                view[c, self.ns_pad : self.ns_pad + n_ns] = slot_lists[c]
            mapped = vp[inv].astype(pos_h.dtype)
            n_ht = pos_h[c, :, :, 0].size
            n_neg = nt_h[c].size
            pos_v[c, :, :, 0] = mapped[:n_ht].reshape(pos_h[c, :, :, 0].shape)
            pos_v[c, :, :, 2] = mapped[n_ht : 2 * n_ht].reshape(
                pos_h[c, :, :, 2].shape
            )
            nt_v[c] = mapped[2 * n_ht : 2 * n_ht + n_neg].reshape(nt_h[c].shape)
            nh_v[c] = mapped[2 * n_ht + n_neg :].reshape(nh_h[c].shape)
        return cache, view, pos_v, nt_v, nh_v

    def materialize_params(
        self, store: HostTieredStore, state: TieredState
    ) -> dict:
        """Flush the cache and assemble full padded params (the ONE point
        where a full ``(C, E_max, D)`` table is materialized — eval / final
        snapshot boundaries only)."""
        store.flush(state.cache)
        return {
            "entity": jnp.asarray(store.ent),
            "relation": state.rel,
        }
