"""Federation flight recorder: on-device round records + host trace spans.

Two halves, matching the two places observability costs something:

* **On-device records** — :class:`RoundTelemetry`, a pytree of per-round,
  per-client accumulators (upload/download row counts, realized Top-K
  overlap with the previous round, EF-residual L2 mass, fault masks and
  staleness ages, change-score histogram buckets).  The engines compute one
  record per comm round *inside* the compiled program — threaded through
  the same scan carries as the download counts — and the host drains them
  at eval boundaries alongside the deferred ledger flush, so recording
  costs no extra dispatches.  The carried state is :class:`TelemetryArrays`
  (the previous round's upload selection, for the overlap signal); with
  telemetry off the carry is ``None`` — zero pytree leaves, so the engines
  compile exactly the pre-telemetry programs (the PR-7 trivial-schedule
  pattern).
* **Host spans + sink** — :class:`TelemetrySink` writes newline-delimited
  JSON events (``run`` / ``round`` / ``eval`` / ``span`` / ``ledger``) to
  the path given by ``FederatedConfig.telemetry`` / ``--telemetry``;
  :func:`span` times host-side stages (tiered staging, checkpoint writes,
  eval readback) and is a shared no-op context manager when no sink is
  installed, so call sites are unconditional.  Set
  ``REPRO_TELEMETRY_PROFILE=1`` to additionally wrap spans in
  ``jax.profiler.TraceAnnotation``.

``tools/trace_report.py`` renders the JSONL into a per-round table and a
bytes/MRR/participation summary, and checks the **reconciliation
invariant**: replaying each round event's recorded quantities through a
shadow :class:`~repro.federated.comm.CommLedger` (same codec, same call
order) must reproduce the real ledger's totals bitwise.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# Change scores are 1 - cos similarity, in [0, 2]; the histogram buckets
# them uniformly over that range with the last bucket open above.
NUM_SCORE_BUCKETS = 8
SCORE_BUCKET_RANGE = 2.0


class TelemetryArrays(NamedTuple):
    """Carried telemetry state: the last upload each client actually sent.

    ``prev_idx`` (C, k_max) int32 slot indices and ``prev_msk`` (C, k_max)
    0/1 float sent-mask from the most recent sparse round in which the
    client participated; the next sparse round's realized Top-K overlap is
    measured against it.  Sync rounds pass it through unchanged (their
    exchange is dense, so "overlap" is meaningless there and recorded as 0).
    """

    prev_idx: jnp.ndarray
    prev_msk: jnp.ndarray


class RoundTelemetry(NamedTuple):
    """One comm round's per-client record, computed on device.

    All leaves lead with the client axis: ``up_rows``/``dn_rows`` (C,) int32
    rows billed on each leg, ``overlap`` (C,) int32 rows shared with the
    client's previous upload, ``res_mass`` (C,) f32 L2 norm of the
    post-round EF residual bank, ``part``/``up_ok``/``dn_ok`` (C,) 0/1 fault
    masks, ``age`` (C,) int32 rounds since last participation (post-update),
    ``score_hist`` (C, NUM_SCORE_BUCKETS) int32 change-score histogram.

    Model-health probes ride the same record: ``div_mean``/``div_max``
    (C,) f32 mean/max L2 distance of the client's post-round shared rows
    from the existence-masked cross-client mean (the inconsistency the
    paper's intermittent synchronization bounds — it collapses at sync
    rounds), ``upd_norm`` (C,) f32 L2 norm of the round's shared-row
    update, ``nonfinite`` (C,) int32 count of non-finite components in
    the client's post-round shared rows.
    """

    up_rows: jnp.ndarray
    dn_rows: jnp.ndarray
    overlap: jnp.ndarray
    res_mass: jnp.ndarray
    part: jnp.ndarray
    up_ok: jnp.ndarray
    dn_ok: jnp.ndarray
    age: jnp.ndarray
    score_hist: jnp.ndarray
    div_mean: jnp.ndarray
    div_max: jnp.ndarray
    upd_norm: jnp.ndarray
    nonfinite: jnp.ndarray


# The exact key set of a ``{"ev": "round"}`` JSONL event.  Kept as a literal
# tuple so tools/docs_lint.py can parse it without importing jax and check
# the docs/architecture.md schema table stays in sync.
ROUND_EVENT_FIELDS = (
    "round", "kind", "up_rows", "dn_rows", "overlap", "res_mass",
    "part", "up_ok", "dn_ok", "age", "score_hist",
    "div_mean", "div_max", "upd_norm", "nonfinite",
    "up_bytes", "dn_bytes", "cache_hits", "cache_misses",
    "cache_evictions", "cum_params", "cum_bytes",
)


def init_telemetry_arrays(num_clients: int, k_max: int) -> TelemetryArrays:
    """Zeroed carry: round 0 has no previous upload, so overlap starts 0."""
    return TelemetryArrays(
        prev_idx=jnp.zeros((num_clients, k_max), jnp.int32),
        prev_msk=jnp.zeros((num_clients, k_max), jnp.float32),
    )


def telemetry_spec(p):
    """PartitionSpec pytree for TelemetryArrays (client-axis-only leaves)."""
    return TelemetryArrays(prev_idx=p, prev_msk=p)


def record_spec(p):
    """PartitionSpec pytree for RoundTelemetry (client-axis-only leaves)."""
    return RoundTelemetry(*([p] * len(RoundTelemetry._fields)))


# --------------------------------------------------------- jit-safe helpers
def score_histogram(scores, valid, entity_axis: Optional[str] = None):
    """(C, NUM_SCORE_BUCKETS) int32 histogram of change scores over valid
    rows.  ``scores`` may carry -inf on invalid slots (the engines mask
    before Top-K); the int cast clips those into bucket 0 where the zero
    ``valid`` weight drops them.  Under entity sharding the per-block counts
    are psum-reduced so every shard holds the full (replicated) histogram.
    """
    nb = NUM_SCORE_BUCKETS
    idx = jnp.clip(
        (scores * (nb / SCORE_BUCKET_RANGE)).astype(jnp.int32), 0, nb - 1
    )
    one_hot = idx[:, :, None] == jnp.arange(nb, dtype=jnp.int32)[None, None, :]
    hist = (one_hot & valid[:, :, None]).sum(axis=1).astype(jnp.int32)
    if entity_axis is not None:
        hist = jax.lax.psum(hist, entity_axis)
    return hist


def residual_mass(res, entity_axis: Optional[str] = None):
    """(C,) f32 L2 norm of each client's EF residual bank.

    Shared by the engines and the reference path's host record builder —
    same function, same (C, Ns, D) shape, same reduction order, so records
    agree bitwise whenever the residual values do.  Zero-width banks
    (non-EF codecs) reduce to exact zeros.
    """
    sq = jnp.sum(res * res, axis=(1, 2))
    if entity_axis is not None:
        sq = jax.lax.psum(sq, entity_axis)
    return jnp.sqrt(sq)


def upload_overlap(up_idx, sent_maskf, prev_idx, prev_msk):
    """(C,) int32 count of slots in this round's sent upload that were also
    in the client's previous sent upload.  Slot indices within one upload
    are distinct, so the masked pair-match sum is exactly the intersection
    size."""
    match = (up_idx[:, :, None] == prev_idx[:, None, :]).astype(jnp.float32)
    pair = match * sent_maskf[:, :, None] * prev_msk[:, None, :]
    return pair.sum(axis=(1, 2)).astype(jnp.int32)


def shared_divergence(rows, gid, valid, num_global: int,
                      axis_name: Optional[str] = None):
    """Per-client shared-entity divergence against the cross-client mean.

    ``rows`` (C, Ns, D) padded shared-row values, ``gid`` (C, Ns) int32
    global entity ids (padding slots point at ``num_global``), ``valid``
    (C, Ns) existence mask.  For every global entity the existence-masked
    cross-client mean row is formed by segment sum (one throwaway segment
    swallows the padding), then each client's valid rows are measured
    against it: ``div_mean`` averages the per-row L2 distances, ``div_max``
    takes the worst row.  A fault-free sync round makes every copy equal
    the mean, so both collapse to exactly zero — the recovery signal the
    paper's intermittent synchronization predicts.

    Callers under entity sharding must pass full-width (all-blocks) rows so
    the segment sums reduce in unsharded order (the
    :func:`~repro.core.engine.batched_sync_round` rule); ``axis_name``
    psum-reduces across a *client* mesh only.
    """
    _, _, d = rows.shape
    validf = valid.astype(rows.dtype)
    ids = jnp.where(valid, gid, num_global).reshape(-1)
    total = jax.ops.segment_sum(
        (rows * validf[:, :, None]).reshape(-1, d), ids,
        num_segments=num_global + 1)
    cnt = jax.ops.segment_sum(validf.reshape(-1), ids,
                              num_segments=num_global + 1)
    if axis_name is not None:
        total = jax.lax.psum(total, axis_name)
        cnt = jax.lax.psum(cnt, axis_name)
    mean = total / jnp.maximum(cnt, 1.0)[:, None]
    diff = rows - mean[gid]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1)) * validf
    div_mean = dist.sum(axis=1) / jnp.maximum(validf.sum(axis=1), 1.0)
    return div_mean, dist.max(axis=1, initial=0.0)


def update_norm(new_rows, old_rows, valid):
    """(C,) f32 L2 norm of each client's shared-row update this round.

    Padding slots are masked; like :func:`residual_mass`, callers under
    entity sharding pass full-width buffers so the reduction order matches
    the unsharded program bitwise.
    """
    diff = (new_rows - old_rows) * valid.astype(new_rows.dtype)[:, :, None]
    return jnp.sqrt(jnp.sum(diff * diff, axis=(1, 2)))


def nonfinite_count(rows, valid):
    """(C,) int32 count of non-finite components in valid shared rows.

    Integer accumulation is order-exact, so this is safe under any
    sharding; it feeds the ``nan`` alert rule.
    """
    bad = ~jnp.isfinite(rows) & valid[:, :, None]
    return bad.sum(axis=(1, 2)).astype(jnp.int32)


# -------------------------------------------------------- host sink + spans
class TelemetrySink:
    """Newline-delimited JSON event writer with span timing.

    The file opens lazily on first emit (so constructing a sink for a run
    that crashes before round 0 leaves no empty artifact) and every event is
    flushed immediately — the JSONL must survive a kill, like the
    checkpoint.  ``shadow`` is installed by the simulation: a second
    :class:`~repro.federated.comm.CommLedger` fed only from device-recorded
    telemetry, whose totals the ``ledger`` event compares against the real
    ledger's.  ``monitor`` (a :class:`~repro.core.health.HealthMonitor`,
    installed by the simulation when ``--alerts`` is set) observes every
    ``round``/``eval`` event as it drains and may append ``alert`` events
    to the stream, right after the event that fired them.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._f = None
        self.shadow = None
        self.monitor = None

    def emit(self, event: dict) -> None:
        if self._f is None:
            self._f = open(self.path, "w")
        self._f.write(json.dumps(event) + "\n")
        self._f.flush()
        if self.monitor is not None and event.get("ev") in ("round", "eval"):
            for alert in self.monitor.observe(event):
                self.emit(alert)

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        ann = None
        if os.environ.get("REPRO_TELEMETRY_PROFILE"):
            ann = jax.profiler.TraceAnnotation(f"telemetry/{name}")
            ann.__enter__()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dur = time.perf_counter() - t0
            if ann is not None:
                ann.__exit__(None, None, None)
            self.emit({"ev": "span", "name": name, "dur_s": dur, **attrs})

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


_ACTIVE: Optional[TelemetrySink] = None
_NULL_SPAN = contextlib.nullcontext()


def active() -> Optional[TelemetrySink]:
    """The sink installed for the current run, or None."""
    return _ACTIVE


def install(sink: Optional[TelemetrySink]) -> None:
    global _ACTIVE
    _ACTIVE = sink


@contextlib.contextmanager
def session(sink: Optional[TelemetrySink]):
    """Install ``sink`` for the duration of a run (restores the previous)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = sink
    try:
        yield sink
    finally:
        _ACTIVE = prev


def span(name: str, **attrs):
    """Time a host-side stage into the active sink.

    Call sites are unconditional: with no sink installed this returns one
    shared ``nullcontext`` — no allocation, no timing, no event.
    """
    if _ACTIVE is None:
        return _NULL_SPAN
    return _ACTIVE.span(name, **attrs)
