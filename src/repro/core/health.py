"""Streaming health monitor over the flight-recorder event stream.

The PR-9 telemetry pipe records what happened; this module judges it while
the run is still going.  A declarative alert-rule spec (the PR-4 codec /
PR-7 fault spec-string shape) compiles into a :class:`HealthMonitor` that
the simulation hangs on the :class:`~repro.core.telemetry.TelemetrySink` —
every ``round`` / ``eval`` event is observed as it drains, and a violated
rule appends an ``{"ev": "alert"}`` event to the JSONL stream right after
the event that fired it.  Four rules cover the paper's failure modes:

* ``divergence>X`` — some client's shared rows drifted more than ``X``
  (mean L2 vs the cross-client mean) from the federation consensus: the
  inconsistency intermittent synchronization is supposed to bound.
* ``nan`` — non-finite components appeared in shared rows (the training
  run is numerically dead; everything downstream is noise).
* ``mrr-stall=N`` — validation MRR has not improved for ``N`` rounds.
* ``byte-budget=B`` — the cumulative wire bytes crossed ``B``.

Rules latch: each fires at most once per run, recording the first
violation (the report renders the full alert log).  The monitor's
``mode`` decides severity: ``warn`` only records; ``fail`` additionally
makes :meth:`HealthMonitor.should_stop` true, which the simulation checks
at eval boundaries for a *graceful* fail-fast — the stream still ends
with the terminal ledger event, so the JSONL grammar (and the shadow
reconciliation) survives an aborted run.  ``tools/health_report.py``
exits non-zero on fired fail-level alerts so CI can gate on the stream.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

ALERT_RULES = ("divergence", "nan", "mrr-stall", "byte-budget")
ALERT_MODES = ("warn", "fail")
_SPEC_GRAMMAR = (
    "alert spec grammar: semicolon-separated rules over "
    f"{ALERT_RULES}, e.g. 'divergence>0.5;nan;mrr-stall=20;byte-budget=2e9' "
    "('nan' takes no value; divergence uses '>', the others '=')"
)


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One parsed alert rule: a name from :data:`ALERT_RULES` plus its
    threshold (None only for ``nan``, whose threshold is implicitly 0)."""

    name: str
    threshold: Optional[float] = None

    def __post_init__(self):
        if self.name not in ALERT_RULES:
            raise ValueError(
                f"unknown alert rule {self.name!r}; {_SPEC_GRAMMAR}"
            )
        if self.name == "nan":
            if self.threshold is not None:
                raise ValueError(f"rule 'nan' takes no value; {_SPEC_GRAMMAR}")
        else:
            if self.threshold is None or not self.threshold > 0:
                raise ValueError(
                    f"rule {self.name!r} needs a positive threshold, got "
                    f"{self.threshold!r}; {_SPEC_GRAMMAR}"
                )
            if self.name == "mrr-stall" and self.threshold != int(self.threshold):
                raise ValueError(
                    f"rule 'mrr-stall' takes an integer round count, got "
                    f"{self.threshold!r}; {_SPEC_GRAMMAR}"
                )

    @property
    def spec(self) -> str:
        """The canonical spec-string form (parse/format round-trips)."""
        if self.name == "nan":
            return "nan"
        if self.name == "divergence":
            return f"divergence>{self.threshold:g}"
        if self.name == "mrr-stall":
            return f"mrr-stall={int(self.threshold)}"
        return f"byte-budget={self.threshold:g}"


def parse_alert_spec(spec: str) -> Tuple[AlertRule, ...]:
    """Parse the ``--alerts`` spec string into a rule tuple.

    An empty string means "no monitoring" and returns ``()``.  Errors are
    self-describing: they restate the grammar alongside the bad item.
    """
    spec = (spec or "").strip()
    if not spec:
        return ()
    rules = []
    seen = set()
    for item in spec.split(";"):
        item = item.strip()
        if not item:
            raise ValueError(f"empty alert rule in {spec!r}; {_SPEC_GRAMMAR}")
        if ">" in item:
            name, _, val = (s.strip() for s in item.partition(">"))
        elif "=" in item:
            name, _, val = (s.strip() for s in item.partition("="))
        else:
            name, val = item, None
        if name in seen:
            raise ValueError(f"duplicate alert rule {name!r}")
        seen.add(name)
        threshold = None
        if val is not None:
            try:
                threshold = float(val)
            except ValueError:
                raise ValueError(
                    f"bad value {val!r} for alert rule {name!r}; "
                    f"{_SPEC_GRAMMAR}"
                ) from None
        rules.append(AlertRule(name, threshold))
    return tuple(rules)


def format_alert_spec(rules: Tuple[AlertRule, ...]) -> str:
    """Inverse of :func:`parse_alert_spec` (canonical form)."""
    return ";".join(r.spec for r in rules)


class HealthMonitor:
    """Evaluates alert rules online against the drained event stream.

    Stateful across one run: ``observe`` consumes each ``round`` / ``eval``
    event (in emission order) and returns the ``alert`` events it fired —
    the sink writes them immediately after the triggering event.  ``fired``
    keeps every alert for the terminal summary; ``should_stop`` is the
    fail-fast signal the simulation polls at eval boundaries.
    """

    def __init__(self, rules: Tuple[AlertRule, ...], mode: str = "warn"):
        if mode not in ALERT_MODES:
            raise ValueError(
                f"unknown alert mode {mode!r}; expected one of {ALERT_MODES}"
            )
        self.rules = tuple(rules)
        self.mode = mode
        self.fired: list[dict] = []
        self._latched: set[str] = set()
        self._best_mrr = -math.inf
        self._best_round = 0

    def _fire(self, rule: AlertRule, round_no: int, value, detail: str):
        if rule.name in self._latched:
            return None
        self._latched.add(rule.name)
        alert = {
            "ev": "alert", "rule": rule.spec, "name": rule.name,
            "round": int(round_no), "level": self.mode,
            "value": float(value),
            "threshold": (
                float(rule.threshold) if rule.threshold is not None else 0.0
            ),
            "detail": detail,
        }
        self.fired.append(alert)
        return alert

    def should_stop(self) -> bool:
        return self.mode == "fail" and bool(self.fired)

    # ------------------------------------------------------------ observers
    def observe(self, event: dict) -> list[dict]:
        ev = event.get("ev")
        if ev == "round":
            return self._observe_round(event)
        if ev == "eval" and event.get("split") == "valid":
            return self._observe_eval(event)
        return []

    def _observe_round(self, event: dict) -> list[dict]:
        out = []
        t = event.get("round", 0)
        for rule in self.rules:
            if rule.name == "divergence":
                worst = max(event.get("div_mean") or [0.0])
                if worst > rule.threshold:
                    c = (event["div_mean"]).index(worst)
                    a = self._fire(
                        rule, t, worst,
                        f"client {c} div_mean {worst:.4g} > "
                        f"{rule.threshold:g} at round {t}",
                    )
                    if a:
                        out.append(a)
            elif rule.name == "nan":
                bad = sum(event.get("nonfinite") or [0])
                floats = (event.get("div_mean") or []) \
                    + (event.get("upd_norm") or []) \
                    + (event.get("res_mass") or [])
                if bad > 0 or any(not math.isfinite(x) for x in floats):
                    a = self._fire(
                        rule, t, bad,
                        f"{bad} non-finite component(s) in shared rows "
                        f"at round {t}",
                    )
                    if a:
                        out.append(a)
            elif rule.name == "byte-budget":
                spent = event.get("cum_bytes", 0.0)
                if spent > rule.threshold:
                    a = self._fire(
                        rule, t, spent,
                        f"cumulative wire bytes {spent:.4g} > budget "
                        f"{rule.threshold:g} at round {t}",
                    )
                    if a:
                        out.append(a)
        return out

    def _observe_eval(self, event: dict) -> list[dict]:
        out = []
        t = event.get("round", 0)
        mrr = event.get("mrr", -math.inf)
        if mrr > self._best_mrr:
            self._best_mrr = mrr
            self._best_round = t
        for rule in self.rules:
            if rule.name != "mrr-stall":
                continue
            stalled = t - self._best_round
            if stalled >= rule.threshold:
                a = self._fire(
                    rule, t, stalled,
                    f"val MRR best ({self._best_mrr:.4f}) unimproved for "
                    f"{stalled} rounds (limit {int(rule.threshold)})",
                )
                if a:
                    out.append(a)
        return out
