"""Codec registry: name -> class, CLI spec parsing, self-describing errors.

The registry is the single source of truth for which codecs exist and which
kwargs each accepts (via :attr:`WireCodec.ARGS`): construction
(:func:`get_codec`), the ``--codec name:key=val,...`` CLI surface
(:func:`parse_codec_spec`), and every parse error message
(:func:`codec_usage`) all derive from it, so adding a codec is one
``@register`` away from being constructible, launchable, and documented in
error output.
"""
from __future__ import annotations

from typing import Dict, Type

from repro.core.codecs.base import CodecArg, WireCodec

_REGISTRY: Dict[str, Type[WireCodec]] = {}
_ALIASES: Dict[str, str] = {}

_TRUE = {"1", "true", "yes", "on"}
_FALSE = {"0", "false", "no", "off"}


def register(cls: Type[WireCodec] = None, *, aliases: tuple = ()):
    """Class decorator: register a codec under ``cls.name`` (+ aliases)."""

    def _do(cls: Type[WireCodec]) -> Type[WireCodec]:
        if cls.name in _REGISTRY or cls.name in _ALIASES:
            raise ValueError(f"codec name {cls.name!r} already registered")
        for a in aliases:
            if a in _REGISTRY or a in _ALIASES:
                raise ValueError(f"codec alias {a!r} already registered")
        _REGISTRY[cls.name] = cls
        for a in aliases:
            _ALIASES[a] = cls.name
        return cls

    return _do(cls) if cls is not None else _do


def registered_codecs() -> Dict[str, Type[WireCodec]]:
    """Registered codec classes by canonical name (sorted, aliases excluded)."""
    return dict(sorted(_REGISTRY.items()))


def codec_usage() -> str:
    """One line per registered codec: ``name:key=type(default),...  help``."""
    lines = []
    for name, cls in registered_codecs().items():
        if cls.ARGS:
            kw = ",".join(
                f"{a.name}={a.type.__name__}({a.default})" for a in cls.ARGS
            )
            spec = f"{name}:{kw}"
        else:
            spec = f"{name} (no kwargs)"
        doc_lines = (cls.__doc__ or "").strip().splitlines()
        doc = doc_lines[0] if doc_lines else ""
        lines.append(f"  {spec}  — {doc}")
    return "\n".join(lines)


def _coerce(arg: CodecArg, raw):
    """Coerce a CLI string to the arg's declared type (pass non-str through)."""
    if not isinstance(raw, str):
        return raw
    if arg.type is bool:
        low = raw.lower()
        if low in _TRUE:
            return True
        if low in _FALSE:
            return False
        raise ValueError(
            f"codec kwarg {arg.name!r} expects a bool "
            f"({'/'.join(sorted(_TRUE | _FALSE))}), got {raw!r}"
        )
    try:
        return arg.type(raw)
    except ValueError:
        raise ValueError(
            f"codec kwarg {arg.name!r} expects {arg.type.__name__}, got {raw!r}"
        ) from None


def get_codec(name: str, **kwargs) -> WireCodec:
    """Construct a registered codec by (canonical or alias) name.

    Unknown names and kwargs raise ``ValueError`` messages listing the
    registered codec names and their accepted kwargs — the registry is the
    single source of truth the CLI leans on.
    """
    canonical = _ALIASES.get(name, name)
    if canonical not in _REGISTRY:
        raise ValueError(
            f"unknown wire codec {name!r}; registered codecs:\n{codec_usage()}"
        )
    cls = _REGISTRY[canonical]
    by_name = {a.name: a for a in cls.ARGS}
    unknown = sorted(set(kwargs) - set(by_name))
    if unknown:
        accepted = ", ".join(
            f"{a.name}={a.type.__name__}({a.default})" for a in cls.ARGS
        ) or "none"
        raise ValueError(
            f"unknown kwarg(s) {unknown} for codec {canonical!r}; "
            f"accepted kwargs: {accepted}"
        )
    coerced = {k: _coerce(by_name[k], v) for k, v in kwargs.items()}
    return cls(**coerced)


def parse_codec_spec(spec: str) -> WireCodec:
    """Parse ``name`` or ``name:key=val,key=val,...`` into a codec instance.

    The CLI surface of the registry (``launch/train.py --codec``); every
    error lists the registered codecs and their accepted kwargs.
    """
    name, _, rest = spec.partition(":")
    name = name.strip()
    kwargs = {}
    if rest:
        for item in rest.split(","):
            key, sep, val = item.partition("=")
            if not sep or not key.strip():
                raise ValueError(
                    f"bad codec spec {spec!r}: expected name:key=val,... ; "
                    f"registered codecs:\n{codec_usage()}"
                )
            kwargs[key.strip()] = val.strip()
    return get_codec(name, **kwargs)
