"""Per-round low-rank subspace projection of transmitted rows."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.codecs.base import EF_ARG, CodecArg, WireCodec
from repro.core.codecs.registry import register


@register
class LowRankCodec(WireCodec):
    """Low-rank truncation of each row's (m, cols) reshape (arXiv:2412.13442-style).

    Absorbs the FedE-SVD baseline (paper Table I / Appendix VI-B,
    historically the host-only numpy pipeline in ``core/compression.py``)
    into the real engines: each transmitted ``(D,)`` row is reshaped to
    ``(m, cols)`` with ``m = D // cols`` and truncated to its top ``rank``
    singular triples via ``jnp.linalg.svd`` inside the compiled round, both
    legs.  Transmitted parameters per row: ``m*r + r + cols*r``
    (U factors + singular values + V factors), the paper's accounting.

    The paper's *negative finding* is that this universal precision
    reduction stalls convergence; ``ef=1`` banks the truncation error in the
    error-feedback residual so it is delayed rather than lost.
    """

    name = "lowrank"
    ARGS = (
        CodecArg("cols", int, 8, "row reshape width n (requires D % cols == 0)"),
        CodecArg("rank", int, 2, "truncation rank r (clamped to min(m, cols))"),
        EF_ARG,
    )

    def __init__(self, cols: int = 8, rank: int = 2, ef: bool = False):
        if cols < 1 or rank < 1:
            raise ValueError(f"lowrank requires cols >= 1 and rank >= 1, got "
                             f"cols={cols}, rank={rank}")
        self.cols = int(cols)
        self.rank = int(rank)
        self.ef = bool(ef)

    def _shape(self, dim: int) -> tuple[int, int]:
        """(m, effective rank) for a given row width; validates divisibility."""
        if dim % self.cols:
            raise ValueError(
                f"lowrank codec: row width {dim} not divisible by cols={self.cols}"
            )
        m = dim // self.cols
        return m, min(self.rank, m, self.cols)

    def encode(self, values: jnp.ndarray):
        k, dim = values.shape
        m, r = self._shape(dim)
        u, s, vt = jnp.linalg.svd(
            values.reshape(k, m, self.cols), full_matrices=False
        )
        return u[..., :r], s[..., :r], vt[..., :r, :]

    def decode(self, payload) -> jnp.ndarray:
        u, s, vt = payload
        mat = jnp.einsum("kmr,kr,krn->kmn", u, s, vt)
        return mat.reshape(mat.shape[0], -1)

    def params_per_row(self, dim: int) -> int:
        """Transmitted parameter count per row: m*r + r + cols*r."""
        m, r = self._shape(dim)
        return m * r + r + self.cols * r

    def log_upload(self, ledger, k: int, dim: int, num_shared: int) -> None:
        ppr = self.params_per_row(dim)
        ledger.params_transmitted += k * ppr + num_shared
        # f32 factors + i32 row index per row + i8 sign vector
        ledger.bytes_int8_signs += k * ppr * 4 + k * 4 + num_shared

    def log_download(self, ledger, k: int, dim: int, num_shared: int) -> None:
        ppr = self.params_per_row(dim)
        ledger.params_transmitted += k * ppr + k + num_shared
        # factors + f32 priority + i32 row index per row + sign vector
        ledger.bytes_int8_signs += k * ppr * 4 + k * 4 + k * 4 + num_shared
