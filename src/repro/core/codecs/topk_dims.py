"""Per-row dimension sparsification composing with the entity-wise Top-K."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codecs.base import EF_ARG, CodecArg, WireCodec
from repro.core.codecs.registry import register


@register
class TopKDimsCodec(WireCodec):
    """Keep only the top ``frac`` of each row's dimensions by magnitude.

    The second sparsification axis, composed with the paper's entity-wise
    selection: FedS picks *which rows* go on the wire, this codec then drops
    each selected row's smallest-magnitude coordinates (parameter-wise Top-K
    *within* the row — exactly the generic-FL sparsifier the paper contrasts
    against, §III-B).  Transmitted per row: ``k_dims`` f32 values + ``k_dims``
    i16 dimension indices.  ``ef=1`` banks the dropped coordinates in the
    error-feedback residual so they are transmitted eventually instead of
    never.
    """

    name = "topk-dims"
    ARGS = (
        CodecArg("frac", float, 0.25, "fraction of dimensions kept per row"),
        EF_ARG,
    )

    def __init__(self, frac: float = 0.25, ef: bool = False):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk-dims requires 0 < frac <= 1, got {frac}")
        self.frac = float(frac)
        self.ef = bool(ef)

    def k_dims(self, dim: int) -> int:
        """Kept coordinates per row (static given the row width)."""
        return min(dim, max(1, int(round(dim * self.frac))))

    def encode(self, values: jnp.ndarray):
        kd = self.k_dims(values.shape[-1])
        _, idx = jax.lax.top_k(jnp.abs(values), kd)  # (k, kd), stable order
        vals = jnp.take_along_axis(values, idx, axis=-1)
        return vals, idx, values.shape[-1]

    def decode(self, payload) -> jnp.ndarray:
        vals, idx, dim = payload
        zeros = jnp.zeros(vals.shape[:-1] + (dim,), vals.dtype)
        return jax.vmap(lambda z, i, v: z.at[i].set(v))(zeros, idx, vals)

    def log_upload(self, ledger, k: int, dim: int, num_shared: int) -> None:
        kd = self.k_dims(dim)
        ledger.params_transmitted += k * kd + num_shared
        # f32 values + i16 dim indices + i32 row index per row + sign vector
        ledger.bytes_int8_signs += k * kd * 4 + k * kd * 2 + k * 4 + num_shared

    def log_download(self, ledger, k: int, dim: int, num_shared: int) -> None:
        kd = self.k_dims(dim)
        ledger.params_transmitted += k * kd + k + num_shared
        # values + dim indices + f32 priority + i32 row index + sign vector
        ledger.bytes_int8_signs += (
            k * kd * 4 + k * kd * 2 + k * 4 + k * 4 + num_shared
        )
