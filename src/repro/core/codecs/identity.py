"""Full-precision f32 rows on the wire — the paper's FedS protocol."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.codecs.base import WireCodec
from repro.core.codecs.registry import register


@register
class IdentityCodec(WireCodec):
    """Full-precision f32 rows on the wire — the paper's FedS protocol."""

    name = "identity"
    transforms_values = False

    def encode(self, values: jnp.ndarray) -> jnp.ndarray:
        return values

    def decode(self, payload: jnp.ndarray) -> jnp.ndarray:
        return payload

    def roundtrip(self, values: jnp.ndarray) -> jnp.ndarray:
        return values

    def log_upload(self, ledger, k: int, dim: int, num_shared: int) -> None:
        ledger.log_upload_sparse(k, dim, num_shared)

    def log_download(self, ledger, k: int, dim: int, num_shared: int) -> None:
        ledger.log_download_sparse(k, dim, num_shared)
