"""The wire-codec interface: jit-safe encode/decode pieces + ledger math.

A :class:`WireCodec` owns every aspect of putting selected embedding rows on
the wire:

* the **value transform** — ``encode`` maps ``(k, D)`` rows to a payload
  pytree (what is actually transmitted), ``decode`` maps it back to ``(k, D)``
  rows, and ``roundtrip = decode(encode(.))`` is the fused "rows as the
  receiver sees them" path the compiled engines apply inside their programs
  (per round for :class:`repro.core.state.CycleEngine`, inside the scanned
  span for :class:`repro.core.state.SuperstepEngine`).  All three are
  jit-safe (pure jnp, static shapes), so the same codec object serves the
  host jit, the ``shard_map`` pod programs, and the ragged numpy reference
  path.
* the :class:`repro.federated.comm.CommLedger` accounting for both protocol
  legs, so a codec's byte/parameter math lives in exactly one place.
  Conventions (match the paper's Eq. 5 accounting): ``params`` are
  float-equivalent parameter counts (an int8 element counts as 1/4
  parameter; row indices are *not* params), ``bytes`` are realistic wire
  bytes including i32 row indices and int8 sign vectors.  The per-entity
  sign vector is transmitted on every leg, including empty downloads — the
  receiver cannot know the download was empty without it.
* optional **error-feedback residual state** (``ef=True`` on lossy codecs):
  a device-resident ``(C, Ns_max, D)`` buffer carried in
  :class:`repro.core.state.StateArrays` that accumulates, per shared-entity
  slot, whatever the codec dropped the last time that row was transmitted.
  The residual is re-injected into the row before the next upstream encode,
  so compression error is *delayed*, never *lost* — the standard fix for
  the universal-precision-loss problem the paper identifies (§III-A).  See
  :func:`repro.core.engine.batched_sparse_round` for the exact update rule
  and EXPERIMENTS.md §Codecs for the contract (sync rounds transmit exact
  values and therefore clear the residual).

Codecs only ever see **sparse** rounds: under the ISM schedule
(:mod:`repro.core.sync`) the one-in-``s+1`` sync rounds are full FedE
exchanges accounted at full precision directly by the ledger
(``log_full_exchange``), which is what makes Eq. 5's ``p*s + 1`` numerator
shape.

Every concrete codec registers itself as a leafless pytree node (hyper-
parameters as static aux data), so codec objects can ride inside pytrees
and jit closures cache correctly across engine rebuilds.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Tuple

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # avoid a core -> federated import cycle at runtime
    from repro.federated.comm import CommLedger


@dataclasses.dataclass(frozen=True)
class CodecArg:
    """One accepted codec kwarg — the single source of truth shared by the
    constructor, ``--codec name:key=val,...`` CLI parsing, and the error
    messages the registry emits."""

    name: str
    type: type  # int | float | bool
    default: Any
    help: str


#: The shared error-feedback switch lossy codecs opt into.
EF_ARG = CodecArg(
    "ef", bool, False,
    "device-resident error-feedback residuals (re-inject dropped error)",
)


class WireCodec:
    """Interface: encode/decode value transform + per-leg ledger accounting."""

    name = "abstract"
    #: False when roundtrip is the identity — lets ragged host paths skip the
    #: per-message device round-trip entirely.
    transforms_values = True
    #: Accepted constructor kwargs (single source of truth for the CLI).
    ARGS: Tuple[CodecArg, ...] = ()

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        # concrete codecs become leafless pytree nodes: hyper-parameters are
        # static aux data, so tree ops pass them through untouched
        if cls.__dict__.get("name", "abstract") != "abstract":
            jax.tree_util.register_pytree_node(
                cls,
                lambda c: ((), tuple(sorted(c.config().items()))),
                lambda aux, _, cls=cls: cls(**dict(aux)),
            )

    # ------------------------------------------------------------- identity
    def config(self) -> dict:
        """Constructor kwargs of this instance (keyed by ``CodecArg.name``)."""
        return {a.name: getattr(self, a.name) for a in self.ARGS}

    @property
    def has_residual(self) -> bool:
        """True when this instance carries error-feedback residual state."""
        return bool(getattr(self, "ef", False))

    def __eq__(self, other) -> bool:
        return type(self) is type(other) and self.config() == other.config()

    def __hash__(self) -> int:
        return hash((type(self), tuple(sorted(self.config().items()))))

    def __repr__(self) -> str:
        kw = ",".join(f"{k}={v}" for k, v in sorted(self.config().items()))
        return f"{self.name}:{kw}" if kw else self.name

    # ------------------------------------------------------ value transform
    def encode(self, values: jnp.ndarray):
        """(k, D) rows -> transmitted payload pytree (jit-safe)."""
        raise NotImplementedError

    def decode(self, payload) -> jnp.ndarray:
        """Payload pytree -> (k, D) rows as reconstructed by the receiver."""
        raise NotImplementedError

    def roundtrip(self, values: jnp.ndarray) -> jnp.ndarray:
        """(k, D) rows -> (k, D) rows as decoded by the receiver (jit-safe).

        The fused path the compiled engines apply; defaults to
        ``decode(encode(values))`` and must stay consistent with it.

        The decoded rows are canonicalized through a value-preserving
        ``where(x == 0, 0, x)`` select.  XLA:CPU freely contracts a
        decoder's final multiply (int8's ``q * scale``) into whatever add
        consumes it, as a true fma — straight through
        ``jax.lax.optimization_barrier`` and simplifier-foldable
        identities like ``+ 0.0`` — and whether that fires depends on
        fusion decisions that vary with program structure, so the "same
        wire bytes" could decode to values a ulp apart between the
        unsharded and entity-sharded engines, breaking their
        bitwise-equality contract.  A data-dependent select is opaque to
        the algebraic simplifier and breaks the multiply->add adjacency
        the contraction needs, so every consumer in every program sees the
        exactly rounded multiply (with the side effect that a decoded
        ``-0.0`` becomes ``+0.0``, uniformly across all engine and oracle
        paths).
        """
        out = self.decode(self.encode(values))
        return jnp.where(out == 0.0, 0.0, out)

    # ----------------------------------------------------- ledger accounting
    def log_upload(self, ledger: CommLedger, k: int, dim: int, num_shared: int) -> None:
        """Account one client's upstream leg (k selected rows)."""
        raise NotImplementedError

    def log_download(self, ledger: CommLedger, k: int, dim: int, num_shared: int) -> None:
        """Account one client's downstream leg (k aggregated rows)."""
        raise NotImplementedError
