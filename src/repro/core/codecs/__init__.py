"""Pluggable wire-compression codec subsystem for FedS protocol payloads.

Registry-backed: every codec is a set of jit-safe ``encode``/``decode``
pieces plus per-leg :class:`repro.federated.comm.CommLedger` accounting,
registered under a name the simulation/CLI select by spec string
(``name:key=val,...``).  Lossy codecs optionally carry device-resident
error-feedback residual state threaded through the engine scans (see
:mod:`repro.core.codecs.base` for the full contract, docs/architecture.md
for where the pieces sit in the compiled programs, and EXPERIMENTS.md
§Codecs for measurements).

Registered codecs:

* ``identity``  — full-precision f32 rows (the paper's FedS protocol)
* ``int8``      — row-wise symmetric int8 + f32 scale (FedS+Q8; alias
  ``int8-rows``)
* ``lowrank``   — per-row truncated SVD of the ``(m, cols)`` reshape (the
  absorbed FedE-SVD Table-I baseline, arXiv:2412.13442-style)
* ``topk-dims`` — per-row dimension Top-K, composing parameter-wise
  sparsification with the paper's entity-wise selection

``repro.core.codec`` remains as a back-compat shim over this package.
"""
from repro.core.codecs.base import CodecArg, EF_ARG, WireCodec
from repro.core.codecs.identity import IdentityCodec
from repro.core.codecs.int8 import Int8RowCodec
from repro.core.codecs.lowrank import LowRankCodec
from repro.core.codecs.topk_dims import TopKDimsCodec
from repro.core.codecs.registry import (
    codec_usage,
    get_codec,
    parse_codec_spec,
    register,
    registered_codecs,
)

__all__ = [
    "CodecArg",
    "EF_ARG",
    "WireCodec",
    "IdentityCodec",
    "Int8RowCodec",
    "LowRankCodec",
    "TopKDimsCodec",
    "codec_usage",
    "get_codec",
    "parse_codec_spec",
    "register",
    "registered_codecs",
]
