"""Row-wise symmetric int8 payloads + one f32 scale per row (FedS+Q8)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.codecs.base import EF_ARG, WireCodec
from repro.core.codecs.registry import register
from repro.core.sparsify import dequantize_rows, quantize_rows


@register(aliases=("int8-rows",))
class Int8RowCodec(WireCodec):
    """FedS+Q8: row-wise symmetric int8 payloads + one f32 scale per row.

    Beyond-paper extension (EXPERIMENTS.md §Repro): precision is reduced only
    on the wire, never in the training state.  Upstream leg: int8 values
    (dim/4 param-equivalents per row) + f32 scale + i32 index per row + the
    (num_shared,) sign vector.  Downstream leg additionally carries the f32
    priority count per row.  With ``ef=1`` the per-row quantization error is
    banked in the error-feedback residual and re-injected next round.
    """

    name = "int8"
    ARGS = (EF_ARG,)

    def __init__(self, ef: bool = False):
        self.ef = bool(ef)

    def encode(self, values: jnp.ndarray):
        return quantize_rows(values)

    def decode(self, payload) -> jnp.ndarray:
        return dequantize_rows(*payload)

    def log_upload(self, ledger, k: int, dim: int, num_shared: int) -> None:
        ledger.params_transmitted += k * dim / 4 + k + num_shared
        ledger.bytes_int8_signs += k * dim + k * 4 + num_shared + k * 4

    def log_download(self, ledger, k: int, dim: int, num_shared: int) -> None:
        ledger.params_transmitted += k * dim / 4 + 2 * k + num_shared
        # int8 values + (scale, priority) f32 pair + i32 index per row + sign
        ledger.bytes_int8_signs += k * (dim + 8) + k * 4 + num_shared
