"""Unified FedS round engine: one jitted program over batched client state.

This module is the single implementation of the paper's communication round
(upstream entity-wise Top-K -> personalized aggregation Eq. 3 -> downstream
Top-K -> Eq. 4 apply) that both deployment shapes share:

* **host** — all clients' shared-entity rows are stacked into padded
  ``(C, Ns_max, D)`` buffers with validity masks and the whole round runs as
  one ``jax.jit`` program on a single device (the federated simulation path),
* **pod**  — the same per-shard function runs under ``shard_map`` over the
  client axis of a mesh; the only cross-client exchange is ONE ``all_gather``
  of the fixed-size ``(k_max,)`` index / ``(k_max, D)`` value / mask buffers
  (EXPERIMENTS.md §Perf: the server round-trip is computed redundantly
  on-shard, which is free once the gather delivered the inputs).

Heterogeneity (clients with different shared-entity counts and different
``K``) is expressed with static shapes: rows are padded to ``Ns_max`` and
masked by ``valid``; Top-K always selects ``k_max`` slots and masks slots
``>= k_c`` per client.  Change scoring runs through the fused Pallas kernel
across the flattened client axis — one kernel launch for all clients.

Semantic deltas vs the numpy reference (:mod:`repro.core.aggregate`), as
already documented for :mod:`repro.core.distributed`: static K and a
deterministic jitter tie-break instead of random tie-breaking.  The host
reference path stays available as ``engine="reference"`` in the simulation
and is what the property tests compare against.

Wire payloads go through a pluggable :class:`repro.core.codecs.WireCodec`
(registry in :mod:`repro.core.codecs`: identity / int8 / lowrank /
topk-dims), applied inside the jitted round; error-feedback codecs
additionally thread a ``(C, Ns_max, D)`` residual buffer through
:func:`batched_sparse_round` (carried in
:class:`repro.core.state.FederationState` by the cycle engines).

ISM round-schedule semantics: this module implements the two round *kinds* —
:func:`batched_sparse_round` (entity-wise Top-K, the ``"sparse"`` kind) and
:func:`batched_sync_round` (full FedE mean, the ``"sync"`` kind) — but does
NOT decide when each runs.  The schedule (``s`` sparse rounds then one sync
round per period) lives in :mod:`repro.core.sync` (:func:`~repro.core.sync.
round_kind`); :class:`repro.core.state.CycleEngine` fuses one scheduled
round with its local training, and :class:`repro.core.state.SuperstepEngine`
scans whole schedule spans into single programs.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eshard
from repro.core.telemetry import (
    RoundTelemetry,
    nonfinite_count,
    record_spec as telemetry_record_spec,
    residual_mass,
    score_histogram,
    shared_divergence,
    update_norm,
    upload_overlap,
)
from repro.core.codecs import IdentityCodec, WireCodec
from repro.core.sparsify import change_scores, sparsity_k, top_k_select
from repro.kernels import ops as kernel_ops

# --------------------------------------------------------------------------
# jax version compatibility: shard_map moved to the jax namespace after 0.4;
# older versions also lack lax.pcast (used to align vma types across lax.cond
# branches) — there check_rep=False makes the rep check a no-op instead.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, mesh, in_specs, out_specs):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def pcast_varying(x, axis_name: str):
    """Mark a replicated value as axis-varying (no-op on jax without pcast)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis_name, to="varying")
    return x


def axis_size(axis_name: str) -> int:
    """Static mesh-axis size inside shard_map, on either jax generation."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)  # constant-folded on jax <= 0.4.x


def make_client_mesh(num_devices: int, axis_name: str = "clients"):
    """A 1-D mesh over ``num_devices`` for the client axis."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh((num_devices,), (axis_name,),
                             axis_types=(jax.sharding.AxisType.Auto,))
    return jax.make_mesh((num_devices,), (axis_name,))


# --------------------------------------------------------------------------
# shared primitives (also used by repro.core.distributed)
def segment_aggregate(
    ids: jnp.ndarray,  # (M,) int segment ids
    vals: jnp.ndarray,  # (M, D) contribution rows (already masked/weighted)
    weights: jnp.ndarray,  # (M,) contribution counts (0 for masked slots)
    num_segments: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Eq. 3 scatter-add: dense (S, D) aggregate + (S,) priority counts."""
    agg = jax.ops.segment_sum(vals, ids, num_segments=num_segments)
    pri = jax.ops.segment_sum(weights, ids, num_segments=num_segments)
    return agg, pri


def downstream_sign(
    pri: jnp.ndarray,  # (N,) priority counts
    rank_key: jnp.ndarray,  # (N,) priority + tie-break jitter
    k: int,
) -> jnp.ndarray:
    """Static downstream Top-K selection as a (N,) int8 sign vector.

    Rows with zero priority are never selected (the paper's "fewer than K
    available" rule).
    """
    sel = top_k_select(rank_key, k)
    sign = jnp.zeros(pri.shape[0], jnp.int8).at[sel].set(1)
    return jnp.where(pri > 0, sign, 0)


# --------------------------------------------------------------------------
def build_padded_views(views: Sequence, num_global: int, sparsity_p: float):
    """Static padded buffers shared by RoundEngine and the fused CycleEngine.

    Returns ``(gid, valid, k_per_client, ns_max, k_max)`` as numpy arrays /
    ints; ``gid`` padding slots hold ``num_global`` (the round functions treat
    it as a throwaway aggregation segment).
    """
    ns = [v.num_shared for v in views]
    ns_max = max(1, max(ns, default=0))
    k_per_client = np.asarray([sparsity_k(n, sparsity_p) for n in ns], np.int32)
    k_max = max(1, int(k_per_client.max(initial=0)))
    gid = np.full((len(views), ns_max), num_global, np.int32)
    valid = np.zeros((len(views), ns_max), bool)
    for c, v in enumerate(views):
        gid[c, : v.num_shared] = v.shared_global
        valid[c, : v.num_shared] = True
    return gid, valid, k_per_client, ns_max, k_max


# --------------------------------------------------------------------------
# the batched round (runs plain-jit on host, or per-shard under shard_map)
def batched_sparse_round(
    emb: jnp.ndarray,  # (C_local, Ns_max, D) shared-entity rows
    hist: jnp.ndarray,  # (C_local, Ns_max, D) upload history
    gid: jnp.ndarray,  # (C_local, Ns_max) global entity id; padding -> num_global
    valid: jnp.ndarray,  # (C_local, Ns_max) bool row validity
    k: jnp.ndarray,  # (C_local,) per-client K
    jitter: jnp.ndarray,  # (C_local, Ns_max) tie-break noise in [0, 1)
    *,
    k_max: int,
    num_global: int,
    codec: WireCodec,
    axis_name: Optional[str],
    res: Optional[jnp.ndarray] = None,  # (C_local, Ns_max, D) EF residuals
    entity_axis: Optional[str] = None,
    faults=None,  # Optional[repro.core.faults.RoundFaults] of (C_local,) masks
    straggler: Optional[jnp.ndarray] = None,  # (C_local,) f32 straggler set
    queue=None,  # (q_idx, q_val, q_msk) straggler in-flight message buffers
    prev=None,  # (prev_idx, prev_msk) telemetry carry (core/telemetry.py)
):
    """One sparse FedS round over padded batched client state.

    Returns ``(emb', hist', down_count)``, plus ``res'`` when ``res`` is
    given, plus the advanced ``queue`` when ``queue`` is given, plus
    ``(RoundTelemetry, (prev_idx', prev_msk'))`` appended last when ``prev``
    is given (the flight-recorder record and the advanced overlap carry;
    ``prev=None`` compiles exactly the untelemetered program).  With an
    error-feedback codec (``codec.has_residual``) the residual of each
    *uploaded* row — what the codec's lossy round-trip dropped — is banked
    in ``res`` and re-injected into that row's wire value the next time it
    is selected; rows not uploaded this round keep their banked residual
    untouched.  Non-residual codecs pass ``res`` through unchanged.

    With ``faults`` (:class:`repro.core.faults.RoundFaults`), participation
    gates what is *computed* (history/residual refresh, download selection),
    ``part * up_ok`` gates what is *delivered* into the Eq. 3 aggregate, and
    ``part * dn_ok`` gates whether the Eq. 4 apply lands.  A dropped upload
    still refreshed the sender's history and residual bank — the client
    cannot know the message was lost.  ``faults=None`` compiles exactly the
    fault-free program.

    With ``queue`` (plus the static ``straggler`` indicator), clients in the
    straggler set contribute the message at the HEAD of their fixed-depth
    queue to this round's aggregate — the upload they computed ``lag``
    sparse rounds ago — while this round's freshly-computed (and
    delivery-masked) message is pushed at the tail.  Non-straggler pushes
    are masked to zero, so their queues stay empty.  Eq. 3's
    own-contribution subtraction and priority discount are built from the
    *contributed* message, history/residual refresh from the *fresh* one.

    With ``entity_axis`` the ``(..., D)`` row buffers (``emb``, ``hist``,
    ``res``) are this shard's ``(C, Ns_pad / n_shards, D)`` blocks of a
    row-sharded slot axis, while the cheap per-slot vectors (``gid``,
    ``valid``, ``jitter``) stay replicated at full ``(C, Ns_pad)`` width.
    Change scoring and the Eq. 4 apply run on the local block only; the two
    Top-K selections become per-shard ``top_k`` + one ``(K, score)``
    candidate merge (:func:`repro.core.sparsify.top_k_select`); the Eq. 3
    segment-sum runs redundantly per shard on the replicated merged uploads
    so its f32 summation order — hence the result, bit for bit — matches
    the unsharded round.
    """
    if codec.has_residual and res is None:
        raise ValueError(
            f"codec {codec!r} carries error-feedback residual state; "
            "pass the (C, Ns_max, D) res buffer (CycleEngine/SuperstepEngine "
            "thread it through FederationState)"
        )
    if queue is not None and straggler is None:
        raise ValueError("straggler indicator required with a message queue")
    ea = entity_axis
    cl, ns_blk, d = emb.shape  # ns_blk == full Ns_max when unsharded
    gid_blk = eshard.local_block(gid, ea, ns_blk)
    valid_blk = eshard.local_block(valid, ea, ns_blk)
    jitter_blk = eshard.local_block(jitter, ea, ns_blk)
    validf = valid_blk.astype(emb.dtype)
    slot = jnp.arange(k_max)[None, :]

    # -- upstream Top-K (Eq. 1-2): one fused kernel call across all clients
    scores = change_scores(
        emb.reshape(cl * ns_blk, d), hist.reshape(cl * ns_blk, d)
    ).reshape(cl, ns_blk)
    scores = jnp.where(valid_blk, scores, -jnp.inf)
    up_idx = top_k_select(scores, k_max, entity_axis=ea)  # (cl, k_max) global
    up_mask = (slot < k[:, None]) & jnp.take_along_axis(valid, up_idx, axis=1)
    up_maskf = up_mask.astype(emb.dtype)

    # (cl, ns_blk) 0/1 — which of my local rows went upstream this round;
    # under faults only participating clients compute an upload at all
    sent_maskf = up_maskf if faults is None else up_maskf * faults.part[:, None]
    uploaded = eshard.scatter_add_vec(
        jnp.zeros((cl, ns_blk), emb.dtype), up_idx, sent_maskf, ea
    )
    new_hist = jnp.where(uploaded[:, :, None] > 0, emb, hist)

    vals = eshard.dist_take_rows(emb, up_idx, ea)  # (cl, k_max, d)
    if codec.has_residual:
        # error feedback: re-inject the banked residual before encoding, bank
        # the fresh encode error after.  Only rows a participating client
        # actually encoded refresh the bank — a dropped-in-flight upload
        # still banked its error (the sender cannot know), an absent client
        # banked nothing.
        res_sel = eshard.dist_take_rows(res, up_idx, ea)
        corrected = vals + res_sel * up_maskf[:, :, None]
        vals = codec.roundtrip(corrected.reshape(-1, d)).reshape(cl, k_max, d)
        err_rows = (corrected - vals) * sent_maskf[:, :, None]
        err_full = eshard.scatter_add_rows(
            jnp.zeros((cl, ns_blk, d), emb.dtype), up_idx, err_rows, ea
        )
        new_res = jnp.where(uploaded[:, :, None] > 0, err_full, res)
    else:
        vals = codec.roundtrip(vals.reshape(-1, d)).reshape(cl, k_max, d)
        new_res = res

    # the message CONTRIBUTED to this round's Eq. 3 aggregate: normally the
    # fresh wire-coded upload (delivery-masked under faults); stragglers
    # contribute the head of their in-flight queue — the message they sent
    # ``lag`` sparse rounds ago — while the fresh message is pushed at the
    # tail (masked to zero for non-stragglers, whose queues stay empty)
    if faults is None:
        msg_maskf = up_maskf
    else:
        msg_maskf = up_maskf * (faults.part * faults.up_ok)[:, None]
    if queue is not None:
        q_idx, q_val, q_msk = queue
        stragb = straggler[:, None] > 0.5
        contrib_idx = jnp.where(stragb, q_idx[:, 0], up_idx)
        contrib_val = jnp.where(stragb[:, :, None], q_val[:, 0], vals)
        contrib_msk = jnp.where(stragb, q_msk[:, 0], msg_maskf)
        new_queue = (
            jnp.concatenate([q_idx[:, 1:], up_idx[:, None]], axis=1),
            jnp.concatenate([q_val[:, 1:], vals[:, None]], axis=1),
            jnp.concatenate(
                [q_msk[:, 1:], (msg_maskf * straggler[:, None])[:, None]],
                axis=1,
            ),
        )
    else:
        contrib_idx, contrib_val, contrib_msk = up_idx, vals, msg_maskf
        new_queue = None

    # this client's wire-coded contribution scattered back to row positions,
    # for the Eq. 3 own-contribution subtraction below
    own_wire = eshard.scatter_add_rows(
        jnp.zeros((cl, ns_blk, d), emb.dtype), contrib_idx,
        contrib_val * contrib_msk[:, :, None], ea,
    )
    if faults is None and queue is None:
        uploaded_contrib = uploaded
    else:
        uploaded_contrib = eshard.scatter_add_vec(
            jnp.zeros((cl, ns_blk), emb.dtype), contrib_idx, contrib_msk, ea
        )

    # -- exchange: one all-gather of fixed-size buffers (no-op on host)
    if faults is None and queue is None:
        up_gid = jnp.where(
            up_mask, jnp.take_along_axis(gid, up_idx, axis=1), num_global
        )
    else:
        up_gid = jnp.where(
            contrib_msk > 0,
            jnp.take_along_axis(gid, contrib_idx, axis=1), num_global,
        )
    ex_vals, ex_msk = contrib_val, contrib_msk
    if axis_name is not None:
        up_gid = jax.lax.all_gather(up_gid, axis_name).reshape(-1, k_max)
        ex_vals = jax.lax.all_gather(ex_vals, axis_name).reshape(-1, k_max, d)
        ex_msk = jax.lax.all_gather(ex_msk, axis_name).reshape(-1, k_max)

    # -- Eq. 3 over the global entity space (+1 padding segment); under
    # entity sharding this runs redundantly per shard on replicated inputs,
    # preserving the unsharded f32 summation order bit for bit.  The
    # existence weights are already existence x participation: absent or
    # undelivered messages arrive with mask 0, so a zero-participant round
    # produces an all-zero aggregate and priority — a no-op, not a NaN.
    agg, cnt = segment_aggregate(
        up_gid.reshape(-1),
        (ex_vals * ex_msk[:, :, None]).reshape(-1, d),
        ex_msk.reshape(-1),
        num_global + 1,
    )

    # -- personalized views: subtract the own wire-coded contribution
    agg_rows = agg[gid_blk] - own_wire
    pri_rows = (cnt[gid_blk] - uploaded_contrib) * validf
    # downstream leg crosses the wire too
    agg_rows = codec.roundtrip(agg_rows.reshape(-1, d)).reshape(cl, ns_blk, d)

    # -- downstream Top-K by priority; jitter < 1 never reorders priorities
    rank = jnp.where(valid_blk, pri_rows + jitter_blk, -1.0)
    dn_idx = top_k_select(rank, k_max, entity_axis=ea)
    dn_mask = (slot < k[:, None]) & (
        eshard.dist_take_vec(pri_rows, dn_idx, ea) > 0
    )
    if faults is not None:
        # the server only selects (and bills) rows for participating clients
        dn_mask = dn_mask & (faults.part[:, None] > 0.5)
    sign = eshard.scatter_add_vec(
        jnp.zeros((cl, ns_blk), jnp.int8), dn_idx, dn_mask.astype(jnp.int8), ea
    )
    down_count = dn_mask.sum(axis=1).astype(jnp.int32)
    if faults is not None:
        # a lost download never lands; the bytes were still sent (and the
        # down_count above — which drives the ledger — already charged them)
        sign = sign * (faults.dn_ok[:, None] > 0.5).astype(jnp.int8)

    # -- Eq. 4 masked row update, fused over the flattened client axis
    new_emb = kernel_ops.sparse_apply(
        emb.reshape(-1, d),
        agg_rows.reshape(-1, d),
        pri_rows.reshape(-1),
        sign.reshape(-1),
    ).reshape(cl, ns_blk, d).astype(emb.dtype)
    out = (new_emb, new_hist, down_count)
    if res is not None:
        out = out + (new_res,)
    if queue is not None:
        out = out + (new_queue,)
    if prev is not None:
        prev_idx, prev_msk = prev
        up_idx32 = up_idx.astype(jnp.int32)
        if faults is None:
            partf = up_okf = dn_okf = jnp.ones((cl,), emb.dtype)
            new_prev = (up_idx32, up_maskf)
        else:
            partf, up_okf, dn_okf = faults.part, faults.up_ok, faults.dn_ok
            # the carry tracks the last upload actually SENT: absent clients
            # keep their previous selection
            partb = partf[:, None] > 0.5
            new_prev = (
                jnp.where(partb, up_idx32, prev_idx),
                jnp.where(partb, up_maskf, prev_msk),
            )
        if new_res is not None:
            res_mass = residual_mass(new_res, entity_axis=ea)
        else:
            res_mass = jnp.zeros((cl,), emb.dtype)
        # model-health probes run on full-width (all-blocks) buffers so the
        # divergence segment sums keep the unsharded summation order (the
        # batched_sync_round rule); nonfinite is integer, hence order-exact
        new_full = eshard.all_blocks(new_emb, ea)
        div_mean, div_max = shared_divergence(
            new_full, gid, valid, num_global, axis_name=axis_name
        )
        rec = RoundTelemetry(
            up_rows=sent_maskf.sum(axis=1).astype(jnp.int32),
            dn_rows=down_count,
            overlap=upload_overlap(up_idx, sent_maskf, prev_idx, prev_msk),
            res_mass=res_mass,
            part=partf,
            up_ok=up_okf,
            dn_ok=dn_okf,
            # ages live in FaultArrays; the cycle engines overwrite this
            # placeholder with the post-update counters
            age=jnp.zeros((cl,), jnp.int32),
            score_hist=score_histogram(scores, valid_blk, entity_axis=ea),
            div_mean=div_mean,
            div_max=div_max,
            upd_norm=update_norm(new_full, eshard.all_blocks(emb, ea), valid),
            nonfinite=nonfinite_count(new_full, valid),
        )
        out = out + (rec, new_prev)
    return out


def batched_sync_round(
    emb: jnp.ndarray,  # (C_local, Ns_max, D)
    gid: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    num_global: int,
    axis_name: Optional[str],
    entity_axis: Optional[str] = None,
    faults=None,  # Optional[repro.core.faults.RoundFaults] of (C_local,) masks
):
    """Intermittent synchronization (§III-E): FedE mean over owning clients.

    Returns (synchronized rows, refreshed history).  History is the PRE-sync
    rows — the protocol refreshes it with what was uploaded, matching
    :func:`repro.core.protocol.full_upload`.

    With ``faults``, the mean runs over delivered uploads only
    (``part * up_ok`` existence weights) and lands only on clients that
    participate and receive (``part * dn_ok``) — the recovery point for
    clients that missed the span.  Entities whose every owner is absent this
    round keep their rows: the ``cnt > 0`` guard below masks them out of the
    mean instead of writing the clamped-denominator zero row (the latent
    zero-participant divide-by-zero edge in the Eq. 3 weight normalization;
    unreachable without faults since every valid row contributes itself,
    so the guard changes nothing in fault-free programs).

    With ``entity_axis``, ``emb`` is this shard's slot block; the blocks are
    all-gathered once and the Eq. 3-style segment mean computed redundantly
    per shard in the unsharded summation order (a per-shard partial sum +
    f32 psum would reorder the additions and break the bitwise contract),
    then each shard keeps its local slice of the synchronized rows.
    """
    blk = emb.shape[1]
    emb_full = eshard.all_blocks(emb, entity_axis)
    cl, ns, d = emb_full.shape
    validf = valid.astype(emb.dtype)
    if faults is not None:
        validf = validf * (faults.part * faults.up_ok)[:, None]
    ids = jnp.where(valid, gid, num_global).reshape(-1)
    total, cnt = segment_aggregate(
        ids, (emb_full * validf[:, :, None]).reshape(-1, d), validf.reshape(-1),
        num_global + 1,
    )
    if axis_name is not None:
        total = jax.lax.psum(total, axis_name)
        cnt = jax.lax.psum(cnt, axis_name)
    mean = total / jnp.maximum(cnt, 1.0)[:, None]
    live = valid & (cnt[gid] > 0)
    if faults is not None:
        live = live & ((faults.part * faults.dn_ok)[:, None] > 0.5)
    new_emb = jnp.where(live[:, :, None], mean[gid], emb_full)
    if entity_axis is None:
        return new_emb, emb
    return eshard.local_block(new_emb, entity_axis, blk), emb


# --------------------------------------------------------------------------
class RoundEngine:
    """Compiled FedS communication rounds over batched client state.

    Built once per federation from the static comm views; per round the
    caller gathers each client's current entity table into the padded batch,
    runs :meth:`sparse_round` / :meth:`sync_round`, and scatters the result
    back.  With ``mesh=None`` the round is a single-device jit; with a mesh
    it is ``shard_map``-ped over the client axis (C must be divisible by the
    mesh size).
    """

    def __init__(
        self,
        views: Sequence,  # list[repro.core.protocol.ClientCommView]
        num_global_entities: int,
        dim: int,
        sparsity_p: float,
        codec: Optional[WireCodec] = None,
        mesh=None,
        axis_name: str = "clients",
        telemetry: bool = False,
    ):
        self.views = list(views)
        self._tel = bool(telemetry)
        self.num_global = int(num_global_entities)
        self.dim = int(dim)
        self.codec = codec if codec is not None else IdentityCodec()
        if self.codec.has_residual:
            raise ValueError(
                f"codec {self.codec!r} carries error-feedback residual state; "
                "RoundEngine is stateless per round — use CycleEngine/"
                "SuperstepEngine, which thread residuals through "
                "FederationState"
            )
        self.num_clients = len(self.views)
        gid, valid, self.k_per_client, self.ns_max, self.k_max = build_padded_views(
            self.views, self.num_global, sparsity_p
        )
        self._gid = jnp.asarray(gid)
        self._valid = jnp.asarray(valid)
        self._k = jnp.asarray(self.k_per_client)

        axis = axis_name if mesh is not None else None
        sparse_core = functools.partial(
            batched_sparse_round, k_max=self.k_max, num_global=self.num_global,
            codec=self.codec, axis_name=axis,
        )
        sync_core = functools.partial(
            batched_sync_round, num_global=self.num_global, axis_name=axis,
        )
        def sparse_faulted(emb, hist, gid, valid, k, jitter, part, up_ok, dn_ok):
            from repro.core.faults import RoundFaults

            return sparse_core(
                emb, hist, gid, valid, k, jitter,
                faults=RoundFaults(part, up_ok, dn_ok),
            )

        def sparse_tel(emb, hist, gid, valid, k, jitter, prev_idx, prev_msk):
            return sparse_core(
                emb, hist, gid, valid, k, jitter, prev=(prev_idx, prev_msk)
            )

        def sparse_faulted_tel(
            emb, hist, gid, valid, k, jitter, part, up_ok, dn_ok,
            prev_idx, prev_msk,
        ):
            from repro.core.faults import RoundFaults

            return sparse_core(
                emb, hist, gid, valid, k, jitter,
                faults=RoundFaults(part, up_ok, dn_ok),
                prev=(prev_idx, prev_msk),
            )

        def sync_faulted(emb, gid, valid, part, up_ok, dn_ok):
            from repro.core.faults import RoundFaults

            return sync_core(
                emb, gid, valid, faults=RoundFaults(part, up_ok, dn_ok)
            )

        if mesh is None:
            self._sparse = jax.jit(sparse_core)
            self._sync = jax.jit(sync_core)
            self._sparse_faulted = jax.jit(sparse_faulted)
            self._sync_faulted = jax.jit(sync_faulted)
            if self._tel:
                self._sparse_tel = jax.jit(sparse_tel)
                self._sparse_faulted_tel = jax.jit(sparse_faulted_tel)
        else:
            p = jax.sharding.PartitionSpec(axis_name)
            self._sparse = jax.jit(shard_map(
                sparse_core, mesh=mesh,
                in_specs=(p, p, p, p, p, p), out_specs=(p, p, p),
            ))
            self._sync = jax.jit(shard_map(
                sync_core, mesh=mesh, in_specs=(p, p, p), out_specs=(p, p),
            ))
            self._sparse_faulted = jax.jit(shard_map(
                sparse_faulted, mesh=mesh,
                in_specs=(p,) * 9, out_specs=(p, p, p),
            ))
            self._sync_faulted = jax.jit(shard_map(
                sync_faulted, mesh=mesh,
                in_specs=(p,) * 6, out_specs=(p, p),
            ))
            if self._tel:
                ts = telemetry_record_spec(p)
                self._sparse_tel = jax.jit(shard_map(
                    sparse_tel, mesh=mesh,
                    in_specs=(p,) * 8, out_specs=(p, p, p, ts, (p, p)),
                ))
                self._sparse_faulted_tel = jax.jit(shard_map(
                    sparse_faulted_tel, mesh=mesh,
                    in_specs=(p,) * 11, out_specs=(p, p, p, ts, (p, p)),
                ))

    # ------------------------------------------------------- host transfers
    def gather(self, tables: Sequence) -> jnp.ndarray:
        """Stack each client's shared-entity rows into (C, Ns_max, D)."""
        out = np.zeros((self.num_clients, self.ns_max, self.dim), np.float32)
        for c, (v, t) in enumerate(zip(self.views, tables)):
            if v.num_shared:
                out[c, : v.num_shared] = np.asarray(t, np.float32)[v.shared_local]
        return jnp.asarray(out)

    def scatter(self, batch: jnp.ndarray, tables: Sequence) -> list:
        """Write the updated shared rows back into each client's full table."""
        out = []
        for c, (v, t) in enumerate(zip(self.views, tables)):
            if v.num_shared:
                rows = batch[c, : v.num_shared].astype(t.dtype)
                t = t.at[jnp.asarray(v.shared_local)].set(rows)
            out.append(t)
        return out

    # --------------------------------------------------------------- rounds
    def sparse_round(
        self,
        emb: jnp.ndarray,  # (C, Ns_max, D)
        hist: jnp.ndarray,  # (C, Ns_max, D)
        jitter: Optional[jnp.ndarray] = None,  # (C, Ns_max) in [0, 1)
        faults=None,  # Optional[repro.core.faults.RoundFaults] of (C,) masks
        prev=None,  # telemetry carry (requires telemetry=True at init)
    ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """One sparse FedS round.  Returns (emb', hist', down_count (C,)),
        plus ``(RoundTelemetry, prev')`` when a telemetry carry is passed.

        ``faults`` injects per-round participation / message-drop masks
        (:mod:`repro.core.faults`).  RoundEngine is stateless per round, so
        straggler queues (which need carried state) are the cycle engines'
        job — exactly like EF residuals; the telemetry overlap carry is
        likewise the *caller's* state, threaded explicitly via ``prev``.
        """
        if prev is not None and not self._tel:
            raise ValueError("pass telemetry=True at construction to record")
        if jitter is None:
            jitter = jnp.zeros((self.num_clients, self.ns_max), jnp.float32)
        # halve after the f32 cast: float64 values in [1-2^-25, 1) round to
        # exactly 1.0f, which would tie with the next priority level
        jitter = jnp.asarray(jitter, jnp.float32) * 0.5
        if faults is None:
            if prev is None:
                return self._sparse(
                    emb, hist, self._gid, self._valid, self._k, jitter
                )
            return self._sparse_tel(
                emb, hist, self._gid, self._valid, self._k, jitter,
                prev[0], prev[1],
            )
        masks = (
            jnp.asarray(faults.part, jnp.float32),
            jnp.asarray(faults.up_ok, jnp.float32),
            jnp.asarray(faults.dn_ok, jnp.float32),
        )
        if prev is None:
            return self._sparse_faulted(
                emb, hist, self._gid, self._valid, self._k, jitter, *masks
            )
        return self._sparse_faulted_tel(
            emb, hist, self._gid, self._valid, self._k, jitter, *masks,
            prev[0], prev[1],
        )

    def sync_round(
        self, emb: jnp.ndarray, faults=None
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """One full-synchronization round.  Returns (emb', hist')."""
        if faults is None:
            return self._sync(emb, self._gid, self._valid)
        return self._sync_faulted(
            emb, self._gid, self._valid,
            jnp.asarray(faults.part, jnp.float32),
            jnp.asarray(faults.up_ok, jnp.float32),
            jnp.asarray(faults.dn_ok, jnp.float32),
        )
