"""TPU-native FedS: sparse embedding synchronization as an SPMD collective.

This is the deployment path of the paper's protocol (DESIGN.md §3): clients
are shards of the ``data`` mesh axis (cross-silo federation on a pod), and one
FedS communication round becomes a single `shard_map`-wrapped collective:

1. each shard computes entity-wise change scores vs its upload history
   (fused Pallas kernel) and selects its static Top-K rows,
2. the (indices, values) buffers are exchanged with ``lax.all_gather`` over
   the client axis — fixed-size dense buffers, the TPU-idiomatic replacement
   for the paper's ragged uploads,
3. every shard reproduces the *personalized* server aggregation locally:
   ``segment_sum`` scatter-adds every OTHER shard's uploads into a dense
   (N, D) aggregate + (N,) priority-count vector (Eq. 3),
4. downstream Top-K by priority (upload frequency) with a deterministic
   jitter tie-break, then the fused Eq. 4 masked row update.

Semantic deltas vs the host protocol (property-tested in
tests/test_distributed.py): static K (ragged "fewer-than-K" handled by the
priority>0 mask) and deterministic instead of random tie-breaking.

The aggregation and downstream-selection primitives (``segment_aggregate``,
``downstream_sign``) are shared with :mod:`repro.core.engine`, whose
RoundEngine runs the same round over heterogeneous batched client state —
this module keeps the homogeneous shard-per-client collective where each
shard holds the full (N, D) table.

Communication cost per round per shard: ``K·D + K`` words gathered from each
peer — exactly the paper's upstream payload; the "download" is computed
redundantly on-shard instead of transmitted, which on a pod is free (the
all-gather already delivered the inputs) and removes the server round-trip
entirely.  This is a beyond-paper optimization recorded in EXPERIMENTS.md
§Perf: bidirectional client↔server traffic becomes one all-gather.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import (
    axis_size,
    downstream_sign,
    pcast_varying,
    segment_aggregate,
    shard_map,
)
from repro.core.sparsify import change_scores, select_top_k
from repro.kernels import ops as kernel_ops


def sparse_sync_step(
    emb: jnp.ndarray,  # (N, D) this shard's embedding table
    hist: jnp.ndarray,  # (N, D) this shard's upload history
    k: int,
    axis_name: str = "data",
    jitter: Optional[jnp.ndarray] = None,  # (N,) tie-break noise in [0, 1)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One FedS round as seen by one shard (call inside shard_map).

    Returns (updated embeddings, updated history).
    """
    n, d = emb.shape
    num_clients = axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)

    # -- upstream: entity-wise Top-K (Eq. 1-2)
    scores = change_scores(emb, hist)
    idx, _sign = select_top_k(scores, k)
    vals = jnp.take(emb, idx, axis=0)
    new_hist = hist.at[idx].set(vals)

    # -- exchange: one all-gather of fixed-size buffers
    all_idx = jax.lax.all_gather(idx, axis_name)  # (C, K)
    all_vals = jax.lax.all_gather(vals, axis_name)  # (C, K, D)

    # -- personalized aggregation (Eq. 3): exclude own upload
    peer = (jnp.arange(num_clients) != me).astype(emb.dtype)  # (C,)
    flat_idx = all_idx.reshape(-1)
    flat_vals = (all_vals * peer[:, None, None]).reshape(-1, d)
    flat_cnt = jnp.broadcast_to(peer[:, None], (num_clients, k)).reshape(-1)
    agg, pri = segment_aggregate(flat_idx, flat_vals, flat_cnt, n)

    # -- downstream personalized Top-K by priority weight
    rank_key = pri + (jitter if jitter is not None else 0.0)
    sign = downstream_sign(pri, rank_key, k)

    # -- Eq. 4 masked row update (fused kernel)
    new_emb = kernel_ops.sparse_apply(emb, agg, pri, sign).astype(emb.dtype)
    return new_emb, new_hist


def full_sync_step(
    emb: jnp.ndarray, axis_name: str = "data"
) -> jnp.ndarray:
    """Intermittent synchronization round: FedE mean across all shards."""
    return jax.lax.pmean(emb, axis_name)


def feds_round(
    emb: jnp.ndarray,
    hist: jnp.ndarray,
    round_idx: jnp.ndarray,  # () int32
    k: int,
    sync_interval: int,
    axis_name: str = "data",
    jitter: Optional[jnp.ndarray] = None,
):
    """Dispatch sparse vs synchronization round under jit (lax.cond)."""

    def sparse(args):
        e, h = args
        return sparse_sync_step(e, h, k, axis_name, jitter)

    def full(args):
        e, _h = args
        mean = full_sync_step(e, axis_name)
        # pmean output is axis-invariant; re-mark it varying so both cond
        # branches have identical vma types under shard_map.
        mean = pcast_varying(mean, axis_name)
        # history refreshes to the PRE-sync rows — what this shard uploaded —
        # matching repro.core.protocol.full_upload and the batched engine.
        return mean, e

    is_sync = (round_idx + 1) % (sync_interval + 1) == 0
    return jax.lax.cond(is_sync, full, sparse, (emb, hist))


def make_sharded_feds_round(mesh, k: int, sync_interval: int, axis_name: str = "data"):
    """Build a jitted shard_map'd FedS round over ``mesh[axis_name]``.

    The embedding/history tables are per-shard-private (one "client" replica
    per data shard), expressed as a leading client axis sharded over
    ``axis_name``: callers pass (C, N, D) global arrays.
    """

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P()),
        out_specs=(P(axis_name), P(axis_name)),
    )
    def _round(emb_c, hist_c, round_idx):
        # emb_c: (1, N, D) — this shard's client table
        new_emb, new_hist = feds_round(
            emb_c[0], hist_c[0], round_idx[0], k, sync_interval, axis_name
        )
        return new_emb[None], new_hist[None]

    return jax.jit(_round)
