"""arctic-480b [moe] — 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].

Arctic's dense-MoE hybrid: every layer runs a routed top-2 MoE *in parallel*
with a dense residual FFN (``dense_residual=True``).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    num_experts_per_tok=2,
    moe_d_ff=4864,
    dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
    attn_pad_heads=64,  # 56 heads don't divide the 16-way model axis
    moe_group_size=2048,  # smaller routing groups (dispatch flops)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, num_experts=4, num_experts_per_tok=2,
        moe_d_ff=128,
    )
