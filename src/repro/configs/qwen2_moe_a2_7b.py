"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,  # shared-expert fused width = 4 * 1408
    vocab_size=151936,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    moe_d_ff=1408,
    qkv_bias=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
    moe_pad_experts=64,  # 60 experts don't divide the 16-way model axis
    moe_group_size=256,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, num_experts=4, num_experts_per_tok=2,
        num_shared_experts=1, moe_d_ff=64,
    )
