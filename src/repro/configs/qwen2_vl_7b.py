"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision encoder (ViT + merger) is a STUB per the brief: ``input_specs``
provides precomputed patch embeddings of shape (B, num_patches, d_model);
this config is the language/decoder backbone that consumes them.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,  # GQA kv=4
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),  # t/h/w rotary halves (head_dim 128)
    rope_theta=1000000.0,
    num_patches=256,
    source="arXiv:2409.12191",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
    attn_pad_heads=32,  # 28 heads don't divide the 16-way model axis
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=512, num_patches=16, mrope_sections=(8, 12, 12),
    )
