"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block [arXiv:2411.15242].

38 Mamba2 layers; ONE shared (weight-tied) attention+MLP block applied every
``attn_every`` layers — the zamba2 design point: attention quality at
near-zero parameter cost.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    source="arXiv:2411.15242",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, ssm_state=16, ssm_head_dim=32, attn_every=2,
    )
