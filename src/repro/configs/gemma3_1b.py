"""gemma3-1b [dense] — 5:1 local:global attention, 128k [hf:google/gemma-3-1b-pt].

Every 6th layer is global; the rest use a 512-token sliding window.  This is
the one dense arch that runs ``long_500k``: at that shape the global layers
fall back to the 128k design-budget window (DESIGN.md §5).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    sliding_window=512,
    global_every=6,
    tie_embeddings=True,
    rope_theta=1000000.0,
    source="hf:google/gemma-3-1b-pt",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
    shard_heads="context",  # 4 heads: context parallelism (§Perf)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=1,
        head_dim=64, d_ff=512, vocab_size=512, sliding_window=16, global_every=2,
    )
