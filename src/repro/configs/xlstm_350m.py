"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

24 blocks, every 6th an sLSTM (scalar memory, sequential), the rest mLSTM
(matrix memory, chunkwise-parallel).  ``d_ff=0``: the FFN lives inside the
blocks (mLSTM pre-up-projection / sLSTM 4/3 gated FFN).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=6,
    tie_embeddings=True,
    source="arXiv:2405.04517",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
    anchor_batch=False,  # GSPMD's batch x (data,model) layout wins here
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        vocab_size=512, slstm_every=2,
    )
