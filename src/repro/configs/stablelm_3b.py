"""stablelm-3b [dense] — [hf:stabilityai/stablelm-2-1_6b]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    arch_type="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,  # GQA kv=32 (full MHA)
    d_ff=6912,
    vocab_size=50304,
    rope_theta=10000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        d_ff=512, vocab_size=512,
    )
