"""whisper-base [audio] — enc-dec transformer backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the brief: ``input_specs``
provides precomputed frame embeddings (B, 1500, d_model) = 30 s of audio at
the post-conv 50 Hz rate.  Adaptation note (DESIGN.md): decoder uses RoPE in
place of Whisper's learned absolute positions (mechanically equivalent for
dry-run/roofline purposes; both are O(1) params vs the stack).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=6,
    encoder_seq_len=1500,
    tie_embeddings=True,
    source="arXiv:2212.04356",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
    shard_heads="context",  # 8 heads: context parallelism (§Perf)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, encoder_layers=2, encoder_seq_len=64,
    )
