"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B]."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B",
    flash_vjp=True,  # §Perf default (exact; see EXPERIMENTS.md)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
    )
