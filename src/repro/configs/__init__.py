"""Assigned-architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Each module defines ``CONFIG`` (the exact assigned dimensions, source cited)
and ``smoke()`` (a reduced same-family variant: <=2 layers, d_model <= 512,
<= 4 experts) used by the CPU smoke tests.

Liveness audit (2026-08): none of these modules are seed-era dead weight —
every arch in ``_ARCHS`` is exercised by tier-1 tests
(tests/test_stack_structure.py, tests/test_models_zoo.py via
``get_smoke_config``) and by ``repro.launch.dryrun --all`` /
``repro.launch.serve``, which iterate ``list_archs()``.  Removing one
breaks those suites; adding one here is all it takes to cover a new arch.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_ARCHS = {
    "stablelm-3b": "stablelm_3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-base": "whisper_base",
    "arctic-480b": "arctic_480b",
    "gemma3-1b": "gemma3_1b",
    "qwen2-72b": "qwen2_72b",
    "qwen3-0.6b": "qwen3_0_6b",
    "xlstm-350m": "xlstm_350m",
}


def list_archs() -> list[str]:
    return list(_ARCHS)


def _module(arch_id: str):
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke()
