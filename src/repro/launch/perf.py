"""§Perf iteration tool: re-lower one (arch x shape) with config overrides
and print the roofline deltas vs the recorded baseline.

  PYTHONPATH=src python -m repro.launch.perf --arch arctic-480b \
      --shape prefill_32k --set moe_shard_dispatch=True --set moe_group_size=2048

Also supports ``--dump-collectives`` to print the largest collective ops of
the optimized HLO (the "profile" of the dry-run methodology).
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)

import argparse
import ast
import json


def parse_set(items):
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        try:
            out[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            out[k] = v
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--set", action="append", help="cfg override key=value")
    ap.add_argument("--baseline", default="dryrun_baseline.jsonl")
    ap.add_argument("--out", default=None, help="append optimized record here")
    args = ap.parse_args()

    from benchmarks.roofline import roofline_row
    from repro.launch.dryrun import dryrun_one

    extra = parse_set(args.set)
    rec = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod,
                     strategy=args.strategy, extra=extra)
    if rec["status"] != "OK":
        print(json.dumps(rec, indent=2))
        raise SystemExit(1)
    row = roofline_row(rec)

    base_row = None
    if os.path.exists(args.baseline):
        mesh = "2x16x16" if args.multi_pod else "16x16"
        for line in open(args.baseline):
            r = json.loads(line)
            if (r.get("arch"), r.get("shape"), r.get("mesh")) == (
                args.arch, args.shape, mesh,
            ) and r["status"] == "OK":
                base_row = roofline_row(r)

    print(f"\n{args.arch} x {args.shape}  overrides={extra}")
    hdr = f"{'term':14s} {'baseline':>12s} {'optimized':>12s} {'delta':>8s}"
    print(hdr)
    for key in ("t_compute_s", "t_memory_s", "t_collective_s",
                "useful_ratio", "step_lower_bound_s"):
        b = base_row[key] if base_row else float("nan")
        o = row[key]
        delta = (o - b) / b * 100 if base_row and b else float("nan")
        print(f"{key:14s} {b:12.4e} {o:12.4e} {delta:+7.1f}%")
    print(f"bottleneck: {base_row['bottleneck'] if base_row else '?'} -> "
          f"{row['bottleneck']}")
    print("collectives/dev:", {k: f"{v:.2e}" for k, v in
                               rec["collective_bytes_per_device"].items()})
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps({**rec, "overrides": extra}) + "\n")


if __name__ == "__main__":
    main()
