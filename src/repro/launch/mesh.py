"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device.

Target hardware: TPU v5e pods, 256 chips each (16x16 ICI torus);
``multi_pod=True`` models 2 pods = 512 chips with a leading DCN ``pod`` axis.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    # jax <= 0.4.x has no AxisType; meshes there are implicitly auto-typed
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for CI-scale sharding tests (requires >= data*model devices)."""
    return _make_mesh((data, model), ("data", "model"))


def make_federation_mesh(
    num_devices: int,
    axis_name: str = "clients",
    entity_devices: int = 1,
    entity_axis: str = "entities",
) -> jax.sharding.Mesh:
    """Mesh for the federation engines (pod-mode simulation).

    ``federated/simulation.py`` builds this when ``mesh_devices > 1`` or
    ``mesh_entities > 1`` and hands it to
    :class:`repro.core.state.CycleEngine` /
    :class:`~repro.core.state.SuperstepEngine`, which ``shard_map`` their
    per-cycle / per-superstep programs over it.

    * ``entity_devices == 1`` (default): the historical 1-D ``clients`` mesh
      — the client count must be divisible by ``num_devices``, and the only
      collectives are the round's one all-gather (sparse) / psum (sync).
    * ``entity_devices > 1``: a 2-D ``(clients, entities)`` mesh.  The
      second axis block-shards every padded row-major table — entity
      embeddings + Adam moments along ``E_pad``, upload history / EF
      residuals along ``Ns_pad``, eval filter words along the packed word
      axis — so per-device resident state shrinks by ``entity_devices``
      while staying bitwise identical to the unsharded engines
      (:mod:`repro.core.eshard`).
    """
    if entity_devices <= 1:
        return _make_mesh((num_devices,), (axis_name,))
    return _make_mesh(
        (num_devices, entity_devices), (axis_name, entity_axis)
    )


def mesh_context(mesh: jax.sharding.Mesh):
    """``jax.sharding.set_mesh(mesh)`` on jax >= 0.5; on jax <= 0.4.x the
    ``Mesh`` object is itself the equivalent context manager."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


# TPU v5e per-chip constants for the roofline (DESIGN.md §6)
TPU_V5E = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link
}
