"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this:
  1. builds ShapeDtypeStruct stand-ins for params / optimizer / inputs
     (``jax.eval_shape`` — zero allocation),
  2. jits the right step (train_step / prefill_step / serve_step) with
     explicit NamedShardings from repro.sharding.specs,
  3. ``.lower(...).compile()`` — a sharding mismatch, an unsupported
     collective, or a per-chip OOM here is a bug in the system,
  4. records memory_analysis / cost_analysis / per-collective bytes parsed
     from the partitioned HLO into a JSON report consumed by
     benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
# ^ MUST run before any other import (jax locks the device count on first
# init).  The dry-run — and ONLY the dry-run — needs 512 placeholder devices.

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models.transformer import init_lm
from repro.sharding.specs import (
    decode_state_specs,
    input_specs_sharding,
    param_specs,
    strategy_for,
)
from repro.train.optimizer import AdamState
from repro.train.steps import (
    INPUT_SHAPES,
    init_serve_state,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    shape_supported,
)

def dryrun_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    strategy: str | None = None,
    extra: dict | None = None,
) -> dict:
    """Lower + compile one (arch, shape, mesh). Returns the roofline record."""
    cfg = get_config(arch)
    if extra:
        import dataclasses
        cfg = dataclasses.replace(cfg, **extra)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "SKIP", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = strategy or strategy_for(cfg, shape.kind)
    t0 = time.time()

    params_shape = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, cfg, mesh, strategy)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    in_specs = input_specs(cfg, shape)
    ispecs = input_specs_sharding(in_specs, cfg, mesh)
    ishard = {k: NamedSharding(mesh, v) for k, v in ispecs.items()}

    if shape.kind == "train":
        opt_shape = jax.eval_shape(
            lambda p: AdamState(
                step=jnp.zeros((), jnp.int32),
                mu=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                nu=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
            ),
            params_shape,
        )
        ospecs = AdamState(step=P(), mu=pspecs, nu=pspecs)
        oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)
        step = make_train_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, ishard),
            out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
        )
        with mesh_context(mesh):
            lowered = jitted.lower(params_shape, opt_shape, in_specs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(pshard, ishard))
        with mesh_context(mesh):
            lowered = jitted.lower(params_shape, in_specs)
    else:  # decode
        long_ctx = shape.name == "long_500k"
        enc_spec = in_specs.get("encoder_embeds")
        state_shape = jax.eval_shape(
            lambda p: init_serve_state(p, cfg, shape, encoder_embeds=enc_spec and
                                       jnp.zeros(enc_spec.shape, enc_spec.dtype)),
            params_shape,
        )
        sspecs = decode_state_specs(state_shape, cfg, mesh)
        sshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs)
        step = make_serve_step(cfg, long_context=long_ctx)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, ishard["token"], sshard),
            out_shardings=(None, sshard),
        )
        with mesh_context(mesh):
            lowered = jitted.lower(params_shape, in_specs["token"], state_shape)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax <= 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    n_dev = mesh.devices.size
    hlo = compiled.as_text()
    from repro.launch.hlo_costs import analyze as hlo_analyze

    walker = hlo_analyze(hlo)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy,
        "status": "OK",
        "kind": shape.kind,
        "num_devices": int(n_dev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # loop-aware walker numbers (per device) — the roofline inputs
        "flops_per_device": float(walker["flops"]),
        "bytes_per_device": float(walker["bytes"]),
        "collective_bytes_per_device": {
            **{k: float(v) for k, v in walker["collectives"].items()},
            "_total": float(walker["collective_bytes"]),
        },
        # raw XLA numbers for reference (while bodies counted once!)
        "xla_flops_per_device": float(cost.get("flops", -1.0)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "tokens": shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1),
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default=None, choices=[None, "tp", "fsdp"])
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if multi else '16x16'}"
                try:
                    rec = dryrun_one(arch, shape, multi_pod=multi, strategy=args.strategy)
                except Exception as e:  # a failure here is a sharding bug
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
                records.append(rec)
                if rec["status"] == "OK":
                    mem_gb = (rec["memory"]["argument_bytes"]
                              + rec["memory"]["temp_bytes"]) / rec["num_devices"] / 2**30
                    print(f"[OK]   {tag}  compile={rec['compile_s']}s  "
                          f"flops/dev={rec['flops_per_device']:.3e}  "
                          f"coll/dev={rec['collective_bytes_per_device']['_total']:.3e}B")
                elif rec["status"] == "SKIP":
                    print(f"[SKIP] {tag}  ({rec['reason'][:60]}...)")
                else:
                    print(f"[FAIL] {tag}  {rec['error'][:200]}")
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "OK" for r in records)
    n_skip = sum(r["status"] == "SKIP" for r in records)
    n_fail = sum(r["status"] == "FAIL" for r in records)
    print(f"\ndry-run summary: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
