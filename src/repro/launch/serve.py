"""Serving driver: batched autoregressive decode for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --steps 16

``--smoke`` selects the reduced config (CPU-runnable); without it the full
config is lowered but not executed (this container cannot hold the weights).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.train.steps import (
    InputShape,
    init_serve_state,
    init_train_state,
    make_serve_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = InputShape("serve", seq_len=args.cache_len, global_batch=args.batch,
                       kind="decode")
    print(f"serving {cfg.name} ({'smoke' if args.smoke else 'full'}) "
          f"batch={args.batch} cache={args.cache_len}")

    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    enc = None
    if cfg.arch_type == "audio":
        enc = jnp.zeros((args.batch, cfg.encoder_seq_len, cfg.d_model), cfg.dtype)
    state = init_serve_state(params, cfg, shape, encoder_embeds=enc)
    state = state._replace(pos=jnp.zeros((args.batch,), jnp.int32))
    step = jax.jit(make_serve_step(cfg))

    key = jax.random.PRNGKey(1)
    token = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.time()
    toks = []
    for i in range(args.steps):
        logits, state = step(params, token, state)
        key, sub = jax.random.split(key)
        token = jax.random.categorical(
            sub, logits / args.temperature, axis=-1
        )[:, None].astype(jnp.int32)
        toks.append(token[:, 0])
    jax.block_until_ready(token)
    dt = time.time() - t0
    toks_arr = jnp.stack(toks, axis=1)
    print(f"decoded {args.steps} steps x {args.batch} seqs in {dt:.2f}s "
          f"({args.steps * args.batch / dt:.1f} tok/s on CPU)")
    print("sampled ids (seq 0):", toks_arr[0].tolist())


if __name__ == "__main__":
    main()
