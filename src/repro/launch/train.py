"""Federated KGE training driver (the paper's end-to-end workload).

Runs FedS / FedEP / FedEPL / Single on the synthetic FB15k-237-R{N} stand-in
with fault injection, checkpoint/resume durability, and a final report.

  PYTHONPATH=src python -m repro.launch.train --protocol feds --clients 3 \
      --method transe --rounds 40 --faults p=0.8,drop_up=0.1,seed=7 \
      --checkpoint out/feds.npz --checkpoint-every 10 --resume
"""
from __future__ import annotations

import argparse
import json

from repro.core.codecs import codec_usage, parse_codec_spec
from repro.core.faults import parse_fault_spec
from repro.core.health import ALERT_MODES, parse_alert_spec
from repro.core.sync import comm_ratio_worst_case
from repro.data import generate_kg, partition_by_relation
from repro.federated.simulation import FederatedConfig, run_federated
from repro.kge.scoring import parse_method, scoring_usage


def _positive_int(value: str) -> int:
    """argparse type for flags that must be >= 1 (cadences, caps)."""
    n = int(value)
    if n < 1:
        raise argparse.ArgumentTypeError(f"expected a positive integer, got {n}")
    return n


def _codec_spec(spec: str) -> str:
    """Validate a --codec spec eagerly so parse errors surface at argparse
    time, carrying the registry's own name/kwargs listing."""
    try:
        parse_codec_spec(spec)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None
    return spec


def _method_name(name: str) -> str:
    """Validate --method against the scoring registry eagerly, carrying the
    registry's own listing of registered methods (unlike a frozen choices=
    list, new registrations show up here automatically)."""
    try:
        return parse_method(name)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None


def _fault_spec(spec: str) -> str:
    """Validate a --faults spec eagerly, carrying the grammar message."""
    try:
        parse_fault_spec(spec)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None
    return spec


def _alert_spec(spec: str) -> str:
    """Validate an --alerts spec eagerly, carrying the grammar message."""
    try:
        parse_alert_spec(spec)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None
    return spec


def main() -> None:
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="registered scoring methods (--method name):\n"
        + scoring_usage()
        + "\n\nregistered wire codecs (--codec name:key=val,...):\n"
        + codec_usage(),
    )
    ap.add_argument("--protocol", default="feds",
                    choices=["feds", "feds_nosync", "fedep", "single"])
    ap.add_argument("--method", default="transe", type=_method_name,
                    help="scoring method from the registry (see epilog)")
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--local-epochs", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--negatives", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--sparsity", type=float, default=0.4)
    ap.add_argument("--engine", default="fused",
                    choices=["fused", "superstep", "batched", "reference",
                             "tiered"],
                    help="fused = one device-resident program per cycle; "
                         "superstep = one scanned program per ISM span; "
                         "batched = per-round jitted programs (oracle); "
                         "reference = numpy host protocol; "
                         "tiered = host-tiered embedding store "
                         "(E_max-scalable, see --host-store)")
    ap.add_argument("--mesh-devices", type=int, default=0,
                    help=">1: pod mode — shard the client axis over a 1-D "
                         "device mesh (clients must divide evenly)")
    ap.add_argument("--mesh-entities", type=int, default=0,
                    help=">1: shard the ENTITY axis over a 2-D (clients, "
                         "entities) mesh — per-device entity state scales as "
                         "E_pad / shards, bitwise identical to unsharded")
    ap.add_argument("--host-store", action="store_true",
                    help="host-tiered embedding store (engine='tiered'): "
                         "device holds only the shared prefix + a bounded "
                         "row cache; E_max becomes a config value, not a "
                         "device-memory obligation")
    ap.add_argument("--cache-slots", type=int, default=0,
                    help="tiered engine device cache rows per client "
                         "(0 = floor: exactly the working-view width)")
    ap.add_argument("--stage-steps", type=int, default=0,
                    help="tiered engine batches per staging segment — sets "
                         "the device working-set width (0 = whole epoch)")
    ap.add_argument("--codec", type=_codec_spec, default="identity",
                    metavar="NAME[:KEY=VAL,...]",
                    help="wire codec spec (see the registered-codec listing "
                         "below); ef=1 enables device-resident error-feedback "
                         "residuals on lossy codecs")
    ap.add_argument("--quantize-upload", action="store_true",
                    help="FedS+Q8: int8 row payloads on the wire "
                         "(legacy alias for --codec int8)")
    ap.add_argument("--sync-interval", type=int, default=4)
    ap.add_argument("--eval-every", type=_positive_int, default=5,
                    help="validation cadence in rounds; a terminal eval is "
                         "guaranteed even when rounds %% eval-every != 0")
    ap.add_argument("--max-eval-triples", type=_positive_int, default=500,
                    help="per-client cap on eval triples per split (sizes "
                         "the device evaluator's padded (C, B_max) banks)")
    ap.add_argument("--entities", type=int, default=400)
    ap.add_argument("--triples", type=int, default=5000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", type=_fault_spec, default="",
                    metavar="KEY=VAL[,...]",
                    help="seeded fault schedule, e.g. "
                         "'p=0.8,drop_up=0.1,stragglers=0:2,lag=2,seed=7' — "
                         "per-round Bernoulli participation, message drops "
                         "on either leg, lagged stragglers (empty = "
                         "reliable federation, bitwise identical to no "
                         "--faults at all)")
    ap.add_argument("--checkpoint", default="",
                    metavar="PATH.npz",
                    help="checkpoint file for durable resume (atomic "
                         "writes; holds the full FederationState + ledger "
                         "+ loop bookkeeping)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    metavar="N",
                    help="write --checkpoint at eval boundaries at least N "
                         "rounds apart (0 = never write; a --resume run "
                         "can still read an existing checkpoint)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint when it exists; the "
                         "resumed trajectory is bitwise identical to an "
                         "uninterrupted run")
    ap.add_argument("--telemetry", default="",
                    metavar="PATH.jsonl",
                    help="flight-recorder JSONL event stream (per-round "
                         "on-device records, host spans, ledger "
                         "reconciliation); render with "
                         "tools/trace_report.py (empty = off, zero cost)")
    ap.add_argument("--alerts", type=_alert_spec, default="",
                    metavar="RULE[;RULE...]",
                    help="streaming health alert rules evaluated over the "
                         "--telemetry stream, e.g. 'divergence>0.5;nan;"
                         "mrr-stall=20;byte-budget=2e9'; fired alerts land "
                         "as 'alert' events (render with "
                         "tools/health_report.py)")
    ap.add_argument("--alert-mode", default="warn", choices=ALERT_MODES,
                    help="'warn' records alerts; 'fail' also stops the run "
                         "gracefully at the next eval boundary after one "
                         "fires")
    ap.add_argument("--out", default=None, help="write JSON result here")
    args = ap.parse_args()

    kg = generate_kg(num_entities=args.entities,
                     num_relations=6 * args.clients,
                     num_triples=args.triples, seed=7)
    clients = partition_by_relation(kg, args.clients, seed=0)
    print(f"dataset: {kg.num_triples} triples, {kg.num_entities} entities, "
          f"{args.clients} clients "
          f"({[c.num_train for c in clients]} train triples each)")

    cfg = FederatedConfig(
        method=args.method, protocol=args.protocol, dim=args.dim,
        rounds=args.rounds, local_epochs=args.local_epochs,
        batch_size=args.batch_size, num_negatives=args.negatives, lr=args.lr,
        sparsity_p=args.sparsity, sync_interval=args.sync_interval,
        eval_every=args.eval_every, max_eval_triples=args.max_eval_triples,
        engine=args.engine, mesh_devices=args.mesh_devices,
        mesh_entities=args.mesh_entities,
        host_store=args.host_store or args.engine == "tiered",
        cache_slots=args.cache_slots, stage_steps=args.stage_steps,
        codec=args.codec, quantize_upload=args.quantize_upload,
        seed=args.seed, faults=args.faults,
        checkpoint_path=args.checkpoint,
        checkpoint_every=args.checkpoint_every, resume=args.resume,
        telemetry=args.telemetry,
        alerts=args.alerts, alert_mode=args.alert_mode,
    )
    res = run_federated(clients, kg.num_entities, cfg, verbose=True)

    ratio_bound = comm_ratio_worst_case(args.sparsity, args.sync_interval, args.dim)
    report = {
        "protocol": args.protocol, "method": args.method,
        "codec": args.codec, "clients": args.clients,
        "test_mrr": res.test_mrr_cg, "test_hits10": res.test_hits10_cg,
        "best_round": res.best_round, "rounds_run": res.rounds_run,
        "params_transmitted": res.ledger.params_transmitted,
        "eq5_worst_case_ratio": ratio_bound,
    }
    print(json.dumps(report, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({**report, "eval_history": res.eval_history}, f, indent=2)


if __name__ == "__main__":
    main()
