"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified
empirically — a scan over 8 layers reports the same FLOPs as 1 layer), which
makes it useless for scan-based models.  This walker parses the partitioned
HLO text, computes per-computation costs bottom-up, and multiplies while-loop
bodies by their trip counts (parsed from the loop-condition constant), giving:

* ``flops``      — dot FLOPs (2 * output_elems * contraction) + 1 flop/elem
  for elementwise/reduce ops (the dominant terms on both MXU and VPU),
* ``bytes``      — an HBM-traffic model: for every non-free top-level
  instruction, output bytes + operand bytes.  Fusion internals are *not*
  counted (they live in registers/VMEM); while bodies are (each iteration
  really re-touches memory).
* ``collectives``— per-kind output bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, trip-count multiplied.

All shapes in the partitioned module are PER-DEVICE shapes, so every number
here is per-device.  Methodology caveats are documented in EXPERIMENTS.md
§Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# ops that are pure plumbing — no flops, no memory traffic of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier", "domain",
    "get-dimension-size", "custom-call",  # custom-calls on CPU are tiny (topk handled below)
}

# Standalone elementwise/layout ops that the TARGET backend (XLA:TPU) fuses
# into neighbouring producers/consumers: they contribute FLOPs (VPU work) but
# no independent HBM round trip.  The CPU backend leaves many of these
# unfused at top level; counting their bytes would model CPU lowering, not
# the TPU target (measured: it inflates a 72B dense train step to an
# arithmetic intensity of ~8 flop/byte — two orders off).
_ASSUME_FUSED = {
    "add", "subtract", "multiply", "divide", "power", "negate", "abs",
    "maximum", "minimum", "compare", "select", "and", "or", "not", "xor",
    "convert", "broadcast", "iota", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "rsqrt", "sqrt", "cbrt", "tanh", "sine", "cosine", "tan",
    "logistic", "atan2", "is-finite", "clamp", "reduce-precision",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "remainder",
    "transpose", "reshape", "map", "expm1", "log1p", "erf",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# type is matched non-greedily up to the first ` opcode(` token; HLO types
# never contain parens-after-word, so the first such token IS the opcode.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_COMMENT_RE = re.compile(r"/\*[^*]*\*/")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"([0-9]+)"\}')


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.collectives[k] += other.collectives[k] * mult

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


def _shapes_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = _DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_elems(type_str: str) -> float:
    total = 0.0
    for _dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _split_operands(rest: str) -> tuple[list[str], str, str]:
    """Split 'a, %b, %c), attr=...' -> (operand names, inner text, attr tail)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner, tail = rest[:i], rest[i + 1 :]
                ops = re.findall(r"%([\w.\-]+)", inner)
                return ops, inner, tail
    return re.findall(r"%([\w.\-]+)", rest), rest, ""


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[dict]] = {}
        self._parse(hlo_text)
        self._costs: dict[str, Cost] = {}
        self._trip_cache: dict[str, float] = {}
        self.entry = self._find_entry(hlo_text)

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        comp = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc and "=" not in line.split("(")[0]:
                comp = mc.group(1)
                self.computations[comp] = []
                continue
            if comp is None:
                continue
            if line.strip() == "}":
                comp = None
                continue
            line = _COMMENT_RE.sub("", line)
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, type_str, opcode, rest = mi.groups()
            operands, inner, tail = _split_operands(rest)
            called = re.findall(
                r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)", tail
            )
            branches = re.findall(r"branch_computations=\{([^}]*)\}", tail)
            if branches:
                called += re.findall(r"%?([\w.\-]+)", branches[0])
            attrs = {}
            mdot = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", tail)
            if mdot:
                attrs["lhs_contracting"] = [
                    int(x) for x in mdot.group(1).split(",") if x
                ]
            self.computations[comp].append(
                {
                    "name": name,
                    "type": type_str,
                    "op": opcode,
                    "operands": operands,
                    "inner": inner,
                    "called": called,
                    "tail": tail,
                }
            )

    @staticmethod
    def _find_entry(text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        return m.group(1) if m else "main"

    # ------------------------------------------------------------- costing
    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._costs:
            return self._costs[comp_name]
        total = Cost()
        defs = {i["name"]: i for i in self.computations.get(comp_name, [])}
        for inst in self.computations.get(comp_name, []):
            op = inst["op"]
            out_bytes = _shapes_bytes(inst["type"])
            out_elems = _shape_elems(inst["type"])

            if op == "while":
                body, cond = None, None
                mb = re.search(r"body=%?([\w.\-]+)", inst["tail"])
                mc = re.search(r"condition=%?([\w.\-]+)", inst["tail"])
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                mt = _TRIP_RE.search(inst["tail"])
                if mt:  # XLA-annotated trip count — authoritative
                    trips = float(mt.group(1))
                else:  # fall back to the condition's compare constant
                    trips = self._const_in_comp(cond) if cond else 1.0
                if body:
                    total.add(self.cost_of(body), trips)
                if cond:
                    total.add(self.cost_of(cond), trips)
                continue
            if op == "conditional":
                branch_costs = [self.cost_of(c) for c in inst["called"]]
                if branch_costs:
                    # upper bound: most expensive branch
                    total.add(max(branch_costs, key=lambda c: c.flops))
                continue
            if op in ("call",):
                for c in inst["called"]:
                    total.add(self.cost_of(c))
                continue
            if op == "fusion":
                # flops from the fused computation; bytes only at the boundary.
                # Pure-elementwise fusions are skipped entirely: the CPU
                # backend splits elementwise chains into many small kLoop
                # fusions that XLA:TPU would absorb into the neighbouring
                # dot/reduce/DUS fusion — their traffic is already counted at
                # the producer's output and the consumer's operand.
                for c in inst["called"]:
                    total.flops += self.cost_of(c).flops
                if not self._fusion_is_pure_elementwise(inst):
                    total.bytes += (self._fusion_output_bytes(inst, out_bytes)
                                    + self._fusion_operand_bytes(inst, defs))
                continue
            if op == "dynamic-update-slice":
                # in-place slice write: traffic = read+write of the UPDATE
                # region, not the whole buffer (XLA aliases the operand)
                upd = defs.get(inst["operands"][1]) if len(inst["operands"]) > 1 else None
                upd_bytes = _shapes_bytes(upd["type"]) if upd else out_bytes
                total.bytes += 2.0 * upd_bytes
                continue
            if op == "dot":
                lhs = defs.get(inst["operands"][0]) if inst["operands"] else None
                contr = 1
                mdot = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst["tail"])
                if lhs is not None and mdot:
                    dims = _first_shape_dims(lhs["type"])
                    for ci in [int(x) for x in mdot.group(1).split(",") if x]:
                        if ci < len(dims):
                            contr *= dims[ci]
                total.flops += 2.0 * out_elems * contr
                total.bytes += out_bytes + self._operand_bytes(inst, defs)
                continue
            for kind in _COLLECTIVES:
                if op == kind or op == f"{kind}-start":
                    total.collectives[kind] += out_bytes
                    total.bytes += out_bytes + self._operand_bytes(inst, defs)
                    break
            else:
                if op in _FREE_OPS or op.endswith("-done"):
                    continue
                # reductions / elementwise / data movement
                if op in ("reduce", "reduce-window", "scatter", "select-and-scatter"):
                    total.flops += self._operand_elems(inst, defs)
                elif op not in ("copy", "transpose", "reshape", "broadcast",
                                "concatenate", "slice", "dynamic-slice",
                                "dynamic-update-slice", "pad", "gather",
                                "iota", "convert", "rng", "rng-bit-generator",
                                "compare", "select", "sort"):
                    total.flops += out_elems  # elementwise-ish
                if op not in _ASSUME_FUSED:
                    total.bytes += out_bytes + self._operand_bytes(inst, defs)
        self._costs[comp_name] = total
        return total

    def _const_in_comp(self, comp: str) -> float:
        """Largest scalar integer constant in a computation.

        jax scans lower to `while(...)` whose condition compares the
        induction variable LT <trip count constant>; the trip count is the
        (only) integer constant in the condition computation.  Fusion-wrapped
        compares reference the constant from the condition's top level, so it
        is always visible here.
        """
        if comp in self._trip_cache:
            return self._trip_cache[comp]
        best = 1.0
        for inst in self.computations.get(comp, []):
            if inst["op"] == "constant" and "[]" in inst["type"]:
                m = re.match(r"^\s*(\-?[0-9]+)\s*$", inst["inner"])
                if m:
                    best = max(best, float(m.group(1)))
        self._trip_cache[comp] = best
        return best

    def _fusion_is_pure_elementwise(self, inst: dict) -> bool:
        called = inst["called"][0] if inst["called"] else None
        body = self.computations.get(called, []) if called else []
        if not body:
            return False
        allowed = _ASSUME_FUSED | _FREE_OPS | {"slice", "pad", "concatenate",
                                               "reverse", "rev", "copy"}
        return all(i["op"] in allowed for i in body)

    def _fusion_output_bytes(self, inst: dict, out_bytes: float) -> float:
        """If the fusion root is a dynamic-update-slice (possibly behind a
        bitcast), the written bytes are the update region, not the whole
        aliased buffer — the scan-backward 'accumulate grads into the stacked
        (L, ...) buffer' pattern."""
        called = inst["called"][0] if inst["called"] else None
        body = self.computations.get(called, []) if called else []
        if not body:
            return out_bytes
        by_name = {i["name"]: i for i in body}
        root = body[-1]  # ROOT is last in HLO text order
        seen = 0
        passthrough = _ASSUME_FUSED | {"bitcast", "copy"}
        while root["op"] in passthrough and root["operands"] and seen < 8:
            nxt = by_name.get(root["operands"][0])
            if nxt is None:
                break
            root, seen = nxt, seen + 1
        if root["op"] == "dynamic-update-slice" and len(root["operands"]) > 1:
            upd = by_name.get(root["operands"][1])
            if upd is not None:
                return min(out_bytes, 2.0 * _shapes_bytes(upd["type"]))
        return out_bytes

    def _fusion_operand_bytes(self, inst: dict, defs: dict) -> float:
        """Boundary traffic of a fusion: operands count at the bytes ACTUALLY
        read.  The scan-over-layers pattern passes the full stacked (L, ...)
        weight tensors into in-loop fusions that immediately dynamic-slice
        one layer out — per-iteration HBM traffic is the slice, not the
        stack.  For each fused-computation parameter whose only uses are
        dynamic-slice ops, count the slice output size instead."""
        called = inst["called"][0] if inst["called"] else None
        body = self.computations.get(called, []) if called else []
        param_read: dict[int, float] = {}
        if body:
            by_name = {i["name"]: i for i in body}
            params = {}
            for i in body:
                if i["op"] == "parameter":
                    mi = re.match(r"^\s*([0-9]+)", i["inner"])
                    if mi:
                        params[i["name"]] = int(mi.group(1))
            # effective uses: follow pass-through (bitcast/copy/elementwise-
            # unary) chains so `param -> bitcast -> dynamic-slice` still
            # counts as a sliced read.
            passthrough = _ASSUME_FUSED | {"bitcast", "copy"}
            direct_uses: dict[str, list[dict]] = {}
            for i in body:
                for o in i["operands"]:
                    direct_uses.setdefault(o, []).append(i)

            def effective_uses(name: str, alias: str, depth: int = 0):
                out = []
                for u in direct_uses.get(name, []):
                    if u["op"] in passthrough and len(u["operands"]) == 1 and depth < 6:
                        out += effective_uses(u["name"], alias, depth + 1)
                    else:
                        out.append((u, name))
                return out

            for pname, idx in params.items():
                us = effective_uses(pname, pname)
                if not us:
                    continue
                if all(u["op"] == "dynamic-slice" for u, _ in us):
                    param_read[idx] = sum(_shapes_bytes(u["type"]) for u, _ in us)
                elif all(
                    u["op"] == "dynamic-update-slice" and u["operands"][0] == via
                    for u, via in us
                ):
                    # aliased update target: only the update region is touched
                    param_read[idx] = sum(
                        _shapes_bytes(by_name[u["operands"][1]]["type"])
                        for u, _ in us if u["operands"][1] in by_name
                    )
        total = 0.0
        for pos, o in enumerate(inst["operands"]):
            d = defs.get(o)
            if d is None:
                continue
            if pos in param_read:
                total += min(param_read[pos], _shapes_bytes(d["type"]))
            else:
                total += _shapes_bytes(d["type"])
        return total

    def _operand_bytes(self, inst: dict, defs: dict) -> float:
        total = 0.0
        for o in inst["operands"]:
            d = defs.get(o)
            if d is not None:
                total += _shapes_bytes(d["type"])
        return total

    def _operand_elems(self, inst: dict, defs: dict) -> float:
        total = 0.0
        for o in inst["operands"]:
            d = defs.get(o)
            if d is not None:
                total += _shape_elems(d["type"])
        return total

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": dict(c.collectives),
        "collective_bytes": c.collective_total,
    }
