"""Federated runtime: clients, server orchestration, metrics, comm ledger."""
from repro.federated.client import KGEClient
from repro.federated.comm import CommLedger
from repro.federated.metrics import weighted_average
from repro.federated.simulation import FederatedConfig, FederatedResult, run_federated

__all__ = [
    "KGEClient",
    "CommLedger",
    "weighted_average",
    "FederatedConfig",
    "FederatedResult",
    "run_federated",
]
