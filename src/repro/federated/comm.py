"""Communication-cost ledger: the paper's P@CG / P@99 / P@98 / R@CG metrics.

Counts are in *parameters* (float-equivalents), matching Eq. 5's accounting
where sign vectors are counted at full dtype width.  Byte counts with int8
sign vectors are tracked alongside (DESIGN.md §3 adaptation note).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class CommLedger:
    params_transmitted: float = 0.0  # Eq.5-style float-equivalent parameter count
    bytes_int8_signs: float = 0.0  # realistic wire bytes (f32 payload, i8 signs)
    rounds: int = 0
    history: list = dataclasses.field(default_factory=list)  # (round, cum_params)

    def log_upload_sparse(self, k: int, dim: int, n_entities: int) -> None:
        self.params_transmitted += k * dim + n_entities  # values + sign vector
        self.bytes_int8_signs += k * dim * 4 + n_entities * 1 + k * 4  # +indices i32

    def log_download_sparse(self, k: int, dim: int, n_entities: int) -> None:
        # values + priority vector + sign vector
        self.params_transmitted += k * dim + k + n_entities
        self.bytes_int8_signs += k * dim * 4 + k * 4 + n_entities * 1 + k * 4

    def log_full_exchange(self, n_entities: int, dim: int) -> None:
        """One direction of a full (sync / FedE) exchange."""
        self.params_transmitted += n_entities * dim
        self.bytes_int8_signs += n_entities * dim * 4

    def end_round(self) -> None:
        self.rounds += 1
        self.history.append((self.rounds, self.params_transmitted))

    def params_at_round(self, r: int) -> float:
        """Cumulative params transmitted by the end of round r (1-indexed)."""
        for rr, p in self.history:
            if rr == r:
                return p
        return self.history[-1][1] if self.history else 0.0
