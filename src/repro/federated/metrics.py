"""Federated metric aggregation (paper §IV-B).

"The overall metric value is derived by aggregating all clients' values
through weighted average, with weights being the proportions of the triple
size."
"""
from __future__ import annotations

import numpy as np


def weighted_average(per_client: list[dict]) -> dict:
    """per_client: list of {"mrr", "hits10", "count"} dicts."""
    total = sum(m["count"] for m in per_client)
    if total == 0:
        return {"mrr": 0.0, "hits10": 0.0, "count": 0}
    mrr = sum(m["mrr"] * m["count"] for m in per_client) / total
    hits = sum(m["hits10"] * m["count"] for m in per_client) / total
    return {"mrr": mrr, "hits10": hits, "count": total}


def aggregate_eval_block(block) -> dict:
    """Aggregate the device evaluator's ``(C, 3)`` scalar block.

    ``block`` rows are per-client ``[mrr, hits10, count]`` as produced by
    :class:`repro.core.evaluation.BatchedEvaluator` — the same weighted
    average as :func:`weighted_average`, but from the one array an eval
    boundary reads back instead of per-client dicts.
    """
    block = np.asarray(block, dtype=np.float64)
    total = float(block[:, 2].sum())
    if total == 0:
        return {"mrr": 0.0, "hits10": 0.0, "count": 0}
    return {
        "mrr": float((block[:, 0] * block[:, 2]).sum() / total),
        "hits10": float((block[:, 1] * block[:, 2]).sum() / total),
        "count": int(total),
    }


def first_round_reaching(history: list[tuple[int, float]], target: float) -> int | None:
    """First (eval) round whose metric >= target; None if never reached.

    ``history`` is [(round, metric), ...] in round order.
    """
    for r, v in history:
        if v >= target:
            return r
    return None
