"""Federated metric aggregation (paper §IV-B).

"The overall metric value is derived by aggregating all clients' values
through weighted average, with weights being the proportions of the triple
size."
"""
from __future__ import annotations

import numpy as np

#: Metric-dict keys for the rate columns of the evaluator's block, in
#: column order (column 0 .. n-2; the last column is always ``count``).
#: Mirrors :data:`repro.core.evaluation.HITS_LEVELS` = (1, 3, 10).
RATE_KEYS = ("mrr", "hits1", "hits3", "hits10")


def _zero() -> dict:
    out = {k: 0.0 for k in RATE_KEYS}
    out["count"] = 0
    return out


def weighted_average(per_client: list[dict]) -> dict:
    """per_client: list of {"mrr", "hits1", "hits3", "hits10", "count"}
    dicts (missing rate keys are treated as 0)."""
    total = sum(m["count"] for m in per_client)
    if total == 0:
        return _zero()
    out = {
        k: sum(m.get(k, 0.0) * m["count"] for m in per_client) / total
        for k in RATE_KEYS
    }
    out["count"] = total
    return out


def aggregate_eval_block(block) -> dict:
    """Aggregate the device evaluator's ``(C, EVAL_BLOCK_COLS)`` scalar
    block.

    ``block`` rows are per-client ``[mrr, hits@1, hits@3, hits@10, count]``
    as produced by :class:`repro.core.evaluation.BatchedEvaluator` — the
    same weighted average as :func:`weighted_average`, but from the one
    array an eval boundary reads back instead of per-client dicts.
    """
    block = np.asarray(block, dtype=np.float64)
    if block.shape[1] != len(RATE_KEYS) + 1:
        raise ValueError(
            f"eval block has {block.shape[1]} columns, expected "
            f"{len(RATE_KEYS) + 1} ({RATE_KEYS} + count)"
        )
    total = float(block[:, -1].sum())
    if total == 0:
        return _zero()
    out = {
        k: float((block[:, i] * block[:, -1]).sum() / total)
        for i, k in enumerate(RATE_KEYS)
    }
    out["count"] = int(total)
    return out


def first_round_reaching(history: list[tuple[int, float]], target: float) -> int | None:
    """First (eval) round whose metric >= target; None if never reached.

    ``history`` is [(round, metric), ...] in round order.
    """
    for r, v in history:
        if v >= target:
            return r
    return None
