"""Federated metric aggregation (paper §IV-B).

"The overall metric value is derived by aggregating all clients' values
through weighted average, with weights being the proportions of the triple
size."
"""
from __future__ import annotations


def weighted_average(per_client: list[dict]) -> dict:
    """per_client: list of {"mrr", "hits10", "count"} dicts."""
    total = sum(m["count"] for m in per_client)
    if total == 0:
        return {"mrr": 0.0, "hits10": 0.0, "count": 0}
    mrr = sum(m["mrr"] * m["count"] for m in per_client) / total
    hits = sum(m["hits10"] * m["count"] for m in per_client) / total
    return {"mrr": mrr, "hits10": hits, "count": total}


def first_round_reaching(history: list[tuple[int, float]], target: float) -> int | None:
    """First (eval) round whose metric >= target; None if never reached.

    ``history`` is [(round, metric), ...] in round order.
    """
    for r, v in history:
        if v >= target:
            return r
    return None
