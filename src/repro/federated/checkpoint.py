"""Host-loop durability: checkpoint / restore of the full federation state.

The device engines keep *everything* that determines the trajectory inside
:class:`repro.core.state.FederationState` (padded entity/relation tables,
Adam state, upload history, EF residuals, fault arrays incl. the straggler
queue, and the jitter PRNG key) plus a small set of host-side loop
variables (the communication ledger, the eval history, the best-snapshot
bookkeeping, the next round index).  A checkpoint is therefore one ``.npz``
with every :class:`StateArrays` leaf, the key, and a JSON header — enough
to resume and reproduce the uninterrupted run *bitwise* (the fault masks
are pure functions of the absolute round index, so they need no state at
all; see :mod:`repro.core.faults`).

Format (single ``np.savez`` archive):

* ``__meta__``     — JSON: format version, config fingerprint, loop
  bookkeeping (``next_round``, ``eval_history``, best round/mrr/hits,
  ``declines``, ``prev_mrr``), ledger scalars, and whether a best snapshot
  is stored.
* ``state_<i>``    — the ``i``-th leaf of ``jax.tree_util`` -flattened
  :class:`StateArrays` (a fixed traversal order for a fixed config).
* ``key``          — the jitter PRNG key.
* ``ledger_history`` — the per-round cumulative parameter counts.
* ``best_<name>``  — the best-snapshot params dict, when one exists.

Writes are atomic (tmp file + ``os.replace``), so a kill mid-write leaves
the previous checkpoint intact — the crash-recovery contract the CI
kill-and-resume job exercises.
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry

FORMAT_VERSION = 1

# config fields that shape the state pytree or drive the trajectory; a
# checkpoint only resumes under a config that agrees on all of them.
# ``rounds`` is deliberately NOT fingerprinted: the trajectory up to the
# checkpointed round is independent of the horizon, so a resume may extend
# (or re-truncate) a run — which is also how the kill-and-resume test
# simulates a crash without actually killing the process.
_FINGERPRINT_FIELDS = (
    "method", "protocol", "dim", "local_epochs", "batch_size",
    "num_negatives", "lr", "adversarial_temperature", "gamma", "sparsity_p",
    "codec", "engine", "sync_interval", "eval_every", "patience",
    "max_eval_triples", "seed", "faults",
)


def config_fingerprint(cfg) -> dict:
    return {f: getattr(cfg, f) for f in _FINGERPRINT_FIELDS}


def save_checkpoint(
    path: str,
    state,  # repro.core.state.FederationState
    ledger,  # repro.federated.comm.CommLedger
    *,
    cfg,
    next_round: int,
    eval_history: list,
    best: dict,
    declines: int,
    prev_mrr: float,
) -> None:
    """Atomically write the full resume image to ``path``."""
    with telemetry.span("checkpoint"):
        _save_checkpoint(
            path, state, ledger, cfg=cfg, next_round=next_round,
            eval_history=eval_history, best=best, declines=declines,
            prev_mrr=prev_mrr,
        )


def _save_checkpoint(
    path, state, ledger, *, cfg, next_round, eval_history, best,
    declines, prev_mrr,
) -> None:
    leaves = jax.tree_util.tree_leaves(state.arrays)
    payload = {f"state_{i}": np.asarray(v) for i, v in enumerate(leaves)}
    payload["key"] = np.asarray(state.key)
    payload["ledger_history"] = np.asarray(
        ledger.history, np.float64
    ).reshape(-1, 2)  # (round, cum_params) pairs
    snap = best.get("snap")
    if snap is not None:
        for name, v in snap.items():
            payload[f"best_{name}"] = np.asarray(v)
    meta = {
        "format_version": FORMAT_VERSION,
        "fingerprint": config_fingerprint(cfg),
        "num_state_leaves": len(leaves),
        "next_round": int(next_round),
        "eval_history": [
            [int(r), float(m), float(h)] for r, m, h in eval_history
        ],
        "best": {
            "mrr": float(best["mrr"]),
            "round": int(best["round"]),
            "hits": float(best["hits"]),
            "has_snap": snap is not None,
            "snap_keys": sorted(snap) if snap is not None else [],
        },
        "declines": int(declines),
        "prev_mrr": float(prev_mrr),
        "ledger": {
            "params_transmitted": float(ledger.params_transmitted),
            "bytes_int8_signs": float(ledger.bytes_int8_signs),
            "rounds": int(ledger.rounds),
        },
    }
    payload["__meta__"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8
    )
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_checkpoint(path: str, state, ledger, *, cfg):
    """Restore a checkpoint written by :func:`save_checkpoint`.

    ``state`` is a *freshly initialized* FederationState for the same
    config — it supplies the pytree structure (and the leaf shapes/dtypes
    the stored leaves are validated against).  ``ledger`` is mutated in
    place.  Returns ``(state, loop)`` where ``loop`` is a dict of the host
    bookkeeping: ``next_round``, ``eval_history``, ``best``, ``declines``,
    ``prev_mrr``.
    """
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode("utf-8"))
        if meta["format_version"] != FORMAT_VERSION:
            raise ValueError(
                f"checkpoint {path!r} has format version "
                f"{meta['format_version']}, expected {FORMAT_VERSION}"
            )
        fp, want = meta["fingerprint"], config_fingerprint(cfg)
        diff = {k for k in want if fp.get(k) != want[k]}
        if diff:
            raise ValueError(
                f"checkpoint {path!r} was written under a different config; "
                f"mismatched fields: {sorted(diff)} "
                f"(stored {({k: fp.get(k) for k in sorted(diff)})!r})"
            )
        leaves, treedef = jax.tree_util.tree_flatten(state.arrays)
        if meta["num_state_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint {path!r} stores {meta['num_state_leaves']} state "
                f"leaves, this config builds {len(leaves)}"
            )
        new_leaves = []
        for i, ref in enumerate(leaves):
            v = z[f"state_{i}"]
            if v.shape != ref.shape or v.dtype != ref.dtype:
                raise ValueError(
                    f"checkpoint {path!r} state leaf {i} is "
                    f"{v.shape}/{v.dtype}, expected {ref.shape}/{ref.dtype}"
                )
            new_leaves.append(jnp.asarray(v))
        arrays = jax.tree_util.tree_unflatten(treedef, new_leaves)
        key = jnp.asarray(z["key"])
        bm = meta["best"]
        snap = (
            {k: jnp.asarray(z[f"best_{k}"]) for k in bm["snap_keys"]}
            if bm["has_snap"] else None
        )
        ledger.params_transmitted = meta["ledger"]["params_transmitted"]
        ledger.bytes_int8_signs = meta["ledger"]["bytes_int8_signs"]
        ledger.rounds = meta["ledger"]["rounds"]
        ledger.history = [
            (int(r), float(p)) for r, p in z["ledger_history"]
        ]
    state = type(state)(arrays=arrays, key=key)
    loop = {
        "next_round": meta["next_round"],
        "eval_history": [tuple(e) for e in meta["eval_history"]],
        "best": {
            "mrr": bm["mrr"], "round": bm["round"], "hits": bm["hits"],
            "snap": snap,
        },
        "declines": meta["declines"],
        "prev_mrr": meta["prev_mrr"],
    }
    return state, loop
