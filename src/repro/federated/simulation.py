"""The federated training simulation loop (paper §IV experimental protocol).

A thin host loop: it owns the communication ledger, eval scheduling, and
best-snapshot logic — everything else runs on device.  Four engines drive
the per-round work (``FederatedConfig.engine``):

* ``superstep`` — whole spans of the ISM round schedule (``s`` sparse
  rounds + 1 sync round per period, chunked to eval boundaries) run as ONE
  ``lax.scan``-ned program per superstep *including the boundary eval*
  (:class:`repro.core.state.SuperstepEngine` ``"eval"`` plan segments over
  :class:`repro.core.evaluation.BatchedEvaluator`): one host touch-point
  per superstep instead of one per round.  Fastest path; compiles one
  program per distinct schedule plan.
* ``fused`` (default) — the whole cycle (``local_epochs`` of local training with
  device-pre-sampled batches + the FedS communication round) is ONE
  compiled program per round over :class:`repro.core.state.FederationState`,
  which keeps every client's entity/relation tables, Adam state, upload
  history, and the jitter PRNG key device-resident across rounds.
* ``batched`` — the same device-resident state and random streams, but the
  training scan and the communication round run as separate jitted programs
  per round.  This is the correctness oracle for ``fused`` (same seeds ->
  same eval trajectory and ledger totals, see tests/test_state.py).
* ``reference`` — the ragged numpy host protocol (per-client
  ``KGEClient.train_local`` + :mod:`repro.core.aggregate`), the
  paper-faithful path the engine property tests compare against.

All device engines produce bit-identical trajectories and ledgers for the
same config/seeds — they differ only in how many rounds each compiled
program covers (the fused==batched==superstep equivalence contract,
property-tested in tests/test_state.py; see docs/architecture.md).

Pod mode: ``mesh_devices > 1`` builds a 1-D client-axis mesh via
:func:`repro.launch.mesh.make_federation_mesh` and runs the same engine
programs under ``shard_map`` with the client axis sharded over devices.

Ledger accounting for the device engines is deferred: per-round download
counts stay on device and are flushed to the :class:`CommLedger` only at
eval boundaries (one transfer for all pending rounds), producing bitwise-
identical totals to per-round flushing.  Wire payloads and their cost
accounting go through the pluggable codec registry
(:mod:`repro.core.codecs`, selected by ``FederatedConfig.codec`` spec
strings like ``"int8:ef=1"``); error-feedback codecs carry device-resident
residual state inside :class:`repro.core.state.FederationState` on the
device engines, and host-side numpy banks
(:func:`repro.core.protocol.sparse_upload_coded`) on the ``reference``
path.

Evaluation on the device engines is itself device-resident
(:mod:`repro.core.evaluation`): boundaries read back only a ``(C, 5)``
``[mrr, hits@1, hits@3, hits@10, count]`` block, best-model snapshots are on-device params
copies taken when MRR improves, and entity tables cross the host exactly
once — at the terminal snapshot materialization.  A terminal eval boundary
is guaranteed even when ``rounds % eval_every != 0``.  The ``reference``
engine keeps the per-client host oracle (``KGEClient.evaluate``) the
device path is property-tested exactly equal to.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import fede_aggregate, personalized_aggregate
from repro.core.codecs import parse_codec_spec
from repro.core.evaluation import BatchedEvaluator
from repro.core.protocol import (
    apply_full_download,
    apply_sparse_download,
    build_comm_views,
    full_upload,
    sparse_upload_coded,
)
from repro.core.sparsify import sparsity_k
from repro.core.state import CycleEngine, FederationState, SuperstepEngine
from repro.core.store import TieredCycleEngine
from repro.core.sync import round_kind
from repro.data.partition import ClientData
from repro.federated.client import KGEClient
from repro.federated.comm import CommLedger
from repro.federated.metrics import aggregate_eval_block, weighted_average
from repro.launch.mesh import make_federation_mesh

ENGINES = ("fused", "batched", "reference", "superstep", "tiered")


@dataclasses.dataclass
class FederatedConfig:
    method: str = "transe"  # transe | rotate | complex
    protocol: str = "feds"  # single | fedep | feds | feds_nosync
    dim: int = 256
    rounds: int = 200
    local_epochs: int = 3
    batch_size: int = 512
    num_negatives: int = 64
    lr: float = 1e-4
    adversarial_temperature: float = 1.0
    gamma: float = 8.0
    sparsity_p: float = 0.4
    # wire-codec spec "name:key=val,..." (repro.core.codecs registry), e.g.
    # "int8:ef=1" or "lowrank:cols=8,rank=2" — error-feedback (ef) codecs
    # carry device-resident residual state and need a device engine
    codec: str = "identity"
    quantize_upload: bool = False  # legacy alias for codec="int8" (FedS+Q8)
    # fused (one program per cycle) | superstep (one program per ISM span)
    # | batched (per-round programs, oracle) | reference (ragged numpy host)
    engine: str = "fused"
    # >1: pod mode — shard the client axis over a 1-D device mesh
    # (launch/mesh.py); requires a device engine and C % mesh_devices == 0
    mesh_devices: int = 0
    # >1: entity-sharded pod mode — a 2-D (clients, entities) mesh; the
    # padded entity/hist/residual state and the eval candidate scan shard
    # over the entity axis so per-device memory scales as E_pad / shards.
    # Bitwise identical to the unsharded engines (tests/test_eshard*.py).
    # Total devices used = max(mesh_devices, 1) * mesh_entities.
    mesh_entities: int = 0
    # host-tiered embedding store (engine="tiered", or host_store=True as an
    # alias): the device holds only the pinned shared prefix plus a
    # temperature/LRU row cache — E_max becomes a config value instead of a
    # device-memory obligation.  Training is lockstep (clients' train sets
    # are truncated to the common minimum) and uses sparse-Adam segment
    # semantics, so trajectories are NOT bitwise equal to the dense engines
    # (they ARE bitwise invariant to cache_slots — see tests/test_store.py).
    host_store: bool = False
    cache_slots: int = 0  # 0 -> floor: exactly the working-view width W
    stage_steps: int = 0  # batches per staging segment; 0 -> whole epoch
    sync_interval: int = 4
    eval_every: int = 5
    patience: int = 3
    max_eval_triples: int = 500
    seed: int = 0


@dataclasses.dataclass
class FederatedResult:
    config: FederatedConfig
    eval_history: list  # [(round, val_mrr, val_hits10)]
    ledger: CommLedger
    best_round: int
    val_mrr_cg: float  # validation MRR at convergence (best round)
    test_mrr_cg: float
    test_hits10_cg: float
    rounds_run: int

    def params_at(self, round_idx: int) -> float:
        return self.ledger.params_at_round(round_idx)


def _snapshot(clients: list[KGEClient]):
    return [
        {k: np.asarray(v) for k, v in c.params.items()} for c in clients
    ]


def _restore(clients: list[KGEClient], snap) -> None:
    for c, s in zip(clients, snap):
        c.params = {k: jnp.asarray(v) for k, v in s.items()}


def _flush_ledger(ledger, pending, views, codec, dim, k_per_client) -> None:
    """Replay deferred rounds into the ledger.

    ``pending`` holds ``(kind, down_count)`` per round in order; sparse-round
    download counts are device arrays, pulled to host in ONE transfer here.
    The replay performs the exact same accounting-call sequence a per-round
    flush would, so ledger totals/history are bitwise identical.
    """
    sparse_counts = [d for kind, d in pending if kind == "sparse"]
    dc_all = np.asarray(jnp.stack(sparse_counts)) if sparse_counts else None
    i = 0
    for kind, _ in pending:
        if kind == "sync":
            for v in views:  # upload leg + download leg
                ledger.log_full_exchange(v.num_shared, dim)
                ledger.log_full_exchange(v.num_shared, dim)
        elif kind == "sparse":
            for v, k_c, dc in zip(views, k_per_client, dc_all[i]):
                codec.log_upload(ledger, int(k_c), dim, v.num_shared)
                codec.log_download(ledger, int(dc), dim, v.num_shared)
            i += 1
        ledger.end_round()
    pending.clear()


def run_federated(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: FederatedConfig,
    verbose: bool = False,
) -> FederatedResult:
    if cfg.engine not in ENGINES:
        raise ValueError(
            f"unknown engine {cfg.engine!r}; expected one of {ENGINES}"
        )
    if cfg.host_store or cfg.engine == "tiered":
        if cfg.mesh_devices > 1 or cfg.mesh_entities > 1:
            raise ValueError(
                "the host-tiered engine is a host-loop path; it composes "
                "with neither mesh_devices nor mesh_entities"
            )
        if cfg.engine not in ("tiered", "fused"):
            raise ValueError(
                f"host_store=True selects engine='tiered'; it conflicts "
                f"with engine={cfg.engine!r}"
            )
        return _run_federated_tiered(
            clients_data, num_global_entities, cfg, verbose
        )
    clients = [
        KGEClient(
            d,
            method=cfg.method,
            dim=cfg.dim,
            gamma=cfg.gamma,
            batch_size=cfg.batch_size,
            num_negatives=cfg.num_negatives,
            lr=cfg.lr,
            adversarial_temperature=cfg.adversarial_temperature,
            seed=cfg.seed,
        )
        for d in clients_data
    ]
    views = build_comm_views(
        [d.local_to_global for d in clients_data], num_global_entities
    )
    codec_spec = cfg.codec
    if cfg.quantize_upload:
        if codec_spec not in ("identity", "int8", "int8-rows"):
            raise ValueError(
                f"quantize_upload (legacy alias for codec='int8') conflicts "
                f"with codec={cfg.codec!r}; set one of the two"
            )
        codec_spec = "int8"
    codec = parse_codec_spec(codec_spec)
    ledger = CommLedger()

    use_device = cfg.engine != "reference"
    mesh = None
    entity_axis = None
    if cfg.mesh_devices > 1 or cfg.mesh_entities > 1:
        if not use_device:
            raise ValueError(
                "pod mode (mesh_devices/mesh_entities > 1) requires a "
                "device engine, not engine='reference'"
            )
        mesh = make_federation_mesh(
            max(cfg.mesh_devices, 1),
            entity_devices=max(cfg.mesh_entities, 1),
        )
        entity_axis = "entities" if cfg.mesh_entities > 1 else None
    evaluator = None
    if use_device:
        engine_cls = SuperstepEngine if cfg.engine == "superstep" else CycleEngine
        cycle = engine_cls(
            clients, views, num_global_entities,
            sparsity_p=cfg.sparsity_p, local_epochs=cfg.local_epochs,
            codec=codec, mesh=mesh, entity_axis=entity_axis,
        )
        state = cycle.init_state(clients, seed=cfg.seed + 777)
        pending: list = []  # (kind, device down_count | None) per round
        # device-resident batched eval: banks built ONCE, eval boundaries
        # read back only a (C, EVAL_BLOCK_COLS) scalar block (no
        # sync_clients round-trip)
        evaluator = BatchedEvaluator(
            clients_data, method=cfg.method, gamma=cfg.gamma,
            e_max=cycle.e_max, max_triples=cfg.max_eval_triples,
            splits=("valid", "test"),
            known=[c._known for c in clients], mesh=mesh,
            entity_axis=entity_axis,
        )
    else:  # ragged numpy reference protocol keeps per-client histories
        rng = np.random.default_rng(cfg.seed + 777)
        histories = [
            clients[c].entity_embeddings[jnp.asarray(views[c].shared_local)]
            for c in range(len(clients))
        ]
        # host-side error-feedback banks (the ef=1 paper-faithful oracle)
        residuals = [
            np.zeros((v.num_shared, cfg.dim), np.float32) for v in views
        ] if codec.has_residual else None

    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None, "hits": 0.0}
    declines = 0
    prev_mrr = -1.0
    rounds_run = 0
    # the "single" baseline evaluates on a slower cadence (no comm cost to track)
    ee = max(cfg.eval_every, 10) if cfg.protocol == "single" else cfg.eval_every

    def eval_boundary(round_no: int, block=None) -> bool:
        """Flush+evaluate at ``round_no``; True => early-stop.

        Device engines evaluate on device: ``block`` is the evaluator's
        ``(C, 5)`` metric block when the superstep program already produced
        it in-program, else the standalone compiled evaluator runs here —
        either way no entity table crosses the host, and the best-model
        snapshot is a cheap on-device copy taken only when MRR improves.
        """
        nonlocal best, declines, prev_mrr
        if use_device:
            _flush_ledger(
                ledger, pending, views, codec, cfg.dim, cycle.k_per_client
            )
            if block is None:
                block = evaluator.evaluate(state.arrays.params, "valid")
            val = aggregate_eval_block(block)
        else:
            val = weighted_average(
                [c.evaluate("valid", cfg.max_eval_triples) for c in clients]
            )
        eval_history.append((round_no, val["mrr"], val["hits10"]))
        if verbose:
            print(
                f"round {round_no:4d}  val MRR {val['mrr']:.4f}  "
                f"Hits@10 {val['hits10']:.4f}  params {ledger.params_transmitted:.3e}"
            )
        if val["mrr"] > best["mrr"]:
            snap = (
                {k: jnp.copy(v) for k, v in state.arrays.params.items()}
                if use_device else _snapshot(clients)
            )
            best = {
                "mrr": val["mrr"],
                "round": round_no,
                "snap": snap,
                "hits": val["hits10"],
            }
        declines = declines + 1 if val["mrr"] < prev_mrr else 0
        prev_mrr = val["mrr"]
        return declines >= cfg.patience

    if cfg.engine == "superstep":
        # ------------------- superstep mode: chunk rounds to eval boundaries
        # so every superstep runs as ONE compiled program INCLUDING its
        # boundary eval (an "eval" plan segment), and evals land at exactly
        # the same rounds as the per-round engines.  Chunks end either at an
        # eval boundary or at the final round (terminal eval guarantee), so
        # every chunk carries an eval segment.
        t = 0
        while t < cfg.rounds:
            chunk = min(((t // ee) + 1) * ee, cfg.rounds) - t
            kinds = tuple(
                round_kind(u, cfg.protocol, cfg.sync_interval)
                for u in range(t, t + chunk)
            )
            state, per_round, _losses, block = cycle.superstep_with_eval(
                state, kinds, evaluator, "valid"
            )
            pending.extend(per_round)
            t += chunk
            rounds_run = t
            if eval_boundary(t, block=block):
                break
        # superstep is always a device engine, so cycle/state/pending exist
        return _finish(
            cfg, clients, use_device, cycle, state, pending,
            views, codec, ledger, eval_history, best, rounds_run, evaluator,
        )

    for t in range(cfg.rounds):
        rounds_run = t + 1
        kind = round_kind(t, cfg.protocol, cfg.sync_interval)
        comm = kind != "none"
        sync = kind == "sync"

        if use_device:
            # ------------------------- device-resident train+communicate
            if cfg.engine == "fused":
                if comm:
                    state, down, _loss = cycle.fused_cycle(state, sync=sync)
                else:
                    state, _jitter, _loss = cycle.train_cycle(state)
                    down = None
            else:  # per-round oracle: separate train / comm programs
                state, jitter, _loss = cycle.train_cycle(state)
                down = None
                if comm:
                    state, down = cycle.comm_round(state, jitter, sync=sync)
            pending.append((kind, down if kind == "sparse" else None))
        else:
            # ----------------------------------- numpy reference protocol
            for c in clients:
                c.train_local(cfg.local_epochs)
            if comm and sync:
                if residuals is not None:
                    # the full exchange transmits exact values: stale banked
                    # error would re-inject pre-sync loss (same contract as
                    # the device engines' residual clear)
                    for res in residuals:
                        res[:] = 0.0
                uploads = []
                for c, v in zip(clients, views):
                    up, hist = full_upload(c.params["entity"], v)
                    histories[v.client_id] = hist
                    uploads.append(up)
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
                global_mean, _count = fede_aggregate(uploads, num_global_entities)
                for c, v in zip(clients, views):
                    c.params["entity"] = apply_full_download(
                        c.params["entity"], v, global_mean
                    )
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
            elif comm:  # sparse FedS round, ragged numpy reference path
                uploads = []
                for c, v in zip(clients, views):
                    # wire codec (and its host-side error-feedback bank,
                    # when ef=1) applied inside the coded upload
                    up, hist, res = sparse_upload_coded(
                        c.params["entity"], histories[v.client_id], v,
                        cfg.sparsity_p, codec,
                        residuals[v.client_id] if residuals is not None
                        else None,
                    )
                    histories[v.client_id] = hist
                    if residuals is not None:
                        residuals[v.client_id] = res
                    k_round = sparsity_k(v.num_shared, cfg.sparsity_p)
                    codec.log_upload(ledger, k_round, cfg.dim, v.num_shared)
                    uploads.append(up)
                downloads = personalized_aggregate(
                    uploads,
                    [v.shared_global for v in views],
                    cfg.sparsity_p,
                    rng,
                )
                for c, v, d in zip(clients, views, downloads):
                    if codec.transforms_values and len(d.entity_ids):
                        d = dataclasses.replace(
                            d,
                            agg_values=np.asarray(
                                codec.roundtrip(jnp.asarray(d.agg_values)),
                                np.float32,
                            ),
                        )
                    codec.log_download(
                        ledger, len(d.entity_ids), cfg.dim, v.num_shared
                    )
                    c.params["entity"] = apply_sparse_download(
                        c.params["entity"], v, d.entity_ids, d.agg_values,
                        d.priority,
                    )
            ledger.end_round()

        # ------------------------------------------------------- evaluation
        # terminal-eval guarantee: when rounds is not a multiple of the eval
        # cadence, the final partial span still ends with an eval boundary
        # (otherwise the last rounds are never evaluated and can never win
        # the best-model snapshot)
        at_boundary = (t + 1) % ee == 0 or (t + 1) == cfg.rounds
        if at_boundary and eval_boundary(t + 1):
            break

    return _finish(
        cfg, clients, use_device, cycle if use_device else None,
        state if use_device else None, pending if use_device else None,
        views, codec, ledger, eval_history, best, rounds_run,
        evaluator,
    )


def _finish(
    cfg, clients, use_device, cycle, state, pending,
    views, codec, ledger, eval_history, best, rounds_run, evaluator=None,
) -> FederatedResult:
    """Final flush + best-snapshot restore + test evaluation.

    Device engines restore the best on-device snapshot into the federation
    state, run the device-batched test eval, and only then materialize the
    tables into the per-client params (the single terminal host transfer).
    """
    if use_device:
        _flush_ledger(ledger, pending, views, codec, cfg.dim, cycle.k_per_client)
        if best["snap"] is not None:
            state = FederationState(
                state.arrays._replace(params=best["snap"]), state.key
            )
        test = aggregate_eval_block(
            evaluator.evaluate(state.arrays.params, "test")
        )
        cycle.sync_clients(state, clients)
    else:
        if best["snap"] is not None:
            _restore(clients, best["snap"])
        test = weighted_average(
            [c.evaluate("test", cfg.max_eval_triples) for c in clients]
        )
    return FederatedResult(
        config=cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )


def _run_federated_tiered(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: FederatedConfig,
    verbose: bool = False,
) -> FederatedResult:
    """The host-tiered simulation loop (engine="tiered" / host_store=True).

    Same round schedule, ledger accounting, eval cadence, patience, and
    best-snapshot protocol as the dense device engines, but federation
    state lives in :class:`repro.core.store.HostTieredStore`: the device
    holds the pinned shared prefix + a bounded row cache, and each eval
    boundary materializes the full tables once (the tiered tradeoff — the
    dense engines never move entity tables across the host).

    The tiered engine trains clients in lockstep, so train sets are
    truncated to the common minimum triple count up front.
    """
    n_tr = min(len(d.train) for d in clients_data)
    if verbose and any(len(d.train) != n_tr for d in clients_data):
        print(f"tiered engine: truncating train sets to lockstep ({n_tr} "
              f"triples/client)")
    train_data = [
        dataclasses.replace(d, train=d.train[:n_tr]) for d in clients_data
    ]

    def mk_clients():
        return [
            KGEClient(
                d, method=cfg.method, dim=cfg.dim, gamma=cfg.gamma,
                batch_size=cfg.batch_size, num_negatives=cfg.num_negatives,
                lr=cfg.lr,
                adversarial_temperature=cfg.adversarial_temperature,
                seed=cfg.seed,
            )
            for d in train_data
        ]

    clients = mk_clients()
    views = build_comm_views(
        [d.local_to_global for d in clients_data], num_global_entities
    )
    codec_spec = "int8" if cfg.quantize_upload else cfg.codec
    codec = parse_codec_spec(codec_spec)
    eng = TieredCycleEngine(
        clients, views, num_global_entities,
        sparsity_p=cfg.sparsity_p, local_epochs=cfg.local_epochs,
        codec=codec, cache_slots=cfg.cache_slots,
        stage_steps=cfg.stage_steps,
    )
    store, ts = eng.init_state(mk_clients(), seed=cfg.seed + 777)
    evaluator = BatchedEvaluator(
        clients_data, method=cfg.method, gamma=cfg.gamma, e_max=eng.e_max,
        max_triples=cfg.max_eval_triples, splits=("valid", "test"),
        known=[c._known for c in clients],
    )
    ledger = CommLedger()
    pending: list = []
    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None, "hits": 0.0}
    declines = 0
    prev_mrr = -1.0
    rounds_run = 0
    ee = max(cfg.eval_every, 10) if cfg.protocol == "single" else cfg.eval_every

    for t in range(cfg.rounds):
        rounds_run = t + 1
        kind = round_kind(t, cfg.protocol, cfg.sync_interval)
        ts, down, _loss = eng.run_cycle(store, ts, kind)
        pending.append((kind, down if kind == "sparse" else None))
        if (t + 1) % ee == 0 or (t + 1) == cfg.rounds:
            _flush_ledger(
                ledger, pending, views, codec, cfg.dim, eng.k_per_client
            )
            params = eng.materialize_params(store, ts)
            val = aggregate_eval_block(evaluator.evaluate(params, "valid"))
            eval_history.append((t + 1, val["mrr"], val["hits10"]))
            if verbose:
                print(
                    f"round {t + 1:4d}  val MRR {val['mrr']:.4f}  "
                    f"Hits@10 {val['hits10']:.4f}  "
                    f"params {ledger.params_transmitted:.3e}  "
                    f"cache hit {store.hit_rate:.3f}"
                )
            if val["mrr"] > best["mrr"]:
                best = {
                    "mrr": val["mrr"], "round": t + 1, "hits": val["hits10"],
                    "snap": {k: np.asarray(v) for k, v in params.items()},
                }
            declines = declines + 1 if val["mrr"] < prev_mrr else 0
            prev_mrr = val["mrr"]
            if declines >= cfg.patience:
                break

    _flush_ledger(ledger, pending, views, codec, cfg.dim, eng.k_per_client)
    if best["snap"] is not None:
        params = {k: jnp.asarray(v) for k, v in best["snap"].items()}
    else:
        params = eng.materialize_params(store, ts)
    test = aggregate_eval_block(evaluator.evaluate(params, "test"))
    return FederatedResult(
        config=cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )
