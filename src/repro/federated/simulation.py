"""The federated training simulation loop (paper §IV experimental protocol).

Drives any of the protocol variants over a list of clients:

* local training (``local_epochs`` epochs per round),
* upstream communication (sparse Top-K or full),
* server aggregation (personalized Eq. 3 or FedE averaging),
* downstream communication + client update (Eq. 4 or replacement),
* periodic validation with early stopping (patience on consecutive declines),
* a communication ledger for P@CG / P@99 / P@98 / R@CG.
"""
from __future__ import annotations

import copy
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import fede_aggregate, personalized_aggregate
from repro.core.protocol import (
    apply_full_download,
    apply_sparse_download,
    build_comm_views,
    full_upload,
    sparse_upload,
)
from repro.core.sparsify import dequantize_rows, quantize_rows, sparsity_k
from repro.core.sync import is_sync_round
from repro.data.partition import ClientData
from repro.federated.client import KGEClient
from repro.federated.comm import CommLedger
from repro.federated.metrics import weighted_average


@dataclasses.dataclass
class FederatedConfig:
    method: str = "transe"  # transe | rotate | complex
    protocol: str = "feds"  # single | fedep | feds | feds_nosync
    dim: int = 256
    rounds: int = 200
    local_epochs: int = 3
    batch_size: int = 512
    num_negatives: int = 64
    lr: float = 1e-4
    adversarial_temperature: float = 1.0
    gamma: float = 8.0
    sparsity_p: float = 0.4
    quantize_upload: bool = False  # FedS+Q8: int8 rows on the wire (beyond-paper)
    sync_interval: int = 4
    eval_every: int = 5
    patience: int = 3
    max_eval_triples: int = 500
    seed: int = 0


@dataclasses.dataclass
class FederatedResult:
    config: FederatedConfig
    eval_history: list  # [(round, val_mrr, val_hits10)]
    ledger: CommLedger
    best_round: int
    val_mrr_cg: float  # validation MRR at convergence (best round)
    test_mrr_cg: float
    test_hits10_cg: float
    rounds_run: int

    def params_at(self, round_idx: int) -> float:
        return self.ledger.params_at_round(round_idx)


def _snapshot(clients: list[KGEClient]):
    return [
        {k: np.asarray(v) for k, v in c.params.items()} for c in clients
    ]


def _restore(clients: list[KGEClient], snap) -> None:
    for c, s in zip(clients, snap):
        c.params = {k: jnp.asarray(v) for k, v in s.items()}


def run_federated(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: FederatedConfig,
    verbose: bool = False,
) -> FederatedResult:
    clients = [
        KGEClient(
            d,
            method=cfg.method,
            dim=cfg.dim,
            gamma=cfg.gamma,
            batch_size=cfg.batch_size,
            num_negatives=cfg.num_negatives,
            lr=cfg.lr,
            adversarial_temperature=cfg.adversarial_temperature,
            seed=cfg.seed,
        )
        for d in clients_data
    ]
    views = build_comm_views([d.local_to_global for d in clients_data], num_global_entities)
    histories = [
        clients[c].entity_embeddings[jnp.asarray(views[c].shared_local)]
        for c in range(len(clients))
    ]
    ledger = CommLedger()
    rng = np.random.default_rng(cfg.seed + 777)

    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None, "hits": 0.0}
    declines = 0
    prev_mrr = -1.0
    rounds_run = 0

    for t in range(cfg.rounds):
        rounds_run = t + 1
        # ---------------------------------------------------- local training
        for c in clients:
            c.train_local(cfg.local_epochs)

        # ----------------------------------------------------- communication
        if cfg.protocol != "single":
            sync = (
                cfg.protocol == "fedep"
                or (cfg.protocol == "feds" and is_sync_round(t, cfg.sync_interval))
            )
            if sync:
                uploads = []
                for c, v in zip(clients, views):
                    up, hist = full_upload(c.params["entity"], v)
                    histories[v.client_id] = hist
                    uploads.append(up)
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
                global_mean, _count = fede_aggregate(uploads, num_global_entities)
                for c, v in zip(clients, views):
                    c.params["entity"] = apply_full_download(
                        c.params["entity"], v, global_mean
                    )
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
            else:  # sparse FedS round
                uploads = []
                for c, v in zip(clients, views):
                    up, hist = sparse_upload(
                        c.params["entity"], histories[v.client_id], v, cfg.sparsity_p
                    )
                    histories[v.client_id] = hist
                    k_round = sparsity_k(v.num_shared, cfg.sparsity_p)
                    if cfg.quantize_upload:
                        # FedS+Q8: int8 rows + f32 scale cross the wire
                        q, sc = quantize_rows(jnp.asarray(up.values))
                        up.values = np.asarray(dequantize_rows(q, sc))
                        # ledger in param-equivalents: int8 = 1/4 param
                        ledger.params_transmitted += (
                            k_round * cfg.dim / 4 + k_round + v.num_shared
                        )
                        ledger.bytes_int8_signs += (
                            k_round * cfg.dim + k_round * 4 + v.num_shared + k_round * 4
                        )
                    else:
                        ledger.log_upload_sparse(k_round, cfg.dim, v.num_shared)
                    uploads.append(up)
                downloads = personalized_aggregate(
                    uploads,
                    [v.shared_global for v in views],
                    cfg.sparsity_p,
                    rng,
                )
                for c, v, d in zip(clients, views, downloads):
                    if cfg.quantize_upload and len(d.entity_ids):
                        q, sc = quantize_rows(jnp.asarray(d.agg_values))
                        d.agg_values = np.asarray(dequantize_rows(q, sc))
                        ledger.params_transmitted += (
                            len(d.entity_ids) * cfg.dim / 4
                            + 2 * len(d.entity_ids) + v.num_shared
                        )
                        ledger.bytes_int8_signs += (
                            len(d.entity_ids) * (cfg.dim + 8) + v.num_shared
                        )
                    else:
                        ledger.log_download_sparse(
                            len(d.entity_ids), cfg.dim, v.num_shared
                        )
                    c.params["entity"] = apply_sparse_download(
                        c.params["entity"], v, d.entity_ids, d.agg_values, d.priority
                    )
        ledger.end_round()

        # ------------------------------------------------------- evaluation
        eval_now = (t + 1) % cfg.eval_every == 0
        if cfg.protocol == "single":
            eval_now = (t + 1) % max(cfg.eval_every, 10) == 0
        if eval_now:
            val = weighted_average(
                [c.evaluate("valid", cfg.max_eval_triples) for c in clients]
            )
            eval_history.append((t + 1, val["mrr"], val["hits10"]))
            if verbose:
                print(
                    f"round {t+1:4d}  val MRR {val['mrr']:.4f}  "
                    f"Hits@10 {val['hits10']:.4f}  params {ledger.params_transmitted:.3e}"
                )
            if val["mrr"] > best["mrr"]:
                best = {
                    "mrr": val["mrr"],
                    "round": t + 1,
                    "snap": _snapshot(clients),
                    "hits": val["hits10"],
                }
            declines = declines + 1 if val["mrr"] < prev_mrr else 0
            prev_mrr = val["mrr"]
            if declines >= cfg.patience:
                break

    if best["snap"] is not None:
        _restore(clients, best["snap"])
    test = weighted_average([c.evaluate("test", cfg.max_eval_triples) for c in clients])
    return FederatedResult(
        config=cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )
