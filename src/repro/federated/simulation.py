"""The federated training simulation loop (paper §IV experimental protocol).

Drives any of the protocol variants over a list of clients:

* local training (``local_epochs`` epochs per round),
* one communication round — by default through the jitted batched
  :class:`repro.core.engine.RoundEngine` (upstream Top-K, Eq. 3 personalized
  aggregation, downstream Top-K, Eq. 4 apply as ONE compiled program over all
  clients); ``engine="reference"`` keeps the ragged numpy host protocol,
  which the property tests compare against,
* wire payloads and their cost accounting via a pluggable
  :class:`repro.core.codec.WireCodec` (identity or FedS+Q8 int8 rows),
* periodic validation with early stopping (patience on consecutive declines),
* a communication ledger for P@CG / P@99 / P@98 / R@CG.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import fede_aggregate, personalized_aggregate
from repro.core.codec import get_codec
from repro.core.engine import RoundEngine
from repro.core.protocol import (
    apply_full_download,
    apply_sparse_download,
    build_comm_views,
    full_upload,
    sparse_upload,
)
from repro.core.sparsify import sparsity_k
from repro.core.sync import is_sync_round
from repro.data.partition import ClientData
from repro.federated.client import KGEClient
from repro.federated.comm import CommLedger
from repro.federated.metrics import weighted_average


@dataclasses.dataclass
class FederatedConfig:
    method: str = "transe"  # transe | rotate | complex
    protocol: str = "feds"  # single | fedep | feds | feds_nosync
    dim: int = 256
    rounds: int = 200
    local_epochs: int = 3
    batch_size: int = 512
    num_negatives: int = 64
    lr: float = 1e-4
    adversarial_temperature: float = 1.0
    gamma: float = 8.0
    sparsity_p: float = 0.4
    quantize_upload: bool = False  # FedS+Q8: int8 rows on the wire (beyond-paper)
    engine: str = "batched"  # batched (jitted RoundEngine) | reference (numpy)
    sync_interval: int = 4
    eval_every: int = 5
    patience: int = 3
    max_eval_triples: int = 500
    seed: int = 0


@dataclasses.dataclass
class FederatedResult:
    config: FederatedConfig
    eval_history: list  # [(round, val_mrr, val_hits10)]
    ledger: CommLedger
    best_round: int
    val_mrr_cg: float  # validation MRR at convergence (best round)
    test_mrr_cg: float
    test_hits10_cg: float
    rounds_run: int

    def params_at(self, round_idx: int) -> float:
        return self.ledger.params_at_round(round_idx)


def _snapshot(clients: list[KGEClient]):
    return [
        {k: np.asarray(v) for k, v in c.params.items()} for c in clients
    ]


def _restore(clients: list[KGEClient], snap) -> None:
    for c, s in zip(clients, snap):
        c.params = {k: jnp.asarray(v) for k, v in s.items()}


def run_federated(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: FederatedConfig,
    verbose: bool = False,
) -> FederatedResult:
    if cfg.engine not in ("batched", "reference"):
        raise ValueError(
            f"unknown engine {cfg.engine!r}; expected 'batched' or 'reference'"
        )
    clients = [
        KGEClient(
            d,
            method=cfg.method,
            dim=cfg.dim,
            gamma=cfg.gamma,
            batch_size=cfg.batch_size,
            num_negatives=cfg.num_negatives,
            lr=cfg.lr,
            adversarial_temperature=cfg.adversarial_temperature,
            seed=cfg.seed,
        )
        for d in clients_data
    ]
    views = build_comm_views([d.local_to_global for d in clients_data], num_global_entities)
    codec = get_codec("int8-rows" if cfg.quantize_upload else "identity")
    engine = None
    hist_batch = None
    histories = None
    if cfg.protocol != "single" and cfg.engine != "reference":
        engine = RoundEngine(
            views, num_global_entities, cfg.dim, cfg.sparsity_p, codec=codec
        )
        hist_batch = engine.gather([c.params["entity"] for c in clients])
    else:  # ragged numpy reference protocol keeps per-client histories
        histories = [
            clients[c].entity_embeddings[jnp.asarray(views[c].shared_local)]
            for c in range(len(clients))
        ]
    ledger = CommLedger()
    rng = np.random.default_rng(cfg.seed + 777)

    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None, "hits": 0.0}
    declines = 0
    prev_mrr = -1.0
    rounds_run = 0

    for t in range(cfg.rounds):
        rounds_run = t + 1
        # ---------------------------------------------------- local training
        for c in clients:
            c.train_local(cfg.local_epochs)

        # ----------------------------------------------------- communication
        if cfg.protocol != "single":
            sync = (
                cfg.protocol == "fedep"
                or (cfg.protocol == "feds" and is_sync_round(t, cfg.sync_interval))
            )
            if engine is not None:  # jitted batched RoundEngine path
                emb_batch = engine.gather([c.params["entity"] for c in clients])
                if sync:
                    emb_batch, hist_batch = engine.sync_round(emb_batch)
                    for v in views:  # upload leg + download leg
                        ledger.log_full_exchange(v.num_shared, cfg.dim)
                        ledger.log_full_exchange(v.num_shared, cfg.dim)
                else:
                    jitter = rng.random((len(clients), engine.ns_max))
                    emb_batch, hist_batch, down_counts = engine.sparse_round(
                        emb_batch, hist_batch, jitter
                    )
                    for v, k_c, dc in zip(
                        views, engine.k_per_client, np.asarray(down_counts)
                    ):
                        codec.log_upload(ledger, int(k_c), cfg.dim, v.num_shared)
                        codec.log_download(ledger, int(dc), cfg.dim, v.num_shared)
                new_tables = engine.scatter(
                    emb_batch, [c.params["entity"] for c in clients]
                )
                for c, tab in zip(clients, new_tables):
                    c.params["entity"] = tab
            elif sync:
                uploads = []
                for c, v in zip(clients, views):
                    up, hist = full_upload(c.params["entity"], v)
                    histories[v.client_id] = hist
                    uploads.append(up)
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
                global_mean, _count = fede_aggregate(uploads, num_global_entities)
                for c, v in zip(clients, views):
                    c.params["entity"] = apply_full_download(
                        c.params["entity"], v, global_mean
                    )
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
            else:  # sparse FedS round, ragged numpy reference path
                uploads = []
                for c, v in zip(clients, views):
                    up, hist = sparse_upload(
                        c.params["entity"], histories[v.client_id], v, cfg.sparsity_p
                    )
                    histories[v.client_id] = hist
                    k_round = sparsity_k(v.num_shared, cfg.sparsity_p)
                    if codec.transforms_values:
                        # messages are frozen: the transform builds a new one
                        up = dataclasses.replace(
                            up,
                            values=np.asarray(
                                codec.roundtrip(jnp.asarray(up.values)), np.float32
                            ),
                        )
                    codec.log_upload(ledger, k_round, cfg.dim, v.num_shared)
                    uploads.append(up)
                downloads = personalized_aggregate(
                    uploads,
                    [v.shared_global for v in views],
                    cfg.sparsity_p,
                    rng,
                )
                for c, v, d in zip(clients, views, downloads):
                    if codec.transforms_values and len(d.entity_ids):
                        d = dataclasses.replace(
                            d,
                            agg_values=np.asarray(
                                codec.roundtrip(jnp.asarray(d.agg_values)), np.float32
                            ),
                        )
                    codec.log_download(
                        ledger, len(d.entity_ids), cfg.dim, v.num_shared
                    )
                    c.params["entity"] = apply_sparse_download(
                        c.params["entity"], v, d.entity_ids, d.agg_values, d.priority
                    )
        ledger.end_round()

        # ------------------------------------------------------- evaluation
        eval_now = (t + 1) % cfg.eval_every == 0
        if cfg.protocol == "single":
            eval_now = (t + 1) % max(cfg.eval_every, 10) == 0
        if eval_now:
            val = weighted_average(
                [c.evaluate("valid", cfg.max_eval_triples) for c in clients]
            )
            eval_history.append((t + 1, val["mrr"], val["hits10"]))
            if verbose:
                print(
                    f"round {t+1:4d}  val MRR {val['mrr']:.4f}  "
                    f"Hits@10 {val['hits10']:.4f}  params {ledger.params_transmitted:.3e}"
                )
            if val["mrr"] > best["mrr"]:
                best = {
                    "mrr": val["mrr"],
                    "round": t + 1,
                    "snap": _snapshot(clients),
                    "hits": val["hits10"],
                }
            declines = declines + 1 if val["mrr"] < prev_mrr else 0
            prev_mrr = val["mrr"]
            if declines >= cfg.patience:
                break

    if best["snap"] is not None:
        _restore(clients, best["snap"])
    test = weighted_average([c.evaluate("test", cfg.max_eval_triples) for c in clients])
    return FederatedResult(
        config=cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )
