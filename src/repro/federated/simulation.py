"""The federated training simulation loop (paper §IV experimental protocol).

A thin host loop: it owns the communication ledger, eval scheduling, and
best-snapshot logic — everything else runs on device.  Four engines drive
the per-round work (``FederatedConfig.engine``):

* ``superstep`` — whole spans of the ISM round schedule (``s`` sparse
  rounds + 1 sync round per period, chunked to eval boundaries) run as ONE
  ``lax.scan``-ned program per superstep
  (:class:`repro.core.state.SuperstepEngine`): one host touch-point per
  superstep instead of one per round.  Fastest path; compiles one program
  per distinct schedule plan.
* ``fused`` (default) — the whole cycle (``local_epochs`` of local training with
  device-pre-sampled batches + the FedS communication round) is ONE
  compiled program per round over :class:`repro.core.state.FederationState`,
  which keeps every client's entity/relation tables, Adam state, upload
  history, and the jitter PRNG key device-resident across rounds.  Entity
  tables only cross the host boundary at eval/snapshot boundaries.
* ``batched`` — the same device-resident state and random streams, but the
  training scan and the communication round run as separate jitted programs
  per round.  This is the correctness oracle for ``fused`` (same seeds ->
  same eval trajectory and ledger totals, see tests/test_state.py).
* ``reference`` — the ragged numpy host protocol (per-client
  ``KGEClient.train_local`` + :mod:`repro.core.aggregate`), the
  paper-faithful path the engine property tests compare against.

All device engines produce bit-identical trajectories and ledgers for the
same config/seeds — they differ only in how many rounds each compiled
program covers (the fused==batched==superstep equivalence contract,
property-tested in tests/test_state.py; see docs/architecture.md).

Pod mode: ``mesh_devices > 1`` builds a 1-D client-axis mesh via
:func:`repro.launch.mesh.make_federation_mesh` and runs the same engine
programs under ``shard_map`` with the client axis sharded over devices.

Ledger accounting for the device engines is deferred: per-round download
counts stay on device and are flushed to the :class:`CommLedger` only at
eval boundaries (one transfer for all pending rounds), producing bitwise-
identical totals to per-round flushing.  Wire payloads and their cost
accounting go through the pluggable codec registry
(:mod:`repro.core.codecs`, selected by ``FederatedConfig.codec`` spec
strings like ``"int8:ef=1"``); error-feedback codecs carry device-resident
residual state inside :class:`repro.core.state.FederationState` and
therefore require a device engine.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import fede_aggregate, personalized_aggregate
from repro.core.codecs import parse_codec_spec
from repro.core.protocol import (
    apply_full_download,
    apply_sparse_download,
    build_comm_views,
    full_upload,
    sparse_upload,
)
from repro.core.sparsify import sparsity_k
from repro.core.state import CycleEngine, SuperstepEngine
from repro.core.sync import round_kind
from repro.data.partition import ClientData
from repro.federated.client import KGEClient
from repro.federated.comm import CommLedger
from repro.federated.metrics import weighted_average
from repro.launch.mesh import make_federation_mesh

ENGINES = ("fused", "batched", "reference", "superstep")


@dataclasses.dataclass
class FederatedConfig:
    method: str = "transe"  # transe | rotate | complex
    protocol: str = "feds"  # single | fedep | feds | feds_nosync
    dim: int = 256
    rounds: int = 200
    local_epochs: int = 3
    batch_size: int = 512
    num_negatives: int = 64
    lr: float = 1e-4
    adversarial_temperature: float = 1.0
    gamma: float = 8.0
    sparsity_p: float = 0.4
    # wire-codec spec "name:key=val,..." (repro.core.codecs registry), e.g.
    # "int8:ef=1" or "lowrank:cols=8,rank=2" — error-feedback (ef) codecs
    # carry device-resident residual state and need a device engine
    codec: str = "identity"
    quantize_upload: bool = False  # legacy alias for codec="int8" (FedS+Q8)
    # fused (one program per cycle) | superstep (one program per ISM span)
    # | batched (per-round programs, oracle) | reference (ragged numpy host)
    engine: str = "fused"
    # >1: pod mode — shard the client axis over a 1-D device mesh
    # (launch/mesh.py); requires a device engine and C % mesh_devices == 0
    mesh_devices: int = 0
    sync_interval: int = 4
    eval_every: int = 5
    patience: int = 3
    max_eval_triples: int = 500
    seed: int = 0


@dataclasses.dataclass
class FederatedResult:
    config: FederatedConfig
    eval_history: list  # [(round, val_mrr, val_hits10)]
    ledger: CommLedger
    best_round: int
    val_mrr_cg: float  # validation MRR at convergence (best round)
    test_mrr_cg: float
    test_hits10_cg: float
    rounds_run: int

    def params_at(self, round_idx: int) -> float:
        return self.ledger.params_at_round(round_idx)


def _snapshot(clients: list[KGEClient]):
    return [
        {k: np.asarray(v) for k, v in c.params.items()} for c in clients
    ]


def _restore(clients: list[KGEClient], snap) -> None:
    for c, s in zip(clients, snap):
        c.params = {k: jnp.asarray(v) for k, v in s.items()}


def _flush_ledger(ledger, pending, views, codec, dim, k_per_client) -> None:
    """Replay deferred rounds into the ledger.

    ``pending`` holds ``(kind, down_count)`` per round in order; sparse-round
    download counts are device arrays, pulled to host in ONE transfer here.
    The replay performs the exact same accounting-call sequence a per-round
    flush would, so ledger totals/history are bitwise identical.
    """
    sparse_counts = [d for kind, d in pending if kind == "sparse"]
    dc_all = np.asarray(jnp.stack(sparse_counts)) if sparse_counts else None
    i = 0
    for kind, _ in pending:
        if kind == "sync":
            for v in views:  # upload leg + download leg
                ledger.log_full_exchange(v.num_shared, dim)
                ledger.log_full_exchange(v.num_shared, dim)
        elif kind == "sparse":
            for v, k_c, dc in zip(views, k_per_client, dc_all[i]):
                codec.log_upload(ledger, int(k_c), dim, v.num_shared)
                codec.log_download(ledger, int(dc), dim, v.num_shared)
            i += 1
        ledger.end_round()
    pending.clear()


def run_federated(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: FederatedConfig,
    verbose: bool = False,
) -> FederatedResult:
    if cfg.engine not in ENGINES:
        raise ValueError(
            f"unknown engine {cfg.engine!r}; expected one of {ENGINES}"
        )
    clients = [
        KGEClient(
            d,
            method=cfg.method,
            dim=cfg.dim,
            gamma=cfg.gamma,
            batch_size=cfg.batch_size,
            num_negatives=cfg.num_negatives,
            lr=cfg.lr,
            adversarial_temperature=cfg.adversarial_temperature,
            seed=cfg.seed,
        )
        for d in clients_data
    ]
    views = build_comm_views(
        [d.local_to_global for d in clients_data], num_global_entities
    )
    codec_spec = cfg.codec
    if cfg.quantize_upload:
        if codec_spec not in ("identity", "int8", "int8-rows"):
            raise ValueError(
                f"quantize_upload (legacy alias for codec='int8') conflicts "
                f"with codec={cfg.codec!r}; set one of the two"
            )
        codec_spec = "int8"
    codec = parse_codec_spec(codec_spec)
    ledger = CommLedger()

    use_device = cfg.engine != "reference"
    if codec.has_residual and not use_device:
        raise ValueError(
            f"codec {codec!r} carries device-resident error-feedback "
            "residual state; engine='reference' (ragged numpy host protocol) "
            "does not thread it — use a device engine"
        )
    mesh = None
    if cfg.mesh_devices > 1:
        if not use_device:
            raise ValueError(
                "pod mode (mesh_devices > 1) requires a device engine, "
                "not engine='reference'"
            )
        mesh = make_federation_mesh(cfg.mesh_devices)
    if use_device:
        engine_cls = SuperstepEngine if cfg.engine == "superstep" else CycleEngine
        cycle = engine_cls(
            clients, views, num_global_entities,
            sparsity_p=cfg.sparsity_p, local_epochs=cfg.local_epochs,
            codec=codec, mesh=mesh,
        )
        state = cycle.init_state(clients, seed=cfg.seed + 777)
        pending: list = []  # (kind, device down_count | None) per round
    else:  # ragged numpy reference protocol keeps per-client histories
        rng = np.random.default_rng(cfg.seed + 777)
        histories = [
            clients[c].entity_embeddings[jnp.asarray(views[c].shared_local)]
            for c in range(len(clients))
        ]

    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None, "hits": 0.0}
    declines = 0
    prev_mrr = -1.0
    rounds_run = 0
    # the "single" baseline evaluates on a slower cadence (no comm cost to track)
    ee = max(cfg.eval_every, 10) if cfg.protocol == "single" else cfg.eval_every

    def eval_boundary(round_no: int) -> bool:
        """Flush+sync+evaluate at ``round_no``; True => early-stop."""
        nonlocal best, declines, prev_mrr
        if use_device:
            _flush_ledger(
                ledger, pending, views, codec, cfg.dim, cycle.k_per_client
            )
            cycle.sync_clients(state, clients)
        val = weighted_average(
            [c.evaluate("valid", cfg.max_eval_triples) for c in clients]
        )
        eval_history.append((round_no, val["mrr"], val["hits10"]))
        if verbose:
            print(
                f"round {round_no:4d}  val MRR {val['mrr']:.4f}  "
                f"Hits@10 {val['hits10']:.4f}  params {ledger.params_transmitted:.3e}"
            )
        if val["mrr"] > best["mrr"]:
            best = {
                "mrr": val["mrr"],
                "round": round_no,
                "snap": _snapshot(clients),
                "hits": val["hits10"],
            }
        declines = declines + 1 if val["mrr"] < prev_mrr else 0
        prev_mrr = val["mrr"]
        return declines >= cfg.patience

    if cfg.engine == "superstep":
        # ------------------- superstep mode: chunk rounds to eval boundaries
        # so every superstep runs as one compiled program and evals land at
        # exactly the same rounds as the per-round engines
        t = 0
        while t < cfg.rounds:
            chunk = min(((t // ee) + 1) * ee, cfg.rounds) - t
            kinds = tuple(
                round_kind(u, cfg.protocol, cfg.sync_interval)
                for u in range(t, t + chunk)
            )
            state, per_round, _losses = cycle.superstep(state, kinds)
            pending.extend(per_round)
            t += chunk
            rounds_run = t
            if t % ee == 0 and eval_boundary(t):
                break
        # superstep is always a device engine, so cycle/state/pending exist
        return _finish(
            cfg, clients, use_device, cycle, state, pending,
            views, codec, ledger, eval_history, best, rounds_run,
        )

    for t in range(cfg.rounds):
        rounds_run = t + 1
        kind = round_kind(t, cfg.protocol, cfg.sync_interval)
        comm = kind != "none"
        sync = kind == "sync"

        if use_device:
            # ------------------------- device-resident train+communicate
            if cfg.engine == "fused":
                if comm:
                    state, down, _loss = cycle.fused_cycle(state, sync=sync)
                else:
                    state, _jitter, _loss = cycle.train_cycle(state)
                    down = None
            else:  # per-round oracle: separate train / comm programs
                state, jitter, _loss = cycle.train_cycle(state)
                down = None
                if comm:
                    state, down = cycle.comm_round(state, jitter, sync=sync)
            pending.append((kind, down if kind == "sparse" else None))
        else:
            # ----------------------------------- numpy reference protocol
            for c in clients:
                c.train_local(cfg.local_epochs)
            if comm and sync:
                uploads = []
                for c, v in zip(clients, views):
                    up, hist = full_upload(c.params["entity"], v)
                    histories[v.client_id] = hist
                    uploads.append(up)
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
                global_mean, _count = fede_aggregate(uploads, num_global_entities)
                for c, v in zip(clients, views):
                    c.params["entity"] = apply_full_download(
                        c.params["entity"], v, global_mean
                    )
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
            elif comm:  # sparse FedS round, ragged numpy reference path
                uploads = []
                for c, v in zip(clients, views):
                    up, hist = sparse_upload(
                        c.params["entity"], histories[v.client_id], v,
                        cfg.sparsity_p,
                    )
                    histories[v.client_id] = hist
                    k_round = sparsity_k(v.num_shared, cfg.sparsity_p)
                    if codec.transforms_values:
                        # messages are frozen: the transform builds a new one
                        up = dataclasses.replace(
                            up,
                            values=np.asarray(
                                codec.roundtrip(jnp.asarray(up.values)), np.float32
                            ),
                        )
                    codec.log_upload(ledger, k_round, cfg.dim, v.num_shared)
                    uploads.append(up)
                downloads = personalized_aggregate(
                    uploads,
                    [v.shared_global for v in views],
                    cfg.sparsity_p,
                    rng,
                )
                for c, v, d in zip(clients, views, downloads):
                    if codec.transforms_values and len(d.entity_ids):
                        d = dataclasses.replace(
                            d,
                            agg_values=np.asarray(
                                codec.roundtrip(jnp.asarray(d.agg_values)),
                                np.float32,
                            ),
                        )
                    codec.log_download(
                        ledger, len(d.entity_ids), cfg.dim, v.num_shared
                    )
                    c.params["entity"] = apply_sparse_download(
                        c.params["entity"], v, d.entity_ids, d.agg_values,
                        d.priority,
                    )
            ledger.end_round()

        # ------------------------------------------------------- evaluation
        if (t + 1) % ee == 0 and eval_boundary(t + 1):
            break

    return _finish(
        cfg, clients, use_device, cycle if use_device else None,
        state if use_device else None, pending if use_device else None,
        views, codec, ledger, eval_history, best, rounds_run,
    )


def _finish(
    cfg, clients, use_device, cycle, state, pending,
    views, codec, ledger, eval_history, best, rounds_run,
) -> FederatedResult:
    """Final flush + best-snapshot restore + test evaluation."""
    if use_device:
        _flush_ledger(ledger, pending, views, codec, cfg.dim, cycle.k_per_client)
        cycle.sync_clients(state, clients)
    if best["snap"] is not None:
        _restore(clients, best["snap"])
    test = weighted_average([c.evaluate("test", cfg.max_eval_triples) for c in clients])
    return FederatedResult(
        config=cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )
