"""The federated training simulation loop (paper §IV experimental protocol).

A thin host loop: it owns the communication ledger, eval scheduling, and
best-snapshot logic — everything else runs on device.  Four engines drive
the per-round work (``FederatedConfig.engine``):

* ``superstep`` — whole spans of the ISM round schedule (``s`` sparse
  rounds + 1 sync round per period, chunked to eval boundaries) run as ONE
  ``lax.scan``-ned program per superstep *including the boundary eval*
  (:class:`repro.core.state.SuperstepEngine` ``"eval"`` plan segments over
  :class:`repro.core.evaluation.BatchedEvaluator`): one host touch-point
  per superstep instead of one per round.  Fastest path; compiles one
  program per distinct schedule plan.
* ``fused`` (default) — the whole cycle (``local_epochs`` of local training with
  device-pre-sampled batches + the FedS communication round) is ONE
  compiled program per round over :class:`repro.core.state.FederationState`,
  which keeps every client's entity/relation tables, Adam state, upload
  history, and the jitter PRNG key device-resident across rounds.
* ``batched`` — the same device-resident state and random streams, but the
  training scan and the communication round run as separate jitted programs
  per round.  This is the correctness oracle for ``fused`` (same seeds ->
  same eval trajectory and ledger totals, see tests/test_state.py).
* ``reference`` — the ragged numpy host protocol (per-client
  ``KGEClient.train_local`` + :mod:`repro.core.aggregate`), the
  paper-faithful path the engine property tests compare against.

All device engines produce bit-identical trajectories and ledgers for the
same config/seeds — they differ only in how many rounds each compiled
program covers (the fused==batched==superstep equivalence contract,
property-tested in tests/test_state.py; see docs/architecture.md).

Pod mode: ``mesh_devices > 1`` builds a 1-D client-axis mesh via
:func:`repro.launch.mesh.make_federation_mesh` and runs the same engine
programs under ``shard_map`` with the client axis sharded over devices.

Ledger accounting for the device engines is deferred: per-round download
counts stay on device and are flushed to the :class:`CommLedger` only at
eval boundaries (one transfer for all pending rounds), producing bitwise-
identical totals to per-round flushing.  Wire payloads and their cost
accounting go through the pluggable codec registry
(:mod:`repro.core.codecs`, selected by ``FederatedConfig.codec`` spec
strings like ``"int8:ef=1"``); error-feedback codecs carry device-resident
residual state inside :class:`repro.core.state.FederationState` on the
device engines, and host-side numpy banks
(:func:`repro.core.protocol.sparse_upload_coded`) on the ``reference``
path.

Evaluation on the device engines is itself device-resident
(:mod:`repro.core.evaluation`): boundaries read back only a ``(C, 5)``
``[mrr, hits@1, hits@3, hits@10, count]`` block, best-model snapshots are on-device params
copies taken when MRR improves, and entity tables cross the host exactly
once — at the terminal snapshot materialization.  A terminal eval boundary
is guaranteed even when ``rounds % eval_every != 0``.  The ``reference``
engine keeps the per-client host oracle (``KGEClient.evaluate``) the
device path is property-tested exactly equal to.
"""
from __future__ import annotations

import collections
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core.aggregate import Upload, fede_aggregate, personalized_aggregate
from repro.core.codecs import parse_codec_spec
from repro.core.evaluation import BatchedEvaluator
from repro.core.faults import host_round_faults, parse_fault_spec
from repro.core.health import HealthMonitor, parse_alert_spec
from repro.core.protocol import (
    apply_full_download,
    apply_sparse_download,
    build_comm_views,
    full_upload,
    sparse_upload_coded,
)
from repro.core.sparsify import change_scores, sparsity_k
from repro.core.state import CycleEngine, FederationState, SuperstepEngine
from repro.core.store import TieredCycleEngine
from repro.core.telemetry import (
    NUM_SCORE_BUCKETS,
    RoundTelemetry,
    TelemetrySink,
    nonfinite_count,
    residual_mass,
    score_histogram,
    shared_divergence,
    update_norm,
)
from repro.core.sync import round_kind
from repro.data.partition import ClientData
from repro.federated import checkpoint as fed_checkpoint
from repro.federated.client import KGEClient
from repro.federated.comm import CommLedger
from repro.federated.metrics import aggregate_eval_block, weighted_average
from repro.launch.mesh import make_federation_mesh

ENGINES = ("fused", "batched", "reference", "superstep", "tiered")


@dataclasses.dataclass
class FederatedConfig:
    method: str = "transe"  # any registered scoring method (kge.scoring)
    protocol: str = "feds"  # single | fedep | feds | feds_nosync
    dim: int = 256
    rounds: int = 200
    local_epochs: int = 3
    batch_size: int = 512
    num_negatives: int = 64
    lr: float = 1e-4
    adversarial_temperature: float = 1.0
    gamma: float = 8.0
    sparsity_p: float = 0.4
    # wire-codec spec "name:key=val,..." (repro.core.codecs registry), e.g.
    # "int8:ef=1" or "lowrank:cols=8,rank=2" — error-feedback (ef) codecs
    # carry device-resident residual state and need a device engine
    codec: str = "identity"
    quantize_upload: bool = False  # legacy alias for codec="int8" (FedS+Q8)
    # fused (one program per cycle) | superstep (one program per ISM span)
    # | batched (per-round programs, oracle) | reference (ragged numpy host)
    engine: str = "fused"
    # >1: pod mode — shard the client axis over a 1-D device mesh
    # (launch/mesh.py); requires a device engine and C % mesh_devices == 0
    mesh_devices: int = 0
    # >1: entity-sharded pod mode — a 2-D (clients, entities) mesh; the
    # padded entity/hist/residual state and the eval candidate scan shard
    # over the entity axis so per-device memory scales as E_pad / shards.
    # Bitwise identical to the unsharded engines (tests/test_eshard*.py).
    # Total devices used = max(mesh_devices, 1) * mesh_entities.
    mesh_entities: int = 0
    # host-tiered embedding store (engine="tiered", or host_store=True as an
    # alias): the device holds only the pinned shared prefix plus a
    # temperature/LRU row cache — E_max becomes a config value instead of a
    # device-memory obligation.  Training is lockstep (clients' train sets
    # are truncated to the common minimum) and uses sparse-Adam segment
    # semantics, so trajectories are NOT bitwise equal to the dense engines
    # (they ARE bitwise invariant to cache_slots — see tests/test_store.py).
    host_store: bool = False
    cache_slots: int = 0  # 0 -> floor: exactly the working-view width W
    stage_steps: int = 0  # batches per staging segment; 0 -> whole epoch
    sync_interval: int = 4
    eval_every: int = 5
    patience: int = 3
    max_eval_triples: int = 500
    seed: int = 0
    # fault-injection spec (repro.core.faults grammar), e.g.
    # "p=0.5,drop_up=0.1,stragglers=0:2,lag=2,seed=7"; "" -> fully reliable
    # federation (trivial schedules compile the exact pre-fault programs)
    faults: str = ""
    # host-loop durability: write a full resume image (state + ledger + eval
    # bookkeeping, repro.federated.checkpoint) at the first eval boundary at
    # least checkpoint_every rounds after the last write; resume=True
    # restores it and continues the interrupted run bitwise
    checkpoint_path: str = ""
    checkpoint_every: int = 0
    resume: bool = False
    # flight recorder: JSONL event path ("" -> off).  On: the engines carry
    # per-round on-device records (repro.core.telemetry) drained at eval
    # boundaries, host stages are timed as spans, and a shadow ledger replays
    # the records to cross-check the real accounting (tools/trace_report.py).
    # Off: zero-cost — the engines compile the exact pre-telemetry programs.
    telemetry: str = ""
    # streaming health monitor: alert-rule spec (repro.core.health grammar),
    # e.g. "divergence>0.5;nan;mrr-stall=20;byte-budget=2e9"; requires
    # telemetry (rules judge the drained event stream).  alert_mode "warn"
    # records ``alert`` events only; "fail" additionally stops the run
    # gracefully at the next eval boundary after an alert fires (the stream
    # still ends with the terminal ledger event).
    alerts: str = ""
    alert_mode: str = "warn"


@dataclasses.dataclass
class FederatedResult:
    config: FederatedConfig
    eval_history: list  # [(round, val_mrr, val_hits10)]
    ledger: CommLedger
    best_round: int
    val_mrr_cg: float  # validation MRR at convergence (best round)
    test_mrr_cg: float
    test_hits10_cg: float
    rounds_run: int

    def params_at(self, round_idx: int) -> float:
        return self.ledger.params_at_round(round_idx)


def _empty_upload(client_id: int, dim: int) -> Upload:
    """A zero-entity message: a queue vacancy / an undelivered upload."""
    return Upload(
        client_id=client_id,
        entity_ids=np.zeros(0, dtype=np.int64),
        values=np.zeros((0, dim), dtype=np.float32),
    )


def _snapshot(clients: list[KGEClient]):
    return [
        {k: np.asarray(v) for k, v in c.params.items()} for c in clients
    ]


def _restore(clients: list[KGEClient], snap) -> None:
    for c, s in zip(clients, snap):
        c.params = {k: jnp.asarray(v) for k, v in s.items()}


def _flush_ledger(
    ledger, pending, views, codec, dim, k_per_client, sched=None,
    sink=None, cache_stats=None,
) -> None:
    """Replay deferred rounds into the ledger.

    ``pending`` holds ``(kind, down_count, round_idx, record)`` per round in
    order; sparse-round download counts (and, with telemetry on, the
    :class:`~repro.core.telemetry.RoundTelemetry` records) are device
    arrays, pulled to host in ONE transfer here.  The replay performs the
    exact same accounting-call sequence a per-round flush would, so ledger
    totals/history are bitwise identical.

    With an active fault schedule ``sched``, the per-round participation
    masks are re-drawn on host from the absolute round index (bit-identical
    to the in-program draws, :func:`repro.core.faults.host_round_faults`)
    and absent clients are *skipped entirely* — a non-participating client
    exchanges no bytes, not zero-entity messages (whose sign bitmaps would
    still bill ``Ns`` bytes).  Delivery drops do NOT reduce billing: a
    dropped message was still transmitted.

    With a ``sink``, each drained record is emitted as a ``round`` event and
    replayed into the sink's *shadow* ledger using only device-recorded
    quantities — the reconciliation cross-check trace_report verifies.
    ``cache_stats`` (tiered engine) is a per-pending-round list of cache
    hit/miss/eviction deltas folded into the events.
    """
    sparse_counts = [d for kind, d, _, _ in pending if kind == "sparse"]
    dc_all = np.asarray(jnp.stack(sparse_counts)) if sparse_counts else None
    recs = [r for _, _, _, r in pending if r is not None]
    stacked = (
        jax.tree.map(lambda *xs: np.asarray(jnp.stack(xs)), *recs)
        if recs else None
    )
    i = 0
    j = 0
    for n, (kind, _, t, rec) in enumerate(pending):
        part = (
            host_round_faults(sched, t, len(views))[0]
            if sched is not None else None
        )
        if kind == "sync":
            for v in views:  # upload leg + download leg
                if part is not None and not part[v.client_id]:
                    continue
                ledger.log_full_exchange(v.num_shared, dim)
                ledger.log_full_exchange(v.num_shared, dim)
        elif kind == "sparse":
            for v, k_c, dc in zip(views, k_per_client, dc_all[i]):
                if part is not None and not part[v.client_id]:
                    continue
                codec.log_upload(ledger, int(k_c), dim, v.num_shared)
                codec.log_download(ledger, int(dc), dim, v.num_shared)
            i += 1
        ledger.end_round()
        if sink is not None:
            r = None
            if rec is not None:
                r = jax.tree.map(lambda a, j=j: a[j], stacked)
                j += 1
            _emit_round_event(
                sink, codec, dim, views, kind, t, r,
                cache=cache_stats[n] if cache_stats else None,
            )
    pending.clear()
    if cache_stats is not None:
        cache_stats.clear()


def _emit_round_event(sink, codec, dim, views, kind, t, rec, cache=None):
    """Emit one ``{"ev": "round"}`` event and feed the shadow ledger.

    The shadow replay makes the SAME accounting calls, in the same order,
    as the real flush just did — but parameterized only by device-recorded
    quantities (``rec.up_rows``/``dn_rows``/``part``), never by the host's
    own bookkeeping.  If the records are faithful, shadow totals equal the
    real ledger's bitwise (all per-call increments are integer-valued, so
    float accumulation is exact); trace_report asserts exactly that.
    Per-leg wire bytes are measured as shadow-ledger deltas around each
    call.  ``rec=None`` (a no-comm round) still advances the shadow round
    counter, mirroring ``ledger.end_round()``.
    """
    shadow = sink.shadow
    c_n = len(views)
    if rec is None:
        shadow.end_round()
        zi = [0] * c_n
        sink.emit({
            "ev": "round", "round": int(t), "kind": kind,
            "up_rows": zi, "dn_rows": zi, "overlap": zi,
            "res_mass": [0.0] * c_n, "part": zi, "up_ok": zi, "dn_ok": zi,
            "age": zi,
            "score_hist": [[0] * NUM_SCORE_BUCKETS for _ in range(c_n)],
            "div_mean": [0.0] * c_n, "div_max": [0.0] * c_n,
            "upd_norm": [0.0] * c_n, "nonfinite": zi,
            "up_bytes": [0.0] * c_n, "dn_bytes": [0.0] * c_n,
            "cache_hits": int(cache["hits"]) if cache else 0,
            "cache_misses": int(cache["misses"]) if cache else 0,
            "cache_evictions": int(cache["evictions"]) if cache else 0,
            "cum_params": shadow.params_transmitted,
            "cum_bytes": shadow.bytes_int8_signs,
        })
        return
    up_bytes, dn_bytes = [], []
    for v in views:
        c = v.client_id
        if rec.part[c] <= 0.5:
            up_bytes.append(0.0)
            dn_bytes.append(0.0)
            continue
        b0 = shadow.bytes_int8_signs
        if kind == "sync":
            shadow.log_full_exchange(int(rec.up_rows[c]), dim)
            b1 = shadow.bytes_int8_signs
            shadow.log_full_exchange(int(rec.dn_rows[c]), dim)
        else:
            codec.log_upload(shadow, int(rec.up_rows[c]), dim, v.num_shared)
            b1 = shadow.bytes_int8_signs
            codec.log_download(shadow, int(rec.dn_rows[c]), dim, v.num_shared)
        up_bytes.append(b1 - b0)
        dn_bytes.append(shadow.bytes_int8_signs - b1)
    shadow.end_round()
    sink.emit({
        "ev": "round", "round": int(t), "kind": kind,
        "up_rows": [int(x) for x in rec.up_rows],
        "dn_rows": [int(x) for x in rec.dn_rows],
        "overlap": [int(x) for x in rec.overlap],
        "res_mass": [float(x) for x in rec.res_mass],
        "part": [int(x > 0.5) for x in rec.part],
        "up_ok": [int(x > 0.5) for x in rec.up_ok],
        "dn_ok": [int(x > 0.5) for x in rec.dn_ok],
        "age": [int(x) for x in rec.age],
        "score_hist": [[int(x) for x in row] for row in rec.score_hist],
        "div_mean": [float(x) for x in rec.div_mean],
        "div_max": [float(x) for x in rec.div_max],
        "upd_norm": [float(x) for x in rec.upd_norm],
        "nonfinite": [int(x) for x in rec.nonfinite],
        "up_bytes": up_bytes, "dn_bytes": dn_bytes,
        "cache_hits": int(cache["hits"]) if cache else 0,
        "cache_misses": int(cache["misses"]) if cache else 0,
        "cache_evictions": int(cache["evictions"]) if cache else 0,
        "cum_params": shadow.params_transmitted,
        "cum_bytes": shadow.bytes_int8_signs,
    })


def _emit_ledger_event(sink, ledger) -> None:
    """The terminal reconciliation event: real vs shadow ledger totals."""
    sh = sink.shadow
    sink.emit({
        "ev": "ledger",
        "params_transmitted": ledger.params_transmitted,
        "bytes": ledger.bytes_int8_signs,
        "rounds": ledger.rounds,
        "shadow_params": sh.params_transmitted,
        "shadow_bytes": sh.bytes_int8_signs,
        "shadow_rounds": sh.rounds,
        "reconciled": bool(
            ledger.params_transmitted == sh.params_transmitted
            and ledger.bytes_int8_signs == sh.bytes_int8_signs
            and ledger.rounds == sh.rounds
        ),
    })


def run_federated(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: FederatedConfig,
    verbose: bool = False,
) -> FederatedResult:
    if cfg.engine not in ENGINES:
        raise ValueError(
            f"unknown engine {cfg.engine!r}; expected one of {ENGINES}"
        )
    rules = parse_alert_spec(cfg.alerts)  # eager: bad specs fail before work
    if rules and not cfg.telemetry:
        raise ValueError(
            "alerts need the event stream: set telemetry=<path> "
            "(--telemetry) alongside alerts"
        )
    if not cfg.telemetry:
        return _run_federated_impl(
            clients_data, num_global_entities, cfg, verbose, None
        )
    sink = TelemetrySink(cfg.telemetry)
    # the shadow ledger: re-bills every round from device-recorded telemetry
    # only; _finish's "ledger" event compares it to the real one bitwise
    sink.shadow = CommLedger()
    if rules:
        sink.monitor = HealthMonitor(rules, mode=cfg.alert_mode)
    sink.emit({
        "ev": "run",
        "engine": (
            "tiered" if (cfg.host_store or cfg.engine == "tiered")
            else cfg.engine
        ),
        "codec": "int8" if cfg.quantize_upload else cfg.codec,
        "method": cfg.method,
        "protocol": cfg.protocol,
        "clients": len(clients_data),
        "dim": cfg.dim,
        "rounds": cfg.rounds,
        "telemetry_version": 1,
    })
    try:
        with telemetry.session(sink):
            return _run_federated_impl(
                clients_data, num_global_entities, cfg, verbose, sink
            )
    finally:
        sink.close()


def _run_federated_impl(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: FederatedConfig,
    verbose: bool,
    sink,
) -> FederatedResult:
    sched = parse_fault_spec(cfg.faults)
    faulted = not sched.trivial
    checkpointing = bool(cfg.checkpoint_path)
    if cfg.checkpoint_every and not checkpointing:
        raise ValueError("checkpoint_every set without checkpoint_path")
    if cfg.resume and not checkpointing:
        raise ValueError("resume=True needs checkpoint_path")
    if cfg.host_store or cfg.engine == "tiered":
        if faulted:
            raise ValueError(
                "the host-tiered engine does not support fault schedules; "
                "use a dense device engine (fused/batched/superstep) or "
                "engine='reference'"
            )
        if checkpointing:
            raise ValueError(
                "checkpointing is implemented for the dense device engines "
                "only, not the host-tiered engine"
            )
        if cfg.mesh_devices > 1 or cfg.mesh_entities > 1:
            raise ValueError(
                "the host-tiered engine is a host-loop path; it composes "
                "with neither mesh_devices nor mesh_entities"
            )
        if cfg.engine not in ("tiered", "fused"):
            raise ValueError(
                f"host_store=True selects engine='tiered'; it conflicts "
                f"with engine={cfg.engine!r}"
            )
        return _run_federated_tiered(
            clients_data, num_global_entities, cfg, verbose, sink
        )
    clients = [
        KGEClient(
            d,
            method=cfg.method,
            dim=cfg.dim,
            gamma=cfg.gamma,
            batch_size=cfg.batch_size,
            num_negatives=cfg.num_negatives,
            lr=cfg.lr,
            adversarial_temperature=cfg.adversarial_temperature,
            seed=cfg.seed,
        )
        for d in clients_data
    ]
    views = build_comm_views(
        [d.local_to_global for d in clients_data], num_global_entities
    )
    codec_spec = cfg.codec
    if cfg.quantize_upload:
        if codec_spec not in ("identity", "int8", "int8-rows"):
            raise ValueError(
                f"quantize_upload (legacy alias for codec='int8') conflicts "
                f"with codec={cfg.codec!r}; set one of the two"
            )
        codec_spec = "int8"
    codec = parse_codec_spec(codec_spec)
    ledger = CommLedger()

    use_device = cfg.engine != "reference"
    if checkpointing and not use_device:
        raise ValueError(
            "checkpointing needs a device engine (the reference path keeps "
            "ragged host state with no stable serialization)"
        )
    sched.validate_clients(len(clients))
    mesh = None
    entity_axis = None
    if cfg.mesh_devices > 1 or cfg.mesh_entities > 1:
        if not use_device:
            raise ValueError(
                "pod mode (mesh_devices/mesh_entities > 1) requires a "
                "device engine, not engine='reference'"
            )
        mesh = make_federation_mesh(
            max(cfg.mesh_devices, 1),
            entity_devices=max(cfg.mesh_entities, 1),
        )
        entity_axis = "entities" if cfg.mesh_entities > 1 else None
    evaluator = None
    if use_device:
        engine_cls = SuperstepEngine if cfg.engine == "superstep" else CycleEngine
        cycle = engine_cls(
            clients, views, num_global_entities,
            sparsity_p=cfg.sparsity_p, local_epochs=cfg.local_epochs,
            codec=codec, mesh=mesh, entity_axis=entity_axis,
            faults=sched, telemetry=sink is not None,
        )
        state = cycle.init_state(clients, seed=cfg.seed + 777)
        # (kind, device down_count | None, round, record | None) 4-tuples
        pending: list = []
        # device-resident batched eval: banks built ONCE, eval boundaries
        # read back only a (C, EVAL_BLOCK_COLS) scalar block (no
        # sync_clients round-trip)
        evaluator = BatchedEvaluator(
            clients_data, method=cfg.method, gamma=cfg.gamma,
            e_max=cycle.e_max, max_triples=cfg.max_eval_triples,
            splits=("valid", "test"),
            known=[c._known for c in clients], mesh=mesh,
            entity_axis=entity_axis,
        )
    else:  # ragged numpy reference protocol keeps per-client histories
        rng = np.random.default_rng(cfg.seed + 777)
        histories = [
            clients[c].entity_embeddings[jnp.asarray(views[c].shared_local)]
            for c in range(len(clients))
        ]
        # host-side error-feedback banks (the ef=1 paper-faithful oracle)
        residuals = [
            np.zeros((v.num_shared, cfg.dim), np.float32) for v in views
        ] if codec.has_residual else None
        # straggler in-flight queues (host twin of FaultArrays.q_*): one
        # FIFO of lag messages per straggler, initialized empty — the first
        # lag contributions of a straggler are nothing at all
        straggler_q = {
            c: collections.deque(
                _empty_upload(c, cfg.dim) for _ in range(sched.lag)
            )
            for c in sched.stragglers
        } if (faulted and sched.has_stragglers) else None
        # telemetry host twins: the reference path has no device records, so
        # it rebuilds them from the ragged host state — through the SAME jit
        # helpers on identically padded buffers, so wherever the trajectory
        # matches the device engines bitwise, the records do too
        if sink is not None:
            tel_ns_max = max(v.num_shared for v in views)
            tel_nsv = np.array([v.num_shared for v in views])
            tel_valid = jnp.asarray(
                np.arange(tel_ns_max)[None, :] < tel_nsv[:, None]
            )
            tel_prev = [set() for _ in clients]  # last SENT upload, per client
            tel_ages = np.zeros(len(clients), np.int32)
            # padded gid twin of build_padded_views (padding -> num_global,
            # the throwaway divergence segment)
            tel_gid_np = np.full(
                (len(clients), tel_ns_max), num_global_entities, np.int32
            )
            for v in views:
                tel_gid_np[v.client_id, : v.num_shared] = v.shared_global
            tel_gid = jnp.asarray(tel_gid_np)

            def _tel_rows_pad():
                """Clients' current shared rows, padded like the engines'."""
                pad = np.zeros(
                    (len(clients), tel_ns_max, cfg.dim), np.float32
                )
                for c, v in zip(clients, views):
                    pad[v.client_id, : v.num_shared] = np.asarray(
                        c.params["entity"]
                    )[v.shared_local]
                return pad

    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None, "hits": 0.0}
    declines = 0
    prev_mrr = -1.0
    rounds_run = 0
    start_round = 0
    last_ckpt = 0
    # the "single" baseline evaluates on a slower cadence (no comm cost to track)
    ee = max(cfg.eval_every, 10) if cfg.protocol == "single" else cfg.eval_every

    if cfg.resume and os.path.exists(cfg.checkpoint_path):
        # bitwise continuation: everything trajectory-determining lives in
        # the restored FederationState (tables, Adam, hist, EF residuals,
        # fault arrays, PRNG key) + these host loop variables; fault masks
        # are drawn from the absolute round index, so nothing else is needed
        state, loop = fed_checkpoint.load_checkpoint(
            cfg.checkpoint_path, state, ledger, cfg=cfg
        )
        start_round = last_ckpt = loop["next_round"]
        eval_history = loop["eval_history"]
        best = loop["best"]
        declines = loop["declines"]
        prev_mrr = loop["prev_mrr"]
        rounds_run = start_round
        if verbose:
            print(
                f"resumed from {cfg.checkpoint_path} at round {start_round}"
            )

    def eval_boundary(round_no: int, block=None) -> bool:
        """Flush+evaluate at ``round_no``; True => early-stop.

        Device engines evaluate on device: ``block`` is the evaluator's
        ``(C, 5)`` metric block when the superstep program already produced
        it in-program, else the standalone compiled evaluator runs here —
        either way no entity table crosses the host, and the best-model
        snapshot is a cheap on-device copy taken only when MRR improves.
        """
        nonlocal best, declines, prev_mrr, last_ckpt
        if use_device:
            _flush_ledger(
                ledger, pending, views, codec, cfg.dim, cycle.k_per_client,
                sched=sched if faulted else None, sink=sink,
            )
            if block is None:
                block = evaluator.evaluate(state.arrays.params, "valid")
            val = aggregate_eval_block(block)
        else:
            val = weighted_average(
                [c.evaluate("valid", cfg.max_eval_triples) for c in clients]
            )
        if sink is not None:
            sink.emit({
                "ev": "eval", "round": int(round_no), "split": "valid",
                "mrr": float(val["mrr"]), "hits10": float(val["hits10"]),
                "params_transmitted": ledger.params_transmitted,
                "bytes": ledger.bytes_int8_signs,
            })
        eval_history.append((round_no, val["mrr"], val["hits10"]))
        if verbose:
            print(
                f"round {round_no:4d}  val MRR {val['mrr']:.4f}  "
                f"Hits@10 {val['hits10']:.4f}  params {ledger.params_transmitted:.3e}"
            )
        if val["mrr"] > best["mrr"]:
            snap = (
                {k: jnp.copy(v) for k, v in state.arrays.params.items()}
                if use_device else _snapshot(clients)
            )
            best = {
                "mrr": val["mrr"],
                "round": round_no,
                "snap": snap,
                "hits": val["hits10"],
            }
        declines = declines + 1 if val["mrr"] < prev_mrr else 0
        prev_mrr = val["mrr"]
        if (
            checkpointing
            and cfg.checkpoint_every > 0
            and round_no - last_ckpt >= cfg.checkpoint_every
        ):
            # eval boundaries are the device engines' only host touch-points,
            # so they are the checkpoint cadence too; the ledger was just
            # flushed, so pending is empty and the image is self-contained
            fed_checkpoint.save_checkpoint(
                cfg.checkpoint_path, state, ledger, cfg=cfg,
                next_round=round_no, eval_history=eval_history, best=best,
                declines=declines, prev_mrr=prev_mrr,
            )
            last_ckpt = round_no
        if sink is not None and sink.monitor is not None \
                and sink.monitor.should_stop():
            # fail-fast alert mode: stop gracefully — _finish still runs,
            # so the stream keeps its terminal ledger event
            if verbose:
                print(f"round {round_no:4d}  stopping on fail-level alert")
            return True
        return declines >= cfg.patience

    if cfg.engine == "superstep":
        # ------------------- superstep mode: chunk rounds to eval boundaries
        # so every superstep runs as ONE compiled program INCLUDING its
        # boundary eval (an "eval" plan segment), and evals land at exactly
        # the same rounds as the per-round engines.  Chunks end either at an
        # eval boundary or at the final round (terminal eval guarantee), so
        # every chunk carries an eval segment.
        t = start_round
        while t < cfg.rounds:
            chunk = min(((t // ee) + 1) * ee, cfg.rounds) - t
            kinds = tuple(
                round_kind(u, cfg.protocol, cfg.sync_interval)
                for u in range(t, t + chunk)
            )
            state, per_round, _losses, block = cycle.superstep_with_eval(
                state, kinds, evaluator, "valid", t0=t
            )
            if sink is None:
                pending.extend(
                    (k, d, t + i, None) for i, (k, d) in enumerate(per_round)
                )
            else:  # with telemetry the engine aligns (kind, down, record)
                pending.extend(
                    (k, d, t + i, r)
                    for i, (k, d, r) in enumerate(per_round)
                )
            t += chunk
            rounds_run = t
            if eval_boundary(t, block=block):
                break
        # superstep is always a device engine, so cycle/state/pending exist
        return _finish(
            cfg, clients, use_device, cycle, state, pending,
            views, codec, ledger, eval_history, best, rounds_run, evaluator,
            sched=sched if faulted else None, sink=sink,
        )

    for t in range(start_round, cfg.rounds):
        rounds_run = t + 1
        kind = round_kind(t, cfg.protocol, cfg.sync_interval)
        comm = kind != "none"
        sync = kind == "sync"

        if use_device:
            # ------------------------- device-resident train+communicate
            rec = None
            if cfg.engine == "fused":
                if comm:
                    out = cycle.fused_cycle(state, sync=sync, t=t)
                    if sink is not None:
                        state, down, _loss, rec = out
                    else:
                        state, down, _loss = out
                else:
                    state, _jitter, _loss = cycle.train_cycle(state)
                    down = None
            else:  # per-round oracle: separate train / comm programs
                state, jitter, _loss = cycle.train_cycle(state)
                down = None
                if comm:
                    out = cycle.comm_round(state, jitter, sync=sync, t=t)
                    if sink is not None:
                        state, down, rec = out
                    else:
                        state, down = out
            pending.append((kind, down if kind == "sparse" else None, t, rec))
        else:
            # ----------------------------------- numpy reference protocol
            # fault semantics (repro.core.faults): part -> the client
            # computes its upload (history / EF refresh) and exchanges bytes;
            # part & up_ok -> the message reaches the server (enters Eq. 3);
            # part & dn_ok -> the download lands (Eq. 4 applies).  Local
            # training is never gated — an absent client trains on, it just
            # doesn't communicate (matching the device engines' ungated
            # train scan).
            for c in clients:
                c.train_local(cfg.local_epochs)
            if faulted and comm:
                fpart, fup, fdn = host_round_faults(sched, t, len(clients))
            else:
                fpart = fup = fdn = np.ones(len(clients), dtype=bool)
            tel_pre = None
            if comm and sync:
                if sink is not None:
                    # pre-round shared rows, for the update-norm probe twin
                    tel_pre = _tel_rows_pad()
                uploads = []
                for c, v in zip(clients, views):
                    if not fpart[v.client_id]:
                        continue
                    if residuals is not None:
                        # the full exchange transmits exact values: stale
                        # banked error would re-inject pre-sync loss (same
                        # contract as the device engines' residual clear)
                        residuals[v.client_id][:] = 0.0
                    up, hist = full_upload(c.params["entity"], v)
                    histories[v.client_id] = hist
                    if straggler_q is not None and v.client_id in straggler_q:
                        # the full exchange obsoletes in-flight sparse
                        # messages — a present straggler's queue empties
                        # (the ISM sync round doubles as a recovery point)
                        straggler_q[v.client_id] = collections.deque(
                            _empty_upload(v.client_id, cfg.dim)
                            for _ in range(sched.lag)
                        )
                    if fup[v.client_id]:
                        uploads.append(up)
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
                if uploads:
                    global_mean, count = fede_aggregate(
                        uploads, num_global_entities
                    )
                for c, v in zip(clients, views):
                    if not fpart[v.client_id]:
                        continue
                    if uploads and fdn[v.client_id]:
                        # count-guarded: entities nobody uploaded this round
                        # keep their local rows (zero-participant guard)
                        c.params["entity"] = apply_full_download(
                            c.params["entity"], v, global_mean, count=count
                        )
                    ledger.log_full_exchange(v.num_shared, cfg.dim)
            elif comm:  # sparse FedS round, ragged numpy reference path
                if sink is not None:
                    # the device records score changes on post-train
                    # embeddings vs PRE-round histories — snapshot both
                    # before the upload loop refreshes them, padded to the
                    # same (C, Ns_max, D) the engines scan
                    emb_pad = np.zeros(
                        (len(clients), tel_ns_max, cfg.dim), np.float32
                    )
                    hist_pad = np.zeros_like(emb_pad)
                    for c, v in zip(clients, views):
                        n = v.num_shared
                        emb_pad[v.client_id, :n] = np.asarray(
                            c.params["entity"]
                        )[v.shared_local]
                        hist_pad[v.client_id, :n] = np.asarray(
                            histories[v.client_id]
                        )
                    sc = change_scores(
                        jnp.asarray(emb_pad).reshape(-1, cfg.dim),
                        jnp.asarray(hist_pad).reshape(-1, cfg.dim),
                    ).reshape(len(clients), tel_ns_max)
                    sc = jnp.where(tel_valid, sc, -jnp.inf)
                    tel_hist = np.asarray(score_histogram(sc, tel_valid))
                    tel_overlap = np.zeros(len(clients), np.int32)
                    tel_pre = emb_pad  # post-train, pre-comm — same rows the
                    # device round's update-norm probe measures against
                uploads = []
                for c, v in zip(clients, views):
                    cid = v.client_id
                    fresh = None
                    if fpart[cid]:
                        # wire codec (and its host-side error-feedback bank,
                        # when ef=1) applied inside the coded upload; a
                        # dropped message still refreshed history and
                        # residuals — the sender cannot know it was lost
                        up, hist, res = sparse_upload_coded(
                            c.params["entity"], histories[cid], v,
                            cfg.sparsity_p, codec,
                            residuals[cid] if residuals is not None
                            else None,
                        )
                        histories[cid] = hist
                        if residuals is not None:
                            residuals[cid] = res
                        if sink is not None:
                            # realized Top-K overlap with the previous SENT
                            # upload; absent clients keep their carry
                            cur = {int(e) for e in up.entity_ids}
                            tel_overlap[cid] = len(cur & tel_prev[cid])
                            tel_prev[cid] = cur
                        k_round = sparsity_k(v.num_shared, cfg.sparsity_p)
                        codec.log_upload(
                            ledger, k_round, cfg.dim, v.num_shared
                        )
                        if fup[cid]:
                            fresh = up
                    if straggler_q is not None and cid in straggler_q:
                        # delayed delivery: this round the server sees the
                        # message sent ``lag`` sparse rounds ago; the fresh
                        # (delivery-masked) message joins the queue tail
                        delivered = straggler_q[cid].popleft()
                        straggler_q[cid].append(
                            fresh if fresh is not None
                            else _empty_upload(cid, cfg.dim)
                        )
                    else:
                        delivered = (
                            fresh if fresh is not None
                            else _empty_upload(cid, cfg.dim)
                        )
                    # dense list: personalized_aggregate indexes uploads by
                    # client id; an undelivered message is a zero-entity
                    # Upload, which contributes to no aggregate
                    uploads.append(delivered)
                downloads = personalized_aggregate(
                    uploads,
                    [v.shared_global for v in views],
                    cfg.sparsity_p,
                    rng,
                )
                for c, v, d in zip(clients, views, downloads):
                    if not fpart[v.client_id]:
                        continue  # server neither selects nor bills
                    if codec.transforms_values and len(d.entity_ids):
                        d = dataclasses.replace(
                            d,
                            agg_values=np.asarray(
                                codec.roundtrip(jnp.asarray(d.agg_values)),
                                np.float32,
                            ),
                        )
                    codec.log_download(
                        ledger, len(d.entity_ids), cfg.dim, v.num_shared
                    )
                    if fdn[v.client_id]:
                        c.params["entity"] = apply_sparse_download(
                            c.params["entity"], v, d.entity_ids,
                            d.agg_values, d.priority,
                        )
            ledger.end_round()
            if sink is not None:
                rec_host = None
                if comm:
                    tel_ages = np.where(fpart, 0, tel_ages + 1).astype(
                        np.int32
                    )
                    if residuals is not None:
                        res_pad = np.zeros(
                            (len(clients), tel_ns_max, cfg.dim), np.float32
                        )
                        for v in views:
                            res_pad[v.client_id, : v.num_shared] = residuals[
                                v.client_id
                            ]
                        res_mass_h = np.asarray(
                            residual_mass(jnp.asarray(res_pad))
                        )
                    else:
                        res_mass_h = np.zeros(len(clients), np.float32)
                    if sync:
                        billed = np.where(fpart, tel_nsv, 0).astype(np.int32)
                        up_rows = dn_rows = billed
                        overlap = np.zeros(len(clients), np.int32)
                        hist_rec = np.zeros(
                            (len(clients), NUM_SCORE_BUCKETS), np.int32
                        )
                    else:
                        up_rows = np.where(
                            fpart,
                            [
                                sparsity_k(v.num_shared, cfg.sparsity_p)
                                for v in views
                            ],
                            0,
                        ).astype(np.int32)
                        dn_rows = np.array(
                            [
                                len(d.entity_ids) if fpart[v.client_id] else 0
                                for v, d in zip(views, downloads)
                            ],
                            np.int32,
                        )
                        overlap = tel_overlap
                        hist_rec = tel_hist
                    # health-probe twins: post-round padded rows through the
                    # SAME jit helpers the device records use, so wherever
                    # the trajectory matches bitwise, the probes do too
                    post_pad = jnp.asarray(_tel_rows_pad())
                    div_mean_h, div_max_h = shared_divergence(
                        post_pad, tel_gid, tel_valid, num_global_entities
                    )
                    rec_host = RoundTelemetry(
                        up_rows=up_rows, dn_rows=dn_rows, overlap=overlap,
                        res_mass=res_mass_h,
                        part=fpart.astype(np.float32),
                        up_ok=fup.astype(np.float32),
                        dn_ok=fdn.astype(np.float32),
                        age=tel_ages, score_hist=hist_rec,
                        div_mean=np.asarray(div_mean_h),
                        div_max=np.asarray(div_max_h),
                        upd_norm=np.asarray(update_norm(
                            post_pad, jnp.asarray(tel_pre), tel_valid
                        )),
                        nonfinite=np.asarray(
                            nonfinite_count(post_pad, tel_valid)
                        ),
                    )
                _emit_round_event(
                    sink, codec, cfg.dim, views, kind, t, rec_host
                )

        # ------------------------------------------------------- evaluation
        # terminal-eval guarantee: when rounds is not a multiple of the eval
        # cadence, the final partial span still ends with an eval boundary
        # (otherwise the last rounds are never evaluated and can never win
        # the best-model snapshot)
        at_boundary = (t + 1) % ee == 0 or (t + 1) == cfg.rounds
        if at_boundary and eval_boundary(t + 1):
            break

    return _finish(
        cfg, clients, use_device, cycle if use_device else None,
        state if use_device else None, pending if use_device else None,
        views, codec, ledger, eval_history, best, rounds_run,
        evaluator, sched=sched if faulted else None, sink=sink,
    )


def _finish(
    cfg, clients, use_device, cycle, state, pending,
    views, codec, ledger, eval_history, best, rounds_run, evaluator=None,
    sched=None, sink=None,
) -> FederatedResult:
    """Final flush + best-snapshot restore + test evaluation.

    Device engines restore the best on-device snapshot into the federation
    state, run the device-batched test eval, and only then materialize the
    tables into the per-client params (the single terminal host transfer).
    With telemetry on this also emits the terminal ``eval`` (test split) and
    ``ledger`` (real-vs-shadow reconciliation) events.
    """
    if use_device:
        _flush_ledger(
            ledger, pending, views, codec, cfg.dim, cycle.k_per_client,
            sched=sched, sink=sink,
        )
        if best["snap"] is not None:
            state = FederationState(
                state.arrays._replace(params=best["snap"]), state.key
            )
        test = aggregate_eval_block(
            evaluator.evaluate(state.arrays.params, "test")
        )
        cycle.sync_clients(state, clients)
    else:
        if best["snap"] is not None:
            _restore(clients, best["snap"])
        test = weighted_average(
            [c.evaluate("test", cfg.max_eval_triples) for c in clients]
        )
    if sink is not None:
        sink.emit({
            "ev": "eval", "round": int(rounds_run), "split": "test",
            "mrr": float(test["mrr"]), "hits10": float(test["hits10"]),
            "params_transmitted": ledger.params_transmitted,
            "bytes": ledger.bytes_int8_signs,
        })
        _emit_ledger_event(sink, ledger)
    return FederatedResult(
        config=cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )


def _run_federated_tiered(
    clients_data: list[ClientData],
    num_global_entities: int,
    cfg: FederatedConfig,
    verbose: bool = False,
    sink=None,
) -> FederatedResult:
    """The host-tiered simulation loop (engine="tiered" / host_store=True).

    Same round schedule, ledger accounting, eval cadence, patience, and
    best-snapshot protocol as the dense device engines, but federation
    state lives in :class:`repro.core.store.HostTieredStore`: the device
    holds the pinned shared prefix + a bounded row cache, and each eval
    boundary materializes the full tables once (the tiered tradeoff — the
    dense engines never move entity tables across the host).

    The tiered engine trains clients in lockstep, so train sets are
    truncated to the common minimum triple count up front.
    """
    n_tr = min(len(d.train) for d in clients_data)
    if verbose and any(len(d.train) != n_tr for d in clients_data):
        print(f"tiered engine: truncating train sets to lockstep ({n_tr} "
              f"triples/client)")
    train_data = [
        dataclasses.replace(d, train=d.train[:n_tr]) for d in clients_data
    ]

    def mk_clients():
        return [
            KGEClient(
                d, method=cfg.method, dim=cfg.dim, gamma=cfg.gamma,
                batch_size=cfg.batch_size, num_negatives=cfg.num_negatives,
                lr=cfg.lr,
                adversarial_temperature=cfg.adversarial_temperature,
                seed=cfg.seed,
            )
            for d in train_data
        ]

    clients = mk_clients()
    views = build_comm_views(
        [d.local_to_global for d in clients_data], num_global_entities
    )
    codec_spec = "int8" if cfg.quantize_upload else cfg.codec
    codec = parse_codec_spec(codec_spec)
    eng = TieredCycleEngine(
        clients, views, num_global_entities,
        sparsity_p=cfg.sparsity_p, local_epochs=cfg.local_epochs,
        codec=codec, cache_slots=cfg.cache_slots,
        stage_steps=cfg.stage_steps, telemetry=sink is not None,
    )
    store, ts = eng.init_state(mk_clients(), seed=cfg.seed + 777)
    evaluator = BatchedEvaluator(
        clients_data, method=cfg.method, gamma=cfg.gamma, e_max=eng.e_max,
        max_triples=cfg.max_eval_triples, splits=("valid", "test"),
        known=[c._known for c in clients],
    )
    ledger = CommLedger()
    pending: list = []
    # per-pending-round cache hit/miss/eviction deltas for the round events
    cache_stats: list = [] if sink is not None else None
    tel_prev_stats = (
        {k: store.stats[k] for k in ("hits", "misses", "evictions")}
        if sink is not None else None
    )
    eval_history: list[tuple[int, float, float]] = []
    best = {"mrr": -1.0, "round": 0, "snap": None, "hits": 0.0}
    declines = 0
    prev_mrr = -1.0
    rounds_run = 0
    ee = max(cfg.eval_every, 10) if cfg.protocol == "single" else cfg.eval_every

    for t in range(cfg.rounds):
        rounds_run = t + 1
        kind = round_kind(t, cfg.protocol, cfg.sync_interval)
        rec = None
        if sink is not None:
            ts, down, _loss, rec = eng.run_cycle(store, ts, kind)
            snap_stats = {
                k: store.stats[k] for k in ("hits", "misses", "evictions")
            }
            cache_stats.append(
                {k: snap_stats[k] - tel_prev_stats[k] for k in snap_stats}
            )
            tel_prev_stats = snap_stats
        else:
            ts, down, _loss = eng.run_cycle(store, ts, kind)
        pending.append((kind, down if kind == "sparse" else None, t, rec))
        if (t + 1) % ee == 0 or (t + 1) == cfg.rounds:
            _flush_ledger(
                ledger, pending, views, codec, cfg.dim, eng.k_per_client,
                sink=sink, cache_stats=cache_stats,
            )
            params = eng.materialize_params(store, ts)
            val = aggregate_eval_block(evaluator.evaluate(params, "valid"))
            if sink is not None:
                sink.emit({
                    "ev": "eval", "round": t + 1, "split": "valid",
                    "mrr": float(val["mrr"]),
                    "hits10": float(val["hits10"]),
                    "params_transmitted": ledger.params_transmitted,
                    "bytes": ledger.bytes_int8_signs,
                })
            eval_history.append((t + 1, val["mrr"], val["hits10"]))
            if verbose:
                print(
                    f"round {t + 1:4d}  val MRR {val['mrr']:.4f}  "
                    f"Hits@10 {val['hits10']:.4f}  "
                    f"params {ledger.params_transmitted:.3e}  "
                    f"cache hit {store.hit_rate:.3f}"
                )
            if val["mrr"] > best["mrr"]:
                best = {
                    "mrr": val["mrr"], "round": t + 1, "hits": val["hits10"],
                    "snap": {k: np.asarray(v) for k, v in params.items()},
                }
            declines = declines + 1 if val["mrr"] < prev_mrr else 0
            prev_mrr = val["mrr"]
            if sink is not None and sink.monitor is not None \
                    and sink.monitor.should_stop():
                # graceful fail-fast (mirrors eval_boundary): the terminal
                # flush + ledger event below still run
                if verbose:
                    print(f"round {t + 1:4d}  stopping on fail-level alert")
                break
            if declines >= cfg.patience:
                break

    _flush_ledger(
        ledger, pending, views, codec, cfg.dim, eng.k_per_client,
        sink=sink, cache_stats=cache_stats,
    )
    if best["snap"] is not None:
        params = {k: jnp.asarray(v) for k, v in best["snap"].items()}
    else:
        params = eng.materialize_params(store, ts)
    test = aggregate_eval_block(evaluator.evaluate(params, "test"))
    if sink is not None:
        sink.emit({
            "ev": "eval", "round": int(rounds_run), "split": "test",
            "mrr": float(test["mrr"]), "hits10": float(test["hits10"]),
            "params_transmitted": ledger.params_transmitted,
            "bytes": ledger.bytes_int8_signs,
        })
        _emit_ledger_event(sink, ledger)
    return FederatedResult(
        config=cfg,
        eval_history=eval_history,
        ledger=ledger,
        best_round=int(best["round"]),
        val_mrr_cg=float(best["mrr"]),
        test_mrr_cg=float(test["mrr"]),
        test_hits10_cg=float(test["hits10"]),
        rounds_run=rounds_run,
    )
