"""A federated KGE client: local training + filtered link-prediction eval.

Local training is a ``lax.scan`` over an epoch's worth of pre-sampled batches
(one jit per client shape signature); evaluation ranks every local entity as
candidate head/tail with filtered-setting masking, the standard KGE protocol.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.loader import TripleLoader
from repro.data.partition import ClientData
from repro.kge.scoring import (
    KGEModel,
    get_score_fn,
    init_kge_params,
    loss_from_scores,
    score_triples,
)
from repro.train.optimizer import AdamState, adam_init, adam_update


@functools.partial(jax.jit, static_argnames=("method", "gamma", "lr", "temp"))
def _train_epoch(
    params,
    opt_state,
    pos,  # (S, B, 3)
    neg_t,  # (S, B, N)
    neg_h,  # (S, B, N)
    method: str,
    gamma: float,
    lr: float,
    temp: float,
):
    # Gradients are computed with respect to the GATHERED embedding rows and
    # the row-cotangents scatter-added back ONCE per step (same scheme as the
    # fused trainer in repro.core.state): differentiating the table-indexing
    # loss directly materializes a dense (E, D) cotangent per gather, which
    # at FB15k scale costs ~20x the batch math itself.  Same gradient as
    # kge_loss, summation order aside.
    score = get_score_fn(method)

    def step(carry, batch):
        params, opt_state = carry
        p, nt, nh = batch
        b, n = nt.shape
        h, r, t = p[:, 0], p[:, 1], p[:, 2]
        idx = jnp.concatenate([h, t, nt.reshape(-1), nh.reshape(-1)])

        def loss_fn(rows, rel):
            h_e, t_e = rows[:b], rows[b : 2 * b]
            nt_e = rows[2 * b : (2 + n) * b].reshape(b, n, -1)
            nh_e = rows[(2 + n) * b :].reshape(b, n, -1)
            pos_s = score(h_e, rel, t_e, gamma)
            neg_t_s = score(h_e[:, None, :], rel[:, None, :], nt_e, gamma)
            neg_h_s = score(nh_e, rel[:, None, :], t_e[:, None, :], gamma)
            neg_s = jnp.concatenate([neg_t_s, neg_h_s], axis=-1)
            return loss_from_scores(pos_s, neg_s, method, temp)

        loss, (g_rows, g_rel) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            params["entity"][idx], params["relation"][r]
        )
        grads = {
            "entity": jnp.zeros_like(params["entity"]).at[idx].add(g_rows),
            "relation": jnp.zeros_like(params["relation"]).at[r].add(g_rel),
        }
        params, opt_state = adam_update(grads, opt_state, params, lr)
        return (params, opt_state), loss

    (params, opt_state), losses = jax.lax.scan(
        step, (params, opt_state), (pos, neg_t, neg_h)
    )
    return params, opt_state, losses.mean()


@functools.partial(jax.jit, static_argnames=("method", "gamma"))
def _rank_batch(
    params,
    triples,  # (B, 3)
    filter_tails,  # (B, E) bool — true known tails to mask (excl. the gold one)
    filter_heads,  # (B, E) bool
    method: str,
    gamma: float,
):
    """Filtered ranks of the gold tail and gold head.  Returns (B,), (B,) ranks."""
    h, r, t = triples[:, 0], triples[:, 1], triples[:, 2]
    n_ent = params["entity"].shape[0]
    cand = jnp.arange(n_ent)[None, :].repeat(triples.shape[0], axis=0)  # (B, E)

    t_scores = score_triples(params, h, r, cand, method, gamma)  # (B, E)
    t_scores = jnp.where(filter_tails, -jnp.inf, t_scores)
    gold_t = jnp.take_along_axis(t_scores, t[:, None], axis=1)
    rank_t = (t_scores > gold_t).sum(axis=1) + 1

    h_scores = score_triples(params, cand, r, t, method, gamma)  # (B, E)
    h_scores = jnp.where(filter_heads, -jnp.inf, h_scores)
    gold_h = jnp.take_along_axis(h_scores, h[:, None], axis=1)
    rank_h = (h_scores > gold_h).sum(axis=1) + 1
    return rank_t, rank_h


class KGEClient:
    """One client's full local state: embeddings, optimizer, data, history."""

    def __init__(
        self,
        data: ClientData,
        method: str,
        dim: int,
        gamma: float = 8.0,
        batch_size: int = 512,
        num_negatives: int = 64,
        lr: float = 1e-4,
        adversarial_temperature: float = 1.0,
        seed: int = 0,
    ):
        self.data = data
        self.method = method
        self.gamma = float(gamma)
        self.lr = float(lr)
        self.temp = float(adversarial_temperature)
        self.model = KGEModel(
            method=method,  # type: ignore[arg-type]
            num_entities=data.num_entities,
            num_relations=data.num_relations,
            dim=dim,
        )
        key = jax.random.PRNGKey(seed * 9973 + data.client_id)
        self.params = init_kge_params(key, self.model)
        self.opt_state: AdamState = adam_init(self.params)
        self.loader = TripleLoader(
            data.train,
            batch_size=batch_size,
            num_entities=data.num_entities,
            num_negatives=num_negatives,
            seed=seed * 131 + data.client_id,
        )
        # Filtered-setting lookup: all known triples on this client.
        self._known = {}
        all_triples = np.concatenate([data.train, data.valid, data.test], axis=0)
        for h, r, t in all_triples.tolist():
            self._known.setdefault(("t", h, r), set()).add(t)
            self._known.setdefault(("h", r, t), set()).add(h)
        # Per-split filter-mask cache: rebuilding dense (B, E) masks from
        # python sets on every evaluate() call dominated the eval hot loop.
        # Built lazily on first evaluate() and capped at the requested triple
        # count, so clients that never evaluate (or only evaluate a few
        # hundred rows of a large split) pay neither the build time nor the
        # resident memory.  Maps split -> (n_rows, tail_masks, head_masks).
        self._filter_cache: dict = {}

    # ----------------------------------------------------------- training
    def train_local(self, epochs: int) -> float:
        """Run ``epochs`` local epochs; returns mean loss of the last epoch."""
        loss = np.nan
        for _ in range(epochs):
            stacked = [b for b in self.loader.epoch()]
            pos = jnp.asarray(np.stack([b[0] for b in stacked]))
            neg_t = jnp.asarray(np.stack([b[1] for b in stacked]))
            neg_h = jnp.asarray(np.stack([b[2] for b in stacked]))
            self.params, self.opt_state, loss = _train_epoch(
                self.params,
                self.opt_state,
                pos,
                neg_t,
                neg_h,
                self.method,
                self.gamma,
                self.lr,
                self.temp,
            )
        return float(loss)

    # ---------------------------------------------------------- embeddings
    @property
    def entity_embeddings(self) -> jnp.ndarray:
        return self.params["entity"]

    def set_entity_rows(self, local_ids: np.ndarray, values: np.ndarray) -> None:
        self.params["entity"] = self.params["entity"].at[jnp.asarray(local_ids)].set(
            jnp.asarray(values, dtype=self.params["entity"].dtype)
        )

    # ---------------------------------------------------------------- eval
    def _filters(self, triples: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        b = triples.shape[0]
        e = self.data.num_entities
        ft = np.zeros((b, e), dtype=bool)
        fh = np.zeros((b, e), dtype=bool)
        for i, (h, r, t) in enumerate(triples.tolist()):
            tails = self._known.get(("t", h, r), set())
            heads = self._known.get(("h", r, t), set())
            if tails:
                ft[i, list(tails)] = True
            if heads:
                fh[i, list(heads)] = True
            ft[i, t] = False  # never filter the gold answer itself
            fh[i, h] = False
        return ft, fh

    def evaluate(self, split: str = "valid", max_triples: int = 2000) -> dict:
        """Filtered MRR / Hits@10 over both tail and head prediction."""
        triples = getattr(self.data, split)[:max_triples]
        if triples.shape[0] == 0:
            return {"mrr": 0.0, "hits10": 0.0, "count": 0}
        cached = self._filter_cache.get(split)
        if cached is None or cached[0] < triples.shape[0]:
            cached = (triples.shape[0], *self._filters(triples))
            self._filter_cache[split] = cached
        ft_all, fh_all = cached[1][: triples.shape[0]], cached[2][: triples.shape[0]]
        ranks = []
        bs = 256
        for i in range(0, triples.shape[0], bs):
            chunk = triples[i : i + bs]
            ft, fh = ft_all[i : i + bs], fh_all[i : i + bs]
            rt, rh = _rank_batch(
                self.params,
                jnp.asarray(chunk),
                jnp.asarray(ft),
                jnp.asarray(fh),
                self.method,
                self.gamma,
            )
            ranks.append(np.asarray(rt))
            ranks.append(np.asarray(rh))
        ranks_arr = np.concatenate(ranks).astype(np.float64)
        return {
            "mrr": float((1.0 / ranks_arr).mean()),
            "hits10": float((ranks_arr <= 10).mean()),
            "count": int(triples.shape[0]),
        }
