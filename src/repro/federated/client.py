"""A federated KGE client: local training + filtered link-prediction eval.

Local training is a ``lax.scan`` over an epoch's worth of pre-sampled batches
(one jit per client shape signature); evaluation ranks every local entity as
candidate head/tail with filtered-setting masking, the standard KGE protocol.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.evaluation import (
    build_known_index,
    num_filter_words,
    pack_filter_rows,
    unpack_filter_words,
)
from repro.data.loader import TripleLoader
from repro.data.partition import ClientData
from repro.kge.scoring import (
    KGEModel,
    get_scoring,
    init_kge_params,
    loss_from_scores,
    score_triples,
)
from repro.train.optimizer import AdamState, adam_init, adam_update


@functools.partial(jax.jit, static_argnames=("method", "gamma", "lr", "temp"))
def _train_epoch(
    params,
    opt_state,
    pos,  # (S, B, 3)
    neg_t,  # (S, B, N)
    neg_h,  # (S, B, N)
    method: str,
    gamma: float,
    lr: float,
    temp: float,
):
    # Gradients are computed with respect to the GATHERED embedding rows and
    # the row-cotangents scatter-added back ONCE per step (same scheme as the
    # fused trainer in repro.core.state): differentiating the table-indexing
    # loss directly materializes a dense (E, D) cotangent per gather, which
    # at FB15k scale costs ~20x the batch math itself.  Same gradient as
    # kge_loss, summation order aside.
    score = get_scoring(method).score

    def step(carry, batch):
        params, opt_state = carry
        p, nt, nh = batch
        b, n = nt.shape
        h, r, t = p[:, 0], p[:, 1], p[:, 2]
        idx = jnp.concatenate([h, t, nt.reshape(-1), nh.reshape(-1)])

        def loss_fn(rows, rel):
            h_e, t_e = rows[:b], rows[b : 2 * b]
            nt_e = rows[2 * b : (2 + n) * b].reshape(b, n, -1)
            nh_e = rows[(2 + n) * b :].reshape(b, n, -1)
            pos_s = score(h_e, rel, t_e, gamma)
            neg_t_s = score(h_e[:, None, :], rel[:, None, :], nt_e, gamma)
            neg_h_s = score(nh_e, rel[:, None, :], t_e[:, None, :], gamma)
            neg_s = jnp.concatenate([neg_t_s, neg_h_s], axis=-1)
            return loss_from_scores(pos_s, neg_s, method, temp)

        loss, (g_rows, g_rel) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            params["entity"][idx], params["relation"][r]
        )
        grads = {
            "entity": jnp.zeros_like(params["entity"]).at[idx].add(g_rows),
            "relation": jnp.zeros_like(params["relation"]).at[r].add(g_rel),
        }
        params, opt_state = adam_update(grads, opt_state, params, lr)
        return (params, opt_state), loss

    (params, opt_state), losses = jax.lax.scan(
        step, (params, opt_state), (pos, neg_t, neg_h)
    )
    return params, opt_state, losses.mean()


@functools.partial(jax.jit, static_argnames=("method", "gamma"))
def _rank_batch(
    params,
    triples,  # (B, 3)
    ft_words,  # (B, W) uint32 — bit-packed known-tail mask (gold bit clear)
    fh_words,  # (B, W) uint32 — bit-packed known-head mask
    method: str,
    gamma: float,
):
    """Filtered ranks of the gold tail and gold head.  Returns (B,), (B,) ranks.

    Filters arrive bit-packed (``core.evaluation.pack_filter_rows``) and are
    unpacked on device — the host never materializes ``(B, E)`` bools.
    """
    h, r, t = triples[:, 0], triples[:, 1], triples[:, 2]
    n_ent = params["entity"].shape[0]
    cand = jnp.arange(n_ent)[None, :].repeat(triples.shape[0], axis=0)  # (B, E)
    filter_tails = unpack_filter_words(ft_words, n_ent)
    filter_heads = unpack_filter_words(fh_words, n_ent)

    t_scores = score_triples(params, h, r, cand, method, gamma)  # (B, E)
    t_scores = jnp.where(filter_tails, -jnp.inf, t_scores)
    gold_t = jnp.take_along_axis(t_scores, t[:, None], axis=1)
    rank_t = (t_scores > gold_t).sum(axis=1) + 1

    h_scores = score_triples(params, cand, r, t, method, gamma)  # (B, E)
    h_scores = jnp.where(filter_heads, -jnp.inf, h_scores)
    gold_h = jnp.take_along_axis(h_scores, h[:, None], axis=1)
    rank_h = (h_scores > gold_h).sum(axis=1) + 1
    return rank_t, rank_h


class KGEClient:
    """One client's full local state: embeddings, optimizer, data, history."""

    def __init__(
        self,
        data: ClientData,
        method: str,
        dim: int,
        gamma: float = 8.0,
        batch_size: int = 512,
        num_negatives: int = 64,
        lr: float = 1e-4,
        adversarial_temperature: float = 1.0,
        seed: int = 0,
    ):
        self.data = data
        self.method = method
        self.gamma = float(gamma)
        self.lr = float(lr)
        self.temp = float(adversarial_temperature)
        self.model = KGEModel(
            method=method,  # type: ignore[arg-type]
            num_entities=data.num_entities,
            num_relations=data.num_relations,
            dim=dim,
        )
        key = jax.random.PRNGKey(seed * 9973 + data.client_id)
        self.params = init_kge_params(key, self.model)
        self.opt_state: AdamState = adam_init(self.params)
        self.loader = TripleLoader(
            data.train,
            batch_size=batch_size,
            num_entities=data.num_entities,
            num_negatives=num_negatives,
            seed=seed * 131 + data.client_id,
        )
        # Filtered-setting lookup: all known triples on this client (shared
        # builder with the device-batched evaluator).
        self._known = build_known_index(data.train, data.valid, data.test)
        # Per-(split, n_rows) bit-packed filter cache: rebuilding masks from
        # python sets on every evaluate() call dominated the eval hot loop.
        # Built lazily on first evaluate() and keyed on the exact row count
        # requested, so a later call with a SMALLER max_triples gets its own
        # correct entry (sliced from a superset when one exists) instead of
        # monotonically growing state, and a changed split length naturally
        # misses.  Rows are packed uint32 words (~32x smaller than the old
        # dense (B, E) bools); mutating a split's *contents* in place still
        # requires clearing the cache.  Maps (split, n_rows) ->
        # (ft_words, fh_words).
        self._filter_cache: dict[tuple[str, int], tuple] = {}

    # ----------------------------------------------------------- training
    def train_local(self, epochs: int) -> float:
        """Run ``epochs`` local epochs; returns mean loss of the last epoch."""
        loss = np.nan
        for _ in range(epochs):
            stacked = [b for b in self.loader.epoch()]
            pos = jnp.asarray(np.stack([b[0] for b in stacked]))
            neg_t = jnp.asarray(np.stack([b[1] for b in stacked]))
            neg_h = jnp.asarray(np.stack([b[2] for b in stacked]))
            self.params, self.opt_state, loss = _train_epoch(
                self.params,
                self.opt_state,
                pos,
                neg_t,
                neg_h,
                self.method,
                self.gamma,
                self.lr,
                self.temp,
            )
        return float(loss)

    # ---------------------------------------------------------- embeddings
    @property
    def entity_embeddings(self) -> jnp.ndarray:
        return self.params["entity"]

    def set_entity_rows(self, local_ids: np.ndarray, values: np.ndarray) -> None:
        self.params["entity"] = self.params["entity"].at[jnp.asarray(local_ids)].set(
            jnp.asarray(values, dtype=self.params["entity"].dtype)
        )

    # ---------------------------------------------------------------- eval
    def _packed_filters(self, split: str, n_rows: int) -> tuple:
        """(ft_words, fh_words) for the first ``n_rows`` of ``split``."""
        key = (split, n_rows)
        got = self._filter_cache.get(key)
        if got is None:
            # filter rows are per-triple independent, so a larger cached
            # block for the same split slices correctly
            for (sp, n), (ft, fh) in self._filter_cache.items():
                if sp == split and n >= n_rows:
                    got = (ft[:n_rows], fh[:n_rows])
                    break
            else:
                got = pack_filter_rows(
                    getattr(self.data, split)[:n_rows],
                    self._known,
                    num_filter_words(self.data.num_entities),
                )
            self._filter_cache[key] = got
        return got

    def ranks(self, split: str = "valid", max_triples: int = 2000) -> np.ndarray:
        """Integer filtered ranks, (n, 2): tail-leg and head-leg columns.

        This is the numpy-oracle rank path the device-batched evaluator
        (:mod:`repro.core.evaluation`) is property-tested exactly equal to.
        """
        triples = getattr(self.data, split)[:max_triples]
        n = int(triples.shape[0])
        if n == 0:
            return np.zeros((0, 2), np.int64)
        ft_all, fh_all = self._packed_filters(split, n)
        out = []
        bs = 256
        for i in range(0, n, bs):
            rt, rh = _rank_batch(
                self.params,
                jnp.asarray(triples[i : i + bs]),
                jnp.asarray(ft_all[i : i + bs]),
                jnp.asarray(fh_all[i : i + bs]),
                self.method,
                self.gamma,
            )
            out.append(np.stack([np.asarray(rt), np.asarray(rh)], axis=1))
        return np.concatenate(out).astype(np.int64)

    def evaluate(self, split: str = "valid", max_triples: int = 2000) -> dict:
        """Filtered MRR / Hits@{1,3,10} over both tail and head prediction."""
        ranks = self.ranks(split, max_triples)
        if ranks.shape[0] == 0:
            return {"mrr": 0.0, "hits1": 0.0, "hits3": 0.0, "hits10": 0.0,
                    "count": 0}
        ranks_arr = ranks.astype(np.float64).reshape(-1)
        return {
            "mrr": float((1.0 / ranks_arr).mean()),
            "hits1": float((ranks_arr <= 1).mean()),
            "hits3": float((ranks_arr <= 3).mean()),
            "hits10": float((ranks_arr <= 10).mean()),
            "count": int(ranks.shape[0]),
        }
