"""Adam optimizer (Kingma & Ba 2014) for arbitrary pytrees, from scratch.

No optax in this container; this is the single optimizer implementation used
by both the KGE federated runtime and the LM training steps.  Bias-corrected
Adam with optional global-norm clipping and decoupled weight decay (AdamW
when ``weight_decay > 0``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray  # () int32
    mu: Any  # first-moment pytree
    nu: Any  # second-moment pytree


def adam_init(params: Any) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adam_update(
    grads: Any,
    state: AdamState,
    params: Any,
    lr: float | jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    clip_norm: float | None = None,
) -> tuple[Any, AdamState]:
    """One Adam step.  Returns (new_params, new_state)."""
    if clip_norm is not None:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
    )
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = lr * mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay > 0.0:
            delta = delta + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)


def masked_adam_update(
    grads: Any,
    state: AdamState,
    params: Any,
    lr: float | jnp.ndarray,
    valid: jnp.ndarray,
    **kwargs: Any,
) -> tuple[Any, AdamState]:
    """Adam step gated by a scalar ``valid`` flag.

    When ``valid`` is False, params AND optimizer state (including the step
    count) pass through unchanged — used for padded scan steps when clients
    with different batches-per-epoch are stacked into one program
    (:mod:`repro.core.state`).
    """
    new_params, new_state = adam_update(grads, state, params, lr, **kwargs)
    keep = lambda new, old: jnp.where(valid, new, old)  # noqa: E731
    return (
        jax.tree.map(keep, new_params, params),
        jax.tree.map(keep, new_state, state),
    )
