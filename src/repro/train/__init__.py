"""Training substrate: optimizer, train/serve steps, checkpointing."""
from repro.train.optimizer import AdamState, adam_init, adam_update

__all__ = ["AdamState", "adam_init", "adam_update"]
