"""Jittable train / prefill / decode steps + ShapeDtypeStruct input specs.

These are the functions the launcher jits/lowers: one compile per
(arch x input-shape x mesh).  ``input_specs`` returns ShapeDtypeStruct
stand-ins (no allocation) for the dry-run; the same shapes drive the smoke
tests with real arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import (
    decode_lm,
    forward_lm,
    init_decode_state,
    lm_loss,
)
from repro.train.optimizer import AdamState, adam_init, adam_update


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# archs that may run the 500k decode shape (sub-quadratic story, DESIGN.md §5)
LONG_CONTEXT_ARCHS = ("zamba2-1.2b", "xlstm-350m", "gemma3-1b")


def shape_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(supported, reason-if-skipped) for an (arch, shape) pair."""
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, (
            "pure full-attention arch: 500k decode requires sub-quadratic "
            "attention (run only for ssm/hybrid/sliding-window archs)"
        )
    return True, ""


# ----------------------------------------------------------------- batches
def input_specs(
    cfg: ModelConfig, shape: InputShape, dtype=jnp.int32
) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this step."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {
            "tokens": sds((b, s), dtype),
            "labels": sds((b, s), dtype),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": sds((b, s), dtype)}
    else:  # decode
        specs = {"token": sds((b, 1), dtype)}
    if cfg.arch_type == "vlm" and shape.kind != "decode":
        specs["vision_embeds"] = sds((b, cfg.num_patches, cfg.d_model), cfg.dtype)
    if cfg.arch_type == "audio":
        specs["encoder_embeds"] = sds((b, cfg.encoder_seq_len, cfg.d_model), cfg.dtype)
    return specs


def make_inputs(cfg: ModelConfig, shape: InputShape, seed: int = 0) -> dict[str, Any]:
    """Real (host) arrays matching input_specs — used by smoke tests/examples."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, spec in input_specs(cfg, shape).items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(spec.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, spec.shape, 0, cfg.vocab_size, spec.dtype)
        else:
            out[name] = (jax.random.normal(sub, spec.shape) * 0.02).astype(spec.dtype)
    return out


# ------------------------------------------------------------------- steps
def make_train_step(cfg: ModelConfig, lr: float = 3e-4):
    """(params, opt_state, batch) -> (params, opt_state, loss)."""

    def loss_fn(params, batch):
        hidden, aux = forward_lm(
            params, cfg, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            encoder_embeds=batch.get("encoder_embeds"),
        )
        return lm_loss(params, cfg, hidden, batch["labels"], aux)

    def train_step(params, opt_state: AdamState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adam_update(grads, opt_state, params, lr, clip_norm=1.0)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """(params, batch) -> last-position logits (B, V)."""

    def prefill_step(params, batch):
        hidden, _ = forward_lm(
            params, cfg, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            encoder_embeds=batch.get("encoder_embeds"),
        )
        unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        return jnp.einsum("bd,dv->bv", hidden[:, -1], unembed)

    return prefill_step


def make_serve_step(cfg: ModelConfig, long_context: bool = False):
    """(params, token, state) -> (logits (B, V), new state)."""

    def serve_step(params, token, state):
        return decode_lm(params, cfg, token, state, long_context=long_context)

    return serve_step


def init_train_state(key: jax.Array, cfg: ModelConfig):
    from repro.models.transformer import init_lm

    params = init_lm(key, cfg)
    return params, adam_init(params)


def init_serve_state(
    params, cfg: ModelConfig, shape: InputShape, encoder_embeds=None
):
    state = init_decode_state(
        params, cfg, shape.global_batch, shape.seq_len, encoder_embeds
    )
    # decode against a FULL cache: next token lands at position seq_len - 1
    return state._replace(
        pos=jnp.full((shape.global_batch,), shape.seq_len - 1, jnp.int32)
    )
