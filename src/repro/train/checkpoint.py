"""Checkpointing: msgpack-serialized pytrees (no orbax in this container).

Arrays are stored as (dtype, shape, raw bytes) keyed by their pytree keystr;
restore requires a template pytree with the same structure (the usual
init-then-restore pattern).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def save_pytree(path: str, tree: Any) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {}
    for keypath, leaf in flat:
        arr = np.asarray(leaf)
        payload[jax.tree_util.keystr(keypath)] = (
            str(arr.dtype), list(arr.shape), arr.tobytes()
        )
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore_pytree(path: str, template: Any) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for keypath, leaf in flat:
        key = jax.tree_util.keystr(keypath)
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key}")
        dtype, shape, raw = payload[key]
        arr = np.frombuffer(raw, dtype=dtype).reshape(shape)
        if tuple(shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: {shape} vs {np.shape(leaf)}")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
