"""KGE triple-scoring Pallas kernels (TransE / RotatE negative scoring).

The client-side compute hot spot of FedE-style training is scoring a batch of
positive triples against N negatives: for TransE that is
``gamma - ||h + r - t_neg||`` over a (B, N, D) tensor.  XLA materialises the
(B, N, D) difference tensor in HBM; we instead tile (batch-block x neg-block)
so the difference lives only in VMEM/VREGs.

Tiling:
* grid (B/BB, N/BN); per step the kernel sees h,r blocks (BB, D) and a
  negatives block (BB, BN, D), writes scores (BB, BN),
* D padded to a lane multiple with zeros (exact for the distance: zero-padded
  coordinates contribute 0 to h + r - t when all three are padded),
* BB, BN chosen by the wrapper so the negative block fits VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transe_kernel(gamma, h_ref, r_ref, t_ref, out_ref):
    h = h_ref[...].astype(jnp.float32)  # (BB, D)
    r = r_ref[...].astype(jnp.float32)  # (BB, D)
    t = t_ref[...].astype(jnp.float32)  # (BB, BN, D)
    d = (h + r)[:, None, :] - t
    dist = jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=-1), 1e-24))
    out_ref[...] = gamma - dist


def _rotate_kernel(gamma, half, h_ref, p_ref, t_ref, out_ref):
    h = h_ref[...].astype(jnp.float32)  # (BB, D)
    phase = p_ref[...].astype(jnp.float32)  # (BB, half_padded)
    t = t_ref[...].astype(jnp.float32)  # (BB, BN, D)
    h_re, h_im = h[:, :half], h[:, half : 2 * half]
    t_re, t_im = t[:, :, :half], t[:, :, half : 2 * half]
    ph = phase[:, :half]
    r_re, r_im = jnp.cos(ph), jnp.sin(ph)
    d_re = (h_re * r_re - h_im * r_im)[:, None, :] - t_re
    d_im = (h_re * r_im + h_im * r_re)[:, None, :] - t_im
    dist = jnp.sqrt(d_re * d_re + d_im * d_im + 1e-12).sum(axis=-1)
    out_ref[...] = gamma - dist


@functools.partial(
    jax.jit, static_argnames=("gamma", "block_b", "block_n", "interpret")
)
def transe_neg_score_pallas(
    h: jnp.ndarray,  # (B, D)
    r: jnp.ndarray,  # (B, D)
    t_neg: jnp.ndarray,  # (B, N, D)
    gamma: float,
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, n, d = t_neg.shape
    d_pad = (-d) % 128
    b_pad = (-b) % block_b
    n_pad = (-n) % block_n
    h = jnp.pad(h, ((0, b_pad), (0, d_pad)))
    r = jnp.pad(r, ((0, b_pad), (0, d_pad)))
    t_neg = jnp.pad(t_neg, ((0, b_pad), (0, n_pad), (0, d_pad)))
    bf, nf, df = t_neg.shape

    out = pl.pallas_call(
        functools.partial(_transe_kernel, gamma),
        grid=(bf // block_b, nf // block_n),
        in_specs=[
            pl.BlockSpec((block_b, df), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, df), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, block_n, df), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bf, nf), jnp.float32),
        interpret=interpret,
    )(h, r, t_neg)
    return out[:b, :n]


def _dist_cand_kernel(gamma, mode, half, modulus, q_ref, c_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)  # (BB, D)
    c = c_ref[...].astype(jnp.float32)  # (BN, D)
    d = q[:, None, :] - c[None, :, :]  # (BB, BN, D)
    if mode == "transe":
        dist = jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=-1), 1e-24))
    elif mode == "rotate":  # unit-modulus rotation folded into q
        d_re, d_im = d[:, :, :half], d[:, :, half : 2 * half]
        dist = jnp.sqrt(d_re * d_re + d_im * d_im + 1e-12).sum(axis=-1)
    else:  # protate: q AND c in phase units, weighted |sin| distance
        dist = jnp.abs(jnp.sin(d)).sum(axis=-1) * modulus
    out_ref[...] = gamma - dist


@functools.partial(
    jax.jit,
    static_argnames=("gamma", "method", "modulus", "block_b", "block_n",
                     "interpret"),
)
def dist_cand_score_pallas(
    q: jnp.ndarray,  # (B, D) per-query rows (leg-specific, see kernels.ops)
    cand: jnp.ndarray,  # (N, D) candidate rows SHARED across the batch
    gamma: float,
    method: str = "transe",
    modulus: float = 1.0,
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Evaluation-shaped scoring: ``gamma - dist(q_b, cand_n)`` -> (B, N).

    Unlike the training kernels above (per-query ``(B, N, D)`` negatives),
    filtered-ranking eval scores every query against ONE shared candidate
    block, so the kernel tiles (query-block x candidate-block) and the
    ``(B, N, D)`` difference tensor never exists outside VMEM.  Both legs of
    every distance-family model reduce to this form with a precomputed query
    row (:attr:`repro.kge.scoring.ScoringSpec.cand_queries`): TransE tail
    ``q = h + r``, head ``q = t - r``; RotatE tail ``q = h∘r``, head
    ``q = t∘conj(r)`` (unit-modulus rotations preserve the distance);
    pRotatE rescales both q and the candidate block to phase units and takes
    the ``modulus``-weighted ``|sin|`` distance.  D is zero-padded to a lane
    multiple (exact: padded coordinates cancel in ``q - cand`` and
    ``sin(0) = 0``; RotatE slices its true halves before the modulus).
    """
    b, d = q.shape
    n = cand.shape[0]
    half = d // 2
    d_pad = (-d) % 128
    b_pad = (-b) % block_b
    n_pad = (-n) % block_n
    q = jnp.pad(q, ((0, b_pad), (0, d_pad)))
    cand = jnp.pad(cand, ((0, n_pad), (0, d_pad)))
    bf, df = q.shape
    nf = cand.shape[0]

    out = pl.pallas_call(
        functools.partial(_dist_cand_kernel, gamma, method, half, modulus),
        grid=(bf // block_b, nf // block_n),
        in_specs=[
            pl.BlockSpec((block_b, df), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, df), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bf, nf), jnp.float32),
        interpret=interpret,
    )(q, cand)
    return out[:b, :n]


@functools.partial(
    jax.jit, static_argnames=("gamma", "block_b", "block_n", "interpret")
)
def rotate_neg_score_pallas(
    h: jnp.ndarray,  # (B, D)
    phase: jnp.ndarray,  # (B, D/2)
    t_neg: jnp.ndarray,  # (B, N, D)
    gamma: float,
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, n, d = t_neg.shape
    half = d // 2
    d_pad = (-d) % 128
    p_pad = (-phase.shape[-1]) % 128
    b_pad = (-b) % block_b
    n_pad = (-n) % block_n
    h = jnp.pad(h, ((0, b_pad), (0, d_pad)))
    phase = jnp.pad(phase, ((0, b_pad), (0, p_pad)))
    t_neg = jnp.pad(t_neg, ((0, b_pad), (0, n_pad), (0, d_pad)))
    bf, nf, df = t_neg.shape

    out = pl.pallas_call(
        functools.partial(_rotate_kernel, gamma, half),
        grid=(bf // block_b, nf // block_n),
        in_specs=[
            pl.BlockSpec((block_b, df), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, phase.shape[-1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, block_n, df), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bf, nf), jnp.float32),
        interpret=interpret,
    )(h, phase, t_neg)
    return out[:b, :n]
