"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy:
* on TPU — compiled Pallas (Mosaic),
* elsewhere (this container: CPU) — Pallas ``interpret=True`` when
  ``REPRO_PALLAS_INTERPRET=1`` (used by the kernel test suite), otherwise the
  pure-jnp reference (fast path for the federated simulation, identical
  semantics — asserted by tests/test_kernels.py).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.change_score import change_score_pallas
from repro.kernels.kge_score import rotate_neg_score_pallas, transe_neg_score_pallas
from repro.kernels.sparse_apply import sparse_apply_pallas


def _mode() -> str:
    if jax.default_backend() == "tpu":
        return "tpu"
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return "interpret"
    return "ref"


def change_score(current: jnp.ndarray, history: jnp.ndarray) -> jnp.ndarray:
    """(N, D) x (N, D) -> (N,) fused 1-cosine change scores (Eq. 1)."""
    mode = _mode()
    if mode == "ref":
        return ref.change_score_ref(current, history)
    return change_score_pallas(current, history, interpret=(mode == "interpret"))


def transe_neg_score(h, r, t_neg, gamma: float) -> jnp.ndarray:
    """(B,D),(B,D),(B,N,D) -> (B,N) TransE negative scores."""
    mode = _mode()
    if mode == "ref":
        return ref.transe_neg_score_ref(h, r, t_neg, gamma)
    return transe_neg_score_pallas(h, r, t_neg, gamma, interpret=(mode == "interpret"))


def rotate_neg_score(h, phase, t_neg, gamma: float) -> jnp.ndarray:
    """(B,D),(B,D/2),(B,N,D) -> (B,N) RotatE negative scores."""
    mode = _mode()
    if mode == "ref":
        return ref.rotate_neg_score_ref(h, phase, t_neg, gamma)
    return rotate_neg_score_pallas(h, phase, t_neg, gamma, interpret=(mode == "interpret"))


def sparse_apply(emb, agg, priority, sign) -> jnp.ndarray:
    """Masked Eq. 4 row update."""
    mode = _mode()
    if mode == "ref":
        return ref.sparse_apply_ref(emb, agg, priority, sign)
    return sparse_apply_pallas(emb, agg, priority, sign, interpret=(mode == "interpret"))


def ssd_chunk(x, b, c, dt, ld, h_prev):
    """One Mamba2 SSD chunk: (y (B,L,H,P), h_new (B,H,N,P))."""
    mode = _mode()
    if mode == "ref":
        return ref.ssd_chunk_ref(x, b, c, dt, ld, h_prev)
    from repro.kernels.ssd_chunk import ssd_chunk_pallas

    return ssd_chunk_pallas(x, b, c, dt, ld, h_prev,
                            interpret=(mode == "interpret"))
