"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy:
* on TPU — compiled Pallas (Mosaic),
* elsewhere (this container: CPU) — Pallas ``interpret=True`` when
  ``REPRO_PALLAS_INTERPRET=1`` (used by the kernel test suite), otherwise the
  pure-jnp reference (fast path for the federated simulation, identical
  semantics — asserted by tests/test_kernels.py).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bilinear_score import bilinear_cand_score_pallas
from repro.kernels.change_score import change_score_pallas
from repro.kernels.kge_score import (
    dist_cand_score_pallas,
    rotate_neg_score_pallas,
    transe_neg_score_pallas,
)
from repro.kernels.sparse_apply import sparse_apply_pallas
from repro.kge import scoring


def _mode() -> str:
    if jax.default_backend() == "tpu":
        return "tpu"
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return "interpret"
    return "ref"


def change_score(current: jnp.ndarray, history: jnp.ndarray) -> jnp.ndarray:
    """(N, D) x (N, D) -> (N,) fused 1-cosine change scores (Eq. 1)."""
    mode = _mode()
    if mode == "ref":
        return ref.change_score_ref(current, history)
    return change_score_pallas(current, history, interpret=(mode == "interpret"))


def transe_neg_score(h, r, t_neg, gamma: float) -> jnp.ndarray:
    """(B,D),(B,D),(B,N,D) -> (B,N) TransE negative scores."""
    mode = _mode()
    if mode == "ref":
        return ref.transe_neg_score_ref(h, r, t_neg, gamma)
    return transe_neg_score_pallas(h, r, t_neg, gamma, interpret=(mode == "interpret"))


def rotate_neg_score(h, phase, t_neg, gamma: float) -> jnp.ndarray:
    """(B,D),(B,D/2),(B,N,D) -> (B,N) RotatE negative scores."""
    mode = _mode()
    if mode == "ref":
        return ref.rotate_neg_score_ref(h, phase, t_neg, gamma)
    return rotate_neg_score_pallas(h, phase, t_neg, gamma, interpret=(mode == "interpret"))


def kge_score_rows(h, r, t, method: str, gamma: float) -> jnp.ndarray:
    """Score already-gathered embedding rows (broadcasting, jnp semantics).

    Always the exact :mod:`repro.kge.scoring` arithmetic — this is the gold
    path of the batched evaluator, whose rank-exactness contract with the
    numpy oracle depends on candidate and gold scores sharing one
    definition.
    """
    return scoring.get_score_fn(method)(h, r, t, gamma)


def kge_cand_scores(h, r, t, cand, method: str, gamma: float):
    """Both filtered-ranking legs against a shared candidate block.

    ``h``/``r``/``t``: ``(..., B, D[r])`` gathered query rows;
    ``cand``: ``(..., N, D)`` candidate entity rows shared across the batch
    (leading axes, e.g. the client axis, broadcast/vmap through).  Returns
    ``(tail_scores, head_scores)``, each ``(..., B, N)``.

    Dispatch is by the registry's family tag
    (:attr:`repro.kge.scoring.ScoringSpec.family`): on TPU/interpret the
    distance family runs through the tiled ``dist_cand_score_pallas`` eval
    kernel and the bilinear family (ComplEx/DistMult — contractions, not
    distances) through the matmul-style ``bilinear_cand_score_pallas``,
    both with per-leg query rows precomputed by ``spec.cand_queries`` (see
    the kernel docstrings for the algebra).  The ref path broadcasts the
    exact :mod:`repro.kge.scoring` functions, which is what the
    oracle-exactness property tests pin.  Unknown methods raise the
    registry's ValueError listing every registered name.
    """
    spec = scoring.get_scoring(method)
    mode = _mode()
    if mode == "ref":
        ts = spec.score(
            h[..., :, None, :], r[..., :, None, :], cand[..., None, :, :], gamma
        )
        hs = spec.score(
            cand[..., None, :, :], r[..., :, None, :], t[..., :, None, :], gamma
        )
        return ts, hs
    interpret = mode == "interpret"
    q_t, q_h = spec.cand_queries(h, r, t, gamma)
    hs = None
    if not spec.fold_head:
        # head leg nonlinear in the candidate (spec.cand_queries gave no
        # q_head): evaluate score(c, r, t) exactly on the RAW candidate
        # block, before cand_prep rewrites it for the kernel.
        hs = spec.score(
            cand[..., None, :, :], r[..., :, None, :], t[..., :, None, :], gamma
        )
    cand = spec.cand_prep(cand, gamma)
    if spec.family == "distance":
        statics = spec.kernel_statics(gamma, h.shape[-1])
        fn = lambda q, c: dist_cand_score_pallas(  # noqa: E731
            q, c, gamma, method=spec.kernel_mode, interpret=interpret,
            **statics
        )
    else:  # bilinear: both legs are q @ cand^T on the MXU
        fn = lambda q, c: bilinear_cand_score_pallas(  # noqa: E731
            q, c, interpret=interpret
        )
    for _ in range(h.ndim - 2):  # leading client axes
        fn = jax.vmap(fn)
    return fn(q_t, cand), (hs if hs is not None else fn(q_h, cand))


def sparse_apply(emb, agg, priority, sign) -> jnp.ndarray:
    """Masked Eq. 4 row update."""
    mode = _mode()
    if mode == "ref":
        return ref.sparse_apply_ref(emb, agg, priority, sign)
    return sparse_apply_pallas(emb, agg, priority, sign, interpret=(mode == "interpret"))


def ssd_chunk(x, b, c, dt, ld, h_prev):
    """One Mamba2 SSD chunk: (y (B,L,H,P), h_new (B,H,N,P))."""
    mode = _mode()
    if mode == "ref":
        return ref.ssd_chunk_ref(x, b, c, dt, ld, h_prev)
    from repro.kernels.ssd_chunk import ssd_chunk_pallas

    return ssd_chunk_pallas(x, b, c, dt, ld, h_prev,
                            interpret=(mode == "interpret"))
