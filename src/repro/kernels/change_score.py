"""Fused entity-change-score Pallas kernel (Eq. 1 hot spot).

Computes ``1 - cos(cur_row, hist_row)`` for every row of two (N, D) tables in
a single HBM pass.  Unfused XLA emits three reductions (dot, |cur|^2,
|hist|^2) which — row-reduction fusion aside — reads the tables up to three
times; at FedS scale (N = vocab rows, every communication round) this is the
bandwidth-bound hot spot, so we fuse all three reductions over one VMEM tile.

TPU tiling:
* grid over row blocks; block (BR, D) of both tables lives in VMEM,
* BR chosen by the ops wrapper so 2 * BR * D * 4B fits comfortably in VMEM
  (~4 MiB working set target out of ~16 MiB/core on v5e),
* D padded to a multiple of 128 (lane width) with zeros — zero padding is
  exact for dot products and norms,
* rows padded to a multiple of BR; padded rows are sliced off by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _change_score_kernel(cur_ref, hist_ref, out_ref):
    cur = cur_ref[...].astype(jnp.float32)
    hist = hist_ref[...].astype(jnp.float32)
    dot = jnp.sum(cur * hist, axis=-1)
    nc = jnp.sum(cur * cur, axis=-1)
    nh = jnp.sum(hist * hist, axis=-1)
    out_ref[...] = 1.0 - dot * jax.lax.rsqrt(jnp.maximum(nc * nh, 1e-24))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def change_score_pallas(
    current: jnp.ndarray,
    history: jnp.ndarray,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """(N, D) x (N, D) -> (N,) change scores.  Inputs may be any float dtype."""
    n, d = current.shape
    # Pad D to lane width, N to the row block.
    d_pad = (-d) % 128
    n_pad = (-n) % block_rows
    cur = jnp.pad(current, ((0, n_pad), (0, d_pad)))
    hist = jnp.pad(history, ((0, n_pad), (0, d_pad)))
    n_full, d_full = cur.shape

    out = pl.pallas_call(
        _change_score_kernel,
        grid=(n_full // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d_full), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d_full), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_full,), jnp.float32),
        interpret=interpret,
    )(cur, hist)
    return out[:n]
