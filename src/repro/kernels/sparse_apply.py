"""Masked Eq. 4 row-update Pallas kernel.

Client-side download application: for the rows the server selected (sign=1),
``E <- (A + E) / (1 + P)``; other rows pass through.  Fusing the mask, add,
and divide into one pass avoids the gather -> update -> scatter round trip
through HBM that a straightforward ``E.at[idx].set(...)`` lowers to.

Tiling: row blocks (BR, D) in VMEM; priority/sign come in as (BR, 1) columns
so every operand keeps a lane-aligned 2D layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sparse_apply_kernel(emb_ref, agg_ref, pri_ref, sign_ref, out_ref):
    emb = emb_ref[...].astype(jnp.float32)  # (BR, D)
    agg = agg_ref[...].astype(jnp.float32)  # (BR, D)
    pri = pri_ref[...].astype(jnp.float32)  # (BR, 1)
    sign = sign_ref[...]  # (BR, 1) int32
    updated = (agg + emb) / (1.0 + pri)
    out_ref[...] = jnp.where(sign != 0, updated, emb)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sparse_apply_pallas(
    emb: jnp.ndarray,  # (N, D)
    agg: jnp.ndarray,  # (N, D)
    priority: jnp.ndarray,  # (N,)
    sign: jnp.ndarray,  # (N,) any int dtype
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    n, d = emb.shape
    d_pad = (-d) % 128
    n_pad = (-n) % block_rows
    emb_p = jnp.pad(emb, ((0, n_pad), (0, d_pad)))
    agg_p = jnp.pad(agg, ((0, n_pad), (0, d_pad)))
    pri_p = jnp.pad(priority.astype(jnp.float32), (0, n_pad))[:, None]
    sign_p = jnp.pad(sign.astype(jnp.int32), (0, n_pad))[:, None]
    n_full, d_full = emb_p.shape

    out = pl.pallas_call(
        _sparse_apply_kernel,
        grid=(n_full // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d_full), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d_full), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d_full), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_full, d_full), jnp.float32),
        interpret=interpret,
    )(emb_p, agg_p, pri_p, sign_p)
    return out[:n, :d].astype(emb.dtype)
