"""Pallas TPU kernels for FedS hot spots.

Layout per the repo convention: ``<name>.py`` holds the ``pl.pallas_call`` +
BlockSpec kernel, :mod:`repro.kernels.ops` the jit'd public wrappers, and
:mod:`repro.kernels.ref` the pure-jnp oracles the tests sweep against.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
