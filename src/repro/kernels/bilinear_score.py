"""Bilinear-family candidate-scoring Pallas kernel (DistMult / ComplEx eval).

DistMult and ComplEx score with a trilinear *contraction*, not a distance,
so the distance eval kernel (:mod:`repro.kernels.kge_score`) cannot serve
them — before this kernel existed they silently fell back to the broadcast
ref path even on TPU.  Both filtered-ranking legs reduce to a matmul against
the shared candidate block with a per-leg precomputed query row
(:attr:`repro.kge.scoring.ScoringSpec.cand_queries`):

* DistMult tail ``q = h * r``, head ``q = t * r``;
* ComplEx folds the relation into the query's (re, im) halves so each leg is
  again ``score(c) = q . c``.

That is a plain ``(B, D) x (D, N)`` contraction, so unlike the distance
kernel — whose VPU reduction materialises a per-tile ``(BB, BN, D)``
difference — the MXU does the reduction here.  The grid tiles (query-block x
candidate-block) with full-D blocks, accumulating in f32
(``preferred_element_type``); D zero-padding is exact for a dot product
(padded coordinates contribute 0), B/N padding is sliced off the output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bilinear_kernel(q_ref, c_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)  # (BB, D)
    c = c_ref[...].astype(jnp.float32)  # (BN, D)
    out_ref[...] = jax.lax.dot_general(
        q, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def bilinear_cand_score_pallas(
    q: jnp.ndarray,  # (B, D) per-query rows (leg-specific, see kernels.ops)
    cand: jnp.ndarray,  # (N, D) candidate rows SHARED across the batch
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Evaluation-shaped bilinear scoring: ``q @ cand^T`` -> (B, N)."""
    b, d = q.shape
    n = cand.shape[0]
    d_pad = (-d) % 128
    b_pad = (-b) % block_b
    n_pad = (-n) % block_n
    q = jnp.pad(q, ((0, b_pad), (0, d_pad)))
    cand = jnp.pad(cand, ((0, n_pad), (0, d_pad)))
    bf, df = q.shape
    nf = cand.shape[0]

    out = pl.pallas_call(
        _bilinear_kernel,
        grid=(bf // block_b, nf // block_n),
        in_specs=[
            pl.BlockSpec((block_b, df), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, df), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bf, nf), jnp.float32),
        interpret=interpret,
    )(q, cand)
    return out[:b, :n]
