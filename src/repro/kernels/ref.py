"""Pure-jnp reference oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth: kernel tests sweep shapes/dtypes
and assert_allclose against these.  The ops wrappers also fall back to these
on non-TPU backends when interpret mode is disabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def change_score_ref(current: jnp.ndarray, history: jnp.ndarray) -> jnp.ndarray:
    """1 - cosine(current_row, history_row) per row.  (N, D) -> (N,).

    Uses rsqrt of the norm product (what the fused kernel computes) with an
    epsilon inside the sqrt for zero rows.
    """
    dot = jnp.sum(current * history, axis=-1)
    nc = jnp.sum(current * current, axis=-1)
    nh = jnp.sum(history * history, axis=-1)
    return 1.0 - dot * jax.lax.rsqrt(jnp.maximum(nc * nh, 1e-24))


def transe_neg_score_ref(
    h: jnp.ndarray,  # (B, D)
    r: jnp.ndarray,  # (B, D)
    t: jnp.ndarray,  # (B, N, D) negative tails
    gamma: float,
) -> jnp.ndarray:
    """gamma - ||h + r - t||_2 per (batch, negative).  -> (B, N)."""
    d = h[:, None, :] + r[:, None, :] - t
    return gamma - jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=-1), 1e-24))


def rotate_neg_score_ref(
    h: jnp.ndarray,  # (B, D) interleaved-halves complex
    phase: jnp.ndarray,  # (B, D/2)
    t: jnp.ndarray,  # (B, N, D)
    gamma: float,
) -> jnp.ndarray:
    """gamma - sum_j |h_j * e^{i phase_j} - t_j| .  -> (B, N)."""
    half = h.shape[-1] // 2
    h_re, h_im = h[..., :half], h[..., half:]
    t_re, t_im = t[..., :half], t[..., half:]
    r_re, r_im = jnp.cos(phase), jnp.sin(phase)
    d_re = (h_re * r_re - h_im * r_im)[:, None, :] - t_re
    d_im = (h_re * r_im + h_im * r_re)[:, None, :] - t_im
    return gamma - jnp.sqrt(d_re * d_re + d_im * d_im + 1e-12).sum(axis=-1)


def sparse_apply_ref(
    emb: jnp.ndarray,  # (N, D) local embeddings E^t
    agg: jnp.ndarray,  # (N, D) dense-scattered aggregate A^t (0 where unsent)
    priority: jnp.ndarray,  # (N,) priority weights P^t (0 where unsent)
    sign: jnp.ndarray,  # (N,) 0/1 selection
) -> jnp.ndarray:
    """Eq. 4 masked row update: selected rows -> (A + E) / (1 + P)."""
    updated = (agg + emb) / (1.0 + priority)[:, None]
    return jnp.where(sign[:, None] != 0, updated, emb)


def ssd_chunk_ref(
    x: jnp.ndarray,  # (B, L, H, P)
    b: jnp.ndarray,  # (B, L, N)
    c: jnp.ndarray,  # (B, L, N)
    dt: jnp.ndarray,  # (B, L, H)
    ld: jnp.ndarray,  # (B, L, H) log decay
    h_prev: jnp.ndarray,  # (B, H, N, P)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One Mamba2 SSD chunk (intra + cross + state update), f32.

    y[t] = sum_{j<=t} (c_t . b_j) dt_j exp(cum_t - cum_j) x_j
         + c_t exp(cum_t) h_prev
    h'   = exp(cum_L) h_prev + sum_j exp(cum_L - cum_j) dt_j b_j x_j^T
    """
    f = jnp.float32
    x, b, c, dt, ld = (t.astype(f) for t in (x, b, c, dt, ld))
    h_prev = h_prev.astype(f)
    l = x.shape[1]
    cum = jnp.cumsum(ld, axis=1)  # (B,L,H)
    gap = cum[:, :, None, :] - cum[:, None, :, :]  # (B,L,L,H)
    tri = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(tri[None, :, :, None], jnp.exp(gap), 0.0)
    cb = jnp.einsum("btn,bjn->btj", c, b)  # (B,L,L)
    w = cb[..., None] * decay * dt[:, None, :, :]  # (B,L,L,H)
    y_intra = jnp.einsum("btjh,bjhp->bthp", w, x)
    y_cross = jnp.einsum("btn,bth,bhnp->bthp", c, jnp.exp(cum), h_prev)
    tail = jnp.exp(cum[:, -1:, :] - cum) * dt  # (B,L,H)
    s_k = jnp.einsum("bln,blh,blhp->bhnp", b, tail, x)
    h_new = h_prev * jnp.exp(cum[:, -1, :])[:, :, None, None] + s_k
    return y_intra + y_cross, h_new
