"""Mamba2 SSD chunk kernel — the SSM archs' compute hot spot.

One chunk of the state-space-duality recurrence for one (batch, head)
program: the intra-chunk attention-like masked matmul, the cross-chunk
contribution from the carried state, and the state update — all resident in
VMEM (L x L, L x N, L x P, N x P tiles; L=chunk<=256, N=state<=128, P=head
dim <=128 all fit comfortably).

Grid: (B, H).  b/c projections are shared across heads (Mamba2 design), so
their blocks ignore the head index.  The jnp oracle is
:func:`repro.kernels.ref.ssd_chunk_ref`; `repro/models/ssm.py` routes its
chunk body through :func:`repro.kernels.ops.ssd_chunk`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, b_ref, c_ref, dt_ref, ld_ref, h_ref, y_ref, hn_ref):
    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    b = b_ref[0].astype(jnp.float32)  # (L, N)
    c = c_ref[0].astype(jnp.float32)  # (L, N)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    ld = ld_ref[0, :, 0].astype(jnp.float32)  # (L,)
    h_prev = h_ref[0, 0].astype(jnp.float32)  # (N, P)
    l = x.shape[0]

    cum = jnp.cumsum(ld)  # (L,)
    gap = cum[:, None] - cum[None, :]  # (L, L)
    tri = jnp.tril(jnp.ones((l, l), jnp.bool_))
    decay = jnp.where(tri, jnp.exp(gap), 0.0)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (L, L)
    w = cb * decay * dt[None, :]
    y_intra = jnp.dot(w, x, preferred_element_type=jnp.float32)  # (L, P)
    y_cross = jnp.exp(cum)[:, None] * jnp.dot(
        c, h_prev, preferred_element_type=jnp.float32
    )
    y_ref[0, :, 0, :] = y_intra + y_cross

    tail = jnp.exp(cum[-1] - cum) * dt  # (L,)
    s_k = jnp.dot((b * tail[:, None]).T, x, preferred_element_type=jnp.float32)
    hn_ref[0, 0] = h_prev * jnp.exp(cum[-1]) + s_k


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(
    x: jnp.ndarray,  # (B, L, H, P) f32
    b: jnp.ndarray,  # (B, L, N)
    c: jnp.ndarray,  # (B, L, N)
    dt: jnp.ndarray,  # (B, L, H)
    ld: jnp.ndarray,  # (B, L, H) log decay
    h_prev: jnp.ndarray,  # (B, H, N, P)
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bs, l, h, p = x.shape
    n = b.shape[-1]
    y, hn = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(bs, h),
        in_specs=[
            pl.BlockSpec((1, l, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, l, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, l, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, l, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, l, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs, l, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bs, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(x, b, c, dt, ld, h_prev)
    return y, hn
