"""KGE scoring functions: TransE, RotatE, ComplEx.

Conventions (matching FedE / the RotatE reference implementation):

* entity embeddings ``E  : (num_entities, dim)``
* relation embeddings ``R : (num_relations, rel_dim)``
* For TransE ``rel_dim == dim``. For RotatE the entity embedding is a point
  in C^{dim/2} stored as interleaved (re, im) halves and ``rel_dim == dim/2``
  (a phase per complex coordinate). For ComplEx both entities and relations
  live in C^{dim/2} (``rel_dim == dim``).
* Scores are "higher is better".  TransE / RotatE produce
  ``gamma - distance``; ComplEx produces the trilinear product.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

Method = Literal["transe", "rotate", "complex"]

# Initialisation hyper-parameters from the paper (Section IV-B):
# gamma = 8, epsilon = 2; embedding range = (gamma + eps) / dim.
DEFAULT_GAMMA = 8.0
DEFAULT_EPSILON = 2.0


@dataclasses.dataclass(frozen=True)
class KGEModel:
    """Static description of a KGE scoring model."""

    method: Method
    num_entities: int
    num_relations: int
    dim: int  # entity embedding dimension (real parameter count per entity)
    gamma: float = DEFAULT_GAMMA
    epsilon: float = DEFAULT_EPSILON

    @property
    def rel_dim(self) -> int:
        if self.method == "rotate":
            return self.dim // 2
        return self.dim

    @property
    def embedding_range(self) -> float:
        return (self.gamma + self.epsilon) / self.dim


def init_kge_params(key: jax.Array, model: KGEModel) -> dict:
    """Uniform init in [-embedding_range, embedding_range] as in RotatE/FedE."""
    k_e, k_r = jax.random.split(key)
    rng = model.embedding_range
    ent = jax.random.uniform(
        k_e, (model.num_entities, model.dim), minval=-rng, maxval=rng
    )
    if model.method == "rotate":
        # Phases in [-pi, pi].
        rel = jax.random.uniform(
            k_r, (model.num_relations, model.rel_dim), minval=-jnp.pi, maxval=jnp.pi
        )
    else:
        rel = jax.random.uniform(
            k_r, (model.num_relations, model.rel_dim), minval=-rng, maxval=rng
        )
    return {"entity": ent, "relation": rel}


def _split_complex(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Split the last dim into (re, im) halves."""
    half = x.shape[-1] // 2
    return x[..., :half], x[..., half:]


def transe_score(
    h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray, gamma: float
) -> jnp.ndarray:
    """gamma - ||h + r - t||_2 ; broadcasts over leading dims."""
    return gamma - jnp.linalg.norm(h + r - t, axis=-1)


def rotate_score(
    h: jnp.ndarray, phase: jnp.ndarray, t: jnp.ndarray, gamma: float
) -> jnp.ndarray:
    """gamma - || h o r - t ||  with r = exp(i * phase), h,t in C^{d/2}."""
    h_re, h_im = _split_complex(h)
    t_re, t_im = _split_complex(t)
    r_re, r_im = jnp.cos(phase), jnp.sin(phase)
    d_re = h_re * r_re - h_im * r_im - t_re
    d_im = h_re * r_im + h_im * r_re - t_im
    # RotatE uses the sum of complex moduli (L2 over the (re,im) pair, L1 over
    # coordinates).
    dist = jnp.sqrt(d_re**2 + d_im**2 + 1e-12).sum(axis=-1)
    return gamma - dist


def complex_score(
    h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray, gamma: float = 0.0
) -> jnp.ndarray:
    """Re(<h, r, conj(t)>)."""
    del gamma
    h_re, h_im = _split_complex(h)
    r_re, r_im = _split_complex(r)
    t_re, t_im = _split_complex(t)
    return (
        (h_re * r_re * t_re)
        + (h_im * r_re * t_im)
        + (h_re * r_im * t_im)
        - (h_im * r_im * t_re)
    ).sum(axis=-1)


_SCORE_FNS = {
    "transe": transe_score,
    "rotate": rotate_score,
    "complex": complex_score,
}


def get_score_fn(method: Method):
    """Score function operating directly on embedding rows (h, r, t, gamma).

    Used by callers that manage their own gathers — e.g. the fused trainer in
    :mod:`repro.core.state`, which gathers each batch's rows ONCE and
    differentiates with respect to the gathered rows instead of the full
    table (one dense scatter-add per step instead of one per gather).
    """
    return _SCORE_FNS[method]


def score_triples(
    params: dict,
    heads: jnp.ndarray,
    relations: jnp.ndarray,
    tails: jnp.ndarray,
    method: Method,
    gamma: float = DEFAULT_GAMMA,
) -> jnp.ndarray:
    """Score index triples.  heads/relations/tails broadcast together.

    ``heads``/``tails`` may have an extra negatives axis, e.g.
    heads (B,), relations (B,), tails (B, N) -> scores (B, N).
    """
    h = params["entity"][heads]
    r = params["relation"][relations]
    t = params["entity"][tails]
    if t.ndim == h.ndim + 1:  # negatives on the tail side
        h = h[..., None, :]
        r = r[..., None, :]
    elif h.ndim == t.ndim + 1:  # negatives on the head side
        t = t[..., None, :]
        r = r[..., None, :]
    return _SCORE_FNS[method](h, r, t, gamma)


def kge_loss(
    params: dict,
    pos: jnp.ndarray,  # (B, 3) int32 (h, r, t)
    neg_tails: jnp.ndarray,  # (B, N) int32 corrupted tails
    neg_heads: jnp.ndarray,  # (B, N) int32 corrupted heads
    method: Method,
    gamma: float = DEFAULT_GAMMA,
    adversarial_temperature: float = 1.0,
) -> jnp.ndarray:
    """Self-adversarial negative sampling loss (RotatE Eq. 5, used by FedE).

    L = -log sigma(pos_score) - sum_i w_i log sigma(-neg_score_i)
    with w_i = softmax(neg_score_i * temperature), stop-gradiented.
    ComplEx uses the same loss on its trilinear scores (FedE convention).
    Self-adversarial weighting is applied for transe/rotate (paper: temp 1),
    uniform weighting for complex.
    """
    h, r, t = pos[:, 0], pos[:, 1], pos[:, 2]
    pos_score = score_triples(params, h, r, t, method, gamma)  # (B,)
    neg_t_score = score_triples(params, h, r, neg_tails, method, gamma)  # (B, N)
    neg_h_score = score_triples(params, neg_heads, r, t, method, gamma)  # (B, N)
    neg_score = jnp.concatenate([neg_t_score, neg_h_score], axis=-1)  # (B, 2N)
    return loss_from_scores(pos_score, neg_score, method, adversarial_temperature)


def per_sample_losses(
    pos_score: jnp.ndarray,  # (..., B)
    neg_score: jnp.ndarray,  # (..., B, 2N)
    method: Method,
    adversarial_temperature: float = 1.0,
) -> jnp.ndarray:
    """Per-sample ``pos_loss + neg_loss`` (NOT yet halved/averaged)."""
    if method in ("transe", "rotate") and adversarial_temperature > 0:
        w = jax.nn.softmax(
            jax.lax.stop_gradient(neg_score) * adversarial_temperature, axis=-1
        )
    else:
        w = jnp.full_like(neg_score, 1.0 / neg_score.shape[-1])

    pos_loss = -jax.nn.log_sigmoid(pos_score)
    neg_loss = -(w * jax.nn.log_sigmoid(-neg_score)).sum(axis=-1)
    return pos_loss + neg_loss


def loss_from_scores(
    pos_score: jnp.ndarray,  # (B,)
    neg_score: jnp.ndarray,  # (B, 2N)
    method: Method,
    adversarial_temperature: float = 1.0,
    sample_weight: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """The self-adversarial loss given already-computed scores (see
    :func:`kge_loss` for the semantics; split out so gather-once trainers can
    reuse the exact weighting/averaging logic)."""
    per_sample = per_sample_losses(
        pos_score, neg_score, method, adversarial_temperature
    )
    if sample_weight is None:
        return per_sample.mean() / 2.0
    sw = sample_weight.astype(per_sample.dtype)
    return (per_sample * sw).sum() / jnp.maximum(sw.sum(), 1.0) / 2.0
