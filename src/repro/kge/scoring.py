"""KGE scoring registry: TransE, RotatE, pRotatE, DistMult, ComplEx, HolE.

Conventions (matching FedE / the RotatE reference implementation):

* entity embeddings ``E  : (num_entities, dim)``
* relation embeddings ``R : (num_relations, rel_dim)`` where ``rel_dim`` is a
  per-method rule (:attr:`ScoringSpec.rel_dim`): RotatE stores one phase per
  complex coordinate (``dim/2``); everything else uses ``dim``.
* Complex-valued methods (RotatE, ComplEx) store points of C^{dim/2} as
  (re, im) halves of the real ``dim`` vector.
* Scores are "higher is better".  The **distance** family produces
  ``gamma - distance`` and trains with self-adversarial negative weighting
  (RotatE Eq. 5); the **bilinear** family produces a trilinear contraction
  and trains with uniform negative weighting (FedE convention for ComplEx).

The registry (modeled on :mod:`repro.core.codecs.registry`) is the single
source of truth for which methods exist: construction (:func:`get_scoring`),
the ``--method`` CLI surface (:func:`parse_method`), the engines' loss/score
pieces, the eval-kernel family dispatch in :mod:`repro.kernels.ops`, and
every error message (:func:`scoring_usage`) all derive from it, so adding a
method is one :func:`register` call away from running through all four
engines, the batched evaluator, and the benchmark sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

# Method names are plain strings validated by the registry (get_scoring);
# the alias survives for annotations from the pre-registry Literal era.
Method = str

# Initialisation hyper-parameters from the paper (Section IV-B):
# gamma = 8, epsilon = 2; embedding range = (gamma + eps) / dim.
DEFAULT_GAMMA = 8.0
DEFAULT_EPSILON = 2.0

FAMILIES = ("distance", "bilinear")


def _identity_cand_prep(cand: jnp.ndarray, gamma: float) -> jnp.ndarray:
    del gamma
    return cand


def _no_kernel_statics(gamma: float, dim: int) -> dict:
    del gamma, dim
    return {}


@dataclasses.dataclass(frozen=True)
class ScoringSpec:
    """Everything the engines need to know about one scoring method.

    The jit-safe pieces (``score``, ``cand_queries``, ``cand_prep``) close
    over nothing and take only arrays + the static ``gamma``, so they can be
    traced inside any engine program.  ``family`` drives both the loss
    (distance -> self-adversarial weighting, bilinear -> uniform) and the
    eval-kernel dispatch in :func:`repro.kernels.ops.kge_cand_scores`
    (distance -> ``dist_cand_score_pallas``, bilinear -> the matmul-style
    ``bilinear_cand_score_pallas``).
    """

    name: str
    family: str  # "distance" | "bilinear"
    doc: str  # one-line score formula, shown by scoring_usage()
    # (h, r, t, gamma) -> scores; broadcasts over leading/negative axes.
    score: Callable[..., jnp.ndarray]
    rel_dim: Callable[[int], int]  # entity dim -> relation dim
    rel_dim_doc: str  # human-readable rel_dim rule ("dim", "dim/2")
    rel_init: str  # "uniform" (+-embedding_range) | "phase" (+-pi)
    # (h, r, t, gamma) -> (q_tail, q_head): per-leg query rows that reduce
    # BOTH filtered-ranking legs to a (B, D)-vs-candidate-block kernel call
    # (distance: dist(q, cand); bilinear: q @ cand^T).
    cand_queries: Callable[..., tuple]
    # self-adversarial negative weighting in the loss (RotatE Eq. 5)?
    adversarial: bool
    # candidate-block transform applied once per kernel call (pRotatE
    # rescales entity rows to phase units); identity for everything else.
    cand_prep: Callable[..., jnp.ndarray] = _identity_cand_prep
    # False: the head leg score(c, r, t) is NOT linear/foldable in the
    # candidate (ProjE's tanh(c + r)), so cand_queries returns q_head=None
    # and kge_cand_scores evaluates that leg by broadcasting ``score``
    # exactly on every path; the tail leg still rides the eval kernel.
    fold_head: bool = True
    # distance family only: which distance _dist_cand_kernel computes.
    kernel_mode: str | None = None
    # extra static kwargs for the distance kernel, from (gamma, true dim).
    kernel_statics: Callable[[float, int], dict] = _no_kernel_statics
    aliases: tuple = ()

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(
                f"scoring family {self.family!r} not in {FAMILIES}"
            )
        if self.family == "distance" and self.kernel_mode is None:
            raise ValueError(
                f"distance-family method {self.name!r} needs a kernel_mode"
            )


# --------------------------------------------------------------- registry
_REGISTRY: Dict[str, ScoringSpec] = {}
_ALIASES: Dict[str, str] = {}


def register(spec: ScoringSpec) -> ScoringSpec:
    """Register a spec under ``spec.name`` (+ aliases); returns it."""
    if spec.name in _REGISTRY or spec.name in _ALIASES:
        raise ValueError(f"scoring method {spec.name!r} already registered")
    for a in spec.aliases:
        if a in _REGISTRY or a in _ALIASES:
            raise ValueError(f"scoring alias {a!r} already registered")
    _REGISTRY[spec.name] = spec
    for a in spec.aliases:
        _ALIASES[a] = spec.name
    return spec


def registered_methods() -> Dict[str, ScoringSpec]:
    """Registered specs by canonical name (sorted, aliases excluded)."""
    return dict(sorted(_REGISTRY.items()))


def scoring_usage() -> str:
    """One line per registered method: name, family, rel_dim rule, formula."""
    lines = []
    for name, spec in registered_methods().items():
        lines.append(
            f"  {name}  [{spec.family}] rel_dim={spec.rel_dim_doc}"
            f"  — {spec.doc}"
        )
    return "\n".join(lines)


def get_scoring(method: str) -> ScoringSpec:
    """Look up a registered scoring method by (canonical or alias) name.

    Unknown names raise a ``ValueError`` listing every registered method —
    the registry is the single source of truth the CLI (``--method``), the
    engines, and the eval-kernel dispatch all lean on.
    """
    canonical = _ALIASES.get(method, method)
    spec = _REGISTRY.get(canonical)
    if spec is None:
        raise ValueError(
            f"unknown scoring method {method!r}; registered methods:\n"
            f"{scoring_usage()}"
        )
    return spec


def parse_method(method: str) -> str:
    """Validate a ``--method`` name; returns the canonical name."""
    return get_scoring(method).name


# ------------------------------------------------------------- score pieces
def _split_complex(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Split the last dim into (re, im) halves."""
    half = x.shape[-1] // 2
    return x[..., :half], x[..., half:]


def transe_score(
    h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray, gamma: float
) -> jnp.ndarray:
    """gamma - ||h + r - t||_2 ; broadcasts over leading dims."""
    return gamma - jnp.linalg.norm(h + r - t, axis=-1)


def rotate_score(
    h: jnp.ndarray, phase: jnp.ndarray, t: jnp.ndarray, gamma: float
) -> jnp.ndarray:
    """gamma - || h o r - t ||  with r = exp(i * phase), h,t in C^{d/2}."""
    h_re, h_im = _split_complex(h)
    t_re, t_im = _split_complex(t)
    r_re, r_im = jnp.cos(phase), jnp.sin(phase)
    d_re = h_re * r_re - h_im * r_im - t_re
    d_im = h_re * r_im + h_im * r_re - t_im
    # RotatE uses the sum of complex moduli (L2 over the (re,im) pair, L1 over
    # coordinates).
    dist = jnp.sqrt(d_re**2 + d_im**2 + 1e-12).sum(axis=-1)
    return gamma - dist


def _phase_scale(gamma: float, dim: int) -> float:
    """Entity-embedding -> phase-unit scale: embedding_range / pi.

    pRotatE interprets entity coordinates as angles; the RotatE reference
    divides by ``embedding_range / pi`` so a full init range spans one turn.
    ``embedding_range`` is reconstructed from gamma with the paper's fixed
    epsilon, keeping the score a pure function of (arrays, gamma).
    """
    return (gamma + DEFAULT_EPSILON) / dim / float(jnp.pi)


def _protate_modulus(gamma: float, dim: int) -> float:
    """pRotatE distance weight: 0.5 * embedding_range (the RotatE reference
    learns this scalar from that init; we keep it fixed and stateless)."""
    return 0.5 * (gamma + DEFAULT_EPSILON) / dim


def protate_score(
    h: jnp.ndarray, phase: jnp.ndarray, t: jnp.ndarray, gamma: float
) -> jnp.ndarray:
    """gamma - modulus * sum_j |sin(h_j/s + phase_j - t_j/s)| (pRotatE)."""
    dim = h.shape[-1]
    s = _phase_scale(gamma, dim)
    d = jnp.sin(h / s + phase - t / s)
    return gamma - jnp.abs(d).sum(axis=-1) * _protate_modulus(gamma, dim)


def distmult_score(
    h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray, gamma: float = 0.0
) -> jnp.ndarray:
    """<h, r, t> = sum_j h_j r_j t_j (DistMult trilinear product)."""
    del gamma
    return (h * r * t).sum(axis=-1)


def _ccorr(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Circular correlation ccorr(a, b)_k = sum_i a_i b_{(i+k) mod n}."""
    n = a.shape[-1]
    return jnp.fft.irfft(jnp.conj(jnp.fft.rfft(a)) * jnp.fft.rfft(b), n=n)


def _cconv(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Circular convolution cconv(a, b)_k = sum_i a_i b_{(k-i) mod n}."""
    n = a.shape[-1]
    return jnp.fft.irfft(jnp.fft.rfft(a) * jnp.fft.rfft(b), n=n)


def hole_score(
    h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray, gamma: float = 0.0
) -> jnp.ndarray:
    """<r, ccorr(h, t)> (HolE holographic embedding score)."""
    del gamma
    return (r * _ccorr(h, t)).sum(axis=-1)


def proje_score(
    h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray, gamma: float = 0.0
) -> jnp.ndarray:
    """<tanh(h + r), t> (ProjE pointwise combination, bias-free)."""
    del gamma
    return (jnp.tanh(h + r) * t).sum(axis=-1)


def complex_score(
    h: jnp.ndarray, r: jnp.ndarray, t: jnp.ndarray, gamma: float = 0.0
) -> jnp.ndarray:
    """Re(<h, r, conj(t)>)."""
    del gamma
    h_re, h_im = _split_complex(h)
    r_re, r_im = _split_complex(r)
    t_re, t_im = _split_complex(t)
    return (
        (h_re * r_re * t_re)
        + (h_im * r_re * t_im)
        + (h_re * r_im * t_im)
        - (h_im * r_im * t_re)
    ).sum(axis=-1)


# ------------------------------------------------- per-leg candidate queries
# Each returns (q_tail, q_head) such that scoring candidate c as tail equals
# kernel(q_tail, c) and as head equals kernel(q_head, c) — the algebra that
# lets filtered-ranking eval share ONE candidate block across the batch
# (kernels/kge_score.py + kernels/bilinear_score.py docstrings).
def _transe_queries(h, r, t, gamma):
    del gamma
    return h + r, t - r  # ||(h+r) - c|| ; ||c + r - t|| == ||c - (t - r)||


def _rotate_queries(h, phase, t, gamma):
    del gamma
    cos, sin = jnp.cos(phase), jnp.sin(phase)
    h_re, h_im = _split_complex(h)
    t_re, t_im = _split_complex(t)
    # tail: |h∘r - c|; head: |c∘r - t| == |c - t∘conj(r)|
    q_t = jnp.concatenate([h_re * cos - h_im * sin,
                           h_re * sin + h_im * cos], axis=-1)
    q_h = jnp.concatenate([t_re * cos + t_im * sin,
                           t_im * cos - t_re * sin], axis=-1)
    return q_t, q_h


def _protate_queries(h, phase, t, gamma):
    s = _phase_scale(gamma, h.shape[-1])
    # |sin(ph_h + r - ph_c)| == |sin(q_t - ph_c)|; |sin(ph_c + r - ph_t)| ==
    # |sin(q_h - ph_c)| by sign symmetry of |sin|.  cand_prep rescales the
    # candidate block to the same phase units once per kernel call.
    return h / s + phase, t / s - phase


def _protate_cand_prep(cand, gamma):
    return cand / _phase_scale(gamma, cand.shape[-1])


def _distmult_queries(h, r, t, gamma):
    del gamma
    return h * r, t * r  # <h,r,c> = (h*r)·c ; <c,r,t> = (t*r)·c


def _hole_queries(h, r, t, gamma):
    del gamma
    # <r, ccorr(h,c)> == <cconv(h,r), c> and <r, ccorr(c,t)> == <ccorr(r,t), c>
    # (swap the summation order) — both legs reduce to q · cand, so HolE
    # rides the bilinear eval kernel with no candidate transform.
    return _cconv(h, r), _ccorr(r, t)


def _proje_queries(h, r, t, gamma):
    del gamma, t
    # tail: <tanh(h+r), c> folds to q_t · c — but the head leg
    # <tanh(c+r), t> is nonlinear IN THE CANDIDATE, so no head query row
    # exists (fold_head=False routes that leg through the exact broadcast).
    return jnp.tanh(h + r), None


def _complex_queries(h, r, t, gamma):
    del gamma
    h_re, h_im = _split_complex(h)
    r_re, r_im = _split_complex(r)
    t_re, t_im = _split_complex(t)
    # Re(<h,r,conj(c)>) as a function of c: coefficients of (c_re, c_im);
    # Re(<c,r,conj(t)>) likewise — both legs become q · [c_re, c_im].
    q_t = jnp.concatenate([h_re * r_re - h_im * r_im,
                           h_im * r_re + h_re * r_im], axis=-1)
    q_h = jnp.concatenate([r_re * t_re + r_im * t_im,
                           r_re * t_im - r_im * t_re], axis=-1)
    return q_t, q_h


register(ScoringSpec(
    name="transe", family="distance",
    doc="gamma - ||h + r - t||_2",
    score=transe_score, rel_dim=lambda dim: dim, rel_dim_doc="dim",
    rel_init="uniform", cand_queries=_transe_queries, adversarial=True,
    kernel_mode="transe",
))
register(ScoringSpec(
    name="rotate", family="distance",
    doc="gamma - |h ∘ e^{i phase} - t| (entities in C^{dim/2})",
    score=rotate_score, rel_dim=lambda dim: dim // 2, rel_dim_doc="dim/2",
    rel_init="phase", cand_queries=_rotate_queries, adversarial=True,
    kernel_mode="rotate",
))
register(ScoringSpec(
    name="protate", family="distance",
    doc="gamma - m * sum|sin(h/s + phase - t/s)| (phase-only RotatE)",
    score=protate_score, rel_dim=lambda dim: dim, rel_dim_doc="dim",
    rel_init="phase", cand_queries=_protate_queries, adversarial=True,
    cand_prep=_protate_cand_prep, kernel_mode="protate",
    kernel_statics=lambda gamma, dim: {"modulus": _protate_modulus(gamma, dim)},
    aliases=("prot",),
))
register(ScoringSpec(
    name="distmult", family="bilinear",
    doc="sum_j h_j r_j t_j (symmetric trilinear product)",
    score=distmult_score, rel_dim=lambda dim: dim, rel_dim_doc="dim",
    rel_init="uniform", cand_queries=_distmult_queries, adversarial=False,
))
register(ScoringSpec(
    name="complex", family="bilinear",
    doc="Re(<h, r, conj(t)>) (entities and relations in C^{dim/2})",
    score=complex_score, rel_dim=lambda dim: dim, rel_dim_doc="dim",
    rel_init="uniform", cand_queries=_complex_queries, adversarial=False,
))
register(ScoringSpec(
    name="proje", family="bilinear",
    doc="<tanh(h + r), t> (ProjE pointwise combination; head leg unfolds)",
    score=proje_score, rel_dim=lambda dim: dim, rel_dim_doc="dim",
    rel_init="uniform", cand_queries=_proje_queries, adversarial=False,
    fold_head=False,
))
register(ScoringSpec(
    name="hole", family="bilinear",
    doc="<r, ccorr(h, t)> (holographic circular correlation)",
    score=hole_score, rel_dim=lambda dim: dim, rel_dim_doc="dim",
    rel_init="uniform", cand_queries=_hole_queries, adversarial=False,
))


# ------------------------------------------------------------ model + init
@dataclasses.dataclass(frozen=True)
class KGEModel:
    """Static description of a KGE scoring model."""

    method: Method
    num_entities: int
    num_relations: int
    dim: int  # entity embedding dimension (real parameter count per entity)
    gamma: float = DEFAULT_GAMMA
    epsilon: float = DEFAULT_EPSILON

    def __post_init__(self):
        get_scoring(self.method)  # unknown method -> registry error, eagerly

    @property
    def spec(self) -> ScoringSpec:
        return get_scoring(self.method)

    @property
    def rel_dim(self) -> int:
        return self.spec.rel_dim(self.dim)

    @property
    def embedding_range(self) -> float:
        return (self.gamma + self.epsilon) / self.dim


def init_kge_params(key: jax.Array, model: KGEModel) -> dict:
    """Uniform init in [-embedding_range, embedding_range] as in RotatE/FedE;
    phase-valued relations (RotatE, pRotatE) draw uniformly in [-pi, pi]."""
    k_e, k_r = jax.random.split(key)
    rng = model.embedding_range
    ent = jax.random.uniform(
        k_e, (model.num_entities, model.dim), minval=-rng, maxval=rng
    )
    if model.spec.rel_init == "phase":
        rel = jax.random.uniform(
            k_r, (model.num_relations, model.rel_dim), minval=-jnp.pi, maxval=jnp.pi
        )
    else:
        rel = jax.random.uniform(
            k_r, (model.num_relations, model.rel_dim), minval=-rng, maxval=rng
        )
    return {"entity": ent, "relation": rel}


# ---------------------------------------------------------- scoring + loss
def get_score_fn(method: Method):
    """Score function operating directly on embedding rows (h, r, t, gamma).

    Used by callers that manage their own gathers — e.g. the fused trainer in
    :mod:`repro.core.state`, which gathers each batch's rows ONCE and
    differentiates with respect to the gathered rows instead of the full
    table (one dense scatter-add per step instead of one per gather).
    """
    return get_scoring(method).score


def score_triples(
    params: dict,
    heads: jnp.ndarray,
    relations: jnp.ndarray,
    tails: jnp.ndarray,
    method: Method,
    gamma: float = DEFAULT_GAMMA,
) -> jnp.ndarray:
    """Score index triples.  heads/relations/tails broadcast together.

    ``heads``/``tails`` may have an extra negatives axis, e.g.
    heads (B,), relations (B,), tails (B, N) -> scores (B, N).
    """
    h = params["entity"][heads]
    r = params["relation"][relations]
    t = params["entity"][tails]
    if t.ndim == h.ndim + 1:  # negatives on the tail side
        h = h[..., None, :]
        r = r[..., None, :]
    elif h.ndim == t.ndim + 1:  # negatives on the head side
        t = t[..., None, :]
        r = r[..., None, :]
    return get_scoring(method).score(h, r, t, gamma)


def kge_loss(
    params: dict,
    pos: jnp.ndarray,  # (B, 3) int32 (h, r, t)
    neg_tails: jnp.ndarray,  # (B, N) int32 corrupted tails
    neg_heads: jnp.ndarray,  # (B, N) int32 corrupted heads
    method: Method,
    gamma: float = DEFAULT_GAMMA,
    adversarial_temperature: float = 1.0,
) -> jnp.ndarray:
    """Self-adversarial negative sampling loss (RotatE Eq. 5, used by FedE).

    L = -log sigma(pos_score) - sum_i w_i log sigma(-neg_score_i)
    with w_i = softmax(neg_score_i * temperature), stop-gradiented.
    The bilinear family uses the same loss on its trilinear scores (FedE
    convention) with uniform weighting; self-adversarial weighting applies
    to the distance family (paper: temp 1) — :attr:`ScoringSpec.adversarial`.
    """
    h, r, t = pos[:, 0], pos[:, 1], pos[:, 2]
    pos_score = score_triples(params, h, r, t, method, gamma)  # (B,)
    neg_t_score = score_triples(params, h, r, neg_tails, method, gamma)  # (B, N)
    neg_h_score = score_triples(params, neg_heads, r, t, method, gamma)  # (B, N)
    neg_score = jnp.concatenate([neg_t_score, neg_h_score], axis=-1)  # (B, 2N)
    return loss_from_scores(pos_score, neg_score, method, adversarial_temperature)


def per_sample_losses(
    pos_score: jnp.ndarray,  # (..., B)
    neg_score: jnp.ndarray,  # (..., B, 2N)
    method: Method,
    adversarial_temperature: float = 1.0,
) -> jnp.ndarray:
    """Per-sample ``pos_loss + neg_loss`` (NOT yet halved/averaged)."""
    if get_scoring(method).adversarial and adversarial_temperature > 0:
        w = jax.nn.softmax(
            jax.lax.stop_gradient(neg_score) * adversarial_temperature, axis=-1
        )
    else:
        w = jnp.full_like(neg_score, 1.0 / neg_score.shape[-1])

    pos_loss = -jax.nn.log_sigmoid(pos_score)
    neg_loss = -(w * jax.nn.log_sigmoid(-neg_score)).sum(axis=-1)
    return pos_loss + neg_loss


def loss_from_scores(
    pos_score: jnp.ndarray,  # (B,)
    neg_score: jnp.ndarray,  # (B, 2N)
    method: Method,
    adversarial_temperature: float = 1.0,
    sample_weight: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """The self-adversarial loss given already-computed scores (see
    :func:`kge_loss` for the semantics; split out so gather-once trainers can
    reuse the exact weighting/averaging logic)."""
    per_sample = per_sample_losses(
        pos_score, neg_score, method, adversarial_temperature
    )
    if sample_weight is None:
        return per_sample.mean() / 2.0
    sw = sample_weight.astype(per_sample.dtype)
    return (per_sample * sw).sum() / jnp.maximum(sw.sum(), 1.0) / 2.0
