"""Knowledge-graph-embedding substrate: scoring models + losses.

The three KGE methods the paper evaluates (TransE, RotatE, ComplEx), with the
self-adversarial negative-sampling loss used by FedE/RotatE.
"""
from repro.kge.scoring import (
    KGEModel,
    complex_score,
    init_kge_params,
    kge_loss,
    rotate_score,
    score_triples,
    transe_score,
)

__all__ = [
    "KGEModel",
    "init_kge_params",
    "transe_score",
    "rotate_score",
    "complex_score",
    "score_triples",
    "kge_loss",
]
