"""Knowledge-graph-embedding substrate: scoring registry + losses.

The registered KGE methods (TransE, RotatE, pRotatE, DistMult, ComplEx) as
:class:`repro.kge.scoring.ScoringSpec` entries — per-method score pieces,
rel_dim/init rules, and the distance/bilinear family tag the eval kernels
dispatch on — with the self-adversarial negative-sampling loss used by
FedE/RotatE.
"""
from repro.kge.scoring import (
    KGEModel,
    ScoringSpec,
    complex_score,
    distmult_score,
    get_score_fn,
    get_scoring,
    init_kge_params,
    kge_loss,
    parse_method,
    protate_score,
    registered_methods,
    rotate_score,
    score_triples,
    scoring_usage,
    transe_score,
)

__all__ = [
    "KGEModel",
    "ScoringSpec",
    "init_kge_params",
    "transe_score",
    "rotate_score",
    "protate_score",
    "distmult_score",
    "complex_score",
    "score_triples",
    "kge_loss",
    "get_score_fn",
    "get_scoring",
    "parse_method",
    "registered_methods",
    "scoring_usage",
]
