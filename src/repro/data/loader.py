"""Batch loading + negative sampling for KGE training.

Host-side numpy pipeline (cheap relative to the jitted train step); batches
are handed to JAX as int32 arrays of static shape, so the train step compiles
once per (batch_size, num_negatives).
"""
from __future__ import annotations

import numpy as np


def sample_negatives(
    rng: np.random.Generator,
    batch: np.ndarray,  # (B, 3)
    num_entities: int,
    num_negatives: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Uniform corruption of tails and heads.  Returns (neg_tails, neg_heads).

    Follows FedE: negatives are drawn uniformly from the client's local
    entity set; filtering of false negatives is handled statistically (the
    self-adversarial loss down-weights easy/true negatives).
    """
    b = batch.shape[0]
    neg_t = rng.integers(0, num_entities, size=(b, num_negatives), dtype=np.int32)
    neg_h = rng.integers(0, num_entities, size=(b, num_negatives), dtype=np.int32)
    return neg_t, neg_h


def stack_padded_triples(
    triple_arrays: "list[np.ndarray]",
) -> tuple[np.ndarray, np.ndarray]:
    """Stack ragged per-client ``(T_c, 3)`` triples into ``(C, T_max, 3)``.

    Returns ``(padded, counts)``.  Padding rows are zeros — a structurally
    valid ``(h=0, r=0, t=0)`` triple — but device-side samplers draw indices
    in ``[0, counts[c])`` so padding is never selected; keeping it in-range
    means a mis-sampled index can never read out of bounds.  Used by
    :class:`repro.core.state.CycleEngine` to pre-sample whole-cycle batches
    on device.
    """
    c = len(triple_arrays)
    t_max = max(1, max(int(t.shape[0]) for t in triple_arrays))
    padded = np.zeros((c, t_max, 3), np.int32)
    counts = np.zeros((c,), np.int32)
    for i, t in enumerate(triple_arrays):
        padded[i, : t.shape[0]] = t
        counts[i] = t.shape[0]
    return padded, counts


class TripleLoader:
    """Infinite shuffled batch iterator over a triple array (static shapes).

    The final partial batch of every epoch is wrapped around (standard KGE
    practice) so every yielded batch has exactly ``batch_size`` rows.
    """

    def __init__(
        self,
        triples: np.ndarray,
        batch_size: int,
        num_entities: int,
        num_negatives: int = 64,
        seed: int = 0,
    ):
        assert triples.shape[0] > 0
        self.triples = triples
        self.batch_size = int(min(batch_size, triples.shape[0]))
        self.num_entities = num_entities
        self.num_negatives = num_negatives
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(triples.shape[0])
        self._pos = 0

    @property
    def batches_per_epoch(self) -> int:
        return max(1, self.triples.shape[0] // self.batch_size)

    def next_batch(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (pos (B,3), neg_tails (B,N), neg_heads (B,N))."""
        n = self.triples.shape[0]
        if self._pos + self.batch_size > n:
            self._order = self.rng.permutation(n)
            self._pos = 0
        idx = self._order[self._pos : self._pos + self.batch_size]
        self._pos += self.batch_size
        pos = self.triples[idx]
        neg_t, neg_h = sample_negatives(
            self.rng, pos, self.num_entities, self.num_negatives
        )
        return pos, neg_t, neg_h

    def epoch(self):
        """Yield one epoch's worth of batches."""
        for _ in range(self.batches_per_epoch):
            yield self.next_batch()
