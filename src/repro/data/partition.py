"""Relation-wise partitioning of a KG into federated clients.

The paper builds FB15k-237-R{10,5,3} by "partitioning relations evenly and
then distributing corresponding triples" into 10/5/3 clients.  We reproduce
that construction: relations are dealt round-robin (after a seeded shuffle)
across clients; each client receives all triples of its relations; each
client then applies its own 0.8/0.1/0.1 split.

Each client sees only the entities that occur in its triples, relabelled to a
dense local id space.  The mapping local->global is kept so the server can
align shared entities across clients.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import KnowledgeGraph


@dataclasses.dataclass
class ClientData:
    """One federated client's local KG view."""

    client_id: int
    train: np.ndarray  # (T, 3) int32, LOCAL entity ids / GLOBAL relation ids
    valid: np.ndarray
    test: np.ndarray
    local_to_global: np.ndarray  # (num_local_entities,) int32
    num_relations: int  # global relation count (relation table is local-only)

    @property
    def num_entities(self) -> int:
        return int(self.local_to_global.shape[0])

    @property
    def num_train(self) -> int:
        return int(self.train.shape[0])


def partition_by_relation(
    kg: KnowledgeGraph,
    num_clients: int,
    ratios: tuple[float, float, float] = (0.8, 0.1, 0.1),
    seed: int = 0,
) -> list[ClientData]:
    rng = np.random.default_rng(seed)
    rel_perm = rng.permutation(kg.num_relations)
    owner = np.empty(kg.num_relations, dtype=np.int64)
    for i, r in enumerate(rel_perm):
        owner[r] = i % num_clients

    clients: list[ClientData] = []
    for c in range(num_clients):
        mask = owner[kg.triples[:, 1]] == c
        triples = kg.triples[mask]
        if triples.shape[0] == 0:
            raise ValueError(f"client {c} received no triples; enlarge the KG")
        # Dense local entity ids.
        ents = np.unique(np.concatenate([triples[:, 0], triples[:, 2]]))
        remap = np.full(kg.num_entities, -1, dtype=np.int32)
        remap[ents] = np.arange(len(ents), dtype=np.int32)
        local = triples.copy()
        local[:, 0] = remap[triples[:, 0]]
        local[:, 2] = remap[triples[:, 2]]
        # Per-client split.
        idx = rng.permutation(local.shape[0])
        n_tr = max(1, int(local.shape[0] * ratios[0]))
        n_va = max(1, int(local.shape[0] * ratios[1]))
        clients.append(
            ClientData(
                client_id=c,
                train=local[idx[:n_tr]].astype(np.int32),
                valid=local[idx[n_tr : n_tr + n_va]].astype(np.int32),
                test=local[idx[n_tr + n_va :]].astype(np.int32),
                local_to_global=ents.astype(np.int32),
                num_relations=kg.num_relations,
            )
        )
    return clients


def shared_entity_mask(
    clients: list[ClientData], num_global_entities: int
) -> np.ndarray:
    """Boolean (num_global_entities,): entity appears in >= 2 clients."""
    count = np.zeros(num_global_entities, dtype=np.int64)
    for c in clients:
        count[c.local_to_global] += 1
    return count >= 2
