"""Synthetic knowledge-graph generation.

FB15k-237 is not available offline, so we generate a structurally similar
synthetic KG (see DESIGN.md §7):

* skewed (Zipf) relation frequencies — a few relations cover most triples,
  like Freebase;
* community structure — entities are grouped into soft clusters and each
  relation connects a (source-cluster, target-cluster) pair, so relations
  carry real signal a KGE model can learn;
* a deterministic seed so every experiment/benchmark sees the same graph.

The generator is pure numpy (dataset creation is host-side, not part of the
jitted compute graph).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class KnowledgeGraph:
    """An in-memory KG: integer triples (head, relation, tail)."""

    triples: np.ndarray  # (T, 3) int32
    num_entities: int
    num_relations: int

    def __post_init__(self):
        assert self.triples.ndim == 2 and self.triples.shape[1] == 3

    @property
    def num_triples(self) -> int:
        return int(self.triples.shape[0])


def generate_kg(
    num_entities: int = 2000,
    num_relations: int = 60,
    num_triples: int = 24000,
    num_clusters: int = 12,
    zipf_a: float = 1.3,
    seed: int = 0,
) -> KnowledgeGraph:
    """Generate a clustered, Zipf-skewed synthetic KG.

    Every relation r is assigned a (source, target) cluster pair and a noise
    level; triples for r draw head from the source cluster and tail from the
    target cluster (with a little cross-cluster noise).  This gives relations
    learnable geometric structure (TransE-style translations between cluster
    centroids exist by construction).
    """
    rng = np.random.default_rng(seed)

    # Soft entity clusters (roughly equal sizes).
    cluster_of = rng.integers(0, num_clusters, size=num_entities)
    members = [np.where(cluster_of == c)[0] for c in range(num_clusters)]
    # Guarantee non-empty clusters.
    for c in range(num_clusters):
        if len(members[c]) == 0:
            members[c] = rng.integers(0, num_entities, size=4)

    # Relation profile: cluster pair + noise.
    rel_src = rng.integers(0, num_clusters, size=num_relations)
    rel_dst = rng.integers(0, num_clusters, size=num_relations)
    rel_noise = rng.uniform(0.05, 0.25, size=num_relations)

    # Zipf-skewed relation frequencies.
    ranks = np.arange(1, num_relations + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    rel_ids = rng.choice(num_relations, size=num_triples * 2, p=probs)

    triples = set()
    out = []
    for r in rel_ids:
        if len(out) >= num_triples:
            break
        if rng.random() < rel_noise[r]:
            h = rng.integers(0, num_entities)
            t = rng.integers(0, num_entities)
        else:
            h = rng.choice(members[rel_src[r]])
            t = rng.choice(members[rel_dst[r]])
        if h == t:
            continue
        key = (int(h), int(r), int(t))
        if key in triples:
            continue
        triples.add(key)
        out.append(key)

    arr = np.asarray(out, dtype=np.int32)
    return KnowledgeGraph(
        triples=arr, num_entities=num_entities, num_relations=num_relations
    )


def split_triples(
    kg: KnowledgeGraph,
    ratios: tuple[float, float, float] = (0.8, 0.1, 0.1),
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shuffle + split into train/valid/test with the paper's 0.8/0.1/0.1."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(kg.num_triples)
    n_train = int(kg.num_triples * ratios[0])
    n_valid = int(kg.num_triples * ratios[1])
    train = kg.triples[idx[:n_train]]
    valid = kg.triples[idx[n_train : n_train + n_valid]]
    test = kg.triples[idx[n_train + n_valid :]]
    return train, valid, test
