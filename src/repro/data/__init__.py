"""Data substrate: synthetic KG generation, relation partitioning, loaders."""
from repro.data.synthetic import KnowledgeGraph, generate_kg
from repro.data.partition import ClientData, partition_by_relation
from repro.data.loader import TripleLoader, sample_negatives

__all__ = [
    "KnowledgeGraph",
    "generate_kg",
    "ClientData",
    "partition_by_relation",
    "TripleLoader",
    "sample_negatives",
]
