"""The unified LM stack: init / forward / decode for all six arch families.

Layer params are *stacked* (leading L axis) and consumed by ``lax.scan`` so
HLO size is depth-independent — an 80-layer qwen2-72b lowers as fast as a
2-layer smoke model.  Heterogeneous stacks stay inside one scan body:

* gemma3's 5:1 local:global pattern is a per-layer traced window size,
* zamba2's shared attention block is a ``lax.cond`` on the layer index with
  non-scanned (closure) params and a per-application KV cache,
* xlstm's mLSTM/sLSTM mix is a per-layer flag selecting a cond branch over a
  union param layout.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (
    AttnParams,
    attention,
    cross_attention,
    decode_attention,
    init_attention,
    project_kv,
)
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, embed_init, rms_norm
from repro.models.moe import MoEParams, apply_moe, init_moe
from repro.models.ssm import (
    MambaParams,
    MambaState,
    apply_mamba,
    decode_mamba,
    init_mamba,
    init_mamba_state,
)
from repro.models.xlstm import (
    MLSTMParams,
    MLSTMState,
    SLSTMParams,
    SLSTMState,
    apply_mlstm,
    apply_slstm,
    decode_mlstm,
    decode_slstm,
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    init_slstm_state,
)


# --------------------------------------------------------------------- util
def _stack_init(init_fn, key: jax.Array, n: int):
    """vmap an init over n layer keys -> stacked params."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def slstm_layer_ids(cfg: ModelConfig) -> list[int]:
    """xlstm: which layer indices are sLSTM blocks (every Nth)."""
    if not cfg.slstm_every:
        return []
    return [i for i in range(cfg.num_layers) if (i + 1) % cfg.slstm_every == 0]


def hybrid_segments(cfg: ModelConfig) -> list[tuple[int, int, bool]]:
    """zamba2: [(start, length, attn_after)] segments of mamba layers.

    The shared attention block runs after every ``attn_every`` mamba layers;
    a tail segment shorter than the period has no attention after it.
    Segmenting (python loop over ~L/period scans) instead of a lax.cond in
    one scan keeps the HLO cost analysis exact and never lowers a dead
    branch.
    """
    per = cfg.attn_every or cfg.num_layers
    segs = []
    s0 = 0
    while s0 < cfg.num_layers:
        ln = min(per, cfg.num_layers - s0)
        segs.append((s0, ln, ln == per))
        s0 += ln
    return segs


def _tree_slice(tree, s0: int, ln: int):
    return jax.tree.map(lambda a: a[s0 : s0 + ln], tree)


def layer_windows(cfg: ModelConfig, long_context: bool = False) -> jnp.ndarray:
    """Per-layer sliding-window sizes; 0 = full attention.

    gemma3: 5 local (window) : 1 global (full) repeating.  With
    ``long_context`` (the 500k decode shape) global layers fall back to the
    arch's design-budget window instead of unbounded attention (DESIGN.md §5).
    """
    idx = jnp.arange(cfg.num_layers)
    if cfg.global_every > 0:
        is_global = (idx + 1) % cfg.global_every == 0
        global_win = 131072 if long_context else 0
        return jnp.where(is_global, global_win, cfg.sliding_window).astype(jnp.int32)
    return jnp.full((cfg.num_layers,), cfg.sliding_window, jnp.int32)


class MLPParams(NamedTuple):
    w_gate: jnp.ndarray
    w_up: jnp.ndarray
    w_down: jnp.ndarray


def _init_mlp(key: jax.Array, cfg: ModelConfig) -> MLPParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return MLPParams(
        w_gate=dense_init(k1, cfg.d_model, cfg.d_ff, cfg.dtype),
        w_up=dense_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype),
        w_down=dense_init(k3, cfg.d_ff, cfg.d_model, cfg.dtype),
    )


def _mlp(p: MLPParams, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p.w_gate))
    u = jnp.einsum("bsd,df->bsf", x, p.w_up)
    return jnp.einsum("bsf,fd->bsd", g * u, p.w_down)


# --------------------------------------------------------------------- init
def init_lm(key: jax.Array, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, cfg.dtype)

    if cfg.arch_type in ("dense", "vlm"):
        params["layers"] = {
            "attn": _stack_init(lambda k: init_attention(k, cfg), keys[2], cfg.num_layers),
            "mlp": _stack_init(lambda k: _init_mlp(k, cfg), keys[3], cfg.num_layers),
            "ln1": jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype),
            "ln2": jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype),
        }
    elif cfg.arch_type == "moe":
        params["layers"] = {
            "attn": _stack_init(lambda k: init_attention(k, cfg), keys[2], cfg.num_layers),
            "moe": _stack_init(lambda k: init_moe(k, cfg), keys[3], cfg.num_layers),
            "ln1": jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype),
            "ln2": jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype),
        }
        if cfg.dense_residual:
            params["layers"]["dense_mlp"] = _stack_init(
                lambda k: _init_mlp(k, cfg), keys[4], cfg.num_layers
            )
            params["layers"]["ln3"] = jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype)
    elif cfg.arch_type == "hybrid":
        params["layers"] = {
            "mamba": _stack_init(lambda k: init_mamba(k, cfg), keys[2], cfg.num_layers),
            "ln1": jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype),
        }
        params["shared_attn"] = init_attention(keys[3], cfg)
        params["shared_mlp"] = _init_mlp(keys[4], cfg)
        params["shared_ln1"] = jnp.ones((cfg.d_model,), cfg.dtype)
        params["shared_ln2"] = jnp.ones((cfg.d_model,), cfg.dtype)
    elif cfg.arch_type == "ssm":  # xlstm: separate stacks per block kind
        n_s = len(slstm_layer_ids(cfg))
        n_m = cfg.num_layers - n_s
        params["layers"] = {
            "mlstm": _stack_init(lambda k: init_mlstm(k, cfg), keys[2], max(n_m, 1)),
            "slstm": _stack_init(lambda k: init_slstm(k, cfg), keys[3], max(n_s, 1)),
            "ln_m": jnp.ones((max(n_m, 1), cfg.d_model), cfg.dtype),
            "ln_s": jnp.ones((max(n_s, 1), cfg.d_model), cfg.dtype),
        }
    elif cfg.arch_type == "audio":  # whisper enc-dec
        params["layers"] = {
            "self_attn": _stack_init(lambda k: init_attention(k, cfg), keys[2], cfg.num_layers),
            "cross_attn": _stack_init(lambda k: init_attention(k, cfg), keys[3], cfg.num_layers),
            "mlp": _stack_init(lambda k: _init_mlp(k, cfg), keys[4], cfg.num_layers),
            "ln1": jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype),
            "ln2": jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype),
            "ln3": jnp.ones((cfg.num_layers, cfg.d_model), cfg.dtype),
        }
        params["encoder"] = {
            "attn": _stack_init(lambda k: init_attention(k, cfg), keys[5], cfg.encoder_layers),
            "mlp": _stack_init(lambda k: _init_mlp(k, cfg), keys[6], cfg.encoder_layers),
            "ln1": jnp.ones((cfg.encoder_layers, cfg.d_model), cfg.dtype),
            "ln2": jnp.ones((cfg.encoder_layers, cfg.d_model), cfg.dtype),
        }
        params["enc_pos"] = (
            jax.random.normal(keys[7], (cfg.encoder_seq_len, cfg.d_model)) * 0.02
        ).astype(cfg.dtype)
    else:
        raise ValueError(cfg.arch_type)
    return params


# ------------------------------------------------------------------ forward
def _encode(params: dict, cfg: ModelConfig, enc_in: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder: bidirectional attention over frame embeddings."""
    x = enc_in + params["enc_pos"][None, : enc_in.shape[1]]
    enc = params["encoder"]

    def body(x, layer):
        h = attention(
            AttnParams(*layer["attn"]), cfg, rms_norm(x, layer["ln1"], cfg.norm_eps),
            positions=None, causal=False,
        )
        x = x + h
        x = x + _mlp(MLPParams(*layer["mlp"]), rms_norm(x, layer["ln2"], cfg.norm_eps))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(
        body, x,
        {"attn": tuple(enc["attn"]), "mlp": tuple(enc["mlp"]),
         "ln1": enc["ln1"], "ln2": enc["ln2"]},
    )
    return x


def forward_lm(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S) int32
    positions: Optional[jnp.ndarray] = None,  # (B,S) or (B,3,S) for mrope
    vision_embeds: Optional[jnp.ndarray] = None,  # (B, P, d) vlm stub
    encoder_embeds: Optional[jnp.ndarray] = None,  # (B, T, d) audio stub
    long_context: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (hidden (B,S,d), aux_loss ())."""
    from repro.sharding.specs import constrain_batch

    b, s = tokens.shape
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    if cfg.anchor_batch:
        x = constrain_batch(x)  # re-anchor batch sharding lost in vocab gather
    if cfg.arch_type == "vlm" and vision_embeds is not None:
        p = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, p:]], axis=1)
    if positions is None:
        pos1d = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        positions = (
            jnp.broadcast_to(pos1d[:, None], (b, 3, s)) if cfg.mrope else pos1d
        )

    aux_total = jnp.zeros((), jnp.float32)
    windows = layer_windows(cfg, long_context)

    if cfg.arch_type in ("dense", "vlm", "moe"):
        lp = params["layers"]

        def body(carry, layer):
            x, aux = carry
            h = attention(
                AttnParams(*layer["attn"]), cfg,
                rms_norm(x, layer["ln1"], cfg.norm_eps),
                positions, window=layer["window"],
            )
            x = x + h
            if cfg.arch_type == "moe":
                mo, a = apply_moe(
                    MoEParams(*layer["moe"]), cfg,
                    rms_norm(x, layer["ln2"], cfg.norm_eps),
                )
                if cfg.dense_residual:
                    mo = mo + _mlp(
                        MLPParams(*layer["dense_mlp"]),
                        rms_norm(x, layer["ln3"], cfg.norm_eps),
                    )
                x = x + mo
                aux = aux + a
            else:
                x = x + _mlp(
                    MLPParams(*layer["mlp"]), rms_norm(x, layer["ln2"], cfg.norm_eps)
                )
            return (x, aux), None

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = {k: (tuple(v) if isinstance(v, tuple) or hasattr(v, "_fields") else v)
              for k, v in lp.items()}
        xs["window"] = windows
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), xs)

    elif cfg.arch_type == "hybrid":
        lp = params["layers"]
        shared_attn = AttnParams(*params["shared_attn"])
        shared_mlp = MLPParams(*params["shared_mlp"])
        win = jnp.asarray(131072 if long_context else 0, jnp.int32)

        def body(x, layer):
            x = x + apply_mamba(
                MambaParams(*layer["mamba"]), cfg,
                rms_norm(x, layer["ln1"], cfg.norm_eps),
            )
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)

        def shared_block(x):
            h = attention(
                shared_attn, cfg,
                rms_norm(x, params["shared_ln1"], cfg.norm_eps),
                positions, window=win,
            )
            x = x + h
            return x + _mlp(shared_mlp, rms_norm(x, params["shared_ln2"], cfg.norm_eps))

        if cfg.remat:
            shared_block = jax.checkpoint(shared_block)
        for s0, ln, attn_after in hybrid_segments(cfg):
            seg = _tree_slice({"mamba": tuple(lp["mamba"]), "ln1": lp["ln1"]}, s0, ln)
            x, _ = jax.lax.scan(body, x, seg)
            if attn_after:
                x = shared_block(x)

    elif cfg.arch_type == "ssm":
        lp = params["layers"]

        def m_body(x, layer):
            x = x + apply_mlstm(
                MLSTMParams(*layer["mlstm"]), cfg,
                rms_norm(x, layer["ln"], cfg.norm_eps),
            )
            return x, None

        if cfg.remat:
            m_body = jax.checkpoint(m_body)

        def s_block(x, sp, ln_s):
            return x + apply_slstm(
                SLSTMParams(*sp), cfg, rms_norm(x, ln_s, cfg.norm_eps)
            )

        if cfg.remat:
            s_block = jax.checkpoint(s_block)
        s_ids = slstm_layer_ids(cfg)
        m_used = 0
        seg_start = 0
        for seg_i, s_layer in enumerate(s_ids + [cfg.num_layers]):
            n_m = s_layer - seg_start  # mlstm layers before this slstm
            if n_m > 0:
                seg = _tree_slice(
                    {"mlstm": tuple(lp["mlstm"]), "ln": lp["ln_m"]}, m_used, n_m
                )
                x, _ = jax.lax.scan(m_body, x, seg)
                m_used += n_m
            if s_layer < cfg.num_layers:
                sp = _tree_slice(tuple(lp["slstm"]), seg_i, 1)
                sp = jax.tree.map(lambda a: a[0], sp)
                x = s_block(x, sp, lp["ln_s"][seg_i])
            seg_start = s_layer + 1

    elif cfg.arch_type == "audio":
        assert encoder_embeds is not None, "audio arch needs encoder_embeds"
        enc_out = _encode(params, cfg, encoder_embeds)
        lp = params["layers"]

        def body(carry, layer):
            x, _ = carry
            sa = AttnParams(*layer["self_attn"])
            ca = AttnParams(*layer["cross_attn"])
            x = x + attention(
                sa, cfg, rms_norm(x, layer["ln1"], cfg.norm_eps), positions
            )
            ek, ev = project_kv(ca, cfg, enc_out)
            x = x + cross_attention(
                ca, cfg, rms_norm(x, layer["ln2"], cfg.norm_eps), ek, ev
            )
            x = x + _mlp(
                MLPParams(*layer["mlp"]), rms_norm(x, layer["ln3"], cfg.norm_eps)
            )
            return (x, jnp.zeros((), jnp.float32)), None

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = {"self_attn": tuple(lp["self_attn"]), "cross_attn": tuple(lp["cross_attn"]),
              "mlp": tuple(lp["mlp"]), "ln1": lp["ln1"], "ln2": lp["ln2"],
              "ln3": lp["ln3"]}
        (x, _), _ = jax.lax.scan(body, (x, aux_total), xs)
    else:
        raise ValueError(cfg.arch_type)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    hidden: jnp.ndarray,  # (B, S, d)
    labels: jnp.ndarray,  # (B, S) int32, -1 = masked
    aux: jnp.ndarray,
    chunk: int = 512,
) -> jnp.ndarray:
    """Chunked softmax cross-entropy — never materializes (B, S, V) in f32.

    Scans over sequence chunks; per chunk the (B, c, V) logits live briefly
    (sharded over the model axis on V under GSPMD).
    """
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    )  # (d, V)
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = hidden.shape[1] // chunk
    hs = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def body(carry, inp):
        tot, cnt = carry
        h_c, l_c = inp
        logits = jnp.einsum("bsd,dv->bsv", h_c, unembed).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l_c, 0)[..., None], axis=-1
        )[..., 0]
        mask = (l_c >= 0).astype(jnp.float32)
        tot = tot + ((lse - gold) * mask).sum()
        cnt = cnt + mask.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls)
    )
    return tot / jnp.maximum(cnt, 1.0) + aux


# ------------------------------------------------------------------- decode
class DecodeState(NamedTuple):
    """Family-union single-token decode state (unused fields are ())."""

    pos: jnp.ndarray  # (B,) absolute position of the next token
    k_cache: Any = ()  # (L, B, S, KV, hd) dense/moe/vlm
    v_cache: Any = ()
    mamba: Any = ()  # stacked MambaState (hybrid)
    shared_k: Any = ()  # (A, B, S, KV, hd) zamba2 shared-attn caches
    shared_v: Any = ()
    mlstm: Any = ()  # stacked MLSTMState (ssm)
    slstm: Any = ()  # stacked SLSTMState
    cross_k: Any = ()  # (L, B, T, KV, hd) whisper cross-attn caches
    cross_v: Any = ()


def init_decode_state(
    params: dict, cfg: ModelConfig, batch: int, cache_len: int,
    encoder_embeds: Optional[jnp.ndarray] = None,
) -> DecodeState:
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    pos = jnp.zeros((batch,), jnp.int32)
    zeros_kv = lambda n: jnp.zeros((n, batch, cache_len, kv, hd), cfg.dtype)
    if cfg.arch_type in ("dense", "vlm", "moe"):
        return DecodeState(pos=pos, k_cache=zeros_kv(cfg.num_layers),
                           v_cache=zeros_kv(cfg.num_layers))
    if cfg.arch_type == "hybrid":
        n_app = cfg.num_layers // cfg.attn_every
        mamba = jax.vmap(lambda _: init_mamba_state(cfg, batch, cfg.dtype))(
            jnp.arange(cfg.num_layers)
        )
        return DecodeState(pos=pos, mamba=mamba,
                           shared_k=zeros_kv(max(n_app, 1)),
                           shared_v=zeros_kv(max(n_app, 1)))
    if cfg.arch_type == "ssm":
        n_s = len(slstm_layer_ids(cfg))
        n_m = cfg.num_layers - n_s
        mst = jax.vmap(lambda _: init_mlstm_state(cfg, batch))(jnp.arange(max(n_m, 1)))
        sst = jax.vmap(lambda _: init_slstm_state(cfg, batch))(jnp.arange(max(n_s, 1)))
        return DecodeState(pos=pos, mlstm=mst, slstm=sst)
    if cfg.arch_type == "audio":
        assert encoder_embeds is not None
        enc_out = _encode(params, cfg, encoder_embeds)
        lp = params["layers"]
        ck, cv = jax.vmap(
            lambda ca: project_kv(AttnParams(*ca), cfg, enc_out)
        )(tuple(lp["cross_attn"]))
        return DecodeState(pos=pos, k_cache=zeros_kv(cfg.num_layers),
                           v_cache=zeros_kv(cfg.num_layers),
                           cross_k=ck, cross_v=cv)
    raise ValueError(cfg.arch_type)


def decode_lm(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B, 1) int32
    state: DecodeState,
    long_context: bool = False,
) -> tuple[jnp.ndarray, DecodeState]:
    """One-token decode step.  Returns (logits (B, V), new state)."""
    from repro.sharding.specs import constrain_batch

    x = params["embed"][token] * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    if cfg.anchor_batch:
        x = constrain_batch(x)
    pos = state.pos
    windows = layer_windows(cfg, long_context)

    if cfg.arch_type in ("dense", "vlm", "moe"):
        lp = params["layers"]

        def body(x, layer):
            h, k_new, v_new = decode_attention(
                AttnParams(*layer["attn"]), cfg,
                rms_norm(x, layer["ln1"], cfg.norm_eps),
                layer["k"], layer["v"], pos, window=layer["window"],
            )
            x = x + h
            if cfg.arch_type == "moe":
                mo, _ = apply_moe(
                    MoEParams(*layer["moe"]), cfg,
                    rms_norm(x, layer["ln2"], cfg.norm_eps),
                )
                if cfg.dense_residual:
                    mo = mo + _mlp(
                        MLPParams(*layer["dense_mlp"]),
                        rms_norm(x, layer["ln3"], cfg.norm_eps),
                    )
                x = x + mo
            else:
                x = x + _mlp(
                    MLPParams(*layer["mlp"]), rms_norm(x, layer["ln2"], cfg.norm_eps)
                )
            return x, (k_new, v_new)

        xs = {k: (tuple(v) if hasattr(v, "_fields") else v) for k, v in lp.items()}
        xs["k"], xs["v"] = state.k_cache, state.v_cache
        xs["window"] = windows
        x, (k_c, v_c) = jax.lax.scan(body, x, xs)
        state = state._replace(k_cache=k_c, v_cache=v_c, pos=pos + 1)

    elif cfg.arch_type == "hybrid":
        shared_attn = AttnParams(*params["shared_attn"])
        shared_mlp = MLPParams(*params["shared_mlp"])
        win = jnp.asarray(131072 if long_context else 0, jnp.int32)
        sk, sv = state.shared_k, state.shared_v

        def body(x, layer):
            out, mstate = decode_mamba(
                MambaParams(*layer["mamba"]), cfg,
                rms_norm(x, layer["ln1"], cfg.norm_eps),
                MambaState(*layer["mstate"]),
            )
            return x + out, tuple(mstate)

        lp = params["layers"]
        new_mstates = []
        app = 0
        for s0, ln, attn_after in hybrid_segments(cfg):
            seg = _tree_slice(
                {"mamba": tuple(lp["mamba"]), "ln1": lp["ln1"],
                 "mstate": tuple(state.mamba)}, s0, ln,
            )
            x, mstates = jax.lax.scan(body, x, seg)
            new_mstates.append(mstates)
            if attn_after:
                h, k_new, v_new = decode_attention(
                    shared_attn, cfg,
                    rms_norm(x, params["shared_ln1"], cfg.norm_eps),
                    sk[app], sv[app], pos, window=win,
                )
                x = x + h
                x = x + _mlp(shared_mlp, rms_norm(x, params["shared_ln2"], cfg.norm_eps))
                sk = sk.at[app].set(k_new)
                sv = sv.at[app].set(v_new)
                app += 1
        mstates = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mstates)
        state = state._replace(
            mamba=MambaState(*mstates), shared_k=sk, shared_v=sv, pos=pos + 1
        )

    elif cfg.arch_type == "ssm":
        lp = params["layers"]

        def m_body(x, layer):
            out, new = decode_mlstm(
                MLSTMParams(*layer["mlstm"]), cfg,
                rms_norm(x, layer["ln"], cfg.norm_eps),
                MLSTMState(*layer["mst"]),
            )
            return x + out, tuple(new)

        s_ids = slstm_layer_ids(cfg)
        m_used, seg_start = 0, 0
        new_msts, new_ssts = [], []
        for seg_i, s_layer in enumerate(s_ids + [cfg.num_layers]):
            n_m = s_layer - seg_start
            if n_m > 0:
                seg = _tree_slice(
                    {"mlstm": tuple(lp["mlstm"]), "ln": lp["ln_m"],
                     "mst": tuple(state.mlstm)}, m_used, n_m,
                )
                x, msts = jax.lax.scan(m_body, x, seg)
                new_msts.append(msts)
                m_used += n_m
            if s_layer < cfg.num_layers:
                sp = jax.tree.map(lambda a: a[seg_i], tuple(lp["slstm"]))
                sst = jax.tree.map(lambda a: a[seg_i], tuple(state.slstm))
                out, new_sst = decode_slstm(
                    SLSTMParams(*sp), cfg,
                    rms_norm(x, lp["ln_s"][seg_i], cfg.norm_eps),
                    SLSTMState(*sst),
                )
                x = x + out
                new_ssts.append(jax.tree.map(lambda a: a[None], tuple(new_sst)))
            seg_start = s_layer + 1
        msts = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_msts)
        if new_ssts:
            ssts = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_ssts)
        else:
            ssts = tuple(state.slstm)
        state = state._replace(
            mlstm=MLSTMState(*msts), slstm=SLSTMState(*ssts), pos=pos + 1
        )

    elif cfg.arch_type == "audio":
        lp = params["layers"]

        def body(x, layer):
            sa = AttnParams(*layer["self_attn"])
            ca = AttnParams(*layer["cross_attn"])
            h, k_new, v_new = decode_attention(
                sa, cfg, rms_norm(x, layer["ln1"], cfg.norm_eps),
                layer["k"], layer["v"], pos,
            )
            x = x + h
            x = x + cross_attention(
                ca, cfg, rms_norm(x, layer["ln2"], cfg.norm_eps),
                layer["ck"], layer["cv"],
            )
            x = x + _mlp(MLPParams(*layer["mlp"]), rms_norm(x, layer["ln3"], cfg.norm_eps))
            return x, (k_new, v_new)

        xs = {"self_attn": tuple(lp["self_attn"]), "cross_attn": tuple(lp["cross_attn"]),
              "mlp": tuple(lp["mlp"]), "ln1": lp["ln1"], "ln2": lp["ln2"],
              "ln3": lp["ln3"], "k": state.k_cache, "v": state.v_cache,
              "ck": state.cross_k, "cv": state.cross_v}
        x, (k_c, v_c) = jax.lax.scan(body, x, xs)
        state = state._replace(k_cache=k_c, v_cache=v_c, pos=pos + 1)
    else:
        raise ValueError(cfg.arch_type)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, unembed)[:, 0]
    return logits, state
