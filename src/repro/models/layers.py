"""Shared primitives: norms, linear init, rotary embeddings (RoPE + M-RoPE)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    """Truncated-normal fan-in init (LeCun-style)."""
    std = d_in**-0.5
    return (jax.random.truncated_normal(key, -2, 2, (d_in, d_out)) * std).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2, 2, (vocab, d)) * 0.02).astype(dtype)


# ------------------------------------------------------------------- rotary
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray,  # (..., S, H, head_dim)
    positions: jnp.ndarray,  # (..., S) int32
    theta: float,
) -> jnp.ndarray:
    """Standard RoPE on half-split layout."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,  # (..., S, H, head_dim)
    positions: jnp.ndarray,  # (..., 3, S) int32 — (temporal, height, width)
    theta: float,
    sections: tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL Multimodal RoPE (arXiv:2409.12191 §2.1).

    The rotary half-dims are split into three sections; each section's angle
    uses a different coordinate channel (t / h / w).  For pure text all three
    channels carry the same 1-D position, which makes M-RoPE degenerate to
    standard RoPE — property-tested in tests/test_models_zoo.py.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(head_dim, theta)  # (half,)
    # section id per rotary dim: 0/1/2
    sec = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (half,)
    # positions: (..., 3, S) -> per-rotary-dim coordinate channel
    pos = jnp.moveaxis(positions, -2, -1)  # (..., S, 3)
    pos_per_dim = jnp.take(pos, sec, axis=-1)  # (..., S, half)
    angles = pos_per_dim.astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray):
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)
