"""Unified architecture configuration.

One dataclass covers all six assigned arch families (dense / moe / ssm /
hybrid / vlm / audio); family-specific fields default to "off".  Each
``src/repro/configs/<id>.py`` instantiates this with the exact assigned
dimensions and provides a ``smoke()`` reduced variant.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # ---- attention features
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False  # qwen2-vl 3-section multimodal RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w rotary halves
    sliding_window: int = 0  # 0 = full attention
    global_every: int = 0  # gemma3: every Nth layer is global (1-indexed period)

    # ---- MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0  # qwen2-moe shared experts
    moe_d_ff: int = 0  # per-expert hidden dim (d_ff used for dense/shared path)
    dense_residual: bool = False  # arctic: parallel dense FFN residual
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01  # load-balance auxiliary loss

    # ---- SSM (Mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0  # zamba2: shared attention block every N layers

    # ---- xLSTM
    slstm_every: int = 0  # every Nth block is sLSTM (rest mLSTM); 0 = none

    # ---- encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper: 30 s of audio at 50 Hz post-conv

    # ---- VLM stub frontend
    num_patches: int = 0  # qwen2-vl: patch embeddings prepended to the text

    # ---- misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: object = jnp.bfloat16
    remat: bool = True  # activation-checkpoint each layer in train_step

    # ---- §Perf optimization knobs (baseline = paper-faithful defaults)
    flash_vjp: bool = False  # custom-VJP flash attention (recompute-in-bwd)
    pad_q_groups: int = 0  # pad GQA groups at runtime (superseded by
    #   attn_pad_heads; kept for ablation)
    shard_heads: str = "auto"  # "auto": replicate q/kv projections when head
    #   counts don't divide the model axis (right when attention is a small
    #   share, e.g. MoE archs); "split": legacy flattened-dim sharding
    #   (partial-sum all-reduces of scores); "context": sequence-shard the
    #   queries over the model axis (context parallelism — right for
    #   attention-heavy archs with few heads, e.g. gemma3)
    attn_pad_heads: int = 0  # parameter-level head padding: wq/bq carry this
    #   many heads; the extra heads' context is sliced off before wo, so they
    #   receive zero gradient and never affect the function (exact).
    moe_group_size: int = 0  # routing-group tokens (0 = whole sequence)
    moe_pad_experts: int = 0  # pad the expert dim so it divides the mesh;
    #   padded experts are router-masked to -inf (never routed — exact)
    moe_shard_dispatch: bool = False  # sharding constraints on dispatch path
    anchor_batch: bool = True  # constrain_batch after embedding (off for archs
    #   where GSPMD's own batch x (data,model) layout wins, e.g. xlstm)

    # citation for the assigned config (model card / paper)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def effective_heads(self) -> int:
        return self.attn_pad_heads or self.num_heads

    def param_count(self) -> int:
        """Analytic total parameter count (for roofline MODEL_FLOPS)."""
        d, v, hd = self.d_model, self.vocab_size, self.resolved_head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        att = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d

        def ffn(ff):
            return 3 * d * ff  # SwiGLU

        per_layer = 0
        if self.arch_type in ("dense", "vlm", "audio"):
            per_layer = att + ffn(self.d_ff)
        elif self.arch_type == "moe":
            per_layer = att + self.num_experts * ffn(self.moe_d_ff) + d * self.num_experts
            if self.num_shared_experts:
                per_layer += self.num_shared_experts * ffn(self.moe_d_ff)
            if self.dense_residual:
                per_layer += ffn(self.d_ff)
        elif self.arch_type == "ssm":
            if self.slstm_every:
                per_layer = 4 * d * d + ffn(self.d_ff if self.d_ff else 2 * d)
            else:
                per_layer = att + ffn(self.d_ff)
        elif self.arch_type == "hybrid":
            inner = self.ssm_expand * d
            per_layer = 2 * d * inner + inner * d + inner * self.ssm_state * 2
        n += self.num_layers * per_layer
        if self.arch_type == "hybrid" and self.attn_every:
            n += att + ffn(self.d_ff)  # one shared attention+ffn block
        if self.is_encoder_decoder:
            n += self.encoder_layers * (att + ffn(self.d_ff)) + self.num_layers * att
        return int(n)

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only routed-in experts."""
        if self.arch_type != "moe":
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_expert = self.num_layers * self.num_experts * 3 * d * self.moe_d_ff
        active_expert = (
            self.num_layers * self.num_experts_per_tok * 3 * d * self.moe_d_ff
        )
        return int(full - all_expert + active_expert)
