"""Grouped-query attention with the assigned archs' feature matrix.

Features (per DESIGN.md §5): GQA with arbitrary kv-head counts, optional
qk-norm (qwen3), QKV bias (qwen2 family), RoPE / M-RoPE (qwen2-vl), sliding
windows parameterized by a *traced* per-layer scalar (gemma3's 5:1
local:global pattern lives inside one lax.scan body), causal or bidirectional
(whisper encoder), cross-attention (whisper decoder), and a one-token decode
path against a pre-filled KV cache.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_mrope, apply_rope, dense_init, rms_norm


class AttnParams(NamedTuple):
    wq: jnp.ndarray  # (d, H*hd)
    wk: jnp.ndarray  # (d, KV*hd)
    wv: jnp.ndarray  # (d, KV*hd)
    wo: jnp.ndarray  # (H*hd, d)
    bq: jnp.ndarray  # (H*hd,) zeros when qkv_bias off
    bk: jnp.ndarray
    bv: jnp.ndarray
    q_norm: jnp.ndarray  # (hd,) qk-norm scales (ones when off)
    k_norm: jnp.ndarray


def init_attention(key: jax.Array, cfg: ModelConfig) -> AttnParams:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    return AttnParams(
        wq=dense_init(kq, d, cfg.effective_heads * hd, cfg.dtype),
        wk=dense_init(kk, d, cfg.num_kv_heads * hd, cfg.dtype),
        wv=dense_init(kv, d, cfg.num_kv_heads * hd, cfg.dtype),
        wo=dense_init(ko, cfg.num_heads * hd, d, cfg.dtype),
        bq=jnp.zeros((cfg.effective_heads * hd,), cfg.dtype),
        bk=jnp.zeros((cfg.num_kv_heads * hd,), cfg.dtype),
        bv=jnp.zeros((cfg.num_kv_heads * hd,), cfg.dtype),
        q_norm=jnp.ones((hd,), cfg.dtype),
        k_norm=jnp.ones((hd,), cfg.dtype),
    )


def _project_qkv(p: AttnParams, cfg: ModelConfig, x: jnp.ndarray):
    """x (B, S, d) -> q (B, S, H, hd), k/v (B, S, KV, hd)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p.wq)
    k = jnp.einsum("bsd,dh->bsh", x, p.wk)
    v = jnp.einsum("bsd,dh->bsh", x, p.wv)
    if cfg.qkv_bias:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    q = q.reshape(b, s, cfg.effective_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p.q_norm, cfg.norm_eps)
        k = rms_norm(k, p.k_norm, cfg.norm_eps)
    return q, k, v


def _rotary(cfg: ModelConfig, q, k, positions):
    if positions is None:
        return q, k
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _gqa_scores(q, k, q_groups: int):
    """q (B,S,H,hd) x k (B,T,KV,hd) -> (B, KV, G, S, T) with H = KV*G."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    q = q.reshape(b, s, kvh, q_groups, hd)
    return jnp.einsum("bskgh,btkh->bkgst", q, k) / (hd**0.5)


def _gqa_out(scores, v, wo, real_groups: int = 0):
    """scores (B,KV,G,S,T), v (B,T,KV,hd) -> (B, S, d).

    ``real_groups``: if the G axis was padded for sharding, slice back to the
    real group count before the output projection (exact — padded heads'
    context never reaches wo)."""
    b, kvh, g, s, t = scores.shape
    ctx = jnp.einsum("bkgst,btkh->bskgh", scores, v)
    if real_groups and real_groups < g:
        ctx = ctx[:, :, :, :real_groups]
        g = real_groups
    ctx = ctx.reshape(b, s, kvh * g * v.shape[-1])
    return jnp.einsum("bsh,hd->bsd", ctx, wo)


def _context_parallel(cfg, qr):
    """shard_heads="context": shard the query-sequence dim over the model
    axis.  Online softmax is per-row, so no cross-shard reduction appears;
    only k/v (tiny for few-kv-head archs) are gathered.  No-op outside a
    mesh or when S doesn't divide."""
    if cfg.shard_heads != "context":
        return qr
    from repro.sharding.specs import current_abstract_mesh

    mesh = current_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return qr
    if qr.shape[1] % mesh.shape["model"] != 0:
        return qr
    from jax.sharding import PartitionSpec as _P

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    return jax.lax.with_sharding_constraint(
        qr, _P(dp, "model", *([None] * (qr.ndim - 2)))
    )


def _pad_groups(cfg, q):
    """(B,S,H,hd) -> (B,S,KV*Gp,hd) with zero-padded q groups (exact; see
    _gqa_out).  Returns (q, effective_groups, real_groups).

    With parameter-level padding (cfg.attn_pad_heads) q already carries the
    padded head count from the projection; only the group bookkeeping is
    returned."""
    g = cfg.q_groups
    if cfg.attn_pad_heads:
        return q, cfg.effective_heads // max(cfg.num_kv_heads, 1), g
    gp = cfg.pad_q_groups
    if not gp or gp <= g:
        return q, g, g
    b, s, h, hd = q.shape
    qg = q.reshape(b, s, cfg.num_kv_heads, g, hd)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, gp - g), (0, 0)))
    return qg.reshape(b, s, cfg.num_kv_heads * gp, hd), gp, g


def _dense_attend(cfg, q, k, v, wo, window, causal, dtype):
    """Naive full-matrix attention (small sequences / oracle for tests)."""
    q, g_eff, g_real = _pad_groups(cfg, q)
    scores = _gqa_scores(q, k, g_eff)  # (B,KV,G,S,T)
    s, t = q.shape[1], k.shape[1]
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        # traced sliding window: attend iff (row - col) < window, window<=0 = full
        in_win = (rows - cols) < jnp.maximum(window, 1)
        mask &= jnp.where(window > 0, in_win, True)
    scores = jnp.where(mask, scores.astype(jnp.float32), -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    return _gqa_out(probs, v, wo, g_real)


def _chunk_mask(rows, cols, t, causal, window, s, kc):
    mask = cols < t  # drop padding
    if causal:
        mask = mask & (cols <= rows)
    else:
        mask = jnp.broadcast_to(mask, (s, kc))
    if window is not None:
        in_win = (rows - cols) < jnp.maximum(window, 1)
        mask = mask & jnp.where(window > 0, in_win, True)
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _flash_core(qr, k, v, window, t, causal, kv_chunk):
    """Flash attention with recompute-in-backward (no saved probabilities).

    qr (B,S,KV,G,hd), k/v (B,Tp,KV,hd) already kc-padded; ``window`` is a
    TRACED () int32 scalar (0 = full attention) so gemma3's per-layer
    local:global pattern stays inside one lax.scan body.  Returns ctx
    (B,S,KV,G,hd).  The backward pass recomputes each chunk's probabilities
    from (q, k, lse) — O(S * kc) live memory in both directions, the
    standard FlashAttention-2 residual scheme (saves only out + lse).
    """
    ctx, _lse = _flash_fwd_pass(qr, k, v, window, t, causal, kv_chunk)
    return ctx


def _flash_fwd_pass(qr, k, v, window, t, causal, kv_chunk):
    b, s, kvh, g, hd = qr.shape
    kc = kv_chunk
    nc = k.shape[1] // kc
    win = window
    ks = jnp.moveaxis(k.reshape(b, nc, kc, kvh, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nc, kc, kvh, hd), 1, 0)
    rows = jnp.arange(s)[:, None]

    def body(carry, inp):
        m, l, acc = carry
        k_c, v_c, j0 = inp
        scores = (
            jnp.einsum("bskgh,bjkh->bkgsj", qr, k_c).astype(jnp.float32) / hd**0.5
        )
        cols = j0 * kc + jnp.arange(kc)[None, :]
        mask = _chunk_mask(rows, cols, t, causal, win, s, kc)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p_c = jnp.exp(scores - m_new[..., None])
        l_new = l * corr + p_c.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgsj,bjkh->bkgsh", p_c, v_c)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, jnp.arange(nc)))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).astype(qr.dtype)  # (B,KV,G,S,hd)
    lse = m + jnp.log(l_safe)
    return jnp.moveaxis(out, 3, 1), lse  # ctx (B,S,KV,G,hd)


def _flash_fwd(qr, k, v, window, t, causal, kv_chunk):
    ctx, lse = _flash_fwd_pass(qr, k, v, window, t, causal, kv_chunk)
    return ctx, (qr, k, v, window, ctx, lse)


def _flash_bwd(t, causal, kv_chunk, res, d_ctx):
    qr, k, v, window, ctx, lse = res
    b, s, kvh, g, hd = qr.shape
    kc = kv_chunk
    nc = k.shape[1] // kc
    win = window
    ks = jnp.moveaxis(k.reshape(b, nc, kc, kvh, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nc, kc, kvh, hd), 1, 0)
    rows = jnp.arange(s)[:, None]
    do = jnp.moveaxis(d_ctx.astype(jnp.float32), 1, 3)  # (B,KV,G,S,hd)
    out = jnp.moveaxis(ctx.astype(jnp.float32), 1, 3)
    delta = (do * out).sum(-1)  # (B,KV,G,S)

    def body(dq, inp):
        k_c, v_c, j0 = inp
        scores = (
            jnp.einsum("bskgh,bjkh->bkgsj", qr, k_c).astype(jnp.float32) / hd**0.5
        )
        cols = j0 * kc + jnp.arange(kc)[None, :]
        mask = _chunk_mask(rows, cols, t, causal, win, s, kc)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jnp.exp(scores - lse[..., None])  # (B,KV,G,S,kc)
        dv_c = jnp.einsum("bkgsj,bkgsh->bjkh", p, do)
        dp = jnp.einsum("bkgsh,bjkh->bkgsj", do, v_c.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) / hd**0.5
        dq = dq + jnp.einsum("bkgsj,bjkh->bskgh", ds, k_c.astype(jnp.float32))
        dk_c = jnp.einsum("bkgsj,bskgh->bjkh", ds, qr.astype(jnp.float32))
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((b, s, kvh, g, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (ks, vs, jnp.arange(nc)))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, nc * kc, kvh, hd)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, nc * kc, kvh, hd)
    import numpy as _np
    dwin = _np.zeros((), jax.dtypes.float0)  # int operand: zero cotangent
    return dq.astype(qr.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dwin


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def flash_attend(cfg, q, k, v, wo, window, causal, dtype, kv_chunk: int = 1024):
    """custom-VJP flash attention — the §Perf memory optimization: backward
    recomputes probabilities instead of autodiff saving per-chunk f32 score
    residuals.  ``window`` may be a traced () int32 (0/None = full)."""
    q, g, g_real = _pad_groups(cfg, q)
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    t = k.shape[1]
    kc = min(kv_chunk, t)
    pad = (-t) % kc
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = _context_parallel(cfg, q.reshape(b, s, kvh, g, hd))
    win = jnp.asarray(0 if window is None else window, jnp.int32)
    ctx = _flash_core(qr, k, v, win, t, causal, kc)  # (B,S,KV,G,hd)
    if g_real < g:
        ctx = ctx[:, :, :, :g_real]
        g = g_real
    ctx = ctx.reshape(b, s, kvh * g * hd).astype(dtype)
    return jnp.einsum("bsh,hd->bsd", ctx, wo)


def _blocked_attend(cfg, q, k, v, wo, window, causal, dtype, kv_chunk: int = 1024,
                    _unused=None):
    """Flash-style online-softmax attention, scanned over KV chunks.

    Memory is O(S * kv_chunk) instead of O(S^2): the only live score tensor
    is (B, KV, G, S, kc).  Numerics match `_dense_attend` to fp32 tolerance
    (asserted in tests/test_models_zoo.py).
    """
    q, g, g_real = _pad_groups(cfg, q)
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    t = k.shape[1]
    kc = min(kv_chunk, t)
    pad = (-t) % kc
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (t + pad) // kc
    qr = _context_parallel(cfg, q.reshape(b, s, kvh, g, hd))
    ks = jnp.moveaxis(k.reshape(b, nc, kc, kvh, hd), 1, 0)  # (NC,B,kc,KV,hd)
    vs = jnp.moveaxis(v.reshape(b, nc, kc, kvh, hd), 1, 0)
    rows = jnp.arange(s)[:, None]  # (S,1)

    def body(carry, inp):
        m, l, acc = carry
        k_c, v_c, j0 = inp
        scores = (
            jnp.einsum("bskgh,bjkh->bkgsj", qr, k_c).astype(jnp.float32) / hd**0.5
        )  # (B,KV,G,S,kc)
        cols = j0 * kc + jnp.arange(kc)[None, :]  # (1,kc) global col ids
        mask = cols < t  # drop padding
        if causal:
            mask = mask & (cols <= rows)
        else:
            mask = jnp.broadcast_to(mask, (s, kc))
        if window is not None:
            in_win = (rows - cols) < jnp.maximum(window, 1)
            mask = mask & jnp.where(window > 0, in_win, True)
        # finite mask value (-1e30, not -inf) keeps the online-softmax update
        # NaN-free for rows whose first valid column arrives in a later chunk
        # (sliding windows); bogus all-masked accumulation is wiped by the
        # corr -> 0 rescale when the first real column appears.
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p_c = jnp.exp(scores - m_new[..., None])
        l_new = l * corr + p_c.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgsj,bjkh->bkgsh", p_c, v_c)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,S,hd)
    ctx = jnp.moveaxis(out, 3, 1)  # (B,S,KV,G,hd)
    if g_real < g:
        ctx = ctx[:, :, :, :g_real]
        g = g_real
    ctx = ctx.reshape(b, s, kvh * g * hd).astype(dtype)
    return jnp.einsum("bsh,hd->bsd", ctx, wo)


# sequences at or above this length route through the blocked kernel
BLOCKED_ATTN_THRESHOLD = 2048


def attention(
    p: AttnParams,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, d)
    positions: Optional[jnp.ndarray],  # (B, S) or (B, 3, S) for M-RoPE; None=no rope
    window: Optional[jnp.ndarray] = None,  # () traced window size; <=0 -> full
    causal: bool = True,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill)."""
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rotary(cfg, q, k, positions)
    if x.shape[1] >= BLOCKED_ATTN_THRESHOLD:
        if cfg.flash_vjp:
            return flash_attend(cfg, q, k, v, p.wo, window, causal, x.dtype)
        return _blocked_attend(cfg, q, k, v, p.wo, window, causal, x.dtype)
    return _dense_attend(cfg, q, k, v, p.wo, window, causal, x.dtype)


def cross_attention(
    p: AttnParams,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, d) decoder side
    enc_k: jnp.ndarray,  # (B, T, KV, hd) precomputed encoder keys
    enc_v: jnp.ndarray,  # (B, T, KV, hd)
) -> jnp.ndarray:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p.wq).reshape(b, s, cfg.num_heads, hd)
    scores = _gqa_scores(q, enc_k, cfg.q_groups)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    return _gqa_out(probs, enc_v, p.wo)


def project_kv(p: AttnParams, cfg: ModelConfig, x: jnp.ndarray):
    """Encoder-output -> (k, v) for cached cross-attention."""
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("btd,dh->bth", x, p.wk).reshape(b, t, cfg.num_kv_heads, hd)
    v = jnp.einsum("btd,dh->bth", x, p.wv).reshape(b, t, cfg.num_kv_heads, hd)
    return k, v


def decode_attention(
    p: AttnParams,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, 1, d) the new token
    k_cache: jnp.ndarray,  # (B, S, KV, hd)
    v_cache: jnp.ndarray,  # (B, S, KV, hd)
    pos: jnp.ndarray,  # (B,) current absolute position of the new token
    window: Optional[jnp.ndarray] = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode.  The new token's k/v are written at ``pos`` (the
    cache is treated as a ring of static length S).  Returns (out, k, v)
    caches updated."""
    b, _, _ = x.shape
    s = k_cache.shape[1]
    rope_pos = pos[:, None]  # (B, 1)
    if cfg.mrope:
        rope_pos = jnp.broadcast_to(pos[:, None, None], (b, 3, 1))
    q, k_new, v_new = _project_qkv(p, cfg, x)
    q, k_new = _rotary(cfg, q, k_new, rope_pos)
    q, g_eff, g_real = _pad_groups(cfg, q)

    slot = (pos % s).astype(jnp.int32)  # (B,)
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, slot].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v_new[:, 0])

    scores = _gqa_scores(q, k_cache, g_eff)  # (B,KV,G,1,S)
    cols = jnp.arange(s)[None, :]
    valid = cols <= pos[:, None]  # only written slots (pos >= cache fill)
    if window is not None:
        in_win = (pos[:, None] - cols) < jnp.maximum(window, 1)
        valid &= jnp.where(window > 0, in_win, True)
    scores = jnp.where(
        valid[:, None, None, None, :], scores.astype(jnp.float32), -jnp.inf
    )
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v_cache, p.wo, g_real)  # (B, 1, d)
    return out, k_cache, v_cache
