"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

mLSTM trains in its parallel, attention-like stabilized form (quadratic in
the sequence, MXU-friendly) and decodes with the O(1) recurrent form carrying
a (head_dim x head_dim) matrix memory per head.  sLSTM is inherently
sequential (hidden-state recurrence in the gates), so training uses a
``lax.scan`` over time.

Blocks follow the paper's pre-up-projection design: the sequence-mix cell
lives inside a 2x up-projection (mLSTM) or is followed by a 4/3 gated FFN
(sLSTM); ``cfg.d_ff == 0`` marks this family (no separate transformer FFN).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm


# ------------------------------------------------------------------- mLSTM
class MLSTMParams(NamedTuple):
    up_proj: jnp.ndarray  # (d, 2*inner) -> (cell input, gate)
    wq: jnp.ndarray  # (inner, inner)
    wk: jnp.ndarray
    wv: jnp.ndarray
    w_if: jnp.ndarray  # (inner, 2*H) input+forget gate pre-activations
    b_if: jnp.ndarray  # (2*H,)
    norm: jnp.ndarray  # (inner,) per-head group norm scale
    down_proj: jnp.ndarray  # (inner, d)


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    inner = 2 * cfg.d_model
    heads = cfg.num_heads
    return inner, heads, inner // heads


def init_mlstm(key: jax.Array, cfg: ModelConfig) -> MLSTMParams:
    d = cfg.d_model
    inner, heads, _hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return MLSTMParams(
        up_proj=dense_init(ks[0], d, 2 * inner, cfg.dtype),
        wq=dense_init(ks[1], inner, inner, cfg.dtype),
        wk=dense_init(ks[2], inner, inner, cfg.dtype),
        wv=dense_init(ks[3], inner, inner, cfg.dtype),
        w_if=dense_init(ks[4], inner, 2 * heads, jnp.float32),
        b_if=jnp.concatenate([jnp.zeros((heads,)), 3.0 * jnp.ones((heads,))]),
        norm=jnp.ones((inner,), cfg.dtype),
        down_proj=dense_init(ks[5], inner, d, cfg.dtype),
    )


def apply_mlstm(
    p: MLSTMParams, cfg: ModelConfig, x: jnp.ndarray, chunk: int = 256
) -> jnp.ndarray:
    """Chunkwise stabilized mLSTM.  x (B, S, d) -> (B, S, d).

    The fully-parallel form materializes a (B, S, S, H) decay tensor —
    prohibitive past a few K tokens.  The chunkwise form (xLSTM paper App. /
    mlstm_kernels) carries (C, n, m) state across chunks via a lax.scan and
    keeps only a (B, L, L, H) intra-chunk tensor live — the same structure as
    our Mamba2 SSD.  Validated against the recurrent decode path in
    tests/test_models_zoo.py.
    """
    b, s, d = x.shape
    inner, heads, hd = _mlstm_dims(cfg)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    up = jnp.einsum("bsd,de->bse", x, p.up_proj)
    cell_in, gate = jnp.split(up, 2, axis=-1)  # (B,S,inner)

    q = jnp.einsum("bse,ef->bsf", cell_in, p.wq).reshape(b, s, heads, hd)
    k = jnp.einsum("bse,ef->bsf", cell_in, p.wk).reshape(b, s, heads, hd)
    v = jnp.einsum("bse,ef->bsf", cell_in, p.wv).reshape(b, s, heads, hd)
    gates = jnp.einsum("bse,eg->bsg", cell_in.astype(jnp.float32), p.w_if) + p.b_if
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)  # (B,S,H)
    logf = jax.nn.log_sigmoid(f_pre)

    def cpad(t, fill=0.0):
        if pad == 0:
            return t
        cfgp = [(0, 0)] * t.ndim
        cfgp[1] = (0, pad)
        return jnp.pad(t, cfgp, constant_values=fill)

    sp = s + pad
    nc = sp // chunk
    qf = jnp.moveaxis(cpad(q).astype(jnp.float32).reshape(b, nc, chunk, heads, hd), 1, 0)
    kf = jnp.moveaxis(cpad(k).astype(jnp.float32).reshape(b, nc, chunk, heads, hd), 1, 0)
    vf = jnp.moveaxis(cpad(v).astype(jnp.float32).reshape(b, nc, chunk, heads, hd), 1, 0)
    i_c = jnp.moveaxis(cpad(i_pre, -1e9).reshape(b, nc, chunk, heads), 1, 0)
    lf_c = jnp.moveaxis(cpad(logf).reshape(b, nc, chunk, heads), 1, 0)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_fn(carry, inp):
        c_st, n_st, m_st = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        q_k, k_k, v_k, i_k, lf_k = inp
        cumf = jnp.cumsum(lf_k, axis=1)  # (B,L,H) local cumulative log-forget
        # stabilizer per position: max(intra max, cross)
        dmat = cumf[:, :, None, :] - cumf[:, None, :, :] + i_k[:, None, :, :]
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)  # (B,L,L,H)
        m_local = jnp.max(dmat, axis=2)  # (B,L,H)
        m_cross = cumf + m_st[:, None, :]  # (B,L,H)
        m_t = jnp.maximum(m_local, m_cross)
        # intra-chunk weights
        w = jnp.exp(dmat - m_t[:, :, None, :])  # (B,L,L,H)
        scores = jnp.einsum("blhd,bjhd->bljh", q_k, k_k) / (hd**0.5)
        wn = scores * w  # (B,L,L,H)
        num = jnp.einsum("bljh,bjhd->blhd", wn, v_k)
        den = wn.sum(axis=2)  # (B,L,H)
        # cross-chunk contribution
        cross_sc = jnp.exp(m_cross - m_t)  # (B,L,H)
        num = num + cross_sc[..., None] * jnp.einsum(
            "blhd,bhde->blhe", q_k / (hd**0.5), c_st
        )
        den = den + cross_sc * jnp.einsum("blhd,bhd->blh", q_k / (hd**0.5), n_st)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h_k = num / den[..., None]  # (B,L,H,hd)
        # state update to end of chunk
        g_tot = cumf[:, -1, :]  # (B,H)
        decay_j = g_tot[:, None, :] - cumf + i_k  # (B,L,H)
        m_new = jnp.maximum(g_tot + m_st, jnp.max(decay_j, axis=1))
        sc_j = jnp.exp(decay_j - m_new[:, None, :])  # (B,L,H)
        c_new = c_st * jnp.exp(g_tot + m_st - m_new)[:, :, None, None] + jnp.einsum(
            "blh,blhd,blhe->bhde", sc_j, k_k, v_k
        )
        n_new = n_st * jnp.exp(g_tot + m_st - m_new)[:, :, None] + jnp.einsum(
            "blh,blhd->bhd", sc_j, k_k
        )
        return (c_new, n_new, m_new), h_k

    init = (
        jnp.zeros((b, heads, hd, hd), jnp.float32),
        jnp.zeros((b, heads, hd), jnp.float32),
        jnp.full((b, heads), -1e9, jnp.float32),
    )
    # (no chunk-body remat here: measured +3% step bound for xlstm — its
    # bottleneck is the sLSTM time scan, not the mLSTM chunk tensors)
    _, hs = jax.lax.scan(chunk_fn, init, (qf, kf, vf, i_c, lf_c))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, sp, inner)[:, :s].astype(x.dtype)

    h = rms_norm(h, p.norm, cfg.norm_eps)  # per-channel norm (group-norm stand-in)
    h = h * jax.nn.silu(gate)
    return jnp.einsum("bse,ed->bsd", h, p.down_proj)


class MLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, H, hd, hd) matrix memory
    n: jnp.ndarray  # (B, H, hd) normalizer
    m: jnp.ndarray  # (B, H) stabilizer


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    inner, heads, hd = _mlstm_dims(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, heads, hd, hd), jnp.float32),
        n=jnp.zeros((batch, heads, hd), jnp.float32),
        m=jnp.full((batch, heads), -1e9, jnp.float32),
    )


def decode_mlstm(
    p: MLSTMParams, cfg: ModelConfig, x: jnp.ndarray, state: MLSTMState
) -> tuple[jnp.ndarray, MLSTMState]:
    """One-token recurrent mLSTM step.  x (B, 1, d)."""
    b = x.shape[0]
    inner, heads, hd = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, p.up_proj)
    cell_in, gate = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", cell_in, p.wq).reshape(b, heads, hd)
    k = jnp.einsum("bse,ef->bsf", cell_in, p.wk).reshape(b, heads, hd)
    v = jnp.einsum("bse,ef->bsf", cell_in, p.wv).reshape(b, heads, hd)
    gates = jnp.einsum("bse,eg->bsg", cell_in.astype(jnp.float32), p.w_if)[:, 0] + p.b_if
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)  # (B,H)
    logf = jax.nn.log_sigmoid(f_pre)

    m_new = jnp.maximum(logf + state.m, i_pre)
    f_sc = jnp.exp(logf + state.m - m_new)
    i_sc = jnp.exp(i_pre - m_new)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    c_new = state.c * f_sc[..., None, None] + i_sc[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n_new = state.n * f_sc[..., None] + i_sc[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf / (hd**0.5), c_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", qf / (hd**0.5), n_new)), jnp.exp(-m_new)
    )
    h = (num / den[..., None]).reshape(b, 1, inner).astype(x.dtype)
    h = rms_norm(h, p.norm, cfg.norm_eps) * jax.nn.silu(gate)
    out = jnp.einsum("bse,ed->bsd", h, p.down_proj)
    return out, MLSTMState(c=c_new, n=n_new, m=m_new)


# ------------------------------------------------------------------- sLSTM
class SLSTMParams(NamedTuple):
    w_in: jnp.ndarray  # (d, 4*inner) input weights for (i, f, z, o)
    r_in: jnp.ndarray  # (H, 4*hd, hd) block-diagonal recurrent weights
    b: jnp.ndarray  # (4*inner,)
    norm: jnp.ndarray  # (inner,)
    ffn_gate: jnp.ndarray  # (inner, ff)
    ffn_up: jnp.ndarray
    ffn_down: jnp.ndarray  # (ff, d)
    down_proj: jnp.ndarray  # (inner, d) unused (kept for symmetry) — zeros


def _slstm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    inner = cfg.d_model
    heads = cfg.num_heads
    ff = max(int(4 * inner / 3) // 8 * 8, 8)
    return inner, heads, inner // heads, ff


def init_slstm(key: jax.Array, cfg: ModelConfig) -> SLSTMParams:
    d = cfg.d_model
    inner, heads, hd, ff = _slstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return SLSTMParams(
        w_in=dense_init(ks[0], d, 4 * inner, jnp.float32),
        r_in=(jax.random.normal(ks[1], (heads, 4 * hd, hd)) * hd**-0.5).astype(
            jnp.float32
        ),
        b=jnp.concatenate(
            [jnp.zeros((inner,)), 3.0 * jnp.ones((inner,)), jnp.zeros((2 * inner,))]
        ),
        norm=jnp.ones((inner,), cfg.dtype),
        ffn_gate=dense_init(ks[2], inner, ff, cfg.dtype),
        ffn_up=dense_init(ks[3], inner, ff, cfg.dtype),
        ffn_down=dense_init(ks[4], ff, d, cfg.dtype),
        down_proj=jnp.zeros((inner, d), cfg.dtype),
    )


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, inner)
    n: jnp.ndarray  # (B, inner)
    m: jnp.ndarray  # (B, inner)
    h: jnp.ndarray  # (B, inner)


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    inner = cfg.d_model
    z = jnp.zeros((batch, inner), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full_like(z, -1e9), h=z)


def _slstm_cell(
    p: SLSTMParams, cfg: ModelConfig, wx: jnp.ndarray, state: SLSTMState
) -> SLSTMState:
    """One sLSTM time step.  wx (B, 4*inner) precomputed input projection."""
    b = wx.shape[0]
    inner, heads, hd, _ = _slstm_dims(cfg)
    hh = state.h.reshape(b, heads, hd)
    rec = jnp.einsum("bhd,hgd->bhg", hh, p.r_in).reshape(b, 4 * inner)
    pre = wx + rec + p.b
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)  # (B, inner)

    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state.m, i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(logf + state.m - m_new)
    c_new = f_sc * state.c + i_sc * jnp.tanh(z_pre)
    n_new = f_sc * state.n + i_sc
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c=c_new, n=n_new, m=m_new, h=h_new)


def apply_slstm(p: SLSTMParams, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Sequential sLSTM over the sequence + gated FFN.  x (B,S,d)->(B,S,d)."""
    b, s, d = x.shape
    inner, _, _, _ = _slstm_dims(cfg)
    wx = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p.w_in)  # (B,S,4*inner)

    def step(state, wx_t):
        new = _slstm_cell(p, cfg, wx_t, state)
        return new, new.h

    init = init_slstm_state(cfg, b)
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B,S,inner)
    h = rms_norm(h, p.norm, cfg.norm_eps)
    g = jax.nn.silu(jnp.einsum("bse,ef->bsf", h, p.ffn_gate))
    u = jnp.einsum("bse,ef->bsf", h, p.ffn_up)
    return jnp.einsum("bsf,fd->bsd", g * u, p.ffn_down)


def decode_slstm(
    p: SLSTMParams, cfg: ModelConfig, x: jnp.ndarray, state: SLSTMState
) -> tuple[jnp.ndarray, SLSTMState]:
    """One-token sLSTM step.  x (B, 1, d)."""
    wx = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p.w_in)[:, 0]
    new = _slstm_cell(p, cfg, wx, state)
    h = rms_norm(new.h[:, None, :].astype(x.dtype), p.norm, cfg.norm_eps)
    g = jax.nn.silu(jnp.einsum("bse,ef->bsf", h, p.ffn_gate))
    u = jnp.einsum("bse,ef->bsf", h, p.ffn_up)
    return jnp.einsum("bsf,fd->bsd", g * u, p.ffn_down), new
