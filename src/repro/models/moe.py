"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

TPU-native dispatch (MaxText/GSPMD style): tokens are routed to experts via
one-hot dispatch/combine einsums with a static per-expert capacity — no
ragged gathers, and the expert dimension shards cleanly over the ``model``
mesh axis (expert parallelism).  Covers both assigned MoE archs:

* qwen2-moe-a2.7b — 60 routed experts top-4 + 4 *shared* experts always on
  [hf:Qwen/Qwen1.5-MoE-A2.7B],
* arctic-480b — 128 routed experts top-2 + a parallel *dense residual* FFN
  [hf:Snowflake/snowflake-arctic-base] (the dense branch lives in
  transformer.py; this module provides the routed+shared paths).

A switch-style load-balance auxiliary loss is returned for training.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init


class MoEParams(NamedTuple):
    router: jnp.ndarray  # (d, E)
    w_gate: jnp.ndarray  # (E, d, ff)
    w_up: jnp.ndarray  # (E, d, ff)
    w_down: jnp.ndarray  # (E, ff, d)
    shared_gate: jnp.ndarray  # (Se*ff_or_1, ...) shared experts fused as one SwiGLU
    shared_up: jnp.ndarray
    shared_down: jnp.ndarray


def init_moe(key: jax.Array, cfg: ModelConfig) -> MoEParams:
    d, ff = cfg.d_model, cfg.moe_d_ff
    e = cfg.moe_pad_experts or cfg.num_experts
    ks = jax.random.split(key, 7)
    shared_ff = max(cfg.num_shared_experts * ff, 1)
    return MoEParams(
        router=dense_init(ks[0], d, e, jnp.float32),
        w_gate=jax.vmap(lambda k: dense_init(k, d, ff, cfg.dtype))(
            jax.random.split(ks[1], e)
        ),
        w_up=jax.vmap(lambda k: dense_init(k, d, ff, cfg.dtype))(
            jax.random.split(ks[2], e)
        ),
        w_down=jax.vmap(lambda k: dense_init(k, ff, d, cfg.dtype))(
            jax.random.split(ks[3], e)
        ),
        shared_gate=dense_init(ks[4], d, shared_ff, cfg.dtype),
        shared_up=dense_init(ks[5], d, shared_ff, cfg.dtype),
        shared_down=dense_init(ks[6], shared_ff, d, cfg.dtype),
    )


def moe_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    """Static per-(group, expert) capacity, MXU-aligned (multiple of 8)."""
    cap = int(
        tokens_per_group
        * cfg.num_experts_per_tok
        * cfg.capacity_factor
        / cfg.num_experts
    )
    return max(8, (cap + 7) // 8 * 8)


def apply_moe(
    p: MoEParams, cfg: ModelConfig, x: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss ()).

    Batch-grouped capacity dispatch (GSPMD/MaxText style): each batch row is
    a routing group with static capacity C = S*k*cf/E, so the dispatch
    tensor is (B, S, E, C) — sharded over ``data`` on B and ``model`` on E it
    never materializes at global size.  Tokens overflowing an expert's
    capacity within their group are dropped for that expert (standard switch
    behaviour); shared experts always run.

    §Perf knobs: ``cfg.moe_group_size`` subdivides the sequence into smaller
    routing groups (dispatch-einsum FLOPs scale linearly with group size);
    ``cfg.moe_shard_dispatch`` pins GSPMD shardings on the dispatch path so
    the (groups, G, E, C) tensors never get replicated/all-reduced.
    """
    b_in, s_in, d = x.shape
    g_sz = cfg.moe_group_size
    regrouped = bool(g_sz) and g_sz < s_in and s_in % g_sz == 0
    if regrouped:
        # (B, S, d) -> (B * S/g, g, d): more, smaller routing groups
        x = x.reshape(b_in * (s_in // g_sz), g_sz, d)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = moe_capacity(s, cfg)

    e_eff = cfg.moe_pad_experts or e
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p.router)
    if e_eff > e:
        # padded experts never win the top-k (exact; see config note)
        pad_mask = jnp.arange(e_eff) >= e
        logits = jnp.where(pad_mask, -1e30, logits)
    e = e_eff
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B, S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renormalize

    # Load-balance aux loss (Switch Transformer): E * sum_e f_e * P_e
    occupancy = jax.nn.one_hot(top_e, e).sum(2)  # (B, S, E)
    f = occupancy.mean((0, 1))
    aux = cfg.router_aux_coef * e * jnp.sum(f * probs.mean((0, 1)))

    # Arrival order of each (token, choice) within its (group, expert).
    choice_oh = jax.nn.one_hot(top_e, e, dtype=jnp.int32)  # (B, S, k, E)
    flat_oh = choice_oh.reshape(b, s * k, e)
    pos_in_expert = (jnp.cumsum(flat_oh, axis=1) - flat_oh).reshape(b, s, k, e)
    pos_of_choice = (pos_in_expert * choice_oh).sum(-1)  # (B, S, k)
    keep = pos_of_choice < cap

    # dispatch/combine (B, S, E, C)
    slot_oh = jax.nn.one_hot(
        jnp.where(keep, pos_of_choice, cap), cap + 1, dtype=x.dtype
    )[..., :cap]  # (B, S, k, C); overflow row is all-zero
    dispatch = jnp.einsum("bske,bskc->bsec", choice_oh.astype(x.dtype), slot_oh)
    combine = jnp.einsum(
        "bske,bskc,bsk->bsec",
        choice_oh.astype(jnp.float32),
        slot_oh.astype(jnp.float32),
        top_p.astype(jnp.float32),
    ).astype(x.dtype)

    if cfg.moe_shard_dispatch:
        # pin the dispatch path: groups over data, experts over model —
        # prevents GSPMD from replicating the (B,S,E,C) tensors and
        # all-reducing expert batches (§Perf, arctic collective fix)
        from jax.sharding import PartitionSpec as _P

        dispatch = jax.lax.with_sharding_constraint(
            dispatch, _P("data", None, "model", None)
        )
        combine = jax.lax.with_sharding_constraint(
            combine, _P("data", None, "model", None)
        )

    # expert batches per group: (B, E, C, d)
    xe = jnp.einsum("bsec,bsd->becd", dispatch, x)
    if cfg.moe_shard_dispatch:
        from jax.sharding import PartitionSpec as _P

        xe = jax.lax.with_sharding_constraint(xe, _P("data", "model", None, None))
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p.w_gate))
    u = jnp.einsum("becd,edf->becf", xe, p.w_up)
    ye = jnp.einsum("becf,efd->becd", g * u, p.w_down)  # (B, E, C, d)
    out = jnp.einsum("bsec,becd->bsd", combine, ye)

    if cfg.num_shared_experts:
        sg = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p.shared_gate))
        su = jnp.einsum("bsd,df->bsf", x, p.shared_up)
        out = out + jnp.einsum("bsf,fd->bsd", sg * su, p.shared_down)

    if regrouped:
        out = out.reshape(b_in, s_in, d)
    return out, aux
