"""Mamba2 (SSD) block — chunked, matmul-dominant TPU formulation.

The Mamba2 "state-space duality" recurrence per head (state size N, head dim
P):

    h_t = a_t * h_{t-1} + dt_t * B_t x_t^T      (h in R^{N x P})
    y_t = C_t h_t + D * x_t

with scalar-per-head decay ``a_t = exp(dt_t * A)`` (A < 0 learned).  A naive
time scan is VPU-bound; the SSD insight (Dao & Gu 2024) is to compute it in
chunks: within a chunk the output is an attention-like masked matmul
(MXU-friendly); chunk-to-chunk states are passed by a short ``lax.scan`` over
S/chunk steps.  This is the GPU algorithm's *structural* adaptation to the
TPU: all heavy math becomes (chunk x chunk) / (chunk x N x P) einsums that
map onto the MXU, and the sequential scan shrinks by the chunk factor.

Decode path: one recurrence step on a carried (N x P) state — O(1) in
sequence length, which is why the hybrid/ssm archs run ``long_500k``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm


class MambaParams(NamedTuple):
    in_proj: jnp.ndarray  # (d, 2*inner)  -> (x, z)
    bc_proj: jnp.ndarray  # (d, 2*N*H? ) see init: (d, 2*n_state*n_groups=2*N)
    dt_proj: jnp.ndarray  # (d, H)
    dt_bias: jnp.ndarray  # (H,)
    a_log: jnp.ndarray  # (H,) log(-A)
    d_skip: jnp.ndarray  # (H,)
    conv_w: jnp.ndarray  # (4, inner) depthwise causal conv kernel
    out_proj: jnp.ndarray  # (inner, d)
    norm: jnp.ndarray  # (inner,) gated RMSNorm scale


def mamba_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    """(inner, num_heads, state) for the mamba block."""
    inner = cfg.ssm_expand * cfg.d_model
    heads = inner // cfg.ssm_head_dim
    return inner, heads, cfg.ssm_state


def init_mamba(key: jax.Array, cfg: ModelConfig) -> MambaParams:
    d = cfg.d_model
    inner, heads, n = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    return MambaParams(
        in_proj=dense_init(ks[0], d, 2 * inner, cfg.dtype),
        bc_proj=dense_init(ks[1], d, 2 * n, cfg.dtype),
        dt_proj=dense_init(ks[2], d, heads, cfg.dtype),
        dt_bias=jnp.zeros((heads,), jnp.float32),
        a_log=jnp.zeros((heads,), jnp.float32),  # A = -exp(a_log) = -1
        d_skip=jnp.ones((heads,), jnp.float32),
        conv_w=(jax.random.normal(ks[4], (4, inner)) * 0.1).astype(cfg.dtype),
        out_proj=dense_init(ks[5], inner, d, cfg.dtype),
        norm=jnp.ones((inner,), cfg.dtype),
    )


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, kernel size K: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out)


def apply_mamba(
    p: MambaParams, cfg: ModelConfig, u: jnp.ndarray
) -> jnp.ndarray:
    """Full-sequence Mamba2 SSD.  u (B, S, d) -> (B, S, d)."""
    b, s, d = u.shape
    inner, heads, n = mamba_dims(cfg)
    hd = cfg.ssm_head_dim
    chunk = min(cfg.ssm_chunk, s)
    # pad sequence to a chunk multiple
    pad = (-s) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    sp = u.shape[1]
    nc = sp // chunk

    xz = jnp.einsum("bsd,de->bse", u, p.in_proj)
    x, z = jnp.split(xz, 2, axis=-1)  # (B, Sp, inner)
    x = _causal_conv(x, p.conv_w)
    bc = jnp.einsum("bsd,de->bse", u, p.bc_proj).astype(jnp.float32)
    bmat, cmat = jnp.split(bc, 2, axis=-1)  # (B, Sp, N)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, p.dt_proj).astype(jnp.float32) + p.dt_bias
    )  # (B, Sp, H)
    a = -jnp.exp(p.a_log)  # (H,)
    log_decay = dt * a  # (B, Sp, H)  = log a_t

    xh = x.reshape(b, sp, heads, hd).astype(jnp.float32)  # (B,Sp,H,P)

    # ---- chunked SSD: one lax.scan over chunks, carrying the (B,H,N,P)
    # state.  Only ONE chunk's attention-like (B,L,L,H) tensor is live at a
    # time (the all-chunks formulation would materialize (B,NC,L,L,H)).
    xc = jnp.moveaxis(xh.reshape(b, nc, chunk, heads, hd), 1, 0)  # (NC,B,L,H,P)
    bc_ = jnp.moveaxis(bmat.reshape(b, nc, chunk, n), 1, 0)  # (NC,B,L,N)
    cc_ = jnp.moveaxis(cmat.reshape(b, nc, chunk, n), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, nc, chunk, heads), 1, 0)  # (NC,B,L,H)
    ldc = jnp.moveaxis(log_decay.reshape(b, nc, chunk, heads), 1, 0)

    def chunk_fn(h_prev, inp):
        # one SSD chunk — the compute hot spot; routed through the Pallas
        # kernel wrapper (TPU: compiled kernel; CPU: jnp oracle).  Math:
        # y[t] = sum_{j<=t} (C_t.B_j) dt_j exp(cum_t - cum_j) x_j
        #        + C_t exp(cum_t) h_prev
        # h'   = exp(cum_L) h_prev + sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
        x_k, b_k, c_k, dt_k, ld_k = inp
        y, h_new = kernel_ops.ssd_chunk(x_k, b_k, c_k, dt_k, ld_k, h_prev)
        return h_new, y

    h0 = jnp.zeros((b, heads, n, hd), jnp.float32)
    # remat the chunk body: backward recomputes the (B,L,L,H) intra-chunk
    # tensors from the chunk inputs instead of autodiff stacking them for
    # every chunk (the SSD analogue of flash attention's residual scheme)
    _, y_chunks = jax.lax.scan(jax.checkpoint(chunk_fn), h0, (xc, bc_, cc_, dtc, ldc))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(b, sp, heads, hd)
    y = y + xh * p.d_skip[None, None, :, None]
    y = y.reshape(b, sp, inner).astype(u.dtype)
    # gated RMSNorm (Mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), p.norm, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p.out_proj)
    return out[:, :s]


class MambaState(NamedTuple):
    h: jnp.ndarray  # (B, H, N, P) SSM state
    conv: jnp.ndarray  # (B, K-1, inner) conv tail buffer


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaState:
    inner, heads, n = mamba_dims(cfg)
    return MambaState(
        h=jnp.zeros((batch, heads, n, cfg.ssm_head_dim), jnp.float32),
        conv=jnp.zeros((batch, 3, inner), dtype),
    )


def decode_mamba(
    p: MambaParams, cfg: ModelConfig, u: jnp.ndarray, state: MambaState
) -> tuple[jnp.ndarray, MambaState]:
    """One-token decode.  u (B, 1, d) -> (B, 1, d); O(1) in sequence length."""
    b = u.shape[0]
    inner, heads, n = mamba_dims(cfg)
    hd = cfg.ssm_head_dim

    xz = jnp.einsum("bsd,de->bse", u, p.in_proj)
    x, z = jnp.split(xz, 2, axis=-1)  # (B,1,inner)
    # conv over the (K-1)-token tail buffer + current token
    window = jnp.concatenate([state.conv, x], axis=1)  # (B, K, inner)
    xconv = jax.nn.silu((window * p.conv_w[None]).sum(axis=1, keepdims=True))
    new_conv = window[:, 1:]

    bc = jnp.einsum("bsd,de->bse", u, p.bc_proj).astype(jnp.float32)
    bvec, cvec = jnp.split(bc[:, 0], 2, axis=-1)  # (B, N)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, p.dt_proj).astype(jnp.float32)[:, 0] + p.dt_bias
    )  # (B, H)
    a = -jnp.exp(p.a_log)
    decay = jnp.exp(dt * a)  # (B, H)

    xh = xconv.reshape(b, heads, hd).astype(jnp.float32)  # (B,H,P)
    update = jnp.einsum("bn,bh,bhp->bhnp", bvec, dt, xh)
    h_new = state.h * decay[:, :, None, None] + update
    y = jnp.einsum("bn,bhnp->bhp", cvec, h_new)  # (B,H,P)
    y = y + xh * p.d_skip[None, :, None]
    y = y.reshape(b, 1, inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p.norm, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p.out_proj)
    return out, MambaState(h=h_new, conv=new_conv)
