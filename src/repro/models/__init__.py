"""Model zoo: the ten assigned architectures as composable pure-JAX modules.

No flax — params are pytrees; every block is an ``init_*`` + ``apply``
function pair.  Stacks use ``lax.scan`` over stacked layer params so HLO size
is depth-independent (essential for the 512-device dry-run compiles).
"""
from repro.models.config import ModelConfig
from repro.models.transformer import (
    init_lm,
    forward_lm,
    decode_lm,
    init_decode_state,
    lm_loss,
)

__all__ = [
    "ModelConfig",
    "init_lm",
    "forward_lm",
    "decode_lm",
    "init_decode_state",
    "lm_loss",
]
