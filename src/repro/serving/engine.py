"""Continuous-batching serve engine (slot-based, single jitted step).

A fixed batch of ``max_batch`` slots steps together through the jitted
``serve_step``; per-slot host-side bookkeeping decides what each slot feeds:

* **prefill phase** — the slot's next prompt token (logits discarded),
* **decode phase**  — its previously sampled token,
* **free**          — a pad token (output ignored).

Slots are independent rows of the decode state (KV caches / SSM states are
per-batch-row), so batching never changes any request's output — asserted by
tests/test_serving.py against solo runs.  New requests join as slots free up
(continuous batching), with no recompilation: shapes are static.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import init_decode_state
from repro.train.steps import make_serve_step


@dataclasses.dataclass
class Request:
    uid: str
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos_in_prompt: int = 0
    generated: list = dataclasses.field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.req is None


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 4,
        cache_len: int = 256,
        greedy: bool = True,
        seed: int = 0,
        encoder_embeds=None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.greedy = greedy
        self._step = jax.jit(make_serve_step(cfg))
        self.state = init_decode_state(
            params, cfg, max_batch, cache_len, encoder_embeds=encoder_embeds
        )
        self.slots = [_Slot() for _ in range(max_batch)]
        self.queue: deque[Request] = deque()
        self.done: dict[str, list[int]] = {}
        self._next_token = jnp.zeros((max_batch, 1), jnp.int32)
        self._key = jax.random.PRNGKey(seed)

    # ------------------------------------------------------------- plumbing
    def submit(self, req: Request) -> None:
        assert len(req.prompt) >= 1
        assert len(req.prompt) + req.max_new_tokens <= self.cache_len
        self.queue.append(req)

    def _reset_slot_state(self, b: int) -> None:
        """Zero slot b's row of every per-batch state array + its position."""

        def zero_row(a):
            if hasattr(a, "ndim") and a.ndim >= 2 and a.shape[1] == self.max_batch:
                return a.at[:, b].set(0)
            return a

        # stacked caches/states have layout (L, B, ...); pos is (B,)
        self.state = jax.tree.map(zero_row, self.state)
        self.state = self.state._replace(pos=self.state.pos.at[b].set(0))

    def _admit(self) -> None:
        for b, slot in enumerate(self.slots):
            if slot.free and self.queue:
                req = self.queue.popleft()
                self.slots[b] = _Slot(req=req, pos_in_prompt=0)
                self._reset_slot_state(b)
                self._next_token = self._next_token.at[b, 0].set(req.prompt[0])

    # ----------------------------------------------------------------- step
    def step(self) -> None:
        """One engine tick: admit, run the jitted step, route per-slot."""
        self._admit()
        logits, self.state = self._step(self.params, self._next_token, self.state)
        if self.greedy:
            sampled = jnp.argmax(logits, axis=-1)
        else:
            self._key, sub = jax.random.split(self._key)
            sampled = jax.random.categorical(sub, logits, axis=-1)
        sampled = jax.device_get(sampled)

        for b, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.req
            if slot.pos_in_prompt < len(req.prompt) - 1:
                # still prefilling: feed the next prompt token
                slot.pos_in_prompt += 1
                self._next_token = self._next_token.at[b, 0].set(
                    req.prompt[slot.pos_in_prompt]
                )
                continue
            tok = int(sampled[b])
            slot.generated.append(tok)
            finished = len(slot.generated) >= req.max_new_tokens or (
                req.eos_id is not None and tok == req.eos_id
            )
            if finished:
                self.done[req.uid] = slot.generated
                self.slots[b] = _Slot()
            else:
                self._next_token = self._next_token.at[b, 0].set(tok)

    def run(self, requests: list[Request], max_ticks: int = 10_000) -> dict:
        """Serve all requests to completion; returns {uid: generated tokens}."""
        for r in requests:
            self.submit(r)
        ticks = 0
        while (self.queue or any(not s.free for s in self.slots)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return dict(self.done)
