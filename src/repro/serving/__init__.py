"""Serving substrate: slot-based continuous batching over serve_step."""
from repro.serving.engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
