"""Sharding rules: pytree-path-pattern -> PartitionSpec.

Strategy table (DESIGN.md §6):

* ``tp``   — tensor parallel: weights shard over ``model`` (heads / ffn /
  vocab / experts); replicated over ``data``/``pod``; batch over
  ``(pod, data)``.  Default for the small/medium archs.
* ``fsdp`` — ``tp`` plus parameter/optimizer sharding over ``data`` on a
  second weight axis (ZeRO-3 style; GSPMD inserts per-layer all-gathers).
  Required for qwen2-72b / arctic-480b: TP-only Adam state alone would be
  36 GB/chip, 2.3x over a v5e's 16 GB.

Every rule degrades gracefully: an axis is only used if it divides the dim;
otherwise that dim stays replicated (small archs like whisper-base simply
replicate most weights — correct, and cheap at their size).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# archs whose parameter+optimizer footprint requires ZeRO/FSDP sharding
FSDP_ARCHS = ("qwen2-72b", "arctic-480b")


def current_abstract_mesh():
    """``jax.sharding.get_abstract_mesh()`` on jax >= 0.5, ``None`` earlier.

    On jax <= 0.4.x there is no abstract-mesh context API; the in-graph
    sharding anchors that consult this are optimizations and degrade to
    no-ops there (every caller already handles the no-mesh case).
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    return get()


# small archs that run best fully sequence-parallel / replicated-trunk (§Perf)
SP_ARCHS = ("gemma3-1b", "whisper-base")


def strategy_for(cfg: ModelConfig, kind: str | None = None) -> str:
    if cfg.name in FSDP_ARCHS:
        # serving has no optimizer state: if the bf16 weights fit TP-resident
        # (<= ~12 GB/chip), decode avoids FSDP's per-token weight re-gathers
        # (measured: 9.9x on qwen2-72b decode_32k)
        if kind == "decode" and cfg.param_count() * 2 / 16 <= 12e9:
            return "tp"
        return "fsdp"
    if cfg.name in SP_ARCHS:
        return "sp"
    return "tp"


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape: tuple[int, ...], spec: list) -> P:
    """Drop axes that don't divide their dim (graceful degradation)."""
    out = []
    for dim, axis in zip(shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            axis = None
        out.append(axis)
    return P(*out)


# (regex on keystr path, ndim) -> axis template, aligned to the LAST ndim dims.
# "M" = model axis, "D" = fsdp data axis (dropped under plain tp).
# Templates are for the UNSTACKED layer shapes; stacked (leading L) dims get
# None prepended automatically by alignment-to-last.
_RULES: list[tuple[str, dict[int, list]]] = [
    # embeddings: vocab over model (composes with FedS row sparsification)
    (r"\['embed'\]$", {2: ["M", "D"]}),
    (r"\['unembed'\]$", {2: ["D", "M"]}),
    (r"\['enc_pos'\]$", {2: [None, None]}),
    # attention projections
    (r"\.wq$|\.wk$|\.wv$", {2: ["D", "M"], 3: [None, "D", "M"]}),
    (r"\.wo$", {2: ["M", "D"], 3: [None, "M", "D"]}),
    (r"\.bq$|\.bk$|\.bv$", {1: ["M"], 2: [None, "M"]}),
    (r"\.q_norm$|\.k_norm$", {1: [None], 2: [None, None]}),
    # dense MLP
    (r"\.w_gate$|\.w_up$", {2: ["D", "M"], 3: [None, "D", "M"], 4: [None, "M", "D", None]}),
    (r"\.w_down$", {2: ["M", "D"], 3: [None, "M", "D"], 4: [None, "M", None, "D"]}),
    # MoE: experts over model (expert parallelism), d over fsdp axis
    (r"\.router$", {2: [None, "M"], 3: [None, None, "M"]}),
    (r"\.shared_gate$|\.shared_up$", {2: ["D", "M"], 3: [None, "D", "M"]}),
    (r"\.shared_down$", {2: ["M", "D"], 3: [None, "M", "D"]}),
    # Mamba: inner dim (heads) over model
    (r"\.in_proj$|\.bc_proj$|\.dt_proj$", {2: ["D", "M"], 3: [None, "D", "M"]}),
    (r"\.out_proj$|\.down_proj$", {2: ["M", "D"], 3: [None, "M", "D"]}),
    (r"\.dt_bias$|\.a_log$|\.d_skip$", {1: ["M"], 2: [None, "M"]}),
    (r"\.conv_w$", {2: [None, "M"], 3: [None, None, "M"]}),
    # xLSTM
    (r"\.up_proj$|\.w_in$", {2: ["D", "M"], 3: [None, "D", "M"]}),
    (r"\.w_if$", {2: [None, "M"], 3: [None, None, "M"]}),
    (r"\.r_in$", {3: ["M", None, None], 4: [None, "M", None, None]}),
    (r"\.ffn_gate$|\.ffn_up$", {2: ["D", "M"], 3: [None, "D", "M"]}),
    (r"\.ffn_down$", {2: ["M", "D"], 3: [None, "M", "D"]}),
    # norms & everything defaulting to replication handled by fallback
]


def _spec_for_path(path_str: str, shape: tuple[int, ...], strategy: str):
    if strategy == "sp":
        # sequence-parallel small-model mode: trunk weights replicated (the
        # model axis carries the sequence via shard_heads="context"); only
        # the big vocab tables stay model-sharded.
        if re.search(r"\['embed'\]$|\['unembed'\]$", path_str):
            pass  # fall through to the embed rules below
        else:
            return [None] * len(shape)
    for pat, by_ndim in _RULES:
        if re.search(pat, path_str):
            tmpl = by_ndim.get(len(shape))
            if tmpl is None:
                # align template to the LAST dims (stacked leading axes -> None)
                base = by_ndim[max(by_ndim)]
                tmpl = [None] * (len(shape) - len(base)) + list(base[-len(shape):])
            out = []
            for ax in tmpl:
                if ax == "M":
                    out.append("model")
                elif ax == "D":
                    out.append("data" if strategy == "fsdp" else None)
                else:
                    out.append(None)
            return out
    return [None] * len(shape)  # replicate (norms, scalars, small leftovers)


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh, strategy: str | None = None):
    """Pytree of PartitionSpec matching ``params`` (works on ShapeDtypeStructs)."""
    strategy = strategy or strategy_for(cfg)
    m_size = mesh.shape["model"]
    # When the head counts don't divide the model axis, sharding the
    # flattened (heads*hd) projection dim splits individual heads across
    # devices and GSPMD partial-sums the attention scores (measured: a 34 TB
    # all-reduce per arctic prefill step).  Replicate those projections
    # instead — their matmuls are tiny next to the FFN/expert paths.
    kv_ok = cfg.num_kv_heads % m_size == 0  # conservative: whole heads only
    q_ok = cfg.effective_heads % m_size == 0
    if cfg.shard_heads == "split":  # legacy hd-splitting (see config note)
        kv_ok = q_ok = True

    attn_paths = r"\['(attn|self_attn|cross_attn|shared_attn)'\]"

    def one(path, leaf):
        path_str = jax.tree_util.keystr(path)
        spec = _spec_for_path(path_str, leaf.shape, strategy)
        # scope the head-divisibility overrides to REAL attention blocks —
        # xlstm's mLSTM also has wq/wk/wv leaves, but those are full
        # (inner, inner) projections with no per-head sharding hazard
        is_attn = re.search(attn_paths, path_str) is not None
        if is_attn and not kv_ok and re.search(r"\.wk$|\.wv$|\.bk$|\.bv$", path_str):
            spec = [a if a != "model" else None for a in spec]
        if is_attn and not q_ok and re.search(r"\.wq$|\.bq$", path_str):
            spec = [a if a != "model" else None for a in spec]
        if is_attn and not q_ok and re.search(r"\.wo$", path_str):
            # wo contracts over the head dim; sharding it would partial-sum
            # with fractional heads — replicate the head dim instead
            spec = [a if a != "model" else None for a in spec]
        return _fit(mesh, leaf.shape, spec)

    return jax.tree_util.tree_map_with_path(one, params)


# ------------------------------------------------------------------- inputs
def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    dp = _dp_axes(mesh)
    if global_batch % _axis_size(mesh, dp) == 0:
        return P(dp)
    return P(None)


def input_specs_sharding(
    specs: dict[str, jax.ShapeDtypeStruct], cfg: ModelConfig, mesh: Mesh
) -> dict[str, P]:
    """PartitionSpec per model input: batch over (pod, data), rest replicated."""
    out = {}
    for name, s in specs.items():
        bspec = batch_spec(mesh, s.shape[0])
        out[name] = _fit(mesh, s.shape, [bspec[0] if bspec != P(None) else None]
                         + [None] * (len(s.shape) - 1))
    return out


def decode_state_specs(state: Any, cfg: ModelConfig, mesh: Mesh):
    """Sharding for DecodeState: batch over (pod,data) when divisible, else
    the cache sequence dim over (pod,data); kv-heads over model when they
    divide, else head_dim, else replicated."""
    dp = _dp_axes(mesh)
    dp_size = _axis_size(mesh, dp)
    m_size = mesh.shape["model"]

    def one(path, leaf):
        shape = leaf.shape
        path_str = jax.tree_util.keystr(path)
        if leaf.ndim == 0:
            return P()
        if ".pos" in path_str or leaf.ndim == 1:
            return P(None)
        if re.search(r"\.(k_cache|v_cache|shared_k|shared_v|cross_k|cross_v)", path_str):
            # (L_or_A, B, S, KV, hd).  Axis priority: batch -> kv heads ->
            # SEQUENCE -> head_dim.  Sequence-sharding beats hd-sharding for
            # decode: a hd-sharded cache makes the score contraction partial
            # and GSPMD all-gathers the whole cache every token (measured:
            # 86 GB/token on qwen2-72b); seq-sharding only psums the tiny
            # per-row softmax stats and (B,KV,G,1,hd) outputs.
            l_, b, s, kv, hd = shape
            spec = [None, None, None, None, None]
            if b % dp_size == 0:
                spec[1] = dp
            elif s % dp_size == 0:
                spec[2] = dp
            if kv % m_size == 0:
                spec[3] = "model"
            elif spec[2] is None and s % m_size == 0:
                spec[2] = "model"
            elif spec[2] == dp and s % (dp_size * m_size) == 0:
                spec[2] = dp + ("model",)
            elif hd % m_size == 0:
                spec[4] = "model"
            return P(*spec)
        # SSM / xLSTM states: (L, B, ...) — batch over dp, heads over model
        spec = [None] * leaf.ndim
        if shape[1] % dp_size == 0:
            spec[1] = dp
        if leaf.ndim >= 3 and shape[2] % m_size == 0:
            spec[2] = "model"
        return _fit(mesh, shape, spec)

    return jax.tree_util.tree_map_with_path(one, state)


# -------------------------------------------------------- in-graph anchors
def constrain_batch(x):
    """Anchor the leading (batch) dim to the (pod, data) axes inside jit.

    GSPMD can lose the batch sharding through the vocab-sharded embedding
    gather (measured: arctic/qwen2-72b prefill ran fully data-replicated —
    16x redundant memory and compute).  No-op outside a mesh context or when
    the batch doesn't divide.
    """
    mesh = current_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return x
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if size <= 1 or x.shape[0] % size != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(axes, *([None] * (x.ndim - 1)))
    )
