"""Sharding substrate: NamedSharding rule tables for params, inputs, states."""
from repro.sharding.specs import (
    batch_spec,
    decode_state_specs,
    input_specs_sharding,
    param_specs,
    strategy_for,
)

__all__ = [
    "param_specs",
    "batch_spec",
    "input_specs_sharding",
    "decode_state_specs",
    "strategy_for",
]
