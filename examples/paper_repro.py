"""Paper reproduction driver: runs the FedS vs FedEP vs FedEPL comparison
(Tables II-IV) on the synthetic FB15k-237-R3 stand-in and prints a combined
report with the paper's qualitative claims checked.

  PYTHONPATH=src REPRO_BENCH_FAST=1 python examples/paper_repro.py   # quick
  PYTHONPATH=src python examples/paper_repro.py                      # full
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import table2_accuracy, table3_comm, table4_fedepl


def main():
    claims = []
    rows2 = table2_accuracy.run(methods=("transe",), client_counts=(3,))
    claims += table2_accuracy.check_claims(rows2)
    rows3 = table3_comm.run(methods=("transe",), client_counts=(3,))
    claims += table3_comm.check_claims(rows3)
    rows4 = table4_fedepl.run(methods=("transe",), client_counts=(3,))
    claims += table4_fedepl.check_claims(rows4)

    print("\n== claim check ==")
    for c in claims:
        print(" ", c)


if __name__ == "__main__":
    main()
