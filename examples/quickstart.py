"""Quickstart: FedS vs FedEP on a 3-client federated KG, in ~1 minute on CPU.

Shows the paper's headline result end-to-end: Entity-Wise Top-K
Sparsification reaches the same accuracy while transmitting roughly half the
parameters of full-exchange FedE(P).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.sync import comm_ratio_worst_case
from repro.data import generate_kg, partition_by_relation
from repro.federated.simulation import FederatedConfig, run_federated


def main():
    kg = generate_kg(num_entities=300, num_relations=18, num_triples=3000, seed=7)
    clients = partition_by_relation(kg, 3, seed=0)
    print(f"synthetic KG: {kg.num_triples} triples / {kg.num_entities} entities "
          f"-> 3 clients (relation-partitioned, like FB15k-237-R3)")

    results = {}
    for protocol in ("fedep", "feds"):
        cfg = FederatedConfig(
            method="transe", protocol=protocol, dim=32, rounds=20,
            local_epochs=3, batch_size=128, num_negatives=32, lr=1e-2,
            sparsity_p=0.4, sync_interval=4, eval_every=5, patience=3,
            max_eval_triples=100, seed=0,
        )
        res = run_federated(clients, kg.num_entities, cfg, verbose=True)
        results[protocol] = res
        print(f"[{protocol}] test MRR {res.test_mrr_cg:.4f}  "
              f"Hits@10 {res.test_hits10_cg:.4f}  "
              f"params transmitted {res.ledger.params_transmitted:.3e}\n")

    ratio = (results["feds"].ledger.params_transmitted
             / results["fedep"].ledger.params_transmitted)
    print(f"FedS transmitted {100 * ratio:.1f}% of FedEP's parameters "
          f"(Eq. 5 worst-case bound: "
          f"{100 * comm_ratio_worst_case(0.4, 4, 32):.1f}%)")
    print(f"FedS MRR = {100 * results['feds'].test_mrr_cg / max(results['fedep'].test_mrr_cg, 1e-9):.1f}% of FedEP's")


if __name__ == "__main__":
    main()
