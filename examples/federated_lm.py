"""End-to-end driver: federated LM training with FedS sparse embedding sync.

Demonstrates the paper's technique as a first-class feature of the LM
framework (DESIGN.md §4): four federated "silos" (shards of the ``data``
mesh axis) train a small qwen3-family LM on disjoint token streams; every
round their *embedding tables* synchronize with the TPU-native FedS
collective (entity-wise Top-K over vocab rows) instead of a dense
all-reduce, while the transformer trunk synchronizes densely.

Run (CPU, ~2-4 minutes; 4 fake devices are confined to this process):

  python examples/federated_lm.py --rounds 8 --steps-per-round 10
  python examples/federated_lm.py --model-scale 100m --rounds 200   # paper-scale

The default model is ~6M params so the example completes on one CPU core;
``--model-scale 100m`` selects a ~100M-param config with the same code path.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.core.distributed import make_sharded_feds_round
from repro.core.sparsify import sparsity_k
from repro.models.transformer import init_lm
from repro.train.optimizer import adam_init, adam_update
from repro.train.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--steps-per-round", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sparsity", type=float, default=0.4)
    ap.add_argument("--sync-interval", type=int, default=4)
    ap.add_argument("--model-scale", default="6m", choices=["6m", "100m"])
    args = ap.parse_args()

    n_clients = 4
    mesh = jax.make_mesh((n_clients,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    cfg = get_smoke_config("qwen3-0.6b")
    if args.model_scale == "100m":
        cfg = dataclasses.replace(cfg, num_layers=8, d_model=768, num_heads=12,
                                  num_kv_heads=4, d_ff=2048, vocab_size=32768)
    cfg = dataclasses.replace(cfg, vocab_size=max(cfg.vocab_size, 512))
    print(f"model: {cfg.name}-fed {cfg.param_count()/1e6:.1f}M params, "
          f"{n_clients} federated clients")

    # per-client params: same trunk init, embedding tables drift locally
    params = init_lm(jax.random.PRNGKey(0), cfg)
    params_c = jax.tree.map(lambda a: jnp.stack([a] * n_clients), params)
    opt_c = jax.tree.map(lambda a: jnp.stack([a] * n_clients),
                         adam_init(params))

    # disjoint synthetic token streams (different vocab regions per client =
    # heterogeneity, the regime FedS is designed for)
    rng = np.random.default_rng(0)
    v4 = cfg.vocab_size // 4

    def batch_for(round_i, step_i):
        toks = np.stack([
            rng.integers(c * v4 // 2, cfg.vocab_size - (3 - c) * v4 // 2,
                         size=(args.batch, args.seq))
            for c in range(n_clients)
        ]).astype(np.int32)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}

    train_step = make_train_step(cfg, lr=3e-3)
    vstep = jax.jit(jax.vmap(train_step))  # one client per data shard

    k = sparsity_k(cfg.vocab_size, args.sparsity)
    feds_round = make_sharded_feds_round(mesh, k=k,
                                         sync_interval=args.sync_interval)
    history = params_c["embed"].astype(jnp.float32)

    shard = NamedSharding(mesh, P("data"))
    params_c = jax.device_put(params_c, jax.tree.map(lambda _: shard, params_c))

    t0 = time.time()
    for r in range(args.rounds):
        losses = None
        for s in range(args.steps_per_round):
            params_c, opt_c, losses = vstep(params_c, opt_c, batch_for(r, s))
        # serialize phases: on the 1-core host backend, overlapping per-device
        # dispatch can starve a collective rendezvous (4 device threads, 1 core)
        params_c = jax.block_until_ready(params_c)
        # --- FedS sparse embedding synchronization (one all-gather) ---
        emb, history = feds_round(
            params_c["embed"].astype(jnp.float32), history,
            jnp.asarray([r], jnp.int32),
        )
        params_c["embed"] = emb.astype(cfg.dtype)
        params_c = jax.block_until_ready(params_c)
        # trunk: standard dense FedAvg
        trunk = {kk: vv for kk, vv in params_c.items() if kk != "embed"}
        trunk = jax.tree.map(lambda a: jnp.broadcast_to(a.mean(0, keepdims=True),
                                                        a.shape), trunk)
        params_c.update(trunk)
        params_c = jax.block_until_ready(params_c)
        full = cfg.vocab_size * cfg.d_model
        sparse = k * cfg.d_model + k + cfg.vocab_size
        print(f"round {r+1:3d}  mean loss {float(losses.mean()):.4f}  "
              f"emb payload {sparse/full:.2%} of dense")
    print(f"done in {time.time()-t0:.1f}s — FedS embedding sync transmitted "
          f"{100*(k*cfg.d_model + k + cfg.vocab_size)/(cfg.vocab_size*cfg.d_model):.1f}% "
          f"of a dense exchange per sparse round")


if __name__ == "__main__":
    main()
