"""Batched serving demo: autoregressive decode with a KV cache on the
reduced qwen3 config, plus an SSM-state decode on the xlstm config —
the two serve-path families of the framework.

  PYTHONPATH=src python examples/serve_demo.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.train.steps import (
    InputShape,
    init_serve_state,
    init_train_state,
    make_serve_step,
)


def decode(arch: str, batch: int = 4, steps: int = 12, cache: int = 64):
    cfg = get_smoke_config(arch)
    shape = InputShape("demo", seq_len=cache, global_batch=batch, kind="decode")
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    enc = None
    if cfg.arch_type == "audio":
        enc = jnp.zeros((batch, cfg.encoder_seq_len, cfg.d_model), cfg.dtype)
    state = init_serve_state(params, cfg, shape, encoder_embeds=enc)
    state = state._replace(pos=jnp.zeros((batch,), jnp.int32))
    step = jax.jit(make_serve_step(cfg))
    token = jnp.zeros((batch, 1), jnp.int32)
    key = jax.random.PRNGKey(7)
    t0 = time.time()
    out = []
    for _ in range(steps):
        logits, state = step(params, token, state)
        key, sub = jax.random.split(key)
        token = jax.random.categorical(sub, logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(int(token[0, 0]))
    jax.block_until_ready(token)
    print(f"[{arch:14s}] {steps} tokens x {batch} seqs "
          f"({steps*batch/(time.time()-t0):6.1f} tok/s CPU)  seq0: {out}")


def main():
    for arch in ("qwen3-0.6b", "xlstm-350m", "zamba2-1.2b", "whisper-base"):
        decode(arch)


if __name__ == "__main__":
    main()
