"""Continuous batching demo: 6 requests of different lengths share 2 decode
slots; batched outputs are identical to solo decoding (slot isolation).

  PYTHONPATH=src python examples/continuous_batching.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.transformer import init_lm
from repro.serving.engine import Request, ServeEngine


def main():
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), dtype=jnp.float32)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    reqs = [Request(f"req{i}", prompt=list(range(1, 2 + i)), max_new_tokens=4 + i)
            for i in range(6)]
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    t0 = time.time()
    out = eng.run([dataclasses.replace(r) for r in reqs])
    print(f"served {len(out)} requests through 2 slots in {time.time()-t0:.1f}s")
    for uid in sorted(out):
        print(f"  {uid}: {out[uid]}")


if __name__ == "__main__":
    main()
