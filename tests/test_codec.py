"""WireCodec layer: value round-trips, ledger accounting, frozen messages."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregate import Download, Upload
from repro.core.codec import IdentityCodec, Int8RowCodec, get_codec
from repro.core.sparsify import dequantize_rows, quantize_rows
from repro.federated.comm import CommLedger


# ----------------------------------------------------------- value roundtrip
def test_identity_roundtrip_exact():
    v = jax.random.normal(jax.random.PRNGKey(0), (7, 16))
    np.testing.assert_array_equal(
        np.asarray(IdentityCodec().roundtrip(v)), np.asarray(v)
    )


def test_int8_roundtrip_error_bound():
    """Row-wise symmetric int8: |err| <= scale/2 = max|row| / 254 per row."""
    v = jax.random.normal(jax.random.PRNGKey(1), (12, 32)) * 3.0
    back = np.asarray(Int8RowCodec().roundtrip(v))
    row_max = np.abs(np.asarray(v)).max(axis=-1, keepdims=True)
    assert (np.abs(back - np.asarray(v)) <= row_max / 254.0 + 1e-7).all()
    # and matches the underlying quantize/dequantize pair exactly
    q, sc = quantize_rows(v)
    np.testing.assert_array_equal(back, np.asarray(dequantize_rows(q, sc)))


def test_int8_roundtrip_zero_and_tiny_rows():
    v = jnp.concatenate([jnp.zeros((2, 8)), jnp.full((1, 8), 1e-30)])
    back = np.asarray(Int8RowCodec().roundtrip(v))
    assert np.isfinite(back).all()
    np.testing.assert_array_equal(back[:2], 0.0)


def test_get_codec_registry():
    assert isinstance(get_codec("identity"), IdentityCodec)
    assert isinstance(get_codec("int8-rows"), Int8RowCodec)
    with pytest.raises(ValueError):
        get_codec("zstd")


# -------------------------------------------------------- ledger accounting
def test_identity_codec_ledger_matches_commledger_math():
    a, b = CommLedger(), CommLedger()
    codec = IdentityCodec()
    codec.log_upload(a, k=10, dim=8, num_shared=50)
    codec.log_download(a, k=6, dim=8, num_shared=50)
    b.log_upload_sparse(10, 8, 50)
    b.log_download_sparse(6, 8, 50)
    assert a.params_transmitted == b.params_transmitted
    assert a.bytes_int8_signs == b.bytes_int8_signs


def test_int8_codec_upload_leg_accounting():
    led = CommLedger()
    Int8RowCodec().log_upload(led, k=10, dim=8, num_shared=50)
    # params: int8 values at 1/4 param (10*8/4) + f32 scales (10) + sign (50)
    assert led.params_transmitted == 10 * 8 / 4 + 10 + 50
    # bytes: int8 values + f32 scales + i8 sign vector + i32 indices
    assert led.bytes_int8_signs == 10 * 8 + 10 * 4 + 50 + 10 * 4


def test_int8_codec_download_leg_accounting():
    led = CommLedger()
    Int8RowCodec().log_download(led, k=6, dim=8, num_shared=50)
    # params: int8 values (6*8/4) + scales + priorities (2*6) + sign (50)
    assert led.params_transmitted == 6 * 8 / 4 + 2 * 6 + 50
    # bytes: int8 values + (scale + priority) f32 pairs + i32 indices + sign
    assert led.bytes_int8_signs == 6 * (8 + 8) + 6 * 4 + 50


def test_int8_codec_cheaper_than_identity_per_round():
    """The point of Q8: ~4x fewer payload params on both legs."""
    q8, ident = CommLedger(), CommLedger()
    for led, codec in ((q8, Int8RowCodec()), (ident, IdentityCodec())):
        codec.log_upload(led, k=100, dim=256, num_shared=400)
        codec.log_download(led, k=80, dim=256, num_shared=400)
    assert q8.params_transmitted < 0.35 * ident.params_transmitted
    assert q8.bytes_int8_signs < 0.35 * ident.bytes_int8_signs


def test_int8_empty_download_still_costs_sign_vector():
    led = CommLedger()
    Int8RowCodec().log_download(led, k=0, dim=256, num_shared=400)
    assert led.params_transmitted == 400
    assert led.bytes_int8_signs == 400


# --------------------------------------------------------- frozen messages
def test_protocol_messages_are_immutable():
    up = Upload(
        client_id=0,
        entity_ids=np.arange(3, dtype=np.int64),
        values=np.zeros((3, 4), np.float32),
    )
    with pytest.raises(dataclasses.FrozenInstanceError):
        up.values = np.ones((3, 4), np.float32)
    down = Download(
        client_id=0,
        entity_ids=np.arange(2, dtype=np.int64),
        agg_values=np.zeros((2, 4), np.float32),
        priority=np.ones(2, np.int64),
    )
    with pytest.raises(dataclasses.FrozenInstanceError):
        down.agg_values = np.ones((2, 4), np.float32)
    # the sanctioned wire transform: build a new message
    up2 = dataclasses.replace(up, values=np.ones((3, 4), np.float32))
    np.testing.assert_array_equal(up.values, 0.0)
    np.testing.assert_array_equal(up2.values, 1.0)
