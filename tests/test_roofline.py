"""Roofline math + dry-run record plumbing tests."""
import json

from benchmarks.roofline import roofline_row
from repro.launch.mesh import TPU_V5E


def _rec(flops=1e12, byts=3e11, coll=1e10, kind="train", n=256,
         active=1e9, tokens=1e6):
    return {
        "arch": "x", "shape": "train_4k", "mesh": "16x16", "strategy": "tp",
        "kind": kind, "num_devices": n,
        "flops_per_device": flops, "bytes_per_device": byts,
        "collective_bytes_per_device": {"_total": coll},
        "memory": {"argument_bytes": 2**30 * n, "temp_bytes": 2**30 * n},
        "active_param_count": active, "tokens": tokens,
    }


def test_roofline_terms():
    r = roofline_row(_rec())
    assert abs(r["t_compute_s"] - 1e12 / TPU_V5E["peak_flops_bf16"]) < 1e-12
    assert abs(r["t_memory_s"] - 3e11 / TPU_V5E["hbm_bw"]) < 1e-12
    assert abs(r["t_collective_s"] - 1e10 / TPU_V5E["ici_bw"]) < 1e-12
    assert r["bottleneck"] == "memory"
    assert r["step_lower_bound_s"] == r["t_memory_s"]
    assert abs(r["mem_gb_per_dev"] - 2.0) < 1e-9


def test_roofline_model_flops_multiplier():
    train = roofline_row(_rec(kind="train"))
    dec = roofline_row(_rec(kind="decode"))
    assert abs(train["model_flops"] / dec["model_flops"] - 3.0) < 1e-9


def test_dryrun_jsonl_schema():
    """Every OK record in the shipped results has the roofline fields."""
    import os

    path = "dryrun_results.jsonl"
    if not os.path.exists(path):
        import pytest

        pytest.skip("no dryrun results in workspace")
    n_ok = 0
    for line in open(path):
        r = json.loads(line)
        if r["status"] != "OK":
            continue
        n_ok += 1
        roofline_row(r)  # must not raise
        assert r["flops_per_device"] > 0
        assert r["num_devices"] in (256, 512)
    assert n_ok > 0
