"""The documentation front door stays honest.

README/docs relative links must resolve (tools/docs_lint.py — also a CI
step) and the docs must actually mention the engine modes they promise to
explain.
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_docs_links_resolve():
    res = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "docs_lint.py")],
        capture_output=True, text=True, timeout=60,
    )
    assert res.returncode == 0, res.stdout + res.stderr


def test_readme_covers_engines_and_verify():
    text = (ROOT / "README.md").read_text()
    for needle in (
        "superstep", "fused", "batched", "reference",  # the four engine modes
        "examples/quickstart.py",
        "python -m pytest -x -q",  # tier-1 verify command
        "EXPERIMENTS.md", "ROADMAP.md", "docs/architecture.md",
    ):
        assert needle in text, f"README.md must mention {needle!r}"


def test_architecture_documents_contract_and_layout():
    text = (ROOT / "docs" / "architecture.md").read_text()
    for needle in ("mermaid", "(C, E_max, D)", "superstep", "WireCodec",
                   "bitwise"):
        assert needle in text, f"docs/architecture.md must mention {needle!r}"
