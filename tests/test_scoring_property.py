"""Scoring-registry oracle tests: every registered method's score, gradient,
and loss pinned to an independent float64 numpy oracle, plus the registry's
error-message/alias/CLI contracts.

Seeded deterministic twins run everywhere; the drawn-shape/value property
tests are hypothesis-guarded like tests/test_codecs_property.py (this
container has no hypothesis wheel; CI installs requirements-dev.txt).
"""
import argparse

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

from repro.kge.scoring import (
    KGEModel,
    get_scoring,
    init_kge_params,
    kge_loss,
    loss_from_scores,
    parse_method,
    registered_methods,
    scoring_usage,
)
from repro.launch.train import _method_name

GAMMA = 8.0
EPSILON = 2.0  # the paper's fixed epsilon, baked into pRotatE's scales


# ------------------------------------------------------- float64 numpy oracles
def _np_transe(h, r, t, gamma):
    d = h + r - t
    return gamma - np.sqrt((d * d).sum(-1))


def _np_rotate(h, phase, t, gamma):
    half = h.shape[-1] // 2
    h_re, h_im = h[..., :half], h[..., half:]
    t_re, t_im = t[..., :half], t[..., half:]
    r_re, r_im = np.cos(phase), np.sin(phase)
    d_re = h_re * r_re - h_im * r_im - t_re
    d_im = h_re * r_im + h_im * r_re - t_im
    return gamma - np.sqrt(d_re**2 + d_im**2 + 1e-12).sum(-1)


def _np_protate(h, phase, t, gamma):
    dim = h.shape[-1]
    s = (gamma + EPSILON) / dim / np.pi
    modulus = 0.5 * (gamma + EPSILON) / dim
    return gamma - np.abs(np.sin(h / s + phase - t / s)).sum(-1) * modulus


def _np_distmult(h, r, t, gamma):
    del gamma
    return (h * r * t).sum(-1)


def _np_complex(h, r, t, gamma):
    del gamma
    half = h.shape[-1] // 2
    h_re, h_im = h[..., :half], h[..., half:]
    r_re, r_im = r[..., :half], r[..., half:]
    t_re, t_im = t[..., :half], t[..., half:]
    return (
        h_re * r_re * t_re
        + h_im * r_re * t_im
        + h_re * r_im * t_im
        - h_im * r_im * t_re
    ).sum(-1)


def _np_proje(h, r, t, gamma):
    del gamma
    return (np.tanh(h + r) * t).sum(-1)


def _np_hole(h, r, t, gamma):
    del gamma
    n = h.shape[-1]
    ccorr = np.fft.irfft(np.conj(np.fft.rfft(h)) * np.fft.rfft(t), n=n)
    return (np.broadcast_to(r, ccorr.shape) * ccorr).sum(-1)


ORACLES = {
    "transe": _np_transe,
    "rotate": _np_rotate,
    "protate": _np_protate,
    "distmult": _np_distmult,
    "complex": _np_complex,
    "hole": _np_hole,
    "proje": _np_proje,
}


def _np_log_sigmoid(x):
    return -np.logaddexp(0.0, -x)


def _np_loss(pos_s, neg_s, adversarial, temp):
    """float64 oracle for loss_from_scores (RotatE Eq. 5 / uniform)."""
    if adversarial and temp > 0:
        z = neg_s * temp
        w = np.exp(z - z.max(-1, keepdims=True))
        w = w / w.sum(-1, keepdims=True)
    else:
        w = np.full_like(neg_s, 1.0 / neg_s.shape[-1])
    per = -_np_log_sigmoid(pos_s) - (w * _np_log_sigmoid(-neg_s)).sum(-1)
    return per.mean() / 2.0


def _draw(seed, b, dim, method, n_extra=0):
    """Seeded f64 rows: (h, r, t) with the method's rel_dim; optionally an
    extra (n_extra, dim) candidate block."""
    spec = get_scoring(method)
    rng = np.random.default_rng(seed)
    scale = np.pi if spec.rel_init == "phase" else 2.0
    h = rng.normal(size=(b, dim))
    r = rng.uniform(-scale, scale, size=(b, spec.rel_dim(dim)))
    t = rng.normal(size=(b, dim))
    if n_extra:
        return h, r, t, rng.normal(size=(n_extra, dim))
    return h, r, t


# ----------------------------------------------------------- registry contract
def test_every_registered_method_has_an_oracle():
    """Keep-honest: registering a method without recording its closed-form
    numpy oracle here must fail loudly."""
    for name in registered_methods():
        assert name in ORACLES, (
            f"no numpy oracle recorded for scoring method {name!r} — add one"
        )


def test_unknown_method_error_lists_registered_names():
    with pytest.raises(ValueError) as e:
        get_scoring("no-such-method")
    msg = str(e.value)
    assert "no-such-method" in msg
    for name in registered_methods():
        assert name in msg


def test_aliases_resolve_to_canonical_names():
    assert parse_method("prot") == "protate"
    for name in registered_methods():
        assert parse_method(name) == name


def test_kge_model_validates_method_eagerly():
    with pytest.raises(ValueError, match="registered methods"):
        KGEModel(method="bogus", num_entities=4, num_relations=2, dim=8)


def test_cli_method_type_surfaces_registry_error():
    """--method goes through _method_name: argparse.ArgumentTypeError that
    carries the registry's own listing, and aliases canonicalise."""
    with pytest.raises(argparse.ArgumentTypeError) as e:
        _method_name("no-such-method")
    for name in registered_methods():
        assert name in str(e.value)
    assert _method_name("prot") == "protate"


def test_scoring_usage_mentions_every_method_and_family():
    usage = scoring_usage()
    for name, spec in registered_methods().items():
        assert name in usage
        assert spec.family in usage


def test_rel_dim_and_init_rules():
    dim = 16
    assert get_scoring("rotate").rel_dim(dim) == dim // 2
    for name in ("transe", "protate", "distmult", "complex", "hole", "proje"):
        assert get_scoring(name).rel_dim(dim) == dim
    for name, spec in registered_methods().items():
        model = KGEModel(method=name, num_entities=6, num_relations=3, dim=dim)
        params = init_kge_params(jax.random.PRNGKey(0), model)
        assert params["relation"].shape == (3, spec.rel_dim(dim))
        bound = np.pi if spec.rel_init == "phase" else model.embedding_range
        assert np.abs(np.asarray(params["relation"])).max() <= bound


# -------------------------------------------------- deterministic oracle twins
@pytest.mark.parametrize("method", sorted(ORACLES))
@pytest.mark.parametrize("seed,b,dim", [(0, 5, 8), (1, 1, 16), (2, 7, 32)])
def test_score_matches_numpy_oracle(method, seed, b, dim):
    h, r, t = _draw(seed, b, dim, method)
    spec = get_scoring(method)
    got = spec.score(
        jnp.asarray(h, jnp.float32), jnp.asarray(r, jnp.float32),
        jnp.asarray(t, jnp.float32), GAMMA,
    )
    np.testing.assert_allclose(
        np.asarray(got), ORACLES[method](h, r, t, GAMMA), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("method", sorted(ORACLES))
def test_score_broadcasts_over_eval_candidate_axis(method):
    """The eval ref path scores (B,1,D) queries against a (N,D) candidate
    block by broadcasting — pin both legs' (B, N) surfaces to the oracle."""
    h, r, t, cand = _draw(3, 4, 16, method, n_extra=9)
    spec = get_scoring(method)
    f32 = lambda x: jnp.asarray(x, jnp.float32)  # noqa: E731
    tail = spec.score(f32(h)[:, None, :], f32(r)[:, None, :], f32(cand), GAMMA)
    head = spec.score(f32(cand), f32(r)[:, None, :], f32(t)[:, None, :], GAMMA)
    np.testing.assert_allclose(
        np.asarray(tail),
        ORACLES[method](h[:, None, :], r[:, None, :], cand, GAMMA),
        rtol=1e-4, atol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(head),
        ORACLES[method](cand, r[:, None, :], t[:, None, :], GAMMA),
        rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("method", sorted(ORACLES))
def test_grad_matches_finite_differences_of_oracle(method):
    """jax.grad of the summed score vs central finite differences of the
    float64 oracle — an oracle the autodiff graph never saw."""
    h, r, t = _draw(4, 3, 8, method)
    spec = get_scoring(method)

    def jax_sum(h_, r_, t_):
        return spec.score(h_, r_, t_, GAMMA).sum()

    grads = jax.grad(jax_sum, argnums=(0, 1, 2))(
        jnp.asarray(h, jnp.float32), jnp.asarray(r, jnp.float32),
        jnp.asarray(t, jnp.float32),
    )

    eps = 1e-5
    for arg, arr in enumerate((h, r, t)):
        fd = np.zeros_like(arr)
        for idx in np.ndindex(arr.shape):
            args_p = [h.copy(), r.copy(), t.copy()]
            args_m = [h.copy(), r.copy(), t.copy()]
            args_p[arg][idx] += eps
            args_m[arg][idx] -= eps
            fd[idx] = (
                ORACLES[method](*args_p, GAMMA).sum()
                - ORACLES[method](*args_m, GAMMA).sum()
            ) / (2 * eps)
        np.testing.assert_allclose(
            np.asarray(grads[arg]), fd, rtol=2e-3, atol=2e-3,
            err_msg=f"{method} grad wrt arg {arg}",
        )


@pytest.mark.parametrize("method", sorted(ORACLES))
@pytest.mark.parametrize("temp", [0.0, 1.0])
def test_loss_matches_numpy_oracle(method, temp):
    """loss_from_scores == float64 oracle; the adversarial flag is the
    family rule (distance -> Eq. 5 weighting, bilinear -> uniform)."""
    rng = np.random.default_rng(5)
    pos_s = rng.normal(size=(6,)) * 3.0
    neg_s = rng.normal(size=(6, 10)) * 3.0
    spec = get_scoring(method)
    got = loss_from_scores(
        jnp.asarray(pos_s, jnp.float32), jnp.asarray(neg_s, jnp.float32),
        method, temp,
    )
    want = _np_loss(pos_s, neg_s, spec.adversarial, temp)
    np.testing.assert_allclose(float(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("method", sorted(ORACLES))
def test_kge_loss_matches_oracle_end_to_end(method):
    """kge_loss from params+indices == oracle loss built from oracle scores
    (gathers, negative-leg concat order, and averaging all pinned)."""
    rng = np.random.default_rng(6)
    ne, nr, dim, b, n = 12, 4, 16, 5, 7
    spec = get_scoring(method)
    ent = rng.normal(size=(ne, dim))
    rel = rng.uniform(-np.pi, np.pi, size=(nr, spec.rel_dim(dim)))
    params = {
        "entity": jnp.asarray(ent, jnp.float32),
        "relation": jnp.asarray(rel, jnp.float32),
    }
    pos = rng.integers(0, [ne, nr, ne], size=(b, 3))
    neg_t = rng.integers(0, ne, size=(b, n))
    neg_h = rng.integers(0, ne, size=(b, n))

    got = kge_loss(
        params, jnp.asarray(pos), jnp.asarray(neg_t), jnp.asarray(neg_h),
        method, GAMMA, 1.0,
    )
    oracle = ORACLES[method]
    h, r, t = ent[pos[:, 0]], rel[pos[:, 1]], ent[pos[:, 2]]
    pos_s = oracle(h, r, t, GAMMA)
    neg_s = np.concatenate(
        [
            oracle(h[:, None, :], r[:, None, :], ent[neg_t], GAMMA),
            oracle(ent[neg_h], r[:, None, :], t[:, None, :], GAMMA),
        ],
        axis=-1,
    )
    want = _np_loss(pos_s, neg_s, spec.adversarial, 1.0)
    np.testing.assert_allclose(float(got), want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------- drawn-shape property form
if _HAVE_HYPOTHESIS:
    triple_st = st.tuples(
        st.integers(0, 2**31 - 1),  # value seed
        st.integers(1, 8),  # batch
        st.sampled_from([8, 16, 32]),  # entity dim (even: complex halves)
        st.floats(2.0, 12.0),  # gamma
    )

    @settings(max_examples=25, deadline=None)
    @given(triple_st, st.sampled_from(sorted(ORACLES)))
    def test_score_matches_oracle_drawn(draw, method):
        seed, b, dim, gamma = draw
        h, r, t = _draw(seed, b, dim, method)
        got = get_scoring(method).score(
            jnp.asarray(h, jnp.float32), jnp.asarray(r, jnp.float32),
            jnp.asarray(t, jnp.float32), gamma,
        )
        np.testing.assert_allclose(
            np.asarray(got), ORACLES[method](h, r, t, gamma),
            rtol=1e-4, atol=1e-4,
        )

    @settings(max_examples=25, deadline=None)
    @given(triple_st, st.sampled_from(sorted(ORACLES)), st.integers(1, 12))
    def test_broadcast_eval_shapes_match_oracle_drawn(draw, method, n):
        seed, b, dim, gamma = draw
        h, r, t, cand = _draw(seed, b, dim, method, n_extra=n)
        spec = get_scoring(method)
        f32 = lambda x: jnp.asarray(x, jnp.float32)  # noqa: E731
        tail = spec.score(
            f32(h)[:, None, :], f32(r)[:, None, :], f32(cand), gamma
        )
        np.testing.assert_allclose(
            np.asarray(tail),
            ORACLES[method](h[:, None, :], r[:, None, :], cand, gamma),
            rtol=1e-4, atol=1e-4,
        )

    @settings(max_examples=25, deadline=None)
    @given(
        st.integers(0, 2**31 - 1),
        st.sampled_from(sorted(ORACLES)),
        st.floats(0.0, 2.0),
    )
    def test_loss_matches_oracle_drawn(seed, method, temp):
        rng = np.random.default_rng(seed)
        pos_s = rng.normal(size=(4,)) * 4.0
        neg_s = rng.normal(size=(4, 6)) * 4.0
        got = loss_from_scores(
            jnp.asarray(pos_s, jnp.float32), jnp.asarray(neg_s, jnp.float32),
            method, temp,
        )
        want = _np_loss(pos_s, neg_s, get_scoring(method).adversarial, temp)
        np.testing.assert_allclose(float(got), want, rtol=1e-5, atol=1e-5)
