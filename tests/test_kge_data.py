"""KGE scoring + synthetic data/partition tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.data.partition import partition_by_relation, shared_entity_mask
from repro.data.synthetic import generate_kg, split_triples
from repro.data.loader import TripleLoader
from repro.kge.scoring import (
    KGEModel,
    init_kge_params,
    kge_loss,
    rotate_score,
    score_triples,
    transe_score,
)


# ---------------------------------------------------------------------- kge
def test_transe_score_translation_property():
    """Exact translation h + r = t gives the maximum score gamma."""
    h = jnp.array([[1.0, 2.0, 3.0]])
    r = jnp.array([[0.5, -1.0, 0.0]])
    t = h + r
    s = transe_score(h, r, t, gamma=8.0)
    np.testing.assert_allclose(np.asarray(s), 8.0, atol=1e-6)


def test_rotate_rotation_preserves_modulus():
    """|h o r| == |h| for any phase — rotation is unitary per coordinate."""
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (5, 8))
    phase = jax.random.uniform(jax.random.PRNGKey(1), (5, 4), minval=-3, maxval=3)
    t = jnp.zeros((5, 8))
    # score = gamma - sum |h o r - 0| = gamma - sum|h o r| = gamma - sum|h|
    s = rotate_score(h, phase, t, gamma=0.0)
    h_re, h_im = h[..., :4], h[..., 4:]
    expect = -jnp.sqrt(h_re**2 + h_im**2 + 1e-12).sum(-1)
    np.testing.assert_allclose(np.asarray(s), np.asarray(expect), rtol=1e-5)


@pytest.mark.parametrize("method", ["transe", "rotate", "complex"])
def test_score_triples_shapes(method):
    model = KGEModel(method=method, num_entities=20, num_relations=5, dim=16)
    params = init_kge_params(jax.random.PRNGKey(0), model)
    h = jnp.arange(4)
    r = jnp.zeros(4, jnp.int32)
    t = jnp.arange(4, 8)
    assert score_triples(params, h, r, t, method).shape == (4,)
    t_neg = jnp.zeros((4, 7), jnp.int32)
    assert score_triples(params, h, r, t_neg, method).shape == (4, 7)


@pytest.mark.parametrize("method", ["transe", "rotate", "complex"])
def test_kge_loss_decreases(method):
    """A few gradient steps on a tiny KG must reduce the loss."""
    from repro.train.optimizer import adam_init, adam_update

    model = KGEModel(method=method, num_entities=30, num_relations=4, dim=16)
    params = init_kge_params(jax.random.PRNGKey(0), model)
    opt = adam_init(params)
    rng = np.random.default_rng(0)
    pos = jnp.asarray(rng.integers(0, [30, 4, 30], size=(16, 3)), jnp.int32)
    nt = jnp.asarray(rng.integers(0, 30, size=(16, 8)), jnp.int32)
    nh = jnp.asarray(rng.integers(0, 30, size=(16, 8)), jnp.int32)

    grad_fn = jax.jit(jax.value_and_grad(
        lambda p: kge_loss(p, pos, nt, nh, method)
    ))
    l0, _ = grad_fn(params)
    for _ in range(30):
        _, g = grad_fn(params)
        params, opt = adam_update(g, opt, params, 1e-2)
    l1, _ = grad_fn(params)
    assert float(l1) < float(l0)


# --------------------------------------------------------------------- data
def test_generate_kg_deterministic():
    a = generate_kg(num_entities=100, num_relations=8, num_triples=500, seed=3)
    b = generate_kg(num_entities=100, num_relations=8, num_triples=500, seed=3)
    np.testing.assert_array_equal(a.triples, b.triples)
    assert a.triples[:, 0].max() < 100
    assert a.triples[:, 1].max() < 8
    assert len({tuple(t) for t in a.triples.tolist()}) == a.num_triples  # unique


def test_split_ratios():
    kg = generate_kg(num_entities=200, num_relations=10, num_triples=2000, seed=0)
    tr, va, te = split_triples(kg)
    assert abs(tr.shape[0] / kg.num_triples - 0.8) < 0.02
    assert tr.shape[0] + va.shape[0] + te.shape[0] == kg.num_triples


@settings(max_examples=10, deadline=None)
@given(nc=st.integers(2, 8))
def test_partition_covers_all_triples(nc):
    kg = generate_kg(num_entities=150, num_relations=24, num_triples=1500, seed=1)
    clients = partition_by_relation(kg, nc, seed=0)
    total = sum(c.train.shape[0] + c.valid.shape[0] + c.test.shape[0] for c in clients)
    assert total == kg.num_triples
    # relations are disjoint across clients
    rel_sets = [set(np.concatenate([c.train, c.valid, c.test])[:, 1].tolist())
                for c in clients]
    for i in range(nc):
        for j in range(i + 1, nc):
            assert not (rel_sets[i] & rel_sets[j])


def test_partition_local_ids_valid():
    kg = generate_kg(num_entities=150, num_relations=12, num_triples=1200, seed=2)
    clients = partition_by_relation(kg, 3, seed=0)
    for c in clients:
        allt = np.concatenate([c.train, c.valid, c.test])
        assert allt[:, 0].max() < c.num_entities
        assert allt[:, 2].max() < c.num_entities
        # local->global mapping is injective
        assert len(np.unique(c.local_to_global)) == c.num_entities


def test_shared_entity_mask():
    kg = generate_kg(num_entities=150, num_relations=12, num_triples=1200, seed=2)
    clients = partition_by_relation(kg, 3, seed=0)
    mask = shared_entity_mask(clients, kg.num_entities)
    # dense synthetic graphs share most entities across relation partitions
    assert mask.sum() > 0.5 * kg.num_entities


def test_loader_static_shapes():
    kg = generate_kg(num_entities=100, num_relations=8, num_triples=700, seed=0)
    tr, _, _ = split_triples(kg)
    loader = TripleLoader(tr, batch_size=64, num_entities=100, num_negatives=5, seed=0)
    seen = 0
    for pos, nt, nh in loader.epoch():
        assert pos.shape == (64, 3) and nt.shape == (64, 5) and nh.shape == (64, 5)
        seen += 1
    assert seen == loader.batches_per_epoch
