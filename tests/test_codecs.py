"""The codec subsystem (repro.core.codecs): registry + CLI parsing, value
round-trips with closed-form error bounds, ledger byte math vs closed forms,
error-feedback residual semantics, and the fused==superstep equivalence
contract parameterized over every registered codec (seeded deterministic
versions; tests/test_codecs_property.py holds the hypothesis twins)."""
import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.core.codecs import (
    IdentityCodec,
    Int8RowCodec,
    LowRankCodec,
    TopKDimsCodec,
    codec_usage,
    get_codec,
    parse_codec_spec,
    registered_codecs,
)
from repro.core.engine import RoundEngine, batched_sparse_round
from repro.core.protocol import build_comm_views
from repro.core.state import SuperstepEngine
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.comm import CommLedger
from repro.federated.simulation import FederatedConfig, run_federated

# one spec per registered codec, sized for dim=16 test rows (lowrank: D % cols
# == 0; rank=1 keeps params_per_row below D so compression is real)
ALL_SPECS = ("identity", "int8", "lowrank:cols=4,rank=1", "topk-dims:frac=0.5")
EF_SPECS = ("int8:ef=1", "lowrank:cols=4,rank=1,ef=1", "topk-dims:frac=0.5,ef=1")


def _rows(seed: int, k: int = 9, d: int = 16) -> jnp.ndarray:
    return jax.random.normal(jax.random.PRNGKey(seed), (k, d)) * 2.0


# ------------------------------------------------------------------ registry
def test_registry_ships_the_four_codecs():
    assert set(registered_codecs()) >= {"identity", "int8", "lowrank", "topk-dims"}


def test_aliases_and_legacy_get_codec():
    assert isinstance(get_codec("int8-rows"), Int8RowCodec)
    assert isinstance(get_codec("identity"), IdentityCodec)
    assert "int8-rows" not in registered_codecs()  # aliases are not canonical


def test_parse_codec_spec_kwargs_and_defaults():
    c = parse_codec_spec("lowrank:cols=4,rank=3,ef=1")
    assert isinstance(c, LowRankCodec)
    assert (c.cols, c.rank, c.ef) == (4, 3, True)
    assert c.has_residual
    d = parse_codec_spec("topk-dims")
    assert isinstance(d, TopKDimsCodec) and d.frac == 0.25 and not d.has_residual


def test_parse_error_lists_every_registered_codec_and_kwargs():
    """Satellite contract: parse errors are self-describing from the registry."""
    with pytest.raises(ValueError) as ei:
        parse_codec_spec("zstd")
    msg = str(ei.value)
    for name in registered_codecs():
        assert name in msg
    # accepted kwargs ride along (single source of truth: WireCodec.ARGS)
    assert "rank" in msg and "frac" in msg and "ef" in msg
    # and the same listing backs the usage helper
    for name in registered_codecs():
        assert name in codec_usage()


def test_parse_error_unknown_kwarg_lists_accepted():
    with pytest.raises(ValueError, match=r"accepted kwargs.*cols.*rank.*ef"):
        parse_codec_spec("lowrank:rankk=2")
    with pytest.raises(ValueError, match="bad codec spec"):
        parse_codec_spec("int8:ef")
    with pytest.raises(ValueError, match="expects int"):
        parse_codec_spec("lowrank:rank=two")
    with pytest.raises(ValueError, match="expects a bool"):
        parse_codec_spec("int8:ef=maybe")


def test_codecs_are_hashable_leafless_pytrees():
    c = parse_codec_spec("lowrank:cols=4,rank=1")
    assert c == LowRankCodec(cols=4, rank=1) and hash(c) == hash(LowRankCodec(cols=4, rank=1))
    assert c != LowRankCodec(cols=4, rank=2)
    leaves, treedef = jax.tree_util.tree_flatten(c)
    assert leaves == []
    assert jax.tree_util.tree_unflatten(treedef, leaves) == c


# ------------------------------------------------------- value round-trips
@pytest.mark.parametrize("spec", ALL_SPECS + EF_SPECS)
def test_roundtrip_equals_decode_of_encode(spec):
    codec = parse_codec_spec(spec)
    v = _rows(3)
    np.testing.assert_array_equal(
        np.asarray(codec.roundtrip(v)), np.asarray(codec.decode(codec.encode(v)))
    )
    # and jit agrees with eager
    np.testing.assert_array_equal(
        np.asarray(jax.jit(codec.roundtrip)(v)), np.asarray(codec.roundtrip(v))
    )


def test_int8_roundtrip_error_bound():
    """Row-wise symmetric int8: |err| <= scale/2 = max|row| / 254 per row."""
    v = _rows(1, 12, 32) * 1.5
    back = np.asarray(Int8RowCodec().roundtrip(v))
    row_max = np.abs(np.asarray(v)).max(axis=-1, keepdims=True)
    assert (np.abs(back - np.asarray(v)) <= row_max / 254.0 + 1e-7).all()


def test_lowrank_matches_numpy_truncated_svd():
    """The absorbed FedE-SVD math: reconstruction == numpy rank-r truncation
    (the optimal rank-r approximation of each row's (m, cols) reshape)."""
    codec = LowRankCodec(cols=4, rank=2)
    v = _rows(5, 7, 16)
    got = np.asarray(codec.roundtrip(v))
    mat = np.asarray(v).reshape(7, 4, 4)
    u, s, vt = np.linalg.svd(mat, full_matrices=False)
    want = np.einsum("kmr,kr,krn->kmn", u[:, :, :2], s[:, :2], vt[:, :2, :])
    np.testing.assert_allclose(got, want.reshape(7, 16), atol=1e-5)


def test_lowrank_full_rank_is_lossless_and_projection_idempotent():
    codec = LowRankCodec(cols=4, rank=4)  # rank == min(m, cols): no truncation
    v = _rows(6, 5, 16)
    np.testing.assert_allclose(np.asarray(codec.roundtrip(v)), np.asarray(v), atol=1e-5)
    lossy = LowRankCodec(cols=4, rank=1)
    once = lossy.roundtrip(v)
    np.testing.assert_allclose(
        np.asarray(lossy.roundtrip(once)), np.asarray(once), atol=1e-5
    )


def test_lowrank_rejects_indivisible_width():
    with pytest.raises(ValueError, match="not divisible"):
        LowRankCodec(cols=5).roundtrip(_rows(0, 3, 16))


def test_topk_dims_keeps_largest_and_zeroes_rest():
    codec = TopKDimsCodec(frac=0.25)  # 4 of 16 dims
    v = _rows(2, 6, 16)
    back = np.asarray(codec.roundtrip(v))
    vn = np.asarray(v)
    for i in range(vn.shape[0]):
        kept = np.argsort(-np.abs(vn[i]))[:4]
        np.testing.assert_array_equal(back[i, kept], vn[i, kept])
        dropped = np.setdiff1d(np.arange(16), kept)
        np.testing.assert_array_equal(back[i, dropped], 0.0)


# --------------------------------------------------- ledger vs closed forms
K, DIM, NS = 10, 16, 50


def _legs(codec):
    up, down = CommLedger(), CommLedger()
    codec.log_upload(up, K, DIM, NS)
    codec.log_download(down, K, DIM, NS)
    return up, down


def test_identity_ledger_closed_form():
    up, down = _legs(IdentityCodec())
    assert (up.params_transmitted, up.bytes_int8_signs) == (
        K * DIM + NS, K * DIM * 4 + NS + K * 4)
    assert (down.params_transmitted, down.bytes_int8_signs) == (
        K * DIM + K + NS, K * DIM * 4 + K * 4 + NS + K * 4)


def test_int8_ledger_closed_form():
    up, down = _legs(Int8RowCodec())
    assert (up.params_transmitted, up.bytes_int8_signs) == (
        K * DIM / 4 + K + NS, K * DIM + K * 4 + NS + K * 4)
    assert (down.params_transmitted, down.bytes_int8_signs) == (
        K * DIM / 4 + 2 * K + NS, K * (DIM + 8) + K * 4 + NS)


def test_lowrank_ledger_closed_form():
    codec = LowRankCodec(cols=4, rank=2)
    m, r = DIM // 4, 2
    ppr = m * r + r + 4 * r  # U + s + V factors per row (Appendix VI-B)
    assert codec.params_per_row(DIM) == ppr
    up, down = _legs(codec)
    assert (up.params_transmitted, up.bytes_int8_signs) == (
        K * ppr + NS, K * ppr * 4 + K * 4 + NS)
    assert (down.params_transmitted, down.bytes_int8_signs) == (
        K * ppr + K + NS, K * ppr * 4 + K * 4 + K * 4 + NS)


def test_topk_dims_ledger_closed_form():
    codec = TopKDimsCodec(frac=0.25)
    kd = 4  # round(16 * 0.25)
    assert codec.k_dims(DIM) == kd
    up, down = _legs(codec)
    assert (up.params_transmitted, up.bytes_int8_signs) == (
        K * kd + NS, K * kd * 4 + K * kd * 2 + K * 4 + NS)
    assert (down.params_transmitted, down.bytes_int8_signs) == (
        K * kd + K + NS, K * kd * 4 + K * kd * 2 + K * 4 + K * 4 + NS)


@pytest.mark.parametrize("spec", ("int8", "lowrank:cols=4,rank=1", "topk-dims:frac=0.25"))
def test_lossy_codecs_cheaper_than_identity(spec):
    ident, lossy = CommLedger(), CommLedger()
    for led, codec in ((ident, IdentityCodec()), (lossy, parse_codec_spec(spec))):
        codec.log_upload(led, 100, 256, 400)
        codec.log_download(led, 80, 256, 400)
    assert lossy.params_transmitted < ident.params_transmitted
    assert lossy.bytes_int8_signs < ident.bytes_int8_signs


def test_ef_does_not_change_ledger_math():
    """Error feedback changes transmitted VALUES, never counts."""
    for spec in ("int8", "lowrank:cols=4,rank=1", "topk-dims:frac=0.5"):
        a, _ = _legs(parse_codec_spec(spec))
        b, _ = _legs(parse_codec_spec(spec + ":ef=1" if ":" not in spec else spec + ",ef=1"))
        assert a.params_transmitted == b.params_transmitted
        assert a.bytes_int8_signs == b.bytes_int8_signs


# ------------------------------------------------ error-feedback semantics
def test_ef_residual_update_rule_unit():
    """With every row selected (p=1), round t banks exactly
    corrected_t - roundtrip(corrected_t), with corrected_t = emb_t + res_{t-1}."""
    codec = get_codec("int8", ef=True)
    ns, d = 6, 8
    emb = _rows(11, ns, d)[None]  # (1, ns, d): one client
    hist = jnp.zeros_like(emb)
    res = jnp.zeros_like(emb)
    gid = jnp.arange(ns, dtype=jnp.int32)[None]
    valid = jnp.ones((1, ns), bool)
    k = jnp.asarray([ns], jnp.int32)
    jitter = jnp.zeros((1, ns), jnp.float32)

    _, _, _, res1 = batched_sparse_round(
        emb, hist, gid, valid, k, jitter, k_max=ns, num_global=ns,
        codec=codec, axis_name=None, res=res,
    )
    # rows travel in score order but the codec is row-wise and the error is
    # banked back at each row's own slot, so the rule is checkable in place
    want1 = np.asarray(emb[0]) - np.asarray(codec.roundtrip(emb[0]))
    np.testing.assert_allclose(np.asarray(res1[0]), want1, atol=1e-6)

    emb2 = emb * 1.5
    _, _, _, res2 = batched_sparse_round(
        emb2, hist, gid, valid, k, jitter, k_max=ns, num_global=ns,
        codec=codec, axis_name=None, res=res1,
    )
    corrected = np.asarray(emb2[0]) + np.asarray(res1[0])
    want2 = corrected - np.asarray(codec.roundtrip(jnp.asarray(corrected)))
    np.testing.assert_allclose(np.asarray(res2[0]), want2, atol=1e-6)


def test_residual_codec_requires_res_buffer():
    codec = get_codec("int8", ef=True)
    emb = _rows(0, 4, 8)[None]
    with pytest.raises(ValueError, match="residual state"):
        batched_sparse_round(
            emb, jnp.zeros_like(emb), jnp.arange(4, dtype=jnp.int32)[None],
            jnp.ones((1, 4), bool), jnp.asarray([2], jnp.int32),
            jnp.zeros((1, 4), jnp.float32), k_max=2, num_global=4,
            codec=codec, axis_name=None,
        )


def test_residual_codec_rejected_by_round_engine():
    l2g = [np.array([0, 1, 2]), np.array([1, 2, 3])]
    views = build_comm_views(l2g, 4)
    with pytest.raises(ValueError, match="residual"):
        RoundEngine(views, 4, 8, 0.5, codec=get_codec("int8", ef=True))


# ------------------------------------------- EF-aware reference (host) path
def test_reference_ef_upload_banks_exact_residual():
    """The ragged numpy EF oracle obeys the same update rule as the device
    engines: corrected = row + res, res' = corrected - roundtrip(corrected)
    on uploaded rows, untouched elsewhere."""
    from repro.core.protocol import build_comm_views as bcv, sparse_upload_coded

    rng = np.random.default_rng(0)
    l2g = [np.arange(6), np.arange(6)]  # all entities shared
    views = bcv([a.astype(np.int32) for a in l2g], 6)
    codec = get_codec("int8", ef=True)
    table = jnp.asarray(rng.standard_normal((6, 8)), jnp.float32)
    hist = jnp.zeros((6, 8), jnp.float32)
    res0 = rng.standard_normal((6, 8)).astype(np.float32) * 0.01
    p = 0.5  # k = 3 of 6 rows selected
    up, _hist, res1 = sparse_upload_coded(table, hist, views[0], p, codec, res0)
    rows = np.asarray(
        [views[0].global_to_row[int(g)] for g in up.entity_ids], np.int32
    )
    cur = np.asarray(table)[np.asarray(views[0].shared_local)]
    corrected = cur[rows] + res0[rows]
    wire = np.asarray(codec.roundtrip(jnp.asarray(corrected)))
    np.testing.assert_allclose(up.values, wire, atol=1e-6)
    np.testing.assert_allclose(res1[rows], corrected - wire, atol=1e-6)
    unsel = np.setdiff1d(np.arange(6), rows)
    np.testing.assert_array_equal(res1[unsel], res0[unsel])  # banks persist
    assert res1 is not res0  # the caller's bank is never mutated in place

    with pytest.raises(ValueError, match="residual"):
        sparse_upload_coded(table, hist, views[0], p, codec, None)


def test_reference_ef_runs_and_matches_non_ef_ledger():
    """engine="reference" now threads host-side EF residuals: the run works,
    metrics are finite, and (EF changes transmitted VALUES, never counts)
    the ledger is bitwise identical to the ef=0 run.  Sync rounds clear the
    banked error, so a sync-every-round schedule transmits exact values and
    EF must change nothing at all."""
    kg = generate_kg(num_entities=60, num_relations=4, num_triples=300, seed=0)
    clients = partition_by_relation(kg, 2, seed=0)
    cfg = dict(rounds=4, dim=8, local_epochs=1, batch_size=32, lr=5e-3,
               sync_interval=2, eval_every=2, patience=99,
               max_eval_triples=20, engine="reference")
    plain = run_federated(
        clients, kg.num_entities, FederatedConfig(codec="int8", **cfg))
    ef = run_federated(
        clients, kg.num_entities, FederatedConfig(codec="int8:ef=1", **cfg))
    assert np.isfinite(ef.test_mrr_cg)
    assert ef.ledger.history == plain.ledger.history
    assert ef.ledger.bytes_int8_signs == plain.ledger.bytes_int8_signs

    sync_cfg = dict(cfg, sync_interval=0)  # degenerate ISM: sync every round
    a = run_federated(
        clients, kg.num_entities, FederatedConfig(codec="int8", **sync_cfg))
    b = run_federated(
        clients, kg.num_entities, FederatedConfig(codec="int8:ef=1", **sync_cfg))
    assert a.eval_history == b.eval_history


def test_quantize_upload_legacy_alias_and_conflict():
    kg = generate_kg(num_entities=60, num_relations=4, num_triples=200, seed=0)
    clients = partition_by_relation(kg, 2, seed=0)
    with pytest.raises(ValueError, match="conflicts"):
        run_federated(
            clients, kg.num_entities,
            FederatedConfig(rounds=1, dim=8, quantize_upload=True, codec="lowrank"),
        )


# --------------------------------- fused == superstep over every codec
def _instance():
    kg = generate_kg(num_entities=120, num_relations=9, num_triples=900, seed=5)
    clients = partition_by_relation(kg, 3, seed=0)
    cfg = dict(
        method="transe", dim=16, rounds=6, local_epochs=1, batch_size=48,
        num_negatives=4, lr=5e-3, sparsity_p=0.5, sync_interval=2,
        eval_every=3, patience=99, max_eval_triples=30, seed=0,
    )
    return kg, clients, cfg


@pytest.mark.parametrize("spec", ALL_SPECS + EF_SPECS)
def test_fused_matches_superstep_per_codec(spec):
    """The engine-equivalence contract holds for every registered codec,
    including ones whose residual state rides through the superstep scans."""
    kg, clients, cfg = _instance()
    fused = run_federated(
        clients, kg.num_entities,
        FederatedConfig(protocol="feds", engine="fused", codec=spec, **cfg),
    )
    sstep = run_federated(
        clients, kg.num_entities,
        FederatedConfig(protocol="feds", engine="superstep", codec=spec, **cfg),
    )
    assert fused.eval_history == sstep.eval_history, spec
    assert fused.ledger.history == sstep.ledger.history, spec
    assert fused.ledger.bytes_int8_signs == sstep.ledger.bytes_int8_signs, spec
    assert fused.test_mrr_cg == sstep.test_mrr_cg, spec
    assert np.isfinite(fused.test_mrr_cg)


def test_residual_state_device_resident_and_bitwise_through_superstep():
    """The EF residual lives on device, survives a whole scanned superstep
    bitwise-identically to per-cycle execution, is nonzero after sparse
    rounds, clears on sync, and never touches padding slots."""
    kg = generate_kg(num_entities=130, num_relations=9, num_triples=1000, seed=0)
    cd = partition_by_relation(kg, 3, seed=0)

    def mk():
        return [
            KGEClient(d, method="transe", dim=8, batch_size=48, num_negatives=4,
                      lr=5e-3, seed=0)
            for d in cd
        ]

    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    engine = SuperstepEngine(mk(), views, kg.num_entities, sparsity_p=0.5,
                             local_epochs=2, codec=get_codec("int8", ef=True))

    sa = engine.init_state(mk(), seed=3)
    assert isinstance(sa.arrays.res, jax.Array)  # device-resident buffer
    sa, _, _ = engine.superstep(sa, ("sparse", "sparse"))
    assert float(jnp.abs(sa.arrays.res).max()) > 0  # quantization error banked
    for c, v in enumerate(engine.views):  # padding slots stay zero
        np.testing.assert_array_equal(
            np.asarray(sa.arrays.res)[c, v.num_shared:], 0.0
        )

    sb = engine.init_state(mk(), seed=3)
    for kind in ("sparse", "sparse"):
        sb, _, _ = engine.fused_cycle(sb, sync=False)
    np.testing.assert_array_equal(np.asarray(sa.arrays.res), np.asarray(sb.arrays.res))
    np.testing.assert_array_equal(
        np.asarray(sa.arrays.params["entity"]), np.asarray(sb.arrays.params["entity"])
    )

    sa, _, _ = engine.superstep(sa, ("sync",))
    np.testing.assert_array_equal(np.asarray(sa.arrays.res), 0.0)  # sync clears
