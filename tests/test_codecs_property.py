"""Hypothesis property tests for every registered codec: encode→decode
round-trip error bounds and ledger byte math vs closed form, over drawn
shapes/values/hyper-parameters.  Seeded deterministic twins live in
tests/test_codecs.py (this container has no hypothesis wheel; CI installs
requirements-dev.txt and runs these)."""
import numpy as np

import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.codecs import (
    IdentityCodec,
    Int8RowCodec,
    LowRankCodec,
    TopKDimsCodec,
    get_codec,
    registered_codecs,
)
from repro.federated.comm import CommLedger


def _rows(seed: int, k: int, d: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(k, d)) * 3.0, jnp.float32)


rows_st = st.tuples(
    st.integers(0, 2**31 - 1),  # value seed
    st.integers(1, 12),  # k rows
    st.sampled_from([8, 16, 32]),  # row width (divisible by lowrank cols)
)


@settings(max_examples=25, deadline=None)
@given(rows_st, st.sampled_from(sorted(registered_codecs())))
def test_roundtrip_equals_decode_of_encode(draw, name):
    seed, k, d = draw
    codec = get_codec(name)
    v = _rows(seed, k, d)
    np.testing.assert_array_equal(
        np.asarray(codec.roundtrip(v)), np.asarray(codec.decode(codec.encode(v)))
    )


@settings(max_examples=25, deadline=None)
@given(rows_st)
def test_identity_roundtrip_exact(draw):
    v = _rows(*draw)
    np.testing.assert_array_equal(np.asarray(IdentityCodec().roundtrip(v)), np.asarray(v))


@settings(max_examples=25, deadline=None)
@given(rows_st, st.booleans())
def test_int8_roundtrip_error_bound(draw, ef):
    """Row-wise symmetric int8: |err| <= scale/2 = max|row| / 254 per row."""
    v = _rows(*draw)
    back = np.asarray(Int8RowCodec(ef=ef).roundtrip(v))
    row_max = np.abs(np.asarray(v)).max(axis=-1, keepdims=True)
    assert (np.abs(back - np.asarray(v)) <= row_max / 254.0 + 1e-7).all()


@settings(max_examples=25, deadline=None)
@given(rows_st, st.sampled_from([2, 4]), st.integers(1, 4))
def test_lowrank_roundtrip_error_bound(draw, cols, rank):
    """Truncated SVD is the OPTIMAL rank-r approximation: per-row Frobenius
    error equals sqrt(sum of dropped squared singular values)."""
    seed, k, d = draw
    v = _rows(seed, k, d)
    back = np.asarray(LowRankCodec(cols=cols, rank=rank).roundtrip(v))
    m = d // cols
    r = min(rank, m, cols)
    mat = np.asarray(v).reshape(k, m, cols)
    s = np.linalg.svd(mat, compute_uv=False)  # (k, min(m, cols))
    want_err = np.sqrt((s[:, r:] ** 2).sum(axis=-1))
    got_err = np.linalg.norm((back - np.asarray(v)).reshape(k, -1), axis=-1)
    np.testing.assert_allclose(got_err, want_err, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(rows_st, st.floats(0.05, 1.0))
def test_topk_dims_keeps_top_magnitudes(draw, frac):
    seed, k, d = draw
    codec = TopKDimsCodec(frac=frac)
    v = np.asarray(_rows(seed, k, d))
    back = np.asarray(codec.roundtrip(jnp.asarray(v)))
    kd = codec.k_dims(d)
    for i in range(k):
        order = np.argsort(-np.abs(v[i]), kind="stable")
        kept, dropped = order[:kd], order[kd:]
        np.testing.assert_array_equal(back[i, kept], v[i, kept])
        np.testing.assert_array_equal(back[i, dropped], 0.0)


ledger_st = st.tuples(
    st.integers(0, 200),  # k selected rows
    st.sampled_from([8, 16, 32, 256]),  # dim
    st.integers(0, 500),  # num_shared
)


@settings(max_examples=50, deadline=None)
@given(ledger_st, st.sampled_from(sorted(registered_codecs())))
def test_ledger_byte_math_vs_closed_form(draw, name):
    """Every codec's ledger legs match the closed forms (params exclude row
    indices; bytes include i32 row indices and the i8 sign vector)."""
    k, dim, ns = draw
    codec = get_codec(name)
    up, down = CommLedger(), CommLedger()
    codec.log_upload(up, k, dim, ns)
    codec.log_download(down, k, dim, ns)

    if name == "identity":
        pu, bu = k * dim + ns, k * dim * 4 + ns + k * 4
        pd, bd = k * dim + k + ns, k * dim * 4 + k * 4 + ns + k * 4
    elif name == "int8":
        pu, bu = k * dim / 4 + k + ns, k * dim + k * 4 + ns + k * 4
        pd, bd = k * dim / 4 + 2 * k + ns, k * (dim + 8) + k * 4 + ns
    elif name == "lowrank":
        ppr = codec.params_per_row(dim)
        m = dim // codec.cols
        r = min(codec.rank, m, codec.cols)
        assert ppr == m * r + r + codec.cols * r
        pu, bu = k * ppr + ns, k * ppr * 4 + k * 4 + ns
        pd, bd = k * ppr + k + ns, k * ppr * 4 + k * 4 + k * 4 + ns
    elif name == "topk-dims":
        kd = codec.k_dims(dim)
        pu, bu = k * kd + ns, k * kd * 4 + k * kd * 2 + k * 4 + ns
        pd, bd = k * kd + k + ns, k * kd * 4 + k * kd * 2 + k * 4 + k * 4 + ns
    else:  # a codec registered after this test was written: keep it honest
        pytest.fail(f"no closed form recorded for codec {name!r} — add one")

    assert (up.params_transmitted, up.bytes_int8_signs) == (pu, bu), name
    assert (down.params_transmitted, down.bytes_int8_signs) == (pd, bd), name


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 200), st.integers(0, 500))
def test_lossy_codecs_cost_fewer_params_than_identity_at_paper_dim(k, ns):
    """At the paper's dim (256) every lossy codec's default configuration
    transmits fewer params per leg than identity.  (At toy dims this can
    invert — low-rank factor overhead exceeds the row itself, which is
    exactly the capacity-vs-overhead trade Table I probes.)"""
    dim = 256
    ident = CommLedger()
    IdentityCodec().log_upload(ident, k, dim, ns)
    for name in registered_codecs():
        led = CommLedger()
        get_codec(name).log_upload(led, k, dim, ns)
        assert led.params_transmitted <= ident.params_transmitted, name
