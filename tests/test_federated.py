"""Integration tests for the federated runtime (FedS vs FedEP protocols)."""
import numpy as np
import pytest

from repro.core.sync import comm_ratio_worst_case
from repro.data import generate_kg, partition_by_relation
from repro.federated.comm import CommLedger
from repro.federated.metrics import first_round_reaching, weighted_average
from repro.federated.simulation import FederatedConfig, run_federated


@pytest.fixture(scope="module")
def small_fed():
    kg = generate_kg(num_entities=250, num_relations=12, num_triples=2500, seed=0)
    clients = partition_by_relation(kg, 3, seed=0)
    return kg, clients


def _cfg(**kw):
    base = dict(
        method="transe", dim=32, rounds=6, local_epochs=1, batch_size=128,
        num_negatives=16, lr=5e-3, sparsity_p=0.4, sync_interval=2,
        eval_every=2, max_eval_triples=60, seed=0,
    )
    base.update(kw)
    return FederatedConfig(**base)


def test_feds_runs_and_logs(small_fed):
    kg, clients = small_fed
    res = run_federated(clients, kg.num_entities, _cfg(protocol="feds"))
    assert res.rounds_run == 6
    assert res.ledger.rounds == 6
    assert len(res.eval_history) == 3
    assert res.test_mrr_cg > 0


def test_feds_transmits_less_than_fedep(small_fed):
    """Per-round parameter counts: FedS strictly below FedEP, and within the
    Eq. 5 worst-case bound."""
    kg, clients = small_fed
    feds = run_federated(clients, kg.num_entities, _cfg(protocol="feds"))
    fedep = run_federated(clients, kg.num_entities, _cfg(protocol="fedep"))
    assert feds.ledger.params_transmitted < fedep.ledger.params_transmitted
    ratio = feds.ledger.params_transmitted / fedep.ledger.params_transmitted
    bound = comm_ratio_worst_case(0.4, 2, 32)
    assert ratio <= bound * 1.02  # worst case + slack for round-boundary effects


def test_single_protocol_no_comm(small_fed):
    kg, clients = small_fed
    res = run_federated(clients, kg.num_entities, _cfg(protocol="single", rounds=4))
    assert res.ledger.params_transmitted == 0


def test_feds_nosync_never_syncs(small_fed):
    """Ablation variant transmits even less (no full-exchange rounds)."""
    kg, clients = small_fed
    nosync = run_federated(clients, kg.num_entities, _cfg(protocol="feds_nosync"))
    feds = run_federated(clients, kg.num_entities, _cfg(protocol="feds"))
    assert nosync.ledger.params_transmitted < feds.ledger.params_transmitted


def test_learning_improves_mrr(small_fed):
    """FedS training must substantially beat the round-5 validation MRR."""
    kg, clients = small_fed
    res = run_federated(
        clients, kg.num_entities,
        _cfg(protocol="feds", rounds=30, local_epochs=3, num_negatives=32,
             lr=1e-2, eval_every=5, patience=5, max_eval_triples=60),
    )
    first = res.eval_history[0][1]
    assert res.val_mrr_cg > 2 * first
    assert res.val_mrr_cg > 0.05


# ------------------------------------------------------------------ ledger
def test_ledger_accounting():
    led = CommLedger()
    led.log_upload_sparse(k=10, dim=8, n_entities=50)   # 80 + 50
    led.log_download_sparse(k=10, dim=8, n_entities=50)  # 80 + 10 + 50
    led.end_round()
    assert led.params_transmitted == 270
    led.log_full_exchange(n_entities=50, dim=8)  # 400
    led.end_round()
    assert led.params_transmitted == 670
    assert led.params_at_round(1) == 270
    assert led.params_at_round(2) == 670


def test_weighted_average():
    out = weighted_average([
        {"mrr": 0.5, "hits10": 0.8, "count": 10},
        {"mrr": 0.1, "hits10": 0.2, "count": 30},
    ])
    np.testing.assert_allclose(out["mrr"], 0.2)
    np.testing.assert_allclose(out["hits10"], 0.35)


def test_first_round_reaching():
    hist = [(2, 0.1), (4, 0.3), (6, 0.5)]
    assert first_round_reaching(hist, 0.25) == 4
    assert first_round_reaching(hist, 0.9) is None
