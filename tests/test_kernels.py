"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle.

Sweeps shapes (including non-aligned N and D) and dtypes, per the brief.
Also asserts the ops-layer dispatch (ref fallback) is bit-compatible with the
kernels so the federated simulation and the TPU path compute the same thing.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.change_score import change_score_pallas
from repro.kernels.kge_score import rotate_neg_score_pallas, transe_neg_score_pallas
from repro.kernels.sparse_apply import sparse_apply_pallas


SHAPES_ND = [(8, 16), (100, 64), (257, 130), (512, 256), (33, 100)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES_ND)
@pytest.mark.parametrize("dtype", DTYPES)
def test_change_score_kernel(shape, dtype):
    n, d = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * d))
    cur = jax.random.normal(k1, (n, d)).astype(dtype)
    hist = (jax.random.normal(k2, (n, d)) * 0.5 + cur.astype(jnp.float32)).astype(dtype)
    got = change_score_pallas(cur, hist, block_rows=64, interpret=True)
    want = ref.change_score_ref(cur.astype(jnp.float32), hist.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,n,d", [(4, 8, 32), (7, 33, 64), (16, 128, 100)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_transe_kernel(b, n, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * n + d), 3)
    h = jax.random.normal(ks[0], (b, d)).astype(dtype)
    r = jax.random.normal(ks[1], (b, d)).astype(dtype)
    t = jax.random.normal(ks[2], (b, n, d)).astype(dtype)
    got = transe_neg_score_pallas(h, r, t, gamma=8.0, block_b=4, block_n=32, interpret=True)
    want = ref.transe_neg_score_ref(
        h.astype(jnp.float32), r.astype(jnp.float32), t.astype(jnp.float32), 8.0
    )
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,n,d", [(4, 8, 32), (6, 20, 64)])
def test_rotate_kernel(b, n, d):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    h = jax.random.normal(ks[0], (b, d))
    phase = jax.random.uniform(ks[1], (b, d // 2), minval=-3.14, maxval=3.14)
    t = jax.random.normal(ks[2], (b, n, d))
    got = rotate_neg_score_pallas(h, phase, t, gamma=8.0, block_b=2, block_n=8, interpret=True)
    want = ref.rotate_neg_score_ref(h, phase, t, 8.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,n,d", [(4, 8, 32), (7, 150, 64), (16, 257, 100)])
@pytest.mark.parametrize("method", ["transe", "rotate"])
def test_dist_cand_score_kernel(b, n, d, method):
    """The eval-shaped kernel (shared candidate block across the batch) vs
    the exact scoring-fn broadcast the ref dispatch path uses."""
    from repro.kernels.kge_score import dist_cand_score_pallas
    from repro.kge.scoring import get_score_fn

    if method == "rotate" and d % 2:
        d += 1
    ks = jax.random.split(jax.random.PRNGKey(b * n + d), 3)
    cand = jax.random.normal(ks[2], (n, d))
    score = get_score_fn(method)
    if method == "transe":
        h = jax.random.normal(ks[0], (b, d))
        r = jax.random.normal(ks[1], (b, d))
        q = h + r  # tail-leg query rows (see kernels.ops.kge_cand_scores)
        want = score(h[:, None, :], r[:, None, :], cand[None, :, :], 8.0)
    else:
        h = jax.random.normal(ks[0], (b, d))
        phase = jax.random.uniform(ks[1], (b, d // 2), minval=-3.14, maxval=3.14)
        half = d // 2
        h_re, h_im = h[:, :half], h[:, half:]
        q = jnp.concatenate(
            [h_re * jnp.cos(phase) - h_im * jnp.sin(phase),
             h_re * jnp.sin(phase) + h_im * jnp.cos(phase)], axis=-1)
        want = score(h[:, None, :], phase[:, None, :], cand[None, :, :], 8.0)
    got = dist_cand_score_pallas(q, cand, 8.0, method=method, block_b=4,
                                 block_n=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,n,d", [(4, 8, 32), (7, 150, 64), (16, 257, 100)])
@pytest.mark.parametrize("method", ["distmult", "complex"])
def test_bilinear_cand_score_kernel(b, n, d, method):
    """The bilinear (MXU contraction) eval kernel vs the exact scoring-fn
    broadcast, both legs, using the registry's own query-row folding."""
    from repro.kernels.bilinear_score import bilinear_cand_score_pallas
    from repro.kge.scoring import get_scoring

    if method == "complex" and d % 2:
        d += 1
    spec = get_scoring(method)
    ks = jax.random.split(jax.random.PRNGKey(b * n + d), 4)
    h = jax.random.normal(ks[0], (b, d))
    r = jax.random.normal(ks[1], (b, spec.rel_dim(d)))
    t = jax.random.normal(ks[2], (b, d))
    cand = jax.random.normal(ks[3], (n, d))
    q_t, q_h = spec.cand_queries(h, r, t, 8.0)
    for q, want in (
        (q_t, spec.score(h[:, None, :], r[:, None, :], cand[None, :, :], 8.0)),
        (q_h, spec.score(cand[None, :, :], r[:, None, :], t[:, None, :], 8.0)),
    ):
        got = bilinear_cand_score_pallas(q, cand, block_b=4, block_n=32,
                                         interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_kge_cand_scores_head_leg_algebra():
    """ops.kge_cand_scores' head-leg query folding (t - r for TransE,
    t∘conj(r) for RotatE, t∘r / the conjugated coefficients for the
    bilinear family) must agree with scoring the candidates as heads
    directly, for EVERY registered method."""
    from repro.kernels import ops
    from repro.kge.scoring import registered_methods

    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    b, n, d = 6, 40, 16
    cand = jax.random.normal(ks[3], (n, d))
    for method, spec in registered_methods().items():
        h = jax.random.normal(ks[0], (b, d))
        r = jax.random.normal(ks[1], (b, spec.rel_dim(d)))
        t = jax.random.normal(ks[2], (b, d))
        _, hs = ops.kge_cand_scores(h, r, t, cand, method, 8.0)
        want = spec.score(cand[None, :, :], r[:, None, :], t[:, None, :], 8.0)
        np.testing.assert_allclose(np.asarray(hs), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=method)


@pytest.mark.parametrize(
    "method", ["transe", "rotate", "protate", "distmult", "complex", "proje"]
)
def test_kge_cand_scores_interpret_close_to_ref(monkeypatch, method):
    """Family-tagged Pallas dispatch (interpret) of both legs stays within
    fp tolerance of the exact ref path for every registered method — the
    regression pin for the old silent ComplEx ref fallback."""
    from repro.kernels import ops
    from repro.kge.scoring import get_scoring

    spec = get_scoring(method)
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    b, n, d = 5, 33, 32
    h = jax.random.normal(ks[0], (b, d))
    r = jax.random.normal(ks[1], (b, spec.rel_dim(d)))
    t = jax.random.normal(ks[2], (b, d))
    cand = jax.random.normal(ks[3], (n, d))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    ts_a, hs_a = ops.kge_cand_scores(h, r, t, cand, method, 8.0)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    ts_b, hs_b = ops.kge_cand_scores(h, r, t, cand, method, 8.0)
    np.testing.assert_allclose(np.asarray(ts_a), np.asarray(ts_b),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hs_a), np.asarray(hs_b),
                               rtol=1e-4, atol=1e-4)


def test_kge_cand_scores_unknown_method_lists_registry():
    """Unknown methods must raise the registry's self-describing error, not
    silently fall back to any kernel."""
    from repro.kernels import ops
    from repro.kge.scoring import registered_methods

    x = jnp.zeros((2, 8))
    cand = jnp.zeros((3, 8))
    with pytest.raises(ValueError) as e:
        ops.kge_cand_scores(x, x, x, cand, "no-such-method", 8.0)
    for name in registered_methods():
        assert name in str(e.value)


@pytest.mark.parametrize("shape", [(16, 8), (100, 64), (257, 100)])
def test_sparse_apply_kernel(shape):
    n, d = shape
    ks = jax.random.split(jax.random.PRNGKey(n), 4)
    emb = jax.random.normal(ks[0], (n, d))
    agg = jax.random.normal(ks[1], (n, d))
    pri = jax.random.randint(ks[2], (n,), 0, 5).astype(jnp.float32)
    sign = (jax.random.uniform(ks[3], (n,)) < 0.4).astype(jnp.int8)
    got = sparse_apply_pallas(emb, agg, pri, sign, block_rows=32, interpret=True)
    want = ref.sparse_apply_ref(emb, agg, pri, sign)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 200),
    d=st.integers(4, 150),
    frac=st.floats(0.05, 0.95),
)
def test_sparse_apply_property(n, d, frac):
    """Property: unselected rows pass through untouched; selected rows obey Eq. 4."""
    ks = jax.random.split(jax.random.PRNGKey(n * 1000 + d), 4)
    emb = jax.random.normal(ks[0], (n, d))
    agg = jax.random.normal(ks[1], (n, d))
    pri = jax.random.randint(ks[2], (n,), 1, 7).astype(jnp.float32)
    sign = (jax.random.uniform(ks[3], (n,)) < frac).astype(jnp.int8)
    out = np.asarray(ref.sparse_apply_ref(emb, agg, pri, sign))
    emb_n, agg_n, pri_n, sign_n = map(np.asarray, (emb, agg, pri, sign))
    unsel = sign_n == 0
    np.testing.assert_array_equal(out[unsel], emb_n[unsel])
    sel = ~unsel
    expect = (agg_n[sel] + emb_n[sel]) / (1.0 + pri_n[sel])[:, None]
    np.testing.assert_allclose(out[sel], expect, rtol=1e-6)


def test_ops_dispatch_ref_equals_interpret(monkeypatch):
    """ops.change_score must give the same numbers in ref and interpret modes."""
    from repro.kernels import ops

    cur = jax.random.normal(jax.random.PRNGKey(0), (60, 48))
    hist = jax.random.normal(jax.random.PRNGKey(1), (60, 48))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    a = np.asarray(ops.change_score(cur, hist))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    b = np.asarray(ops.change_score(cur, hist))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,l,h,n,p", [(2, 8, 3, 4, 8), (1, 16, 2, 8, 16),
                                        (2, 12, 4, 16, 32)])
def test_ssd_chunk_kernel(b, l, h, n, p):
    from repro.kernels.ssd_chunk import ssd_chunk_pallas

    ks = jax.random.split(jax.random.PRNGKey(b * l + h), 6)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.4
    bb = jax.random.normal(ks[1], (b, l, n)) * 0.4
    cc = jax.random.normal(ks[2], (b, l, n)) * 0.4
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, l, h)))
    ld = -jnp.abs(jax.random.normal(ks[4], (b, l, h))) * 0.3
    hp = jax.random.normal(ks[5], (b, h, n, p)) * 0.2
    y0, h0 = ref.ssd_chunk_ref(x, bb, cc, dt, ld, hp)
    y1, h1 = ssd_chunk_pallas(x, bb, cc, dt, ld, hp, interpret=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=2e-5, atol=2e-5)


def test_ssd_chunk_sequential_equivalence():
    """Two chained chunks == one double-length chunk (state passing)."""
    b, l, h, n, p = 1, 6, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, 2 * l, h, p)) * 0.4
    bb = jax.random.normal(ks[1], (b, 2 * l, n)) * 0.4
    cc = jax.random.normal(ks[2], (b, 2 * l, n)) * 0.4
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, 2 * l, h)))
    ld = -jnp.abs(jax.random.normal(ks[4], (b, 2 * l, h))) * 0.3
    h0 = jnp.zeros((b, h, n, p))
    y_full, h_full = ref.ssd_chunk_ref(x, bb, cc, dt, ld, h0)
    y1, h1 = ref.ssd_chunk_ref(x[:, :l], bb[:, :l], cc[:, :l], dt[:, :l], ld[:, :l], h0)
    y2, h2 = ref.ssd_chunk_ref(x[:, l:], bb[:, l:], cc[:, l:], dt[:, l:], ld[:, l:], h1)
    np.testing.assert_allclose(np.asarray(y_full[:, :l]), np.asarray(y1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_full[:, l:]), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), rtol=1e-5, atol=1e-5)
