"""Structural unit tests: layer segmentation, windows, comm views."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.protocol import build_comm_views
from repro.models.transformer import hybrid_segments, layer_windows, slstm_layer_ids


def test_hybrid_segments_zamba():
    cfg = get_config("zamba2-1.2b")  # 38 layers, attn every 6
    segs = hybrid_segments(cfg)
    assert sum(ln for _, ln, _ in segs) == 38
    assert [a for _, _, a in segs] == [True] * 6 + [False]  # 6 full + tail of 2
    assert segs[-1][1] == 2


def test_slstm_ids_xlstm():
    cfg = get_config("xlstm-350m")  # 24 layers, every 6th sLSTM
    ids = slstm_layer_ids(cfg)
    assert ids == [5, 11, 17, 23]


def test_layer_windows_gemma():
    cfg = get_config("gemma3-1b")
    w = np.asarray(layer_windows(cfg))
    assert w.shape == (26,)
    # 5 local : 1 global repeating
    assert (w[np.arange(26) % 6 == 5] == 0).all()
    assert (w[np.arange(26) % 6 != 5] == 512).all()
    wl = np.asarray(layer_windows(cfg, long_context=True))
    assert (wl[np.arange(26) % 6 == 5] == 131072).all()  # design-budget window


def test_layer_windows_full_attention():
    cfg = get_config("qwen2-72b")
    assert (np.asarray(layer_windows(cfg)) == 0).all()


def test_build_comm_views_excludes_exclusive_entities():
    l2g = [np.array([0, 1, 2, 3]), np.array([2, 3, 4]), np.array([3, 9])]
    views = build_comm_views(l2g, num_global=10)
    # entity 0,1 only on client 0; 4 only on client 1; 9 only on client 2
    assert views[0].shared_global.tolist() == [2, 3]
    assert views[1].shared_global.tolist() == [2, 3]
    assert views[2].shared_global.tolist() == [3]
    assert views[0].shared_local.tolist() == [2, 3]


def test_effective_heads_and_padding_config():
    cfg = get_config("arctic-480b")
    assert cfg.num_heads == 56 and cfg.effective_heads == 64
    q = get_config("qwen2-vl-7b")
    assert q.num_heads == 28 and q.effective_heads == 32
    m = get_config("qwen2-moe-a2.7b")
    assert m.num_experts == 60 and m.moe_pad_experts == 64
