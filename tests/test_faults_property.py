"""Hypothesis chaos harness for the fault-injection subsystem.

Two properties anchor the robustness story:

* **all-ones neutrality** — a forced-trivial schedule keeps the fault
  machinery compiled into the programs but draws all-ones masks
  (``bernoulli(key, 1.0)`` is deterministically True); the resulting
  trajectory must be *bitwise* identical to the unfaulted engines for every
  registered wire codec, including error-feedback variants.  This pins down
  that the mask plumbing itself (×1.0 multiplies, &True gates, queue
  pass-throughs) never perturbs a value.
* **ledger exactness under chaos** — for arbitrary drawn schedules
  (participation, drops on both legs, lagged stragglers) the numpy
  reference oracle and the scanned superstep engine must bill byte-for-byte
  identical ledgers.  Sparsity is pinned at 1.0, which makes the downstream
  selection tie-break-free, so billing is a pure function of the schedule —
  any divergence is a fault-semantics bug, not a tie-break artifact.

Seeded deterministic twins live in tests/test_faults.py (this container has
no hypothesis wheel; CI installs requirements-dev.txt and runs these).
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core.codecs import parse_codec_spec
from repro.core.faults import FaultSchedule, parse_fault_spec
from repro.core.protocol import build_comm_views
from repro.core.state import CycleEngine
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.simulation import FederatedConfig, run_federated

CODEC_SPECS = ("identity", "int8", "int8:ef=1", "lowrank", "lowrank:ef=1",
               "topk-dims")


def _mini(seed, num_clients=2):
    kg = generate_kg(num_entities=110, num_relations=4 * num_clients,
                     num_triples=700, seed=seed)
    cd = partition_by_relation(kg, num_clients, seed=0)

    def mk():
        return [
            KGEClient(d, method="transe", dim=8, batch_size=32,
                      num_negatives=4, lr=5e-3, seed=0)
            for d in cd
        ]

    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    return kg, cd, views, mk


@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from(CODEC_SPECS),
    st.integers(0, 50),  # instance seed
    st.integers(0, 2**31 - 1),  # fault seed (must not matter at all-ones)
)
def test_forced_all_ones_schedule_is_bitwise_neutral(spec, seed, fault_seed):
    kg, cd, views, mk = _mini(seed % 5)
    codec = parse_codec_spec(spec)
    plain = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5,
                        local_epochs=1, codec=codec)
    forced = CycleEngine(
        mk(), views, kg.num_entities, sparsity_p=0.5, local_epochs=1,
        codec=codec,
        faults=FaultSchedule(seed=fault_seed, force=True),
    )
    assert forced._sched is not None  # machinery really compiled in
    sa = plain.init_state(mk(), seed=seed)
    sb = forced.init_state(mk(), seed=seed)
    for t, sync in enumerate((False, False, True, False)):
        sa, da, la = plain.fused_cycle(sa, sync=sync)
        sb, db, lb = forced.fused_cycle(sb, sync=sync, t=t)
        np.testing.assert_array_equal(np.asarray(da), np.asarray(db))
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_array_equal(np.asarray(sa.key), np.asarray(sb.key))
    for name, a, b in (
        ("entity", sa.arrays.params["entity"], sb.arrays.params["entity"]),
        ("relation", sa.arrays.params["relation"], sb.arrays.params["relation"]),
        ("hist", sa.arrays.hist, sb.arrays.hist),
        ("res", sa.arrays.res, sb.arrays.res),
        ("mu_e", sa.arrays.opt.mu["entity"], sb.arrays.opt.mu["entity"]),
        ("nu_e", sa.arrays.opt.nu["entity"], sb.arrays.opt.nu["entity"]),
    ):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{spec}:{name}"
        )


schedule_st = st.builds(
    lambda p, du, dd, strag, lag, seed: (
        f"p={p},drop_up={du},drop_down={dd},seed={seed}"
        + (f",stragglers=0,lag={lag}" if strag else "")
    ),
    st.sampled_from([0.3, 0.5, 0.8, 1.0]),
    st.sampled_from([0.0, 0.25]),
    st.sampled_from([0.0, 0.25]),
    st.booleans(),
    st.integers(1, 2),
    st.integers(0, 2**31 - 1),
)


@settings(max_examples=4, deadline=None)
@given(schedule_st)
def test_ledger_accounting_exact_reference_vs_superstep(spec):
    """Byte-exact billing under arbitrary seeded schedules.  A trivial draw
    (p=1.0, no drops, no stragglers) degenerates to the existing unfaulted
    equivalence, which is exactly the intended boundary behavior."""
    sched = parse_fault_spec(spec)
    if sched.trivial:
        spec = spec + ",force=1"  # keep the faulted code path under test
    kg, cd, _views, _mk = _mini(3)
    base = dict(method="transe", protocol="feds", dim=8, rounds=6,
                local_epochs=1, batch_size=32, num_negatives=4, lr=5e-3,
                sparsity_p=1.0, sync_interval=3, eval_every=3, patience=99,
                max_eval_triples=30, seed=0, faults=spec)
    ref = run_federated(cd, kg.num_entities,
                        FederatedConfig(engine="reference", **base))
    sstep = run_federated(cd, kg.num_entities,
                          FederatedConfig(engine="superstep", **base))
    assert ref.ledger.history == sstep.ledger.history, spec
    assert ref.ledger.params_transmitted == sstep.ledger.params_transmitted
    assert ref.ledger.bytes_int8_signs == sstep.ledger.bytes_int8_signs
    assert all(np.isfinite(m) for _, m, _ in ref.eval_history)
    assert all(np.isfinite(m) for _, m, _ in sstep.eval_history)
