"""Unit + property tests for the FedS core (sparsify / aggregate / sync)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.aggregate import Upload, fede_aggregate, personalized_aggregate
from repro.core.sparsify import change_scores, select_top_k, sparsity_k, upstream_sparsify
from repro.core.sync import (
    comm_ratio_worst_case,
    cycle_params_feds,
    cycle_params_full,
    is_sync_round,
)


# ----------------------------------------------------------------- sparsify
def test_change_scores_zero_for_unchanged():
    e = jax.random.normal(jax.random.PRNGKey(0), (20, 8))
    s = np.asarray(change_scores(e, e))
    np.testing.assert_allclose(s, 0.0, atol=1e-5)


def test_change_scores_order():
    """Rows rotated further from history must score higher."""
    base = jnp.ones((3, 4))
    cur = jnp.stack([
        jnp.array([1.0, 1, 1, 1]),        # unchanged
        jnp.array([1.0, 1, 1, -1]),       # some change
        jnp.array([-1.0, -1, -1, -1]),    # opposite
    ])
    s = np.asarray(change_scores(cur, base))
    assert s[0] < s[1] < s[2]


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 100), p=st.floats(0.05, 1.0))
def test_sparsity_k_bounds(n, p):
    k = sparsity_k(n, p)
    assert 1 <= k <= n


def test_select_top_k_semantics():
    scores = jnp.array([0.1, 0.9, 0.3, 0.7, 0.0])
    idx, sign = select_top_k(scores, 2)
    assert set(np.asarray(idx).tolist()) == {1, 3}
    np.testing.assert_array_equal(np.asarray(sign), [0, 1, 0, 1, 0])
    assert int(sign.sum()) == 2


def test_upstream_sparsify_history_refresh():
    key = jax.random.PRNGKey(0)
    cur = jax.random.normal(key, (10, 6))
    hist = jax.random.normal(jax.random.PRNGKey(1), (10, 6))
    idx, values, sign, new_hist = upstream_sparsify(cur, hist, k=4)
    idx_np = np.asarray(idx)
    # selected rows: history refreshed to current; values are the current rows
    np.testing.assert_allclose(np.asarray(new_hist)[idx_np], np.asarray(cur)[idx_np])
    np.testing.assert_allclose(np.asarray(values), np.asarray(cur)[idx_np])
    # unselected rows: history untouched
    unsel = np.setdiff1d(np.arange(10), idx_np)
    np.testing.assert_allclose(np.asarray(new_hist)[unsel], np.asarray(hist)[unsel])


# ---------------------------------------------------------------- aggregate
def _mk_upload(cid, ids, dim=4, val=None):
    ids = np.asarray(ids, dtype=np.int64)
    vals = np.full((len(ids), dim), float(cid + 1), np.float32) if val is None else val
    return Upload(client_id=cid, entity_ids=ids, values=vals)


def test_personalized_aggregate_excludes_own_upload():
    # entity 0 uploaded by clients 0 and 1; client 0's download of entity 0
    # must only contain client 1's value.
    uploads = [_mk_upload(0, [0]), _mk_upload(1, [0]), _mk_upload(2, [5])]
    ents = [np.array([0, 5]), np.array([0, 5]), np.array([0, 5])]
    rng = np.random.default_rng(0)
    downs = personalized_aggregate(uploads, ents, sparsity_p=1.0, rng=rng)
    d0 = downs[0]
    row = list(d0.entity_ids).index(0)
    np.testing.assert_allclose(d0.agg_values[row], 2.0)  # only client 1 (val 2)
    assert d0.priority[row] == 1


def test_personalized_aggregate_priority_ranking():
    # entity 7 uploaded by 3 peers, entity 8 by 1 peer; K=1 must pick entity 7.
    uploads = [
        _mk_upload(0, []),
        _mk_upload(1, [7, 8]),
        _mk_upload(2, [7]),
        _mk_upload(3, [7]),
    ]
    ents = [np.array([7, 8]), np.array([7]), np.array([7]), np.array([7])]
    rng = np.random.default_rng(0)
    downs = personalized_aggregate(uploads, ents, sparsity_p=0.5, rng=rng)
    assert list(downs[0].entity_ids) == [7]
    assert downs[0].priority[0] == 3
    np.testing.assert_allclose(downs[0].agg_values[0], 2 + 3 + 4)


def test_personalized_aggregate_fewer_than_k():
    """When fewer aggregated entities exist than K, all are sent."""
    uploads = [_mk_upload(0, [1]), _mk_upload(1, [1])]
    ents = [np.array([1, 2, 3, 4]), np.array([1])]
    downs = personalized_aggregate(uploads, ents, 1.0, np.random.default_rng(0))
    assert list(downs[0].entity_ids) == [1]  # entities 2,3,4 had no uploads


def test_fede_aggregate_mean():
    uploads = [
        _mk_upload(0, [0, 1], val=np.array([[1, 1], [2, 2]], np.float32)),
        _mk_upload(1, [1], val=np.array([[4, 4]], np.float32)),
    ]
    mean, count = fede_aggregate(uploads, num_global_entities=3)
    np.testing.assert_allclose(mean[0], 1.0)
    np.testing.assert_allclose(mean[1], 3.0)  # (2+4)/2
    np.testing.assert_allclose(mean[2], 0.0)
    assert list(count) == [1, 2, 0]


# --------------------------------------------------------------------- sync
def test_sync_cycle_structure():
    s = 4
    rounds = [is_sync_round(t, s) for t in range(10)]
    # cycle: 4 sparse rounds then 1 sync round
    assert rounds == [False] * 4 + [True] + [False] * 4 + [True]


def test_sync_interval_zero_is_always_sync():
    assert all(is_sync_round(t, 0) for t in range(5))


@settings(max_examples=40, deadline=None)
@given(
    p=st.floats(0.1, 0.9),
    s=st.integers(1, 10),
    dim=st.integers(16, 512),
    n=st.integers(50, 2000),
)
def test_eq5_matches_cycle_accounting(p, s, dim, n):
    """Eq. 5 must equal the explicit per-cycle parameter ledger."""
    ratio = comm_ratio_worst_case(p, s, dim)
    explicit = cycle_params_feds(n, dim, p, s) / cycle_params_full(n, dim, s)
    np.testing.assert_allclose(ratio, explicit, rtol=1e-9)


def test_eq5_paper_values():
    """Appendix VI-C: p=0.7, s=4, D=256 -> 0.7642; p=0.4 -> FedEPL dim 135."""
    r = comm_ratio_worst_case(0.7, 4, 256)
    np.testing.assert_allclose(r, 0.7642, atol=5e-4)
    # paper: "the embedding dimension is calculated by rounding up"
    import math

    r2 = comm_ratio_worst_case(0.4, 4, 256)
    assert math.ceil(256 * r2) == 135


# --------------------------------------------------------------- FedS+Q8
def test_quantize_rows_roundtrip():
    from repro.core.sparsify import dequantize_rows, quantize_rows

    v = jax.random.normal(jax.random.PRNGKey(0), (12, 32)) * 3.0
    q, sc = quantize_rows(v)
    assert q.dtype == jnp.int8
    back = dequantize_rows(q, sc)
    # symmetric int8: error bounded by half a quantization step per row
    step = np.asarray(sc)[:, None]
    assert (np.abs(np.asarray(back) - np.asarray(v)) <= step * 0.5 + 1e-7).all()


def test_quantize_rows_zero_row():
    from repro.core.sparsify import dequantize_rows, quantize_rows

    v = jnp.zeros((3, 8))
    q, sc = quantize_rows(v)
    np.testing.assert_array_equal(np.asarray(dequantize_rows(q, sc)), 0.0)
