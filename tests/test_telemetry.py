"""Flight-recorder telemetry (repro.core.telemetry + the simulation sink).

Three layers of guarantee, mirroring the design:

* **unit** — the jit-safe record helpers (overlap, histogram, residual
  mass) against tiny numpy oracles;
* **structural** — telemetry off is the identity: the engines carry
  ``tel=None`` (zero pytree leaves) and the simulation's trajectory,
  ledger, and terminal metrics are bitwise equal with the recorder on or
  off (recording observes, never perturbs);
* **stream** — the JSONL grammar holds (exact round-event key set, one
  run header, a terminal ledger event), the device engines emit bitwise
  identical round events under a chaos schedule for every registered
  codec family, the reference path's billing fields agree with the device
  engines', and the shadow-ledger reconciliation invariant
  (``reconciled: true``) holds for every engine including tiered —
  which is also what ``tools/trace_report.py`` exits non-zero on.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.state import CycleEngine
from repro.core.protocol import build_comm_views
from repro.core.telemetry import (
    NUM_SCORE_BUCKETS,
    ROUND_EVENT_FIELDS,
    init_telemetry_arrays,
    residual_mass,
    score_histogram,
    upload_overlap,
)
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.simulation import FederatedConfig, run_federated

ROOT = Path(__file__).resolve().parent.parent

_CHAOS = "p=0.6,drop_up=0.2,drop_down=0.2,stragglers=0,lag=2,seed=3"
# one spec per registered codec family at dim=8 (lowrank: D % cols == 0),
# EF variants included so the residual-mass signal is live
CODEC_SPECS = (
    "identity",
    "int8:ef=1",
    "lowrank:cols=4,rank=1,ef=1",
    "topk-dims:frac=0.5",
)
DEVICE_ENGINES = ("fused", "batched", "superstep")


# ------------------------------------------------------------- unit helpers
def test_upload_overlap_matches_set_intersection():
    rng = np.random.default_rng(7)
    C, k = 4, 6
    up_idx = rng.integers(0, 30, size=(C, k)).astype(np.int32)
    prev_idx = rng.integers(0, 30, size=(C, k)).astype(np.int32)
    sent = (rng.random((C, k)) < 0.7).astype(np.float32)
    prev = (rng.random((C, k)) < 0.7).astype(np.float32)
    got = np.asarray(upload_overlap(
        jnp.asarray(up_idx), jnp.asarray(sent),
        jnp.asarray(prev_idx), jnp.asarray(prev),
    ))
    for c in range(C):
        a = {int(i) for i, m in zip(up_idx[c], sent[c]) if m}
        b = {int(i) for i, m in zip(prev_idx[c], prev[c]) if m}
        # slot indices within one upload are distinct, so the masked
        # pair-match sum is exactly the intersection size
        assert got[c] == len(a & b), c


def test_score_histogram_buckets_and_masks():
    scores = jnp.asarray([[0.1, 0.3, 1.99, 5.0, -jnp.inf]])
    valid = jnp.asarray([[True, True, True, True, False]])
    hist = np.asarray(score_histogram(scores, valid))
    assert hist.shape == (1, NUM_SCORE_BUCKETS)
    assert hist.sum() == 4  # the invalid slot is dropped
    assert hist[0, 0] == 1 and hist[0, 1] == 1  # 0.1, 0.3 (width 0.25)
    assert hist[0, -1] == 2  # 1.99 and the 5.0 overflow clip into the top


def test_residual_mass_is_l2_and_zero_width_is_zero():
    rng = np.random.default_rng(3)
    res = rng.normal(size=(3, 5, 4)).astype(np.float32)
    got = np.asarray(residual_mass(jnp.asarray(res)))
    want = np.linalg.norm(res.reshape(3, -1), axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    empty = np.asarray(residual_mass(jnp.zeros((3, 0, 4), jnp.float32)))
    np.testing.assert_array_equal(empty, 0.0)


def test_init_telemetry_arrays_zeroed():
    tel = init_telemetry_arrays(3, 5)
    assert tel.prev_idx.shape == (3, 5) and tel.prev_msk.shape == (3, 5)
    assert not np.asarray(tel.prev_msk).any()


# ------------------------------------------------ structural: off is identity
def _mini_clients(num_clients=2, seed=1):
    kg = generate_kg(num_entities=120, num_relations=4 * num_clients,
                     num_triples=800, seed=seed)
    cd = partition_by_relation(kg, num_clients, seed=0)
    clients = [
        KGEClient(d, method="transe", dim=8, batch_size=32,
                  num_negatives=4, lr=5e-3, seed=0)
        for d in cd
    ]
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    return kg, clients, views


def test_telemetry_off_carries_no_leaves():
    """telemetry=False must build the exact pre-telemetry state tree —
    ``tel`` is None (zero pytree leaves), not a zeroed array pair."""
    kg, clients, views = _mini_clients()
    off = CycleEngine(clients, views, kg.num_entities, sparsity_p=0.5,
                      local_epochs=1)
    assert off.init_state(clients, seed=0).arrays.tel is None
    _, clients2, _ = _mini_clients()
    on = CycleEngine(clients2, views, kg.num_entities, sparsity_p=0.5,
                     local_epochs=1, telemetry=True)
    tel = on.init_state(clients2, seed=0).arrays.tel
    assert tel is not None and tel.prev_idx.shape[0] == len(views)


# -------------------------------------------------------- simulation fixture
@pytest.fixture(scope="module")
def sim_env():
    kg = generate_kg(num_entities=120, num_relations=8, num_triples=900, seed=1)
    clients = partition_by_relation(kg, 2, seed=0)
    base = dict(method="transe", protocol="feds", dim=8, rounds=5,
                local_epochs=1, batch_size=32, num_negatives=4, lr=5e-3,
                sparsity_p=1.0, sync_interval=2, eval_every=2, patience=99,
                max_eval_triples=30, seed=0)
    return kg, clients, base


def _events(path):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _recorded_run(sim_env, tmp_path, tag, **overrides):
    kg, clients, base = sim_env
    path = tmp_path / f"{tag}.jsonl"
    cfg = FederatedConfig(telemetry=str(path), **dict(base, **overrides))
    res = run_federated(clients, kg.num_entities, cfg)
    return res, _events(path)


def test_telemetry_off_bitwise_neutral(sim_env, tmp_path):
    """The recorder observes; it never perturbs.  Trajectory, ledger, and
    terminal metrics must be bitwise equal with telemetry on or off."""
    kg, clients, base = sim_env
    off = run_federated(clients, kg.num_entities,
                        FederatedConfig(engine="fused", **base))
    on, events = _recorded_run(sim_env, tmp_path, "on", engine="fused")
    assert off.eval_history == on.eval_history
    assert off.ledger.history == on.ledger.history
    assert off.ledger.params_transmitted == on.ledger.params_transmitted
    assert off.ledger.bytes_int8_signs == on.ledger.bytes_int8_signs
    assert off.test_mrr_cg == on.test_mrr_cg
    assert events  # and the on-run actually recorded something


# ----------------------------------------------------------- stream grammar
def test_event_stream_grammar(sim_env, tmp_path):
    _, events = _recorded_run(sim_env, tmp_path, "grammar", engine="fused")
    kinds = [e["ev"] for e in events]
    assert kinds[0] == "run" and kinds.count("run") == 1
    assert kinds[-1] == "ledger" and kinds.count("ledger") == 1
    rounds = [e for e in events if e["ev"] == "round"]
    assert [e["round"] for e in rounds] == list(range(len(rounds)))
    want_keys = set(ROUND_EVENT_FIELDS) | {"ev"}
    for e in rounds:
        assert set(e) == want_keys, e["round"]
    evals = [e for e in events if e["ev"] == "eval"]
    assert [e["split"] for e in evals].count("test") == 1
    led = events[-1]
    assert led["reconciled"] is True
    assert led["params_transmitted"] == led["shadow_params"]
    assert led["bytes"] == led["shadow_bytes"]
    assert led["rounds"] == led["shadow_rounds"]


# --------------------------- cross-engine bitwise records under chaos×codecs
@pytest.mark.parametrize("codec", CODEC_SPECS)
def test_device_engines_record_bitwise_identical_under_chaos(
        sim_env, tmp_path, codec):
    """fused == batched == superstep round events, byte for byte, under a
    chaos schedule, for every registered codec family — and every stream
    reconciles against the real ledger."""
    streams = {}
    for eng in DEVICE_ENGINES:
        _, events = _recorded_run(
            sim_env, tmp_path, f"{eng}-{codec.split(':')[0]}",
            engine=eng, faults=_CHAOS, codec=codec,
        )
        led = events[-1]
        assert led["ev"] == "ledger" and led["reconciled"] is True, (eng, codec)
        streams[eng] = [e for e in events if e["ev"] == "round"]
    assert streams["fused"] == streams["batched"] == streams["superstep"]
    # the chaos schedule actually bit: some client skipped some round
    parts = [p for e in streams["fused"] for p in e["part"]]
    assert 0.0 in parts and 1.0 in parts


def test_reference_engine_reconciles_and_bills_like_device(sim_env, tmp_path):
    """The host-loop oracle rebuilds its records from ragged host state;
    its informational signals (score_hist, overlap) come from its own
    trajectory, but every billing field must equal the device engines'."""
    _, dev = _recorded_run(sim_env, tmp_path, "dev",
                           engine="superstep", faults=_CHAOS)
    _, ref = _recorded_run(sim_env, tmp_path, "ref",
                           engine="reference", faults=_CHAOS)
    assert ref[-1]["ev"] == "ledger" and ref[-1]["reconciled"] is True
    billing = ("round", "kind", "part", "up_rows", "dn_rows",
               "up_bytes", "dn_bytes", "age", "cum_params", "cum_bytes",
               "nonfinite")  # int probes are order-exact everywhere
    dev_rounds = [e for e in dev if e["ev"] == "round"]
    ref_rounds = [e for e in ref if e["ev"] == "round"]
    assert len(dev_rounds) == len(ref_rounds)
    for d, r in zip(dev_rounds, ref_rounds):
        for k in billing:
            assert d[k] == r[k], (d["round"], k)
        # The float health probes are informational: the twin computes them
        # over its OWN trajectory, which drifts from the device's under
        # chaos (different padding -> different fp paths, compounded by
        # training).  What must agree structurally: exact 0.0 at consensus
        # (mean of two bitwise-identical rows is exact in both), and the
        # same sawtooth within a band — max-type stats pick single
        # entities, so the band is wide.
        for k in ("div_mean", "div_max", "upd_norm"):
            dv, rv = np.asarray(d[k]), np.asarray(r[k])
            np.testing.assert_array_equal(
                dv == 0.0, rv == 0.0,
                err_msg=f"round {d['round']} {k} zero-set")
            np.testing.assert_allclose(
                dv, rv, rtol=0.5, atol=2e-3,
                err_msg=f"round {d['round']} {k}")


def test_tiered_engine_records_cache_activity(tmp_path):
    kg = generate_kg(num_entities=300, num_relations=4, num_triples=900, seed=2)
    cd = partition_by_relation(kg, 2, seed=2)
    path = tmp_path / "tiered.jsonl"
    cfg = FederatedConfig(
        method="transe", protocol="feds", dim=8, rounds=4, local_epochs=1,
        batch_size=32, num_negatives=4, lr=5e-3, sparsity_p=0.5,
        sync_interval=3, eval_every=2, max_eval_triples=32,
        engine="tiered", stage_steps=2, seed=3, telemetry=str(path),
    )
    run_federated(cd, kg.num_entities, cfg)
    events = _events(path)
    assert events[0]["ev"] == "run" and events[0]["engine"] == "tiered"
    assert events[-1]["ev"] == "ledger" and events[-1]["reconciled"] is True
    rounds = [e for e in events if e["ev"] == "round"]
    assert sum(e["cache_misses"] for e in rounds) > 0  # cold start misses
    spans = {e["name"] for e in events if e["ev"] == "span"}
    assert "stage" in spans and "eval" in spans


# -------------------------------------------------------- trace_report smoke
def _trace_report(jsonl_path):
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "trace_report.py"),
         str(jsonl_path)],
        capture_output=True, text=True, timeout=60,
    )


def test_trace_report_renders_and_verifies(sim_env, tmp_path):
    _, events = _recorded_run(sim_env, tmp_path, "report", engine="fused")
    res = _trace_report(tmp_path / "report.jsonl")
    assert res.returncode == 0, res.stdout + res.stderr
    assert "reconciliation [PASS]" in res.stdout
    assert "round" in res.stdout and "totals:" in res.stdout

    # a truncated stream (run died before _finish) must fail loudly
    cut = tmp_path / "cut.jsonl"
    cut.write_text("".join(
        json.dumps(e) + "\n" for e in events if e["ev"] != "ledger"
    ))
    res = _trace_report(cut)
    assert res.returncode == 1
    assert "ERROR" in res.stdout

    # a stream whose shadow totals disagree with the real ledger must fail
    # the reconciliation invariant, not pass on a stale flag
    forged = [
        dict(e, shadow_params=e["shadow_params"] + 1.0)
        if e["ev"] == "ledger" else e
        for e in events
    ]
    bad = tmp_path / "forged.jsonl"
    bad.write_text("".join(json.dumps(e) + "\n" for e in forged))
    res = _trace_report(bad)
    assert res.returncode == 1
    assert "reconciliation [FAIL]" in res.stdout
