"""Dry-run machinery tests.

The full 512-device sweep runs via ``python -m repro.launch.dryrun --all``
(results in dryrun_results.jsonl / EXPERIMENTS.md); here we verify the
machinery itself in a SUBPROCESS (so this pytest process keeps 1 device):
one small arch x shape on the production mesh, plus unit tests of the
sharding rule tables that don't need devices.
"""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import get_config
from repro.train.steps import INPUT_SHAPES, input_specs, shape_supported

ROOT = os.path.dirname(os.path.dirname(__file__))

_WORKER = r"""
import sys, json
sys.path.insert(0, "src")
from repro.launch.dryrun import dryrun_one
rec = dryrun_one("qwen3-0.6b", "decode_32k", multi_pod=False)
rec2 = dryrun_one("whisper-base", "train_4k", multi_pod=True)
print(json.dumps([rec, rec2]))
"""


@pytest.fixture(scope="module")
def dryrun_records():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _WORKER], capture_output=True, text=True,
        cwd=ROOT, env=env, timeout=1200,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_dryrun_compiles_and_reports(dryrun_records):
    rec, rec2 = dryrun_records
    assert rec["status"] == "OK"
    assert rec["num_devices"] == 256
    assert rec["flops_per_device"] > 0
    assert rec["bytes_per_device"] > 0
    assert rec["collective_bytes_per_device"]["_total"] >= 0
    assert rec2["status"] == "OK"
    assert rec2["num_devices"] == 512
    assert rec2["mesh"] == "2x16x16"


def test_skip_long_context_for_full_attention():
    cfg = get_config("qwen2-72b")
    ok, reason = shape_supported(cfg, INPUT_SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in reason
    ok, _ = shape_supported(get_config("zamba2-1.2b"), INPUT_SHAPES["long_500k"])
    assert ok


def test_input_specs_cover_modalities():
    vlm = input_specs(get_config("qwen2-vl-7b"), INPUT_SHAPES["train_4k"])
    assert "vision_embeds" in vlm and vlm["vision_embeds"].shape[1] == 256
    audio = input_specs(get_config("whisper-base"), INPUT_SHAPES["train_4k"])
    assert "encoder_embeds" in audio and audio["encoder_embeds"].shape[1] == 1500
    dense = input_specs(get_config("qwen3-0.6b"), INPUT_SHAPES["decode_32k"])
    assert dense["token"].shape == (128, 1)


def test_param_spec_rules_divisibility():
    """Sharding specs never assign an axis that doesn't divide the dim."""
    import numpy as np
    from repro.launch.mesh import make_debug_mesh  # needs >=4 devices? no — spec-only
    from repro.models.transformer import init_lm
    from repro.sharding.specs import param_specs

    # Build an abstract mesh-like object is overkill: use a real 1-device
    # mesh shape table via jax.sharding.Mesh with fake devices is not
    # possible here; instead check against the production mesh axis sizes.
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        devices = np.empty((16, 16), dtype=object)

    for arch in ("qwen2-72b", "arctic-480b", "gemma3-1b", "xlstm-350m"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda k, c=cfg: init_lm(k, c), jax.random.PRNGKey(0))
        specs = param_specs(shapes, cfg, FakeMesh(), None)

        def check(path, leaf, spec):
            for dim, axis in zip(leaf.shape, tuple(spec)):
                if axis is None:
                    continue
                size = 16 if isinstance(axis, str) else 256
                assert dim % 16 == 0, (arch, path, leaf.shape, spec)

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), shapes, specs
        )
