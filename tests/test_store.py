"""Host-tiered embedding store (repro.core.store) contracts.

Two layers:

* :class:`HostTieredStore` alone — staging / eviction / flush move exact
  row copies, so after any touch-and-write sequence the host tables equal
  a dense shadow copy that never tiered anything.
* :class:`TieredCycleEngine` — **cache-size transparency**: the compiled
  programs only ever see the fixed working view, so the whole trajectory
  (params, Adam moments, upload history, EF residuals, download counts,
  losses) is bitwise identical across cache capacities; ``cache_slots``
  may only change the hit rate and host<->device traffic.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.codecs import parse_codec_spec
from repro.core.protocol import build_comm_views
from repro.core.store import HostTieredStore, TieredCycleEngine, _cache_scatter
from repro.core.sync import ROUND_KINDS, compress_schedule, insert_prefetch
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.simulation import FederatedConfig, run_federated


# --------------------------------------------------------------- store alone
def test_store_stage_flush_exact():
    """Random touch/write/flush sequences == a dense shadow table."""
    rng = np.random.default_rng(0)
    c_n, e_rows, d, ns_pad, h = 2, 60, 4, 6, 16
    ent = rng.normal(size=(c_n, e_rows, d)).astype(np.float32)
    mu = rng.normal(size=(c_n, e_rows, d)).astype(np.float32)
    nu = rng.normal(size=(c_n, e_rows, d)).astype(np.float32)
    shadow = {k: v.copy() for k, v in (("ent", ent), ("mu", mu), ("nu", nu))}
    pinned = [np.arange(ns_pad), np.arange(ns_pad)]
    store = HostTieredStore(
        ent.copy(), mu.copy(), nu.copy(),
        pinned=pinned, cache_slots=h, ns_pad=ns_pad,
    )
    cache = store.seed_cache()
    for it in range(30):
        touched = [
            np.unique(rng.integers(ns_pad, e_rows, size=rng.integers(1, h - ns_pad)))
            for _ in range(c_n)
        ]
        cache, slots = store.stage(cache, touched)
        view = np.full((c_n, h - ns_pad), store.h, np.int32)
        temp = rng.random((c_n, h - ns_pad)).astype(np.float32)
        for c in range(c_n):
            new = rng.normal(size=(len(touched[c]), d)).astype(np.float32)
            cache = _cache_scatter(
                cache, np.full(len(slots[c]), c), slots[c], new, new + 1, new + 2
            )
            for k, off in (("ent", 0), ("mu", 1), ("nu", 2)):
                shadow[k][c, touched[c]] = new + off
            view[c, : len(slots[c])] = slots[c]
        store.after_segment(view, temp)
        if it % 7 == 3:
            store.flush(cache)
    store.flush(cache)
    for k in ("ent", "mu", "nu"):
        np.testing.assert_array_equal(getattr(store, k), shadow[k])
    assert store.stats["evictions"] > 0  # the eviction path actually ran
    assert store.stats["hits"] > 0


def test_store_overflow_raises():
    ent = np.zeros((1, 20, 2), np.float32)
    store = HostTieredStore(
        ent, ent.copy(), ent.copy(), pinned=[np.arange(2)],
        cache_slots=6, ns_pad=2,
    )
    cache = store.seed_cache()
    with pytest.raises(ValueError, match="cache overflow"):
        store.stage(cache, [np.arange(2, 10)])  # 8 rows, 4 dynamic slots


def test_insert_prefetch_schedule_equivalent():
    plan = compress_schedule(["sparse"] * 3 + ["sync"] + ["sparse"] * 2)
    out = insert_prefetch(plan, 2)
    # dropping the markers recovers the original round sequence
    rounds = [(k, n) for k, n in out if k in ROUND_KINDS]
    flat = [k for k, n in rounds for _ in range(n)]
    assert flat == ["sparse"] * 3 + ["sync"] + ["sparse"] * 2
    # one marker before round 0 and before every 2nd round
    marks = [i for i, (k, _) in enumerate(out) if k == "prefetch"]
    assert len(marks) == 3
    assert insert_prefetch(plan, 0) == plan


# ------------------------------------------------------- engine transparency
def _lockstep_instance():
    kg = generate_kg(num_entities=1500, num_relations=6, num_triples=3000, seed=1)
    cd = partition_by_relation(kg, 2, seed=1)
    n_tr = min(len(d.train) for d in cd)  # lockstep: equal batches-per-epoch
    cd = [dataclasses.replace(d, train=d.train[:n_tr]) for d in cd]
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    return kg, cd, views


def _mk_clients(cd):
    return [
        KGEClient(d, method="transe", dim=8, gamma=6.0, batch_size=16,
                  num_negatives=4, lr=5e-3, adversarial_temperature=1.0,
                  seed=3)
        for d in cd
    ]


def _run_tiered(kg, cd, views, cache_slots, codec_spec, kinds):
    eng = TieredCycleEngine(
        _mk_clients(cd), views, kg.num_entities,
        sparsity_p=0.5, local_epochs=1, codec=parse_codec_spec(codec_spec),
        cache_slots=cache_slots, stage_steps=1,
    )
    store, ts = eng.init_state(_mk_clients(cd), seed=7)
    downs, losses = [], []
    for kind in kinds:
        ts, down, loss = eng.run_cycle(store, ts, kind)
        downs.append(down.tolist())
        losses.append(loss.tolist())
    params = eng.materialize_params(store, ts)
    return {
        "ent": np.asarray(params["entity"]),
        "rel": np.asarray(params["relation"]),
        "mu": store.mu.copy(),
        "nu": store.nu.copy(),
        "hist": np.asarray(ts.hist),
        "res": np.asarray(ts.res),
        "downs": downs,
        "losses": losses,
        "hit_rate": store.hit_rate,
        "evictions": store.stats["evictions"],
        "w": eng.w,
    }


@pytest.mark.parametrize("codec_spec", ["identity", "int8:ef=1"])
def test_cache_size_transparency(codec_spec):
    """Tiered trajectories are bitwise identical across cache capacities —
    including EF residual state — while the small cache actually evicts."""
    kg, cd, views = _lockstep_instance()
    kinds = ["sparse", "sparse", "sync", "none", "sparse"]
    small = _run_tiered(kg, cd, views, 0, codec_spec, kinds)  # floor: H == W
    big = _run_tiered(kg, cd, views, small["w"] * 3, codec_spec, kinds)
    for k in ("ent", "rel", "mu", "nu", "hist", "res"):
        np.testing.assert_array_equal(small[k], big[k], err_msg=k)
    assert small["downs"] == big["downs"]
    assert small["losses"] == big["losses"]
    # the tiering machinery was genuinely exercised, and capacity only
    # moves the hit rate
    assert small["evictions"] > 0
    assert big["hit_rate"] >= small["hit_rate"]
    # training trains
    assert np.mean(small["losses"][-1]) < np.mean(small["losses"][0])


def test_run_federated_tiered_engine():
    """engine='tiered' runs the full simulation protocol (ledger, eval
    cadence, best snapshot) and rejects incompatible configs."""
    kg = generate_kg(num_entities=300, num_relations=4, num_triples=900, seed=2)
    cd = partition_by_relation(kg, 2, seed=2)
    cfg = FederatedConfig(
        method="transe", protocol="feds", dim=8, rounds=4, local_epochs=1,
        batch_size=32, num_negatives=4, lr=5e-3, sparsity_p=0.5,
        sync_interval=3, eval_every=2, max_eval_triples=32,
        engine="tiered", stage_steps=2, seed=3,
    )
    res = run_federated(cd, kg.num_entities, cfg)
    assert res.rounds_run == 4
    assert len(res.eval_history) == 2  # eval cadence honored
    assert np.isfinite(res.test_mrr_cg) and np.isfinite(res.test_hits10_cg)
    assert res.ledger.params_transmitted > 0
    with pytest.raises(ValueError, match="host-loop"):
        run_federated(
            cd, kg.num_entities, dataclasses.replace(cfg, mesh_entities=2)
        )
    with pytest.raises(ValueError, match="conflicts"):
        run_federated(
            cd, kg.num_entities,
            dataclasses.replace(cfg, engine="superstep", host_store=True),
        )


def test_tiered_engine_rejects_ragged_clients():
    kg = generate_kg(num_entities=200, num_relations=4, num_triples=500, seed=0)
    cd = partition_by_relation(kg, 2, seed=0)
    if len({len(d.train) // 16 for d in cd}) == 1:  # force raggedness
        cd[0] = dataclasses.replace(cd[0], train=cd[0].train[: len(cd[0].train) // 2])
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    with pytest.raises(ValueError, match="lockstep"):
        TieredCycleEngine(
            _mk_clients(cd), views, kg.num_entities,
            sparsity_p=0.5, local_epochs=1,
        )
