"""Per-assigned-architecture smoke tests (brief deliverable f).

For each of the 10 assigned archs: instantiate the REDUCED same-family
variant (<=2 layers, d_model <= 512, <= 4 experts), run one forward/train
step and one decode step on CPU, and assert output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.train.steps import (
    InputShape,
    init_serve_state,
    init_train_state,
    make_inputs,
    make_serve_step,
    make_train_step,
)

TRAIN_SHAPE = InputShape("smoke_train", seq_len=32, global_batch=2, kind="train")
DECODE_SHAPE = InputShape("smoke_decode", seq_len=32, global_batch=2, kind="decode")


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_limits(arch):
    """The reduced variant respects the brief's smoke limits."""
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    # family must match the full config
    assert cfg.arch_type == get_config(arch).arch_type


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = make_inputs(cfg, TRAIN_SHAPE)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    new_params, new_opt, loss = step(params, opt, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # params actually moved and stayed finite
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                    - b.astype(jnp.float32)).max()),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0.0
    finite = jax.tree.map(
        lambda a: bool(jnp.isfinite(a.astype(jnp.float32)).all()), new_params
    )
    assert all(jax.tree.leaves(finite)), f"{arch}: non-finite params after step"


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_serve_step(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    enc = None
    if cfg.arch_type == "audio":
        enc = jnp.zeros(
            (DECODE_SHAPE.global_batch, cfg.encoder_seq_len, cfg.d_model), cfg.dtype
        )
    state = init_serve_state(params, cfg, DECODE_SHAPE, encoder_embeds=enc)
    step = jax.jit(make_serve_step(cfg))
    token = jnp.zeros((DECODE_SHAPE.global_batch, 1), jnp.int32)
    logits, new_state = step(params, token, state)
    assert logits.shape == (DECODE_SHAPE.global_batch, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(new_state.pos[0]) == int(state.pos[0]) + 1


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_dims_match_assignment(arch):
    """The FULL config must carry the exact assigned dimensions."""
    assigned = {
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, None, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    L, d, h, kv, ff, v = assigned[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_moe_expert_counts():
    q = get_config("qwen2-moe-a2.7b")
    assert (q.num_experts, q.num_experts_per_tok, q.moe_d_ff) == (60, 4, 1408)
    a = get_config("arctic-480b")
    assert (a.num_experts, a.num_experts_per_tok) == (128, 2)
    assert a.dense_residual


def test_zamba_ssm_state():
    z = get_config("zamba2-1.2b")
    assert z.ssm_state == 64 and z.arch_type == "hybrid"


def test_param_counts_in_range():
    """Analytic param counts land near the model names' scales."""
    import math

    expect = {
        "qwen2-72b": (72e9, 0.20),
        "arctic-480b": (480e9, 0.25),
        "gemma3-1b": (1e9, 0.8),  # 1b-class (vocab-heavy)
        "qwen3-0.6b": (0.6e9, 0.6),
        "whisper-base": (74e6, 0.8),
        "xlstm-350m": (350e6, 0.8),
    }
    for arch, (target, tol) in expect.items():
        n = get_config(arch).param_count()
        assert abs(math.log(n / target)) < math.log(1 + tol) + 0.35, (arch, n, target)
