"""Entity-axis sharding contracts.

Two layers, mirroring how the sharded engines are built:

* Hypothesis property tests (guarded like tests/test_codecs_property.py —
  this container has no hypothesis wheel; CI installs requirements-dev.txt)
  for the host-side pieces: shard padding arithmetic, prefetch plan
  equivalence, and host-tier staging exactness under drawn touch/write
  sequences.
* A 2-device ``(1, 2)`` entity-mesh subprocess sweep asserting the fused
  engine under ``shard_map`` over entity blocks is **bitwise identical**
  to the unsharded fused engine — params, upload history, EF residuals,
  and download counts — over randomized heterogeneous federations and
  every registered codec including error-feedback, plus an end-to-end
  ``run_federated`` trajectory (eval history derives from integer ranks,
  so equality there is rank-exact).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.store import HostTieredStore, _cache_scatter
from repro.core.sync import ROUND_KINDS, insert_prefetch

# ------------------------------------------------------ hypothesis layer
# Guarded per-test (NOT pytest.importorskip at module level) so the
# 2-device mesh smoke below still runs where hypothesis is absent.
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    from repro.core.eshard import pad_rows

    @settings(max_examples=100, deadline=None)
    @given(
        st.integers(1, 10_000_000),
        st.integers(1, 16),
        st.sampled_from([1, 32]),
    )
    def test_pad_rows_minimal_aligned(n, shards, multiple):
        """pad_rows gives the smallest padded count that splits into equal,
        word-aligned per-shard blocks."""
        p = pad_rows(n, shards, multiple)
        assert p >= n
        assert p % shards == 0
        assert (p // shards) % multiple == 0
        assert p - n < shards * multiple  # minimality

    plan_st = st.lists(
        st.tuples(st.sampled_from(ROUND_KINDS + ("eval",)), st.integers(1, 5)),
        min_size=0, max_size=6,
    )

    @settings(max_examples=100, deadline=None)
    @given(plan_st, st.integers(0, 7))
    def test_insert_prefetch_preserves_rounds(plan, every):
        """Dropping the markers always recovers the original round sequence."""
        plan = tuple(plan)
        out = insert_prefetch(plan, every)
        strip = lambda p: [  # noqa: E731
            k for k, n in p for _ in range(n) if k != "prefetch"
        ]
        assert strip(out) == strip(plan)
        rounds = sum(n for k, n in plan if k in ROUND_KINDS)
        marks = sum(1 for k, _ in out if k == "prefetch")
        if every > 0 and rounds:
            assert marks == -(-rounds // every)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(7, 30))
    def test_store_staging_exact(seed, h):
        """Host-tier staging == dense shadow for drawn touch/write seqs."""
        rng = np.random.default_rng(seed)
        c_n, e_rows, d, ns_pad = 2, 40, 3, 4
        ent = rng.normal(size=(c_n, e_rows, d)).astype(np.float32)
        shadow = ent.copy()
        store = HostTieredStore(
            ent.copy(), np.zeros_like(ent), np.zeros_like(ent),
            pinned=[np.arange(ns_pad)] * c_n, cache_slots=h, ns_pad=ns_pad,
        )
        cache = store.seed_cache()
        for _ in range(10):
            touched = [
                np.unique(
                    rng.integers(ns_pad, e_rows, size=rng.integers(1, h - ns_pad))
                )
                for _ in range(c_n)
            ]
            cache, slots = store.stage(cache, touched)
            view = np.full((c_n, h - ns_pad), store.h, np.int32)
            for c in range(c_n):
                new = rng.normal(size=(len(touched[c]), d)).astype(np.float32)
                cache = _cache_scatter(
                    cache, np.full(len(slots[c]), c), slots[c], new, new, new
                )
                shadow[c, touched[c]] = new
                view[c, : len(slots[c])] = slots[c]
            store.after_segment(view, np.zeros_like(view, np.float32))
        store.flush(cache)
        np.testing.assert_array_equal(store.ent, shadow)
else:
    @pytest.mark.skip(
        reason="property tests need hypothesis (requirements-dev.txt)"
    )
    def test_hypothesis_properties():
        pass


# --------------------------------------------- 2-device (1, 2) entity mesh
_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, json, dataclasses
sys.path.insert(0, "src")
import numpy as np
from repro.core.codecs import parse_codec_spec
from repro.core.protocol import build_comm_views
from repro.core.state import CycleEngine
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.simulation import FederatedConfig, run_federated
from repro.launch.mesh import make_federation_mesh

def instance(seed):
    rng = np.random.default_rng(seed)
    kg = generate_kg(
        num_entities=int(rng.integers(90, 140)),
        num_relations=int(rng.integers(4, 8)),
        num_triples=int(rng.integers(450, 700)),
        seed=seed,
    )
    cd = partition_by_relation(kg, int(rng.integers(2, 4)), seed=seed)
    # heterogeneity: ragged triple counts -> ragged batches-per-epoch
    cd[0] = dataclasses.replace(
        cd[0], train=cd[0].train[: max(40, len(cd[0].train) // 2)]
    )
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    def mk():
        return [KGEClient(d, method="transe", dim=8, batch_size=24,
                          num_negatives=4, lr=5e-3, seed=seed) for d in cd]
    return kg, cd, views, mk

mesh = make_federation_mesh(1, entity_devices=2)
out = {"engine": {}, "sim": {}}
SPECS = ["identity", "int8", "int8:ef=1", "lowrank", "lowrank:ef=1", "topk-dims"]
for i, spec in enumerate(SPECS):
    seed = 100 + i
    kg, cd, views, mk = instance(seed)
    codec = parse_codec_spec(spec)
    host = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5,
                       local_epochs=1, codec=codec)
    shrd = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5,
                       local_epochs=1, codec=codec,
                       mesh=mesh, entity_axis="entities")
    sh, sp = host.init_state(mk(), seed=7), shrd.init_state(mk(), seed=7)
    ok = True
    for sync in (False, False, True, False):
        sh, dh, lh = host.fused_cycle(sh, sync=sync)
        sp, dp, lp = shrd.fused_cycle(sp, sync=sync)
        ok &= np.array_equal(np.asarray(dh), np.asarray(dp))
        ok &= np.array_equal(np.asarray(lh), np.asarray(lp))
    eh = np.asarray(sh.arrays.params["entity"])
    ep = np.asarray(sp.arrays.params["entity"])[:, : eh.shape[1]]
    ok &= np.array_equal(eh, ep)
    ok &= np.array_equal(np.asarray(sh.arrays.hist),
                         np.asarray(sp.arrays.hist)[:, : sh.arrays.hist.shape[1]])
    if codec.has_residual:
        ok &= np.array_equal(np.asarray(sh.arrays.res),
                             np.asarray(sp.arrays.res)[:, : sh.arrays.res.shape[1]])
    out["engine"][spec] = bool(ok)

# end-to-end trajectory incl. device-resident eval (integer-rank exact)
kg = generate_kg(num_entities=120, num_relations=6, num_triples=800, seed=1)
cd = partition_by_relation(kg, 2, seed=1)
base = dict(method="transe", protocol="feds", dim=8, rounds=7, local_epochs=1,
            batch_size=32, num_negatives=4, lr=5e-3, sparsity_p=0.5,
            codec="int8:ef=1", sync_interval=3, eval_every=3,
            max_eval_triples=64, seed=3)
for engine in ("superstep", "fused"):
    r0 = run_federated(cd, kg.num_entities, FederatedConfig(engine=engine, **base))
    r1 = run_federated(cd, kg.num_entities,
                       FederatedConfig(engine=engine, mesh_entities=2, **base))
    out["sim"][engine] = bool(
        r0.eval_history == r1.eval_history
        and r0.test_mrr_cg == r1.test_mrr_cg
        and r0.test_hits10_cg == r1.test_hits10_cg
        and r0.ledger.params_transmitted == r1.ledger.params_transmitted
    )
print(json.dumps(out))
"""


# ------------------------- 2-device entity-mesh eval exactness (bilinear)
_EVAL_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, json
sys.path.insert(0, "src")
import numpy as np
from repro.core.evaluation import BatchedEvaluator
from repro.core.protocol import build_comm_views
from repro.core.state import CycleEngine
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.launch.mesh import make_federation_mesh

mesh = make_federation_mesh(1, entity_devices=2)
out = {}
for i, method in enumerate(("complex", "distmult")):
    seed = 200 + i
    rng = np.random.default_rng(seed)
    kg = generate_kg(num_entities=110, num_relations=6, num_triples=600,
                     seed=seed)
    cd = partition_by_relation(kg, 2, seed=seed)
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    def mk():
        return [KGEClient(d, method=method, dim=8, batch_size=32,
                          num_negatives=4, lr=5e-3, seed=seed) for d in cd]
    clients = mk()
    host = CycleEngine(clients, views, kg.num_entities, sparsity_p=0.5,
                       local_epochs=1)
    shrd = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5,
                       local_epochs=1, mesh=mesh, entity_axis="entities")
    sh, sp = host.init_state(mk(), seed=9), shrd.init_state(mk(), seed=9)
    for sync in (False, True):
        sh, _, _ = host.fused_cycle(sh, sync=sync)
        sp, _, _ = shrd.fused_cycle(sp, sync=sync)
    host.sync_clients(sh, clients)  # numpy-oracle tables

    cap = int(rng.integers(5, 50))
    chunk = int(rng.choice([7, 64]))
    ev = BatchedEvaluator(cd, method=method, gamma=clients[0].gamma,
                          e_max=shrd.e_max, max_triples=cap, chunk=chunk,
                          mesh=mesh, entity_axis="entities")
    ok = True
    for split in ("valid", "test"):
        rt, rh = ev.ranks(sp.arrays.params, split)
        for c, cl in enumerate(clients):
            oracle = cl.ranks(split, cap)  # (n, 2) tail/head integer ranks
            n = oracle.shape[0]
            ok &= bool(np.array_equal(oracle[:, 0], np.asarray(rt)[c, :n]))
            ok &= bool(np.array_equal(oracle[:, 1], np.asarray(rh)[c, :n]))
    out[method] = ok
print(json.dumps(out))
"""


def test_entity_sharded_eval_ranks_match_oracle_bilinear():
    """Bilinear-family eval exactness on the (1, 2) entity mesh: integer
    filtered ranks from the sharded BatchedEvaluator (each shard scans its
    own candidate block, beat counts psum) EXACTLY equal the per-client
    numpy-oracle ranks for complex and distmult, after real training."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_PALLAS_INTERPRET", None)  # exactness pins the ref dispatch
    res = subprocess.run(
        [sys.executable, "-c", _EVAL_WORKER], capture_output=True, text=True,
        env=env, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out == {"complex": True, "distmult": True}, out


def test_entity_sharded_bitwise_two_devices():
    """(1, 2) entity mesh over 2 fake CPU devices: every registered codec
    (incl. ef) bitwise-equal to unsharded, and end-to-end trajectories with
    eval boundaries identical."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _WORKER], capture_output=True, text=True,
        env=env, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert all(out["engine"].values()), out["engine"]
    assert all(out["sim"].values()), out["sim"]
