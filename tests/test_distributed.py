"""SPMD FedS collective == host (paper) protocol, on 4 fake devices.

The multi-device parts run in a SUBPROCESS so the main pytest process keeps
seeing exactly 1 CPU device (the brief forbids setting
xla_force_host_platform_device_count globally).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np
from repro.core.distributed import make_sharded_feds_round, sparse_sync_step, full_sync_step
from repro.core.aggregate import Upload, personalized_aggregate
from repro.core.engine import make_client_mesh
from repro.core.sparsify import change_scores, select_top_k

C, N, D, K = 4, 32, 16, 8
mesh = make_client_mesh(4, "data")

key = jax.random.PRNGKey(0)
emb = jax.random.normal(key, (C, N, D), jnp.float32)
# tie-break-free construction: every client's top-K change rows are exactly
# rows 0..K-1 (strongly perturbed history there, identical elsewhere), so the
# downstream priority ranking has a unique answer on both paths.
hist = emb.at[:, :K, :].add(
    2.0 + jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (C, K, D)))
)

rnd = make_sharded_feds_round(mesh, k=K, sync_interval=4)
new_emb, new_hist = rnd(emb, hist, jnp.zeros((1,), jnp.int32))
sync_emb, sync_hist = rnd(emb, hist, jnp.asarray([4], jnp.int32))

# ---- host-side (paper/numpy) protocol on the same inputs
uploads = []
for c in range(C):
    scores = change_scores(emb[c], hist[c])
    idx, _ = select_top_k(scores, K)
    uploads.append(Upload(client_id=c, entity_ids=np.asarray(idx, np.int64),
                          values=np.asarray(emb[c])[np.asarray(idx)]))
ents = [np.arange(N)] * C
downs = personalized_aggregate(uploads, ents, sparsity_p=K / N,
                               rng=np.random.default_rng(0))
host_emb = np.asarray(emb).copy()
for c, d in enumerate(downs):
    for i, e in enumerate(d.entity_ids.tolist()):
        host_emb[c, e] = (d.agg_values[i] + host_emb[c, e]) / (1 + d.priority[i])

out = {
    "spmd_emb": np.asarray(new_emb).tolist(),
    "host_emb": host_emb.tolist(),
    "sync_equal": bool(np.allclose(np.asarray(sync_emb[0]), np.asarray(sync_emb[1]))),
    "sync_is_mean": bool(np.allclose(np.asarray(sync_emb[0]),
                                     np.asarray(emb).mean(0), atol=1e-5)),
    "hist_refreshed": bool((np.abs(np.asarray(new_hist) - np.asarray(hist)) > 0)
                           .any(axis=(1, 2)).all()),
}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def worker_output():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _WORKER],
        capture_output=True, text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        env=env, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_spmd_matches_host_protocol(worker_output):
    """Priority-based downstream Top-K + Eq. 4 update must agree with the
    numpy reference when K covers all candidates (tie-break-free setting)."""
    spmd = np.asarray(worker_output["spmd_emb"])
    host = np.asarray(worker_output["host_emb"])
    # With p = K/N and <= K aggregated candidates per client, both paths
    # update exactly the same rows with exactly Eq. 4.
    mismatch = np.abs(spmd - host).max()
    assert mismatch < 1e-4, mismatch


def test_spmd_sync_round_is_fede_mean(worker_output):
    assert worker_output["sync_equal"]
    assert worker_output["sync_is_mean"]


def test_spmd_history_refresh(worker_output):
    assert worker_output["hist_refreshed"]


def test_main_process_still_single_device():
    import jax

    assert len(jax.devices()) == 1
