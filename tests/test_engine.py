"""RoundEngine (jitted batched FedS round) == ragged numpy reference protocol.

Equivalence holds exactly (up to float summation order and the static-K /
deterministic tie-break deltas documented in repro.core.engine) whenever the
downstream selection is tie-break-free:

* with p = 1.0 every aggregated candidate is selected on both paths, so any
  heterogeneous instance is comparable,
* with p < 1.0 a client is comparable iff its candidate count <= K_c (the
  reference then sends all candidates); the property test checks exactly
  those clients and asserts the construction produced enough of them.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregate import fede_aggregate, personalized_aggregate
from repro.core.codec import IdentityCodec, Int8RowCodec
from repro.core.engine import RoundEngine
from repro.core.protocol import (
    apply_full_download,
    apply_sparse_download,
    build_comm_views,
    full_upload,
    sparse_upload,
)
from repro.data import generate_kg, partition_by_relation
from repro.federated.simulation import FederatedConfig, run_federated


# ------------------------------------------------------------------ helpers
def _random_instance(rng, num_clients, num_global=40, dim=8):
    """Random heterogeneous federation: per-client entity subsets + tables."""
    while True:
        l2g = [
            np.sort(
                rng.choice(num_global, size=int(rng.integers(10, 28)), replace=False)
            ).astype(np.int64)
            for _ in range(num_clients)
        ]
        views = build_comm_views(l2g, num_global)
        if all(v.num_shared >= 4 for v in views):
            break
    tables = [jnp.asarray(rng.normal(size=(len(l), dim)), jnp.float32) for l in l2g]
    hist_tables = [
        t + jnp.asarray(rng.normal(size=t.shape) * 0.5, jnp.float32) for t in tables
    ]
    return views, tables, hist_tables


def _reference_round(tables, hists, views, p, tie_rng, codec):
    """One sparse round through the numpy host protocol (simulation path)."""
    uploads, new_hists = [], []
    for t, h, v in zip(tables, hists, views):
        up, hh = sparse_upload(t, h, v, p)
        up = dataclasses.replace(
            up, values=np.asarray(codec.roundtrip(jnp.asarray(up.values)), np.float32)
        )
        uploads.append(up)
        new_hists.append(hh)
    downs = personalized_aggregate(
        uploads, [v.shared_global for v in views], p, tie_rng
    )
    out = []
    for t, v, d in zip(tables, views, downs):
        vals = d.agg_values
        if len(d.entity_ids):
            vals = np.asarray(codec.roundtrip(jnp.asarray(vals)), np.float32)
        out.append(apply_sparse_download(t, v, d.entity_ids, vals, d.priority))
    return out, new_hists, uploads, downs


def _run_engine_round(views, tables, hist_tables, p, codec, num_global=40, dim=8):
    engine = RoundEngine(views, num_global, dim, p, codec=codec)
    emb_b = engine.gather(tables)
    hist_b = engine.gather(hist_tables)
    new_emb, new_hist, down_count = engine.sparse_round(emb_b, hist_b)
    return engine, new_emb, new_hist, np.asarray(down_count)


# --------------------------------------------------- sparse-round equivalence
@pytest.mark.parametrize("num_clients", [2, 3, 5])
@pytest.mark.parametrize("codec_cls", [IdentityCodec, Int8RowCodec])
def test_engine_matches_reference_all_candidates(num_clients, codec_cls):
    """p=1.0: tie-break-free, so heterogeneous instances agree exactly."""
    rng = np.random.default_rng(17 * num_clients)
    views, tables, hist_tables = _random_instance(rng, num_clients)
    codec = codec_cls()
    hists = [
        jnp.asarray(np.asarray(h)[v.shared_local])
        for h, v in zip(hist_tables, views)
    ]
    ref_tables, ref_hists, _, downs = _reference_round(
        tables, hists, views, 1.0, np.random.default_rng(0), codec
    )
    _, new_emb, new_hist, down_count = _run_engine_round(
        views, tables, hist_tables, 1.0, codec
    )
    for c, v in enumerate(views):
        ns = v.num_shared
        np.testing.assert_allclose(
            np.asarray(new_emb[c, :ns]),
            np.asarray(ref_tables[c])[v.shared_local],
            atol=5e-4,
        )
        np.testing.assert_allclose(
            np.asarray(new_hist[c, :ns]), np.asarray(ref_hists[c]), atol=1e-6
        )
        assert down_count[c] == len(downs[c].entity_ids)


def test_engine_matches_reference_sparse_p_where_unambiguous():
    """p<1: compare every client whose candidate count <= K_c."""
    compared = 0
    for seed in range(6):
        rng = np.random.default_rng(seed)
        views, tables, hist_tables = _random_instance(rng, 3)
        codec = IdentityCodec()
        p = 0.5
        hists = [
            jnp.asarray(np.asarray(h)[v.shared_local])
            for h, v in zip(hist_tables, views)
        ]
        ref_tables, _, uploads, downs = _reference_round(
            tables, hists, views, p, np.random.default_rng(0), codec
        )
        engine, new_emb, _, down_count = _run_engine_round(
            views, tables, hist_tables, p, codec
        )
        for c, v in enumerate(views):
            peers = set()
            for up in uploads:
                if up.client_id != c:
                    peers |= set(up.entity_ids.tolist())
            n_cand = len(peers & set(v.shared_global.tolist()))
            assert down_count[c] == len(downs[c].entity_ids)
            if n_cand > int(engine.k_per_client[c]):
                continue  # reference tie-break could pick different rows
            compared += 1
            ns = v.num_shared
            np.testing.assert_allclose(
                np.asarray(new_emb[c, :ns]),
                np.asarray(ref_tables[c])[v.shared_local],
                atol=5e-4,
            )
    assert compared >= 3, "construction produced too few unambiguous clients"


def test_engine_two_identical_views_always_comparable():
    """Two clients over the SAME entity set: candidates == K exactly, so any
    sparsity is tie-break-free and the paths must agree."""
    rng = np.random.default_rng(5)
    l2g = [np.arange(20, dtype=np.int64), np.arange(20, dtype=np.int64)]
    views = build_comm_views(l2g, 20)
    tables = [jnp.asarray(rng.normal(size=(20, 8)), jnp.float32) for _ in range(2)]
    hist_tables = [
        t + jnp.asarray(rng.normal(size=t.shape) * 0.5, jnp.float32) for t in tables
    ]
    codec = IdentityCodec()
    hists = [
        jnp.asarray(np.asarray(h)[v.shared_local])
        for h, v in zip(hist_tables, views)
    ]
    ref_tables, _, _, downs = _reference_round(
        tables, hists, views, 0.3, np.random.default_rng(0), codec
    )
    _, new_emb, _, down_count = _run_engine_round(
        views, tables, hist_tables, 0.3, codec, num_global=20
    )
    for c, v in enumerate(views):
        assert down_count[c] == len(downs[c].entity_ids)
        np.testing.assert_allclose(
            np.asarray(new_emb[c, : v.num_shared]),
            np.asarray(ref_tables[c])[v.shared_local],
            atol=5e-4,
        )


# ------------------------------------------------------ sync-round semantics
def test_engine_sync_round_is_fede_mean():
    rng = np.random.default_rng(11)
    views, tables, _ = _random_instance(rng, 3)
    engine = RoundEngine(views, 40, 8, 0.4)
    emb_b = engine.gather(tables)
    new_emb, new_hist = engine.sync_round(emb_b)

    uploads = [full_upload(t, v)[0] for t, v in zip(tables, views)]
    mean, _ = fede_aggregate(uploads, 40)
    for c, v in enumerate(views):
        ref = apply_full_download(tables[c], v, mean)
        np.testing.assert_allclose(
            np.asarray(new_emb[c, : v.num_shared]),
            np.asarray(ref)[v.shared_local],
            atol=1e-5,
        )
    # history refreshes to the PRE-sync uploaded rows (full_upload semantics)
    np.testing.assert_allclose(np.asarray(new_hist), np.asarray(emb_b), atol=0)


# --------------------------------------------------- end-to-end ledger parity
def test_run_federated_rejects_unknown_engine():
    kg = generate_kg(num_entities=60, num_relations=4, num_triples=200, seed=0)
    clients = partition_by_relation(kg, 2, seed=0)
    with pytest.raises(ValueError, match="unknown engine"):
        run_federated(
            clients, kg.num_entities,
            FederatedConfig(rounds=1, dim=8, engine="numpy"),
        )


def test_run_federated_engine_vs_reference_ledger():
    """The engine path must account communication identically to the numpy
    path: same per-round ledger, both produce finite metrics."""
    kg = generate_kg(num_entities=150, num_relations=9, num_triples=1200, seed=3)
    clients = partition_by_relation(kg, 3, seed=0)
    base = dict(
        method="transe", dim=16, rounds=4, local_epochs=1, batch_size=64,
        num_negatives=8, lr=5e-3, sparsity_p=0.4, sync_interval=2,
        eval_every=2, max_eval_triples=40, seed=0,
    )
    for protocol in ("feds", "fedep"):
        eng = run_federated(
            clients, kg.num_entities,
            FederatedConfig(protocol=protocol, engine="batched", **base),
        )
        ref = run_federated(
            clients, kg.num_entities,
            FederatedConfig(protocol=protocol, engine="reference", **base),
        )
        # round 1 is exactly parity (identical training state pre-comm); for
        # fedep (no tie-breaks at all) every round matches.
        assert eng.params_at(1) == ref.params_at(1), protocol
        if protocol == "fedep":
            assert eng.ledger.history == ref.ledger.history
        assert np.isfinite(eng.test_mrr_cg) and np.isfinite(ref.test_mrr_cg)


# ------------------------------------------------------------- SPMD = host
_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np
from repro.core.engine import RoundEngine, make_client_mesh
from repro.core.protocol import build_comm_views

rng = np.random.default_rng(0)
E, D, C = 32, 8, 4
l2g = [np.sort(rng.choice(E, size=int(rng.integers(10, 20)), replace=False)).astype(np.int64)
       for _ in range(C)]
views = build_comm_views(l2g, E)
tables = [jnp.asarray(rng.normal(size=(len(l), D)), jnp.float32) for l in l2g]
hist_tables = [t + jnp.asarray(rng.normal(size=t.shape) * 0.5, jnp.float32)
               for t in tables]

host = RoundEngine(views, E, D, 0.6)
emb_b = host.gather(tables); hist_b = host.gather(hist_tables)
h_emb, h_hist, h_dc = host.sparse_round(emb_b, hist_b)
hs_emb, hs_hist = host.sync_round(emb_b)

mesh = make_client_mesh(4, "clients")
pod = RoundEngine(views, E, D, 0.6, mesh=mesh)
p_emb, p_hist, p_dc = pod.sparse_round(emb_b, hist_b)
ps_emb, ps_hist = pod.sync_round(emb_b)

out = {
    "emb": float(np.abs(np.asarray(h_emb) - np.asarray(p_emb)).max()),
    "hist": float(np.abs(np.asarray(h_hist) - np.asarray(p_hist)).max()),
    "dc": (np.asarray(h_dc) == np.asarray(p_dc)).all().item(),
    "sync_emb": float(np.abs(np.asarray(hs_emb) - np.asarray(ps_emb)).max()),
    "sync_hist": float(np.abs(np.asarray(hs_hist) - np.asarray(ps_hist)).max()),
}
print(json.dumps(out))
"""


def test_engine_spmd_matches_host():
    """shard_map over the client axis == single-device jit, same engine."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _WORKER], capture_output=True, text=True,
        env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["emb"] < 1e-5, out
    assert out["hist"] == 0.0, out
    assert out["dc"], out
    assert out["sync_emb"] < 1e-5, out
    assert out["sync_hist"] == 0.0, out


def test_engine_heterogeneous_padding_rows_untouched():
    """Padded rows must never change nor leak into aggregates."""
    rng = np.random.default_rng(2)
    views, tables, hist_tables = _random_instance(rng, 3)
    engine = RoundEngine(views, 40, 8, 0.7)
    emb_b = engine.gather(tables)
    hist_b = engine.gather(hist_tables)
    new_emb, new_hist, _ = engine.sparse_round(emb_b, hist_b)
    for c, v in enumerate(views):
        pad = np.asarray(new_emb[c, v.num_shared:])
        np.testing.assert_array_equal(pad, 0.0)
        np.testing.assert_array_equal(np.asarray(new_hist[c, v.num_shared:]), 0.0)
