"""Numerical correctness of the model zoo's non-trivial paths.

* blocked (flash-style) attention == dense attention,
* M-RoPE degenerates to RoPE on text-only positions,
* one-token decode (KV cache / SSM state / mLSTM state / shared-attn cache)
  reproduces the full-sequence forward, token by token — the strongest
  internal-consistency check we have for the cache machinery,
* chunked Mamba2 SSD == its step-by-step recurrence,
* chunked-CE loss == direct cross-entropy.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as attn_mod
from repro.models.attention import AttnParams, init_attention
from repro.models.config import ModelConfig
from repro.models.layers import apply_mrope, apply_rope
from repro.models.transformer import (
    decode_lm,
    forward_lm,
    init_decode_state,
    init_lm,
    lm_loss,
)


def _f32(cfg):
    return dataclasses.replace(cfg, dtype=jnp.float32, remat=False)


# ------------------------------------------------------------------ attention
@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("causal", [True, False])
def test_blocked_attention_matches_dense(window, causal):
    cfg = _f32(get_smoke_config("qwen2-72b"))
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, cfg.d_model), jnp.float32)
    q, k, v = attn_mod._project_qkv(p, cfg, x)
    win = None if window is None else jnp.asarray(window, jnp.int32)
    dense = attn_mod._dense_attend(cfg, q, k, v, p.wo, win, causal, jnp.float32)
    blocked = attn_mod._blocked_attend(cfg, q, k, v, p.wo, win, causal, jnp.float32, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(blocked), rtol=2e-5, atol=2e-5)


def test_mrope_degenerates_to_rope_on_text():
    b, s, h, hd = 2, 9, 4, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd))
    pos1d = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos3d = jnp.broadcast_to(pos1d[:, None], (b, 3, s))
    r1 = apply_rope(x, pos1d, 10000.0)
    r2 = apply_mrope(x, pos3d, 10000.0, (8, 12, 12))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-6, atol=1e-6)


# ------------------------------------------------- decode == forward, per arch
DECODE_ARCHS = [
    "stablelm-3b",  # plain MHA
    "qwen3-0.6b",  # GQA + qk_norm + tied embeddings
    "gemma3-1b",  # sliding window + global pattern
    "qwen2-moe-a2.7b",  # MoE + shared experts
    "zamba2-1.2b",  # mamba + shared attention block
    "xlstm-350m",  # mLSTM/sLSTM union
    "whisper-base",  # enc-dec with cross attention
]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = _f32(get_smoke_config(arch))
    if cfg.arch_type == "moe":
        # capacity drops are a train-path-only behaviour; give the forward
        # pass enough capacity that no token is dropped, so the two paths
        # compute the same function.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    s = 12
    key = jax.random.PRNGKey(42)
    params = init_lm(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab_size)
    enc = None
    if cfg.arch_type == "audio":
        enc = (
            jax.random.normal(jax.random.PRNGKey(2), (2, cfg.encoder_seq_len, cfg.d_model))
            * 0.1
        ).astype(cfg.dtype)

    hidden, _ = forward_lm(params, cfg, tokens, encoder_embeds=enc)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    fwd_logits = jnp.einsum("bsd,dv->bsv", hidden, unembed)  # (B,S,V)

    state = init_decode_state(params, cfg, 2, s, encoder_embeds=enc)
    dec_logits = []
    for t in range(s):
        logits, state = decode_lm(params, cfg, tokens[:, t : t + 1], state)
        dec_logits.append(logits)
    dec_logits = jnp.stack(dec_logits, axis=1)  # (B,S,V)

    np.testing.assert_allclose(
        np.asarray(fwd_logits), np.asarray(dec_logits), rtol=2e-3, atol=2e-3
    )


# ---------------------------------------------------------- mamba chunk sizes
def test_mamba_chunking_invariant():
    """SSD output must not depend on the chunk size."""
    from repro.models.ssm import apply_mamba, init_mamba

    cfg = _f32(get_smoke_config("zamba2-1.2b"))
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model), jnp.float32) * 0.3
    y1 = apply_mamba(p, dataclasses.replace(cfg, ssm_chunk=4), x)
    y2 = apply_mamba(p, dataclasses.replace(cfg, ssm_chunk=24), x)
    y3 = apply_mamba(p, dataclasses.replace(cfg, ssm_chunk=7), x)  # non-divisor
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=2e-4, atol=2e-4)


def test_mlstm_chunking_invariant():
    from repro.models.xlstm import apply_mlstm, init_mlstm

    cfg = _f32(get_smoke_config("xlstm-350m"))
    p = init_mlstm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model), jnp.float32) * 0.3
    y1 = apply_mlstm(p, cfg, x, chunk=4)
    y2 = apply_mlstm(p, cfg, x, chunk=24)
    y3 = apply_mlstm(p, cfg, x, chunk=5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------------- lm loss
def test_chunked_loss_matches_direct():
    cfg = _f32(get_smoke_config("qwen3-0.6b"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    hidden = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0, cfg.vocab_size)
    labels = labels.at[0, :3].set(-1)  # masked positions
    loss = lm_loss(params, cfg, hidden, labels, jnp.zeros(()), chunk=3)

    unembed = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", hidden, unembed).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ref = ((lse - gold) * mask).sum() / mask.sum()
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)


# ------------------------------------------------------- §Perf opt variants
def test_flash_vjp_matches_blocked_gradients():
    """custom-VJP flash attention == dense autodiff (values and grads)."""
    cfg = _f32(get_smoke_config("qwen2-72b"))
    p = attn_mod.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, cfg.d_model))
    q, k, v = attn_mod._project_qkv(p, cfg, x)
    for window in (None, 9):
        win = None if window is None else jnp.asarray(window, jnp.int32)

        def f_dense(q, k, v):
            return (attn_mod._dense_attend(cfg, q, k, v, p.wo, win, True, jnp.float32) ** 2).sum()

        def f_flash(q, k, v):
            return (attn_mod.flash_attend(cfg, q, k, v, p.wo, win, True,
                                          jnp.float32, kv_chunk=16) ** 2).sum()

        vd, gd = jax.value_and_grad(f_dense, argnums=(0, 1, 2))(q, k, v)
        vf, gf = jax.value_and_grad(f_flash, argnums=(0, 1, 2))(q, k, v)
        np.testing.assert_allclose(float(vd), float(vf), rtol=1e-5)
        for a, b in zip(gd, gf):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


def test_moe_group_size_invariance():
    """Smaller routing groups compute the same function at no-drop capacity."""
    from repro.models.moe import apply_moe, init_moe

    cfg = dataclasses.replace(_f32(get_smoke_config("qwen2-moe-a2.7b")),
                              capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.3
    y0, _ = apply_moe(p, cfg, x)
    y1, _ = apply_moe(p, dataclasses.replace(cfg, moe_group_size=8), x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
