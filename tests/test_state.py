"""Fused cycle (repro.core.state) == per-round batched path, exactly.

The fused program and the per-round ``engine="batched"`` oracle share the
same device-resident :class:`FederationState`, the same threaded PRNG key
schedule (one 3-way split per cycle), and the same ``train_core`` /
``comm_core`` functions — the ONLY difference is whether train and
communicate compile as one program or two.  So with the same seeds they must
produce the same eval trajectory and bitwise-identical ledgers, over
randomized heterogeneous federations (different per-client entity counts,
triple counts, batches-per-epoch, and clients smaller than the batch size).

The same contract extends one level up to ``engine="superstep"``
(:class:`SuperstepEngine`): a whole ISM span scanned into ONE program must be
trajectory- and ledger-bitwise-identical to the same rounds driven one
``fused_cycle`` call at a time.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.protocol import build_comm_views
from repro.core.state import CycleEngine, SuperstepEngine
from repro.core.sync import compress_schedule
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.simulation import FederatedConfig, run_federated
from repro.kge.scoring import registered_methods


def _instance(seed):
    """Randomized heterogeneous federation + config (seeded, not hypothesis:
    the container has no hypothesis wheel and determinism helps bisection)."""
    rng = np.random.default_rng(seed)
    num_clients = int(rng.integers(2, 4))
    kg = generate_kg(
        num_entities=int(rng.integers(100, 180)),
        num_relations=3 * num_clients,
        num_triples=int(rng.integers(700, 1400)),
        seed=int(rng.integers(0, 1000)),
    )
    clients = partition_by_relation(kg, num_clients, seed=int(rng.integers(0, 10)))
    cfg = dict(
        method="transe",
        dim=int(rng.choice([8, 16])),
        rounds=5,
        local_epochs=int(rng.integers(1, 3)),
        # deliberately larger than some clients' train split sometimes, to
        # exercise padded batch rows (B_c = min(batch, T_c))
        batch_size=int(rng.choice([32, 64, 512])),
        num_negatives=8,
        lr=5e-3,
        sparsity_p=float(rng.choice([0.3, 0.5, 1.0])),
        sync_interval=2,
        eval_every=2,
        patience=99,
        max_eval_triples=40,
        seed=int(rng.integers(0, 100)),
    )
    return kg, clients, cfg


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("protocol", ["feds"])
def test_fused_matches_batched_trajectory_and_ledger(seed, protocol):
    kg, clients, cfg = _instance(seed)
    fused = run_federated(
        clients, kg.num_entities,
        FederatedConfig(protocol=protocol, engine="fused", **cfg),
    )
    batched = run_federated(
        clients, kg.num_entities,
        FederatedConfig(protocol=protocol, engine="batched", **cfg),
    )
    assert fused.eval_history == batched.eval_history
    assert fused.ledger.history == batched.ledger.history
    assert fused.ledger.params_transmitted == batched.ledger.params_transmitted
    assert fused.ledger.bytes_int8_signs == batched.ledger.bytes_int8_signs
    assert fused.test_mrr_cg == batched.test_mrr_cg
    assert np.isfinite(fused.test_mrr_cg)


def _small_federation(seed=0):
    kg = generate_kg(num_entities=80, num_relations=6, num_triples=400,
                     seed=seed)
    return kg, partition_by_relation(kg, 2, seed=seed)


@pytest.mark.parametrize("method", sorted(registered_methods()))
def test_all_engines_agree_for_every_registered_method(method):
    """Engine-equivalence sweep over the WHOLE scoring registry: for every
    registered method the three device engines (fused, batched, superstep)
    are trajectory- and ledger-bitwise-identical, and the ragged numpy
    reference protocol transmits the bitwise-same ledger (its training
    arithmetic is an independent host oracle with a different summation
    order, so trajectories agree only statistically — finiteness pinned).
    Catches any engine still dispatching on a hardcoded method list instead
    of the registry."""
    kg, clients = _small_federation()
    cfg = dict(method=method, dim=8, rounds=3, local_epochs=1, batch_size=32,
               num_negatives=4, lr=5e-3, sync_interval=2, eval_every=2,
               patience=99, max_eval_triples=20, seed=3)
    runs = {
        eng: run_federated(clients, kg.num_entities,
                           FederatedConfig(engine=eng, **cfg))
        for eng in ("fused", "batched", "superstep", "reference")
    }
    fused = runs["fused"]
    assert np.isfinite(fused.test_mrr_cg)
    for eng in ("batched", "superstep"):
        assert fused.eval_history == runs[eng].eval_history, eng
        assert fused.ledger.history == runs[eng].ledger.history, eng
        assert fused.test_mrr_cg == runs[eng].test_mrr_cg, eng
    for eng in ("batched", "superstep", "reference"):
        assert fused.ledger.params_transmitted == \
            runs[eng].ledger.params_transmitted, eng
        assert fused.ledger.bytes_int8_signs == \
            runs[eng].ledger.bytes_int8_signs, eng
    assert np.isfinite(runs["reference"].test_mrr_cg)


@pytest.mark.parametrize("method", ["protate", "distmult"])
def test_engines_agree_through_ef_codec_sweep(method):
    """Same device-engine parity through an error-feedback wire codec
    (int8:ef=1) for one method of each family — EF residual banks ride the
    engine state, so this catches any registry-routed method whose state
    layout breaks the banked-residual threading."""
    kg, clients = _small_federation(1)
    cfg = dict(method=method, dim=8, rounds=4, local_epochs=1, batch_size=32,
               num_negatives=4, lr=5e-3, sync_interval=2, eval_every=2,
               patience=99, max_eval_triples=20, seed=5, codec="int8:ef=1")
    runs = [
        run_federated(clients, kg.num_entities,
                      FederatedConfig(engine=eng, **cfg))
        for eng in ("fused", "batched", "superstep")
    ]
    for other in runs[1:]:
        assert runs[0].eval_history == other.eval_history
        assert runs[0].ledger.history == other.ledger.history
        assert runs[0].test_mrr_cg == other.test_mrr_cg
    assert np.isfinite(runs[0].test_mrr_cg)


def test_fused_matches_batched_quantized_fedep():
    """Same parity through the int8 wire codec and the sync-every-round
    protocol (exercises the sync-round leg of the fused program)."""
    kg, clients, cfg = _instance(7)
    for protocol, quant in (("fedep", False), ("feds", True)):
        fused = run_federated(
            clients, kg.num_entities,
            FederatedConfig(protocol=protocol, engine="fused",
                            quantize_upload=quant, **cfg),
        )
        batched = run_federated(
            clients, kg.num_entities,
            FederatedConfig(protocol=protocol, engine="batched",
                            quantize_upload=quant, **cfg),
        )
        assert fused.eval_history == batched.eval_history, protocol
        assert fused.ledger.history == batched.ledger.history, protocol


def test_ledger_totals_independent_of_eval_cadence():
    """Deferred device-side accounting: flushing pending rounds at different
    eval boundaries must produce a bitwise-identical ledger (evaluation
    never feeds back into training except through early stopping, which the
    large patience disables)."""
    kg, clients, cfg = _instance(3)
    cfg = dict(cfg, rounds=6, patience=99)
    ledgers = []
    for eval_every in (1, 3, 6):
        res = run_federated(
            clients, kg.num_entities,
            FederatedConfig(protocol="feds", engine="fused",
                            **dict(cfg, eval_every=eval_every)),
        )
        ledgers.append(res.ledger)
    assert ledgers[0].history == ledgers[1].history == ledgers[2].history
    assert (
        ledgers[0].bytes_int8_signs
        == ledgers[1].bytes_int8_signs
        == ledgers[2].bytes_int8_signs
    )


# ------------------------------------------------------ superstep == fused
def test_compress_schedule_rle():
    assert compress_schedule(["sparse", "sparse", "sync"]) == (
        ("sparse", 2), ("sync", 1),
    )
    assert compress_schedule(["sync", "sparse", "sync", "sync"]) == (
        ("sync", 1), ("sparse", 1), ("sync", 2),
    )
    assert compress_schedule([]) == ()
    with pytest.raises(ValueError, match="unknown round kind"):
        compress_schedule(["sparse", "dense"])


@pytest.mark.parametrize("seed", [0, 2])
def test_superstep_matches_fused_trajectory_and_ledger(seed):
    """engine="superstep" (one scanned program per eval span) must be
    trajectory- and ledger-bitwise-identical to engine="fused" (one program
    per round) over the same ISM schedule."""
    kg, clients, cfg = _instance(seed)
    fused = run_federated(
        clients, kg.num_entities,
        FederatedConfig(protocol="feds", engine="fused", **cfg),
    )
    sstep = run_federated(
        clients, kg.num_entities,
        FederatedConfig(protocol="feds", engine="superstep", **cfg),
    )
    assert fused.eval_history == sstep.eval_history
    assert fused.ledger.history == sstep.ledger.history
    assert fused.ledger.params_transmitted == sstep.ledger.params_transmitted
    assert fused.ledger.bytes_int8_signs == sstep.ledger.bytes_int8_signs
    assert fused.test_mrr_cg == sstep.test_mrr_cg
    assert fused.rounds_run == sstep.rounds_run
    assert np.isfinite(sstep.test_mrr_cg)


def test_superstep_equals_sequential_fused_cycles():
    """One superstep over an ISM period (s sparse + 1 sync) + a train-only
    round must leave bitwise-identical device state to the same rounds driven
    one fused_cycle/train_cycle call at a time."""
    kg = generate_kg(num_entities=130, num_relations=9, num_triples=1000, seed=0)
    cd = partition_by_relation(kg, 3, seed=0)

    def mk():
        return [
            KGEClient(d, method="transe", dim=8, batch_size=48, num_negatives=4,
                      lr=5e-3, seed=0)
            for d in cd
        ]

    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    engine = SuperstepEngine(mk(), views, kg.num_entities,
                             sparsity_p=0.5, local_epochs=2)
    kinds = ("sparse", "sparse", "sync", "none")

    sa = engine.init_state(mk(), seed=3)
    sa, per_round, _losses = engine.superstep(sa, kinds)
    downs_a = [np.asarray(d) for k, d in per_round if k == "sparse"]
    assert [k for k, _ in per_round] == list(kinds)
    assert all(d is None for k, d in per_round if k != "sparse")

    sb = engine.init_state(mk(), seed=3)
    downs_b = []
    for kind in kinds:
        if kind == "none":
            sb, _jitter, _loss = engine.train_cycle(sb)
        else:
            sb, down, _loss = engine.fused_cycle(sb, sync=kind == "sync")
            if kind == "sparse":
                downs_b.append(np.asarray(down))

    np.testing.assert_array_equal(np.asarray(sa.key), np.asarray(sb.key))
    np.testing.assert_array_equal(np.asarray(downs_a), np.asarray(downs_b))
    for name, a, b in (
        ("entity", sa.arrays.params["entity"], sb.arrays.params["entity"]),
        ("relation", sa.arrays.params["relation"], sb.arrays.params["relation"]),
        ("hist", sa.arrays.hist, sb.arrays.hist),
        ("mu_e", sa.arrays.opt.mu["entity"], sb.arrays.opt.mu["entity"]),
        ("nu_e", sa.arrays.opt.nu["entity"], sb.arrays.opt.nu["entity"]),
        ("step", sa.arrays.opt.step, sb.arrays.opt.step),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


# ----------------------------------------------------------- state invariants
def _make_engine(num_clients=3, seed=0, **kw):
    kg = generate_kg(num_entities=130, num_relations=3 * num_clients,
                     num_triples=1000, seed=seed)
    cd = partition_by_relation(kg, num_clients, seed=0)
    clients = [
        KGEClient(d, method="transe", dim=8, batch_size=48, num_negatives=4,
                  lr=5e-3, seed=seed)
        for d in cd
    ]
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    engine = CycleEngine(clients, views, kg.num_entities,
                         sparsity_p=0.5, local_epochs=2, **kw)
    return engine, clients


def test_fused_cycle_padding_rows_stay_zero():
    """Padded entity rows / shared slots must never be touched by training
    (never sampled), the optimizer (zero moments), or the round (masked)."""
    engine, clients = _make_engine()
    state = engine.init_state(clients, seed=11)
    for sync in (False, True, False):
        state, _down, _loss = engine.fused_cycle(state, sync=sync)
    ent = np.asarray(state.arrays.params["entity"])
    mu = np.asarray(state.arrays.opt.mu["entity"])
    hist = np.asarray(state.arrays.hist)
    for c, cl in enumerate(clients):
        n = cl.model.num_entities
        np.testing.assert_array_equal(ent[c, n:], 0.0)
        np.testing.assert_array_equal(mu[c, n:], 0.0)
        ns = engine.views[c].num_shared
        np.testing.assert_array_equal(hist[c, ns:], 0.0)


def test_state_roundtrips_through_clients():
    """init_state -> sync_clients is the identity on per-client params."""
    engine, clients = _make_engine()
    before = [{k: np.asarray(v) for k, v in c.params.items()} for c in clients]
    state = engine.init_state(clients, seed=0)
    engine.sync_clients(state, clients)
    for b, c in zip(before, clients):
        np.testing.assert_array_equal(b["entity"], np.asarray(c.params["entity"]))
        np.testing.assert_array_equal(b["relation"], np.asarray(c.params["relation"]))


def test_training_reduces_loss():
    """The device-resident trainer actually learns (loss falls over cycles)."""
    engine, clients = _make_engine()
    state = engine.init_state(clients, seed=0)
    state, _, first = engine.train_cycle(state)
    for _ in range(8):
        state, _, last = engine.train_cycle(state)
    assert float(np.asarray(last).mean()) < float(np.asarray(first).mean())


def test_heterogeneous_hyperparams_rejected():
    engine, clients = _make_engine()
    clients[1].lr = clients[1].lr * 2
    with pytest.raises(ValueError, match="homogeneous"):
        CycleEngine(clients, engine.views, engine.num_global,
                    sparsity_p=0.5, local_epochs=2)


def test_flat_trainer_rejects_unequal_adam_steps():
    """The flat fast path shares one Adam step count; clients arriving with
    divergent counts must be rejected instead of silently mis-corrected."""
    kg = generate_kg(num_entities=130, num_relations=6, num_triples=1000, seed=0)
    cd = partition_by_relation(kg, 2, seed=0)
    clients = [
        # batch >= every split size => one batch per epoch for all clients
        KGEClient(d, method="transe", dim=8, batch_size=10_000,
                  num_negatives=4, lr=5e-3, seed=0)
        for d in cd
    ]
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    engine = CycleEngine(clients, views, kg.num_entities,
                         sparsity_p=0.5, local_epochs=1)
    assert engine._uniform_steps
    engine.init_state(clients)  # equal (zero) steps: fine
    clients[0].train_local(1)  # client 0 advances its Adam step alone
    with pytest.raises(ValueError, match="lockstep"):
        engine.init_state(clients)


# ----------------------------------------------------- eval filter-mask cache
def test_eval_filter_cache_matches_bruteforce():
    from repro.core.evaluation import unpack_filter_words

    _, clients = _make_engine()
    cl = clients[0]
    triples = cl.data.valid
    assert cl._filter_cache == {}  # lazy: nothing built at construction
    n = int(triples.shape[0])
    e = cl.data.num_entities
    cl.evaluate("valid", n)
    ft_w, fh_w = cl._filter_cache[("valid", n)]
    assert ft_w.shape == (n, (e + 31) // 32) and ft_w.dtype == np.uint32
    ft = np.asarray(unpack_filter_words(jnp.asarray(ft_w), e))
    fh = np.asarray(unpack_filter_words(jnp.asarray(fh_w), e))
    for i, (h, r, t) in enumerate(triples.tolist()):
        tails = set(cl._known.get(("t", h, r), set())) - {t}
        heads = set(cl._known.get(("h", r, t), set())) - {h}
        assert set(np.nonzero(ft[i])[0].tolist()) == tails
        assert set(np.nonzero(fh[i])[0].tolist()) == heads
    # repeated evaluations are deterministic; a smaller request gets its own
    # (split, n_rows) entry sliced from the cached superset, so the cache
    # never serves rows from a stale larger build
    m = min(50, n - 1)
    assert cl.evaluate("valid", m) == cl.evaluate("valid", m)
    np.testing.assert_array_equal(cl._filter_cache[("valid", m)][0], ft_w[:m])


# ------------------------------------------------------------- SPMD == host
_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, json
sys.path.insert(0, "src")
import numpy as np
from repro.core.engine import make_client_mesh
from repro.core.protocol import build_comm_views
from repro.core.state import CycleEngine
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient

kg = generate_kg(num_entities=120, num_relations=8, num_triples=900, seed=1)
cd = partition_by_relation(kg, 2, seed=0)
def mk():
    return [KGEClient(d, method="transe", dim=8, batch_size=32,
                      num_negatives=4, lr=5e-3, seed=0) for d in cd]
views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)

host = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5, local_epochs=2)
pod = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5, local_epochs=2,
                  mesh=make_client_mesh(2))
sh = host.init_state(mk(), seed=7)
sp = pod.init_state(mk(), seed=7)
out = {}
for name, sync in (("sparse", False), ("sync", True)):
    sh, dh, lh = host.fused_cycle(sh, sync=sync)
    sp, dp, lp = pod.fused_cycle(sp, sync=sync)
    out[name] = {
        "emb": float(np.abs(np.asarray(sh.arrays.params["entity"])
                            - np.asarray(sp.arrays.params["entity"])).max()),
        "hist": float(np.abs(np.asarray(sh.arrays.hist)
                             - np.asarray(sp.arrays.hist)).max()),
        "down": (np.asarray(dh) == np.asarray(dp)).all().item(),
    }
print(json.dumps(out))
"""


def test_fused_cycle_spmd_matches_host():
    """One shard_map cycle program over >= 2 CPU devices == single-device jit."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _WORKER], capture_output=True, text=True,
        env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for name, rec in out.items():
        assert rec["emb"] < 1e-5, (name, rec)
        assert rec["hist"] < 1e-5, (name, rec)
        assert rec["down"], (name, rec)


_POD_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, json
sys.path.insert(0, "src")
from repro.data import generate_kg, partition_by_relation
from repro.federated.simulation import FederatedConfig, run_federated

kg = generate_kg(num_entities=120, num_relations=8, num_triples=900, seed=1)
clients = partition_by_relation(kg, 2, seed=0)
base = dict(method="transe", dim=8, rounds=4, local_epochs=1, batch_size=32,
            num_negatives=4, lr=5e-3, sparsity_p=0.5, sync_interval=2,
            eval_every=2, patience=99, max_eval_triples=30, seed=0)
host = run_federated(clients, kg.num_entities,
                     FederatedConfig(protocol="feds", engine="fused", **base))
pod = run_federated(clients, kg.num_entities,
                    FederatedConfig(protocol="feds", engine="superstep",
                                    mesh_devices=2, **base))
print(json.dumps({
    "hist_eq": host.eval_history == pod.eval_history,
    "ledger_eq": host.ledger.history == pod.ledger.history,
    "params_eq": host.ledger.params_transmitted
                 == pod.ledger.params_transmitted,
    "mrr_eq": host.test_mrr_cg == pod.test_mrr_cg,
}))
"""


def test_superstep_pod_simulation_matches_host_fused():
    """The pod-mode simulation driver (mesh_devices=2, client axis sharded
    under shard_map) must reproduce the host fused trajectory and ledger."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _POD_WORKER], capture_output=True, text=True,
        env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out == {
        "hist_eq": True, "ledger_eq": True, "params_eq": True, "mrr_eq": True,
    }
