"""Fault-injected federation (repro.core.faults): spec parsing, seeded mask
determinism, faulted round semantics vs a numpy oracle, the zero-participant
aggregation guard, staleness counters, cross-engine equivalence under chaos
schedules, and checkpoint/kill/resume durability.

Two structural guarantees anchor everything:

* a *trivial* schedule makes the engines compile the exact pre-fault
  programs, so the all-present case is bitwise identical to an unfaulted
  run by construction;
* masks are pure functions of the absolute round index (threefry fold-in),
  so the host ledger replay, the numpy reference oracle, and the scanned
  superstep all agree on any schedule with no shared state.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregate import Upload, personalized_aggregate
from repro.core.codec import IdentityCodec, Int8RowCodec
from repro.core.engine import RoundEngine
from repro.core.faults import (
    FaultSchedule,
    RoundFaults,
    draw_round_faults,
    host_round_faults,
    parse_fault_spec,
)
from repro.core.protocol import (
    apply_full_download,
    apply_sparse_download,
    build_comm_views,
    sparse_upload,
)
from repro.core.state import CycleEngine
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.simulation import FederatedConfig, run_federated

NUM_GLOBAL, DIM = 40, 8


def _random_instance(rng, num_clients, num_global=NUM_GLOBAL, dim=DIM):
    """Random heterogeneous federation (tests/test_engine.py twin)."""
    while True:
        l2g = [
            np.sort(
                rng.choice(num_global, size=int(rng.integers(10, 28)),
                           replace=False)
            ).astype(np.int64)
            for _ in range(num_clients)
        ]
        views = build_comm_views(l2g, num_global)
        if all(v.num_shared >= 4 for v in views):
            break
    tables = [
        jnp.asarray(rng.normal(size=(len(l), dim)), jnp.float32) for l in l2g
    ]
    hist_tables = [
        t + jnp.asarray(rng.normal(size=t.shape) * 0.5, jnp.float32)
        for t in tables
    ]
    return views, tables, hist_tables


# ------------------------------------------------------------- spec parsing
def test_parse_fault_spec_roundtrip():
    s = parse_fault_spec("p=0.5,drop_up=0.1,drop_down=0.2,stragglers=2:0,lag=3,seed=7")
    assert s == FaultSchedule(
        participation=0.5, drop_upload=0.1, drop_download=0.2,
        stragglers=(0, 2), lag=3, seed=7,
    )
    assert not s.trivial and s.has_stragglers
    assert parse_fault_spec("").trivial
    assert parse_fault_spec("p=1.0,seed=99").trivial  # seed alone changes nothing
    assert not parse_fault_spec("force=1").trivial  # testing hook


@pytest.mark.parametrize("spec,msg", [
    ("p=0.5,p=0.6", "duplicate"),
    ("p=0", "participation"),
    ("drop_up=1.0", "drop_upload"),
    ("frequency=2", "unknown fault spec key"),
    ("p0.5", "bad fault spec item"),
    ("lag=abc", "bad value"),
    ("stragglers=0:0,lag=1", "unique"),
    ("stragglers=1", "lag"),
    ("lag=2", "lag given without stragglers"),
])
def test_parse_fault_spec_rejects(spec, msg):
    with pytest.raises(ValueError, match=msg):
        parse_fault_spec(spec)


def test_straggler_ids_validated_against_client_count():
    s = parse_fault_spec("stragglers=5,lag=1")
    with pytest.raises(ValueError, match="out of range"):
        s.validate_clients(3)
    s.validate_clients(6)


# ----------------------------------------------------------- mask determinism
def test_draw_round_faults_host_equals_traced():
    """The same (seed, t) must draw bit-identical masks whether t is a
    concrete int (host replay) or a traced scan carry (device program)."""
    s = parse_fault_spec("p=0.4,drop_up=0.3,drop_down=0.2,seed=11")
    for t in (0, 1, 17):
        eager = draw_round_faults(s, t, 6)
        traced = jax.jit(lambda tt: draw_round_faults(s, tt, 6))(jnp.int32(t))
        for a, b in zip(eager, traced):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        part, up, dn = host_round_faults(s, t, 6)
        np.testing.assert_array_equal(part, np.asarray(eager.part) > 0.5)
        np.testing.assert_array_equal(up, np.asarray(eager.up_ok) > 0.5)
        np.testing.assert_array_equal(dn, np.asarray(eager.dn_ok) > 0.5)


def test_forced_trivial_draws_all_ones():
    rf = draw_round_faults(parse_fault_spec("force=1"), 5, 7)
    for leg in rf:
        np.testing.assert_array_equal(np.asarray(leg), 1.0)


# ------------------------------------ round-level: all-ones masks are neutral
@pytest.mark.parametrize("codec_cls", [IdentityCodec, Int8RowCodec])
def test_all_ones_masks_bitwise_neutral(codec_cls):
    """Feeding explicit all-ones masks through the faulted round functions
    must be bitwise identical to the maskless rounds — the mask plumbing
    (x1.0 multiplies on 0/1 floats, &True on bools) never perturbs values."""
    rng = np.random.default_rng(5)
    views, tables, hist_tables = _random_instance(rng, 3, NUM_GLOBAL, DIM)
    engine = RoundEngine(views, NUM_GLOBAL, DIM, 0.5, codec=codec_cls())
    emb, hist = engine.gather(tables), engine.gather(hist_tables)
    jitter = jnp.asarray(rng.random((3, engine.ns_max)), jnp.float32)
    ones = RoundFaults(*(jnp.ones((3,), jnp.float32),) * 3)

    plain = engine.sparse_round(emb, hist, jitter)
    masked = engine.sparse_round(emb, hist, jitter, faults=ones)
    for name, a, b in zip(("emb", "hist", "down"), plain, masked):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)

    plain = engine.sync_round(emb)
    masked = engine.sync_round(emb, faults=ones)
    for name, a, b in zip(("emb", "hist"), plain, masked):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


# ------------------------------------- faulted sparse round vs numpy oracle
def _empty(cid):
    return Upload(client_id=cid, entity_ids=np.zeros(0, np.int64),
                  values=np.zeros((0, DIM), np.float32))


def _faulted_reference_round(tables, hists, views, codec, part, up_ok, dn_ok):
    """Numpy twin of one faulted sparse round at p=1.0 (tie-break-free).

    part -> upload computed (history refreshes); part & up_ok -> delivered
    (enters Eq. 3); part & dn_ok -> download applied; down counts reflect
    part only (the server selected and sent — delivery loss is the
    receiver's problem, not the biller's).
    """
    uploads, new_hists = [], []
    for t, h, v in zip(tables, hists, views):
        if part[v.client_id]:
            up, hh = sparse_upload(t, h, v, 1.0)
            up = dataclasses.replace(
                up,
                values=np.asarray(codec.roundtrip(jnp.asarray(up.values)), np.float32),
            )
            new_hists.append(hh)
            uploads.append(up if up_ok[v.client_id] else _empty(v.client_id))
        else:
            new_hists.append(h)
            uploads.append(_empty(v.client_id))
    downs = personalized_aggregate(
        uploads, [v.shared_global for v in views], 1.0, np.random.default_rng(0)
    )
    out, counts = [], []
    for t, v, d in zip(tables, views, downs):
        counts.append(len(d.entity_ids) if part[v.client_id] else 0)
        if part[v.client_id] and dn_ok[v.client_id]:
            vals = d.agg_values
            if len(d.entity_ids):
                vals = np.asarray(codec.roundtrip(jnp.asarray(vals)), np.float32)
            out.append(apply_sparse_download(t, v, d.entity_ids, vals, d.priority))
        else:
            out.append(t)
    return out, new_hists, counts


@pytest.mark.parametrize("codec_cls", [IdentityCodec, Int8RowCodec])
def test_faulted_sparse_round_matches_oracle(codec_cls):
    """~50% participation + drops on both legs, against the host oracle."""
    rng = np.random.default_rng(23)
    views, tables, hist_tables = _random_instance(rng, 5, NUM_GLOBAL, DIM)
    codec = codec_cls()
    part = np.array([1, 0, 1, 1, 0], bool)
    up_ok = np.array([1, 1, 0, 1, 1], bool)
    dn_ok = np.array([1, 1, 1, 0, 1], bool)
    hists = [
        jnp.asarray(np.asarray(h)[v.shared_local])
        for h, v in zip(hist_tables, views)
    ]
    ref_tables, ref_hists, ref_counts = _faulted_reference_round(
        tables, hists, views, codec, part, up_ok, dn_ok
    )
    engine = RoundEngine(views, NUM_GLOBAL, DIM, 1.0, codec=codec)
    new_emb, new_hist, down = engine.sparse_round(
        engine.gather(tables), engine.gather(hist_tables),
        faults=RoundFaults(
            jnp.asarray(part, jnp.float32),
            jnp.asarray(up_ok, jnp.float32),
            jnp.asarray(dn_ok, jnp.float32),
        ),
    )
    for c, v in enumerate(views):
        ns = v.num_shared
        np.testing.assert_allclose(
            np.asarray(new_emb[c, :ns]),
            np.asarray(ref_tables[c])[v.shared_local],
            atol=5e-4, err_msg=f"client {c} emb",
        )
        np.testing.assert_allclose(
            np.asarray(new_hist[c, :ns]), np.asarray(ref_hists[c]),
            atol=1e-6, err_msg=f"client {c} hist",
        )
        assert int(down[c]) == ref_counts[c], f"client {c} down count"


# --------------------------------------------- zero-participant round guards
def test_zero_participation_rounds_are_noops():
    """Nobody present: both round kinds must leave the tables untouched and
    finite — in particular the sync round's Eq. 3 mean over an all-absent
    entity row must not emit the clamped-denominator zero row."""
    rng = np.random.default_rng(3)
    views, tables, hist_tables = _random_instance(rng, 3, NUM_GLOBAL, DIM)
    engine = RoundEngine(views, NUM_GLOBAL, DIM, 1.0)
    emb, hist = engine.gather(tables), engine.gather(hist_tables)
    nobody = RoundFaults(*(jnp.zeros((3,), jnp.float32),) * 3)

    new_emb, new_hist, down = engine.sparse_round(emb, hist, faults=nobody)
    np.testing.assert_array_equal(np.asarray(new_emb), np.asarray(emb))
    np.testing.assert_array_equal(np.asarray(new_hist), np.asarray(hist))
    np.testing.assert_array_equal(np.asarray(down), 0)

    new_emb, _ = engine.sync_round(emb, faults=nobody)
    np.testing.assert_array_equal(np.asarray(new_emb), np.asarray(emb))


def test_sync_round_zero_contributor_rows_keep_local_values():
    """Client 1 participates but its upload is lost while client 2 is absent
    — so only client 0's upload reaches Eq. 3.  Client 1 still receives the
    download: rows shared with client 0 take client 0's values (count 1);
    rows NOBODY uploaded have zero contributors and must keep client 1's
    local values instead of the clamped-denominator zero mean."""
    rng = np.random.default_rng(8)
    views, tables, hist_tables = _random_instance(rng, 3, NUM_GLOBAL, DIM)
    engine = RoundEngine(views, NUM_GLOBAL, DIM, 1.0)
    emb = engine.gather(tables)
    faults = RoundFaults(
        jnp.asarray([1.0, 1.0, 0.0]),  # part
        jnp.asarray([1.0, 0.0, 1.0]),  # up_ok: client 1's upload is lost
        jnp.asarray([1.0, 1.0, 1.0]),  # dn_ok
    )
    new_emb, _ = engine.sync_round(emb, faults=faults)
    g2r0 = views[0].global_to_row
    guarded = 0
    for r, g in enumerate(views[1].shared_global.tolist()):
        got = np.asarray(new_emb[1, r])
        if g in g2r0:  # one contributor (client 0): mean == its row
            want = np.asarray(emb[0, g2r0[g]])
        else:  # zero contributors: the guard keeps the local row
            want = np.asarray(emb[1, r])
            guarded += 1
        np.testing.assert_allclose(got, want, atol=1e-6, err_msg=f"g={g}")
    # client 0's own upload always reaches it back unchanged; absent client
    # 2 keeps everything
    ns0, ns2 = views[0].num_shared, views[2].num_shared
    np.testing.assert_allclose(
        np.asarray(new_emb[0, :ns0]), np.asarray(emb[0, :ns0]), atol=1e-6
    )
    np.testing.assert_array_equal(
        np.asarray(new_emb[2, :ns2]), np.asarray(emb[2, :ns2])
    )
    assert np.isfinite(np.asarray(new_emb)).all()


def test_apply_full_download_count_guard():
    """Host twin of the sync guard: zero-count entities keep local rows."""
    l2g = [np.array([0, 1, 2], np.int64), np.array([1, 2, 3], np.int64)]
    views = build_comm_views(l2g, 4)
    table = jnp.asarray(np.arange(3 * DIM, dtype=np.float32).reshape(3, DIM))
    mean = np.full((4, DIM), 7.0, np.float32)
    count = np.array([0, 1, 0, 0], np.int64)
    out = np.asarray(apply_full_download(table, views[0], mean, count=count))
    np.testing.assert_array_equal(out[views[0].shared_local[0]], 7.0)  # g=1
    np.testing.assert_array_equal(  # g=2: count 0 -> keep local
        out[views[0].shared_local[1]],
        np.asarray(table)[views[0].shared_local[1]],
    )
    # historical call shape (no count) still overwrites unconditionally
    out = np.asarray(apply_full_download(table, views[0], mean))
    np.testing.assert_array_equal(out[np.asarray(views[0].shared_local)], 7.0)


# ----------------------------------------------- cycle-level fault state
def _mini_federation(num_clients=2, seed=1):
    kg = generate_kg(num_entities=120, num_relations=4 * num_clients,
                     num_triples=800, seed=seed)
    cd = partition_by_relation(kg, num_clients, seed=0)
    def mk():
        return [
            KGEClient(d, method="transe", dim=8, batch_size=32,
                      num_negatives=4, lr=5e-3, seed=0)
            for d in cd
        ]
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    return kg, cd, views, mk


def test_staleness_age_counters_follow_schedule():
    """FederationState.faults.age must count rounds since each client last
    participated, resetting on participation — exactly the host-replayed
    mask sequence."""
    kg, cd, views, mk = _mini_federation(num_clients=2)
    sched = parse_fault_spec("p=0.5,seed=9")
    engine = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5,
                         local_epochs=1, faults=sched)
    state = engine.init_state(mk(), seed=4)
    age = np.zeros(2, np.int32)
    for t in range(6):
        state, _down, _loss = engine.fused_cycle(state, sync=t % 3 == 2, t=t)
        part, _, _ = host_round_faults(sched, t, 2)
        age = np.where(part, 0, age + 1).astype(np.int32)
        np.testing.assert_array_equal(
            np.asarray(state.arrays.faults.age), age, err_msg=f"round {t}"
        )


def test_engine_requires_round_index_when_faulted():
    kg, cd, views, mk = _mini_federation(num_clients=2)
    engine = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5,
                         local_epochs=1, faults=parse_fault_spec("p=0.5"))
    state = engine.init_state(mk(), seed=0)
    with pytest.raises(ValueError, match="round index"):
        engine.fused_cycle(state, sync=False)


def test_trivial_schedule_compiles_pre_fault_programs():
    kg, cd, views, mk = _mini_federation(num_clients=2)
    engine = CycleEngine(mk(), views, kg.num_entities, sparsity_p=0.5,
                         local_epochs=1, faults=parse_fault_spec("p=1.0,seed=5"))
    assert engine._sched is None  # structurally the unfaulted engine
    assert engine.init_state(mk()).arrays.faults.q_val.shape[1] == 0


# ------------------------------------------- simulation-level equivalences
_CHAOS = "p=0.6,drop_up=0.2,drop_down=0.2,stragglers=0,lag=2,seed=3"


@pytest.fixture(scope="module")
def sim_env():
    kg = generate_kg(num_entities=120, num_relations=8, num_triples=900, seed=1)
    clients = partition_by_relation(kg, 2, seed=0)
    base = dict(method="transe", protocol="feds", dim=8, rounds=5,
                local_epochs=1, batch_size=32, num_negatives=4, lr=5e-3,
                sparsity_p=1.0, sync_interval=2, eval_every=2, patience=99,
                max_eval_triples=30, seed=0)
    plain = run_federated(clients, kg.num_entities,
                          FederatedConfig(engine="fused", **base))
    return kg, clients, base, plain


def _same_run(a, b):
    return (
        a.eval_history == b.eval_history
        and a.ledger.history == b.ledger.history
        and a.ledger.params_transmitted == b.ledger.params_transmitted
        and a.ledger.bytes_int8_signs == b.ledger.bytes_int8_signs
        and a.test_mrr_cg == b.test_mrr_cg
    )


def test_trivial_and_forced_schedules_match_unfaulted(sim_env):
    """All-present is bitwise identical to the pre-fault engines, both via
    the structural path (trivial spec -> pre-fault programs) and via the
    forced path (machinery compiled in, masks drawn all-ones)."""
    kg, clients, base, plain = sim_env
    for spec in ("", "p=1.0,seed=42", "force=1"):
        run = run_federated(
            clients, kg.num_entities,
            FederatedConfig(engine="fused", faults=spec, **base),
        )
        assert _same_run(plain, run), spec


def test_chaos_schedule_engines_agree(sim_env):
    """Under a schedule with partial participation, drops on both legs, and
    a lagged straggler: fused == superstep (trajectory + ledger), the run
    differs from the unfaulted one, metrics stay finite, and the reference
    oracle's ledger matches the device replay byte-for-byte (sparsity 1.0
    makes down selection deterministic, so billing is schedule-exact)."""
    kg, clients, base, plain = sim_env
    runs = {
        eng: run_federated(
            clients, kg.num_entities,
            FederatedConfig(engine=eng, faults=_CHAOS, **base),
        )
        for eng in ("fused", "superstep", "reference")
    }
    assert _same_run(runs["fused"], runs["superstep"])
    assert runs["fused"].eval_history != plain.eval_history
    assert all(np.isfinite(m) for _, m, _ in runs["fused"].eval_history)
    ref = runs["reference"]
    assert ref.ledger.history == runs["superstep"].ledger.history
    assert ref.ledger.bytes_int8_signs == runs["superstep"].ledger.bytes_int8_signs
    assert all(np.isfinite(m) for _, m, _ in ref.eval_history)


def test_faults_rejected_on_tiered_engine(sim_env):
    kg, clients, base, _ = sim_env
    with pytest.raises(ValueError, match="tiered"):
        run_federated(
            clients, kg.num_entities,
            FederatedConfig(engine="tiered", faults=_CHAOS, **base),
        )


# ----------------------------------------------------- checkpoint / resume
def test_checkpoint_kill_resume_bitwise(sim_env, tmp_path):
    """A run killed after its round-4 checkpoint and resumed in a fresh
    engine must finish with the uninterrupted run's trajectory, ledger, and
    terminal metrics — bitwise."""
    kg, clients, base, _ = sim_env
    base = dict(base, rounds=8, faults=_CHAOS, engine="superstep")
    full = run_federated(clients, kg.num_entities, FederatedConfig(**base))
    p = str(tmp_path / "ckpt.npz")
    run_federated(  # the "killed" run: stops at round 4, checkpoint written
        clients, kg.num_entities,
        FederatedConfig(**dict(base, rounds=4, checkpoint_path=p,
                               checkpoint_every=4)),
    )
    assert os.path.exists(p)
    resumed = run_federated(
        clients, kg.num_entities,
        FederatedConfig(**dict(base, checkpoint_path=p, checkpoint_every=4,
                               resume=True)),
    )
    assert _same_run(full, resumed)
    assert full.best_round == resumed.best_round
    assert resumed.rounds_run == 8


def test_checkpoint_fingerprint_mismatch_rejected(sim_env, tmp_path):
    kg, clients, base, _ = sim_env
    p = str(tmp_path / "ckpt.npz")
    run_federated(
        clients, kg.num_entities,
        FederatedConfig(engine="fused", checkpoint_path=p, checkpoint_every=2,
                        **base),
    )
    with pytest.raises(ValueError, match="different config"):
        run_federated(
            clients, kg.num_entities,
            FederatedConfig(engine="fused", checkpoint_path=p,
                            checkpoint_every=2, resume=True,
                            **dict(base, lr=1e-3)),
        )


def test_checkpoint_config_validation(sim_env):
    kg, clients, base, _ = sim_env
    with pytest.raises(ValueError, match="checkpoint_path"):
        run_federated(clients, kg.num_entities,
                      FederatedConfig(engine="fused", checkpoint_every=2, **base))
    with pytest.raises(ValueError, match="checkpoint_path"):
        run_federated(clients, kg.num_entities,
                      FederatedConfig(engine="fused", resume=True, **base))
    with pytest.raises(ValueError, match="device engine"):
        run_federated(
            clients, kg.num_entities,
            FederatedConfig(engine="reference", checkpoint_path="/tmp/x.npz",
                            checkpoint_every=2, **base),
        )
