"""Optimizer, checkpoint, and HLO-cost-model unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import restore_pytree, save_pytree
from repro.train.optimizer import adam_init, adam_update, global_norm


# ------------------------------------------------------------------- adam
def _numpy_adam(params, grads, steps, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8):
    m = np.zeros_like(params)
    v = np.zeros_like(params)
    p = params.copy()
    for t in range(1, steps + 1):
        g = grads
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        p = p - lr * mhat / (np.sqrt(vhat) + eps)
    return p


def test_adam_matches_reference():
    p0 = np.linspace(-1, 1, 12).astype(np.float32)
    g = np.linspace(0.5, -0.5, 12).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    grads = {"w": jnp.asarray(g)}
    state = adam_init(params)
    for _ in range(5):
        params, state = adam_update(grads, state, params, lr=1e-2)
    ref = _numpy_adam(p0, g, 5)
    np.testing.assert_allclose(np.asarray(params["w"]), ref, rtol=1e-5)


def test_adam_clip_norm():
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    state = adam_init(params)
    new, _ = adam_update(grads, state, params, lr=1.0, clip_norm=1e-3)
    # clipped gradient direction preserved, magnitude bounded by Adam lr
    assert float(jnp.abs(new["w"]).max()) <= 1.0 + 1e-6


def test_adam_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adam_init(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2)
        )(params)
        params, state = adam_update(g, state, params, lr=5e-2)
        return params, state, loss

    for _ in range(400):
        params, state, loss = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    np.testing.assert_allclose(float(global_norm(tree)), 5.0)


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "emb": jax.random.normal(jax.random.PRNGKey(0), (7, 5)),
        "nested": {"b": jnp.arange(4, dtype=jnp.int32)},
    }
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, tree)
    template = jax.tree.map(jnp.zeros_like, tree)
    restored = restore_pytree(path, template)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch(tmp_path):
    path = str(tmp_path / "c.msgpack")
    save_pytree(path, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_pytree(path, {"w": jnp.zeros((3, 3))})


# -------------------------------------------------------------- hlo costs
def test_hlo_walker_counts_scan_trips():
    from repro.launch.hlo_costs import analyze

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None

        c, _ = jax.lax.scan(body, x, w)
        return c.sum()

    x = jnp.zeros((64, 64))
    flops = {}
    for L in (1, 4):
        comp = jax.jit(f).lower(x, jnp.zeros((L, 64, 64))).compile()
        flops[L] = analyze(comp.as_text())["flops"]
    # dot flops dominate: 4-layer scan ~4x the 1-layer scan
    assert 3.5 < flops[4] / flops[1] < 4.5


def test_hlo_walker_collectives():
    from repro.launch.hlo_costs import analyze

    # single-device module has no collectives
    comp = jax.jit(lambda x: x @ x).lower(jnp.zeros((32, 32))).compile()
    r = analyze(comp.as_text())
    assert r["collective_bytes"] == 0.0
    assert r["flops"] >= 2 * 32**3


def test_federated_checkpoint_roundtrip(tmp_path):
    """Save/restore a client's full training state mid-run."""
    from repro.data import generate_kg, partition_by_relation
    from repro.federated.client import KGEClient

    kg = generate_kg(num_entities=120, num_relations=8, num_triples=900, seed=0)
    clients = partition_by_relation(kg, 2, seed=0)
    c = KGEClient(clients[0], method="transe", dim=16, batch_size=64,
                  num_negatives=8, lr=1e-2, seed=0)
    c.train_local(2)
    path = str(tmp_path / "client0.msgpack")
    save_pytree(path, {"params": c.params, "opt": c.opt_state})
    m1 = c.evaluate("valid", 40)

    c2 = KGEClient(clients[0], method="transe", dim=16, batch_size=64,
                   num_negatives=8, lr=1e-2, seed=99)  # different init
    restored = restore_pytree(path, {"params": c2.params, "opt": c2.opt_state})
    c2.params = restored["params"]
    c2.opt_state = restored["opt"]
    m2 = c2.evaluate("valid", 40)
    assert abs(m1["mrr"] - m2["mrr"]) < 1e-9
