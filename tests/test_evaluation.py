"""Device-batched filtered-ranking eval == the numpy oracle, exactly.

The batched evaluator (repro.core.evaluation) computes integer filtered
ranks that must be EXACTLY equal — both head and tail legs — to the
per-client numpy-oracle ranks of ``KGEClient.ranks`` over randomized
heterogeneous federations, and the superstep program with an ``"eval"``
plan segment must leave bitwise-identical carried state and produce a
bitwise-identical metric block to running the rounds and the standalone
eval program separately.  A 2-device pod spec pins the ``shard_map`` twin.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.evaluation import (
    BatchedEvaluator,
    build_eval_bank,
    build_known_index,
    num_filter_words,
    pack_filter_rows,
    unpack_filter_words,
)
from repro.core.protocol import build_comm_views
from repro.core.state import CycleEngine, SuperstepEngine
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.metrics import aggregate_eval_block, weighted_average
from repro.federated.simulation import FederatedConfig, run_federated


def _federation(seed, method="transe", dim=8):
    """Randomized heterogeneous federation (seeded, no hypothesis wheel)."""
    rng = np.random.default_rng(seed)
    nc = int(rng.integers(2, 5))
    kg = generate_kg(
        num_entities=int(rng.integers(80, 200)),
        num_relations=3 * nc,
        num_triples=int(rng.integers(600, 1500)),
        seed=int(rng.integers(0, 1000)),
    )
    cd = partition_by_relation(kg, nc, seed=int(rng.integers(0, 10)))
    clients = [
        KGEClient(d, method=method, dim=dim, batch_size=32, num_negatives=4,
                  lr=5e-3, seed=seed)
        for d in cd
    ]
    views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)
    return kg, cd, clients, views


# ------------------------------------------------------------ filter packing
def test_pack_unpack_roundtrip_matches_bruteforce():
    rng = np.random.default_rng(3)
    kg = generate_kg(num_entities=70, num_relations=5, num_triples=400, seed=1)
    cd = partition_by_relation(kg, 2, seed=0)[0]
    known = build_known_index(cd.train, cd.valid, cd.test)
    tri = cd.valid
    w = num_filter_words(cd.num_entities)
    ft_w, fh_w = pack_filter_rows(tri, known, w)
    assert ft_w.dtype == np.uint32 and ft_w.shape == (tri.shape[0], w)
    ft = np.asarray(unpack_filter_words(jnp.asarray(ft_w), cd.num_entities))
    fh = np.asarray(unpack_filter_words(jnp.asarray(fh_w), cd.num_entities))
    for i, (h, r, t) in enumerate(tri.tolist()):
        assert set(np.nonzero(ft[i])[0]) == set(known[("t", h, r)]) - {t}
        assert set(np.nonzero(fh[i])[0]) == set(known[("h", r, t)]) - {h}
    # ~32x memory cut over the dense bool representation
    assert ft_w.nbytes * 8 <= ft.nbytes + 31 * ft_w.shape[0] * 8
    del rng


def test_bank_requires_covering_e_max():
    kg = generate_kg(num_entities=70, num_relations=6, num_triples=400, seed=0)
    cd = partition_by_relation(kg, 2, seed=0)
    with pytest.raises(ValueError, match="e_max"):
        BatchedEvaluator(cd, method="transe", gamma=8.0, e_max=4,
                         max_triples=10)


def test_bank_pads_empty_and_capped_splits():
    kg = generate_kg(num_entities=90, num_relations=6, num_triples=500, seed=0)
    cd = partition_by_relation(kg, 2, seed=0)
    e_max = max(d.num_entities for d in cd)
    bank = build_eval_bank(cd, "valid", max_triples=3, e_max=e_max)
    assert bank.triples.shape[1] == 3  # capped B_max
    np.testing.assert_array_equal(np.asarray(bank.count), [3, 3])


# ------------------------------------------------- exact oracle equivalence
@pytest.mark.parametrize("seed,method", [
    (0, "transe"), (1, "rotate"), (2, "complex"), (3, "transe"), (4, "rotate"),
    (5, "distmult"), (6, "protate"), (7, "complex"), (8, "distmult"),
])
def test_batched_ranks_exactly_equal_oracle(seed, method):
    """Integer filtered ranks (both legs) from the device program == the
    numpy-oracle ranks, over randomized heterogeneous federations, after
    real training has moved the tables."""
    kg, cd, clients, views = _federation(seed, method=method)
    engine = CycleEngine(clients, views, kg.num_entities,
                         sparsity_p=0.5, local_epochs=1)
    state = engine.init_state(clients, seed=seed)
    for sync in (False, True):
        state, _, _ = engine.fused_cycle(state, sync=sync)
    engine.sync_clients(state, clients)

    rng = np.random.default_rng(seed + 100)
    cap = int(rng.integers(5, 60))
    chunk = int(rng.choice([7, 64, 512]))
    ev = BatchedEvaluator(cd, method=method, gamma=clients[0].gamma,
                          e_max=engine.e_max, max_triples=cap, chunk=chunk)
    for split in ("valid", "test"):
        rt, rh = ev.ranks(state.arrays.params, split)
        block = ev.evaluate(state.arrays.params, split)
        per_client = []
        for c, cl in enumerate(clients):
            oracle = cl.ranks(split, cap)  # (n, 2) tail/head columns
            n = oracle.shape[0]
            np.testing.assert_array_equal(oracle[:, 0], rt[c, :n], err_msg=split)
            np.testing.assert_array_equal(oracle[:, 1], rh[c, :n], err_msg=split)
            m = cl.evaluate(split, cap)
            per_client.append(m)
            assert int(block[c, 4]) == m["count"]
            # float metric from identical integer ranks: f32 vs f64 only
            assert abs(block[c, 0] - m["mrr"]) < 1e-6
            for j, key in enumerate(("hits1", "hits3", "hits10"), start=1):
                assert abs(block[c, j] - m[key]) < 1e-6
        agg = aggregate_eval_block(block)
        want = weighted_average(per_client)
        assert agg["count"] == want["count"]
        assert abs(agg["mrr"] - want["mrr"]) < 1e-6


# --------------------------------------------- superstep "eval" plan segment
def test_superstep_with_eval_bitwise_equals_separate_eval():
    """One program over (rounds + eval) must leave the SAME carried state
    (bitwise) as the rounds alone, and its in-program metric block must be
    bitwise identical to the standalone compiled evaluator on that state."""
    kg, cd, clients, views = _federation(7)

    def mk():
        return [
            KGEClient(d, method="transe", dim=8, batch_size=32,
                      num_negatives=4, lr=5e-3, seed=7)
            for d in cd
        ]

    engine = SuperstepEngine(mk(), views, kg.num_entities,
                             sparsity_p=0.5, local_epochs=2)
    ev = BatchedEvaluator(cd, method="transe", gamma=8.0, e_max=engine.e_max,
                          max_triples=30)
    kinds = ("sparse", "sparse", "sync", "none")

    sa = engine.init_state(mk(), seed=3)
    sa, pr_a, _l, block = engine.superstep_with_eval(sa, kinds, ev, "valid")

    sb = engine.init_state(mk(), seed=3)
    sb, pr_b, _l2 = engine.superstep(sb, kinds)
    block_sep = ev._eval(sb.arrays.params, ev.banks["valid"])

    np.testing.assert_array_equal(np.asarray(sa.key), np.asarray(sb.key))
    for name, a, b in (
        ("entity", sa.arrays.params["entity"], sb.arrays.params["entity"]),
        ("relation", sa.arrays.params["relation"], sb.arrays.params["relation"]),
        ("hist", sa.arrays.hist, sb.arrays.hist),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
    np.testing.assert_array_equal(np.asarray(block), np.asarray(block_sep))
    downs_a = [np.asarray(d) for k, d in pr_a if k == "sparse"]
    downs_b = [np.asarray(d) for k, d in pr_b if k == "sparse"]
    np.testing.assert_array_equal(np.asarray(downs_a), np.asarray(downs_b))


def test_superstep_eval_cache_keyed_on_evaluator():
    """Two evaluators sharing a plan+split must not reuse each other's
    compiled program (eval_core closes over method/gamma/chunk)."""
    kg, cd, clients, views = _federation(11)

    def mk():
        return [
            KGEClient(d, method="transe", dim=8, batch_size=32,
                      num_negatives=4, lr=5e-3, seed=11)
            for d in cd
        ]

    engine = SuperstepEngine(mk(), views, kg.num_entities,
                             sparsity_p=0.5, local_epochs=1)
    ev_a = BatchedEvaluator(cd, method="transe", gamma=8.0,
                            e_max=engine.e_max, max_triples=10, chunk=32)
    ev_b = BatchedEvaluator(cd, method="transe", gamma=8.0,
                            e_max=engine.e_max, max_triples=25, chunk=512)
    kinds = ("sparse",)
    sa = engine.init_state(mk(), seed=1)
    _, _, _, block_a = engine.superstep_with_eval(sa, kinds, ev_a, "valid")
    sb = engine.init_state(mk(), seed=1)
    _, _, _, block_b = engine.superstep_with_eval(sb, kinds, ev_b, "valid")
    # same rounds, different banks/chunking: counts differ, programs must too
    assert int(np.asarray(block_a)[:, -1].sum()) != int(
        np.asarray(block_b)[:, -1].sum()
    )
    assert len(engine._superstep_cache) == 2


def test_superstep_rejects_inline_eval_kind():
    kg, cd, clients, views = _federation(5)
    engine = SuperstepEngine(clients, views, kg.num_entities,
                             sparsity_p=0.5, local_epochs=1)
    state = engine.init_state(clients, seed=0)
    with pytest.raises(ValueError, match="superstep_with_eval"):
        engine.superstep(state, ("sparse", "eval"))


# --------------------------------------------------- simulation integration
@pytest.mark.parametrize("engine", ["superstep", "fused", "reference"])
def test_terminal_eval_boundary_guaranteed(engine):
    """rounds % eval_every != 0 must still evaluate the final rounds (the
    old loops silently dropped them, so they could never win the best-model
    snapshot), on every engine."""
    kg = generate_kg(num_entities=100, num_relations=6, num_triples=600, seed=2)
    clients = partition_by_relation(kg, 2, seed=0)
    res = run_federated(
        clients, kg.num_entities,
        FederatedConfig(method="transe", dim=8, rounds=7, local_epochs=1,
                        batch_size=32, num_negatives=4, lr=5e-3,
                        sparsity_p=0.5, sync_interval=2, eval_every=5,
                        patience=99, max_eval_triples=20, engine=engine),
    )
    assert [r for r, _, _ in res.eval_history] == [5, 7]
    assert res.rounds_run == 7


def test_simulation_device_eval_history_matches_engines():
    """All three device engines (standalone eval program for fused/batched,
    in-program eval segment for superstep) must produce ONE bitwise eval
    trajectory and the same test metrics."""
    kg = generate_kg(num_entities=110, num_relations=9, num_triples=800, seed=4)
    clients = partition_by_relation(kg, 3, seed=0)
    cfg = dict(method="transe", dim=8, rounds=5, local_epochs=1,
               batch_size=32, num_negatives=4, lr=5e-3, sparsity_p=0.5,
               sync_interval=2, eval_every=2, patience=99,
               max_eval_triples=25, seed=1)
    out = {
        eng: run_federated(clients, kg.num_entities,
                           FederatedConfig(engine=eng, **cfg))
        for eng in ("fused", "batched", "superstep")
    }
    assert out["fused"].eval_history == out["batched"].eval_history
    assert out["fused"].eval_history == out["superstep"].eval_history
    assert out["fused"].test_mrr_cg == out["superstep"].test_mrr_cg
    assert out["fused"].best_round == out["superstep"].best_round
    assert np.isfinite(out["fused"].test_mrr_cg)


# ------------------------------------------------------------- pod (2-device)
_POD_EVAL_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, json
sys.path.insert(0, "src")
import numpy as np
from repro.core.engine import make_client_mesh
from repro.core.evaluation import BatchedEvaluator
from repro.core.protocol import build_comm_views
from repro.core.state import SuperstepEngine
from repro.data import generate_kg, partition_by_relation
from repro.federated.client import KGEClient
from repro.federated.simulation import FederatedConfig, run_federated

kg = generate_kg(num_entities=120, num_relations=8, num_triples=900, seed=1)
cd = partition_by_relation(kg, 2, seed=0)
def mk():
    return [KGEClient(d, method="transe", dim=8, batch_size=32,
                      num_negatives=4, lr=5e-3, seed=0) for d in cd]
views = build_comm_views([d.local_to_global for d in cd], kg.num_entities)

host = SuperstepEngine(mk(), views, kg.num_entities, sparsity_p=0.5, local_epochs=1)
pod = SuperstepEngine(mk(), views, kg.num_entities, sparsity_p=0.5, local_epochs=1,
                      mesh=make_client_mesh(2))
ev_h = BatchedEvaluator(cd, method="transe", gamma=8.0, e_max=host.e_max,
                        max_triples=25)
ev_p = BatchedEvaluator(cd, method="transe", gamma=8.0, e_max=pod.e_max,
                        max_triples=25, mesh=make_client_mesh(2))
kinds = ("sparse", "sync")
sh = host.init_state(mk(), seed=7)
sp = pod.init_state(mk(), seed=7)
sh, _, _, bh = host.superstep_with_eval(sh, kinds, ev_h, "valid")
sp, _, _, bp = pod.superstep_with_eval(sp, kinds, ev_p, "valid")
rt_h, rh_h = ev_h.ranks(sh.arrays.params, "valid")
rt_p, rh_p = ev_p.ranks(sp.arrays.params, "valid")

base = dict(method="transe", dim=8, rounds=3, local_epochs=1, batch_size=32,
            num_negatives=4, lr=5e-3, sparsity_p=0.5, sync_interval=2,
            eval_every=2, patience=99, max_eval_triples=25, seed=0)
host_sim = run_federated(cd, kg.num_entities,
                         FederatedConfig(protocol="feds", engine="fused", **base))
pod_sim = run_federated(cd, kg.num_entities,
                        FederatedConfig(protocol="feds", engine="superstep",
                                        mesh_devices=2, **base))
print(json.dumps({
    "block_eq": bool(np.array_equal(np.asarray(bh), np.asarray(bp))),
    "ranks_eq": bool(np.array_equal(rt_h, rt_p) and np.array_equal(rh_h, rh_p)),
    "sim_hist_eq": host_sim.eval_history == pod_sim.eval_history,
    "sim_mrr_eq": host_sim.test_mrr_cg == pod_sim.test_mrr_cg,
    "tail_evald": [r for r, _, _ in pod_sim.eval_history] == [2, 3],
}))
"""


def test_pod_eval_matches_host():
    """The 2-device shard_map evaluator (and a pod superstep simulation with
    in-program eval, including the terminal partial span) must reproduce the
    host results bitwise."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _POD_EVAL_WORKER], capture_output=True,
        text=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out == {
        "block_eq": True, "ranks_eq": True, "sim_hist_eq": True,
        "sim_mrr_eq": True, "tail_evald": True,
    }


# -------------------------------------------------------- metric aggregation
def test_aggregate_eval_block_matches_weighted_average():
    block = np.asarray([
        [0.5, 0.3, 0.6, 0.8, 10.0],
        [0.25, 0.1, 0.2, 0.4, 30.0],
        [0.0, 0.0, 0.0, 0.0, 0.0],
    ])
    dicts = [
        {"mrr": 0.5, "hits1": 0.3, "hits3": 0.6, "hits10": 0.8, "count": 10},
        {"mrr": 0.25, "hits1": 0.1, "hits3": 0.2, "hits10": 0.4, "count": 30},
        {"mrr": 0.0, "hits1": 0.0, "hits3": 0.0, "hits10": 0.0, "count": 0},
    ]
    a, w = aggregate_eval_block(block), weighted_average(dicts)
    assert a["count"] == w["count"]
    for key in ("mrr", "hits1", "hits3", "hits10"):
        assert abs(a[key] - w[key]) < 1e-12
    assert aggregate_eval_block(np.zeros((2, 5))) == {
        "mrr": 0.0, "hits1": 0.0, "hits3": 0.0, "hits10": 0.0, "count": 0,
    }
    with pytest.raises(ValueError, match="columns"):
        aggregate_eval_block(np.zeros((2, 3)))


def test_eval_state_built_once_and_device_resident():
    """Banks are jax arrays built at construction; evaluate() reads back
    only the (C, 5) block."""
    kg, cd, clients, views = _federation(9)
    engine = CycleEngine(clients, views, kg.num_entities,
                         sparsity_p=0.5, local_epochs=1)
    ev = BatchedEvaluator(cd, method="transe", gamma=8.0, e_max=engine.e_max,
                          max_triples=20)
    for bank in ev.banks.values():
        for leaf in bank:
            assert isinstance(leaf, jax.Array)
    state = engine.init_state(clients, seed=0)
    block = ev.evaluate(state.arrays.params, "valid")
    assert block.shape == (len(clients), 5)
