"""Health observatory: divergence probes, alert rules, monitor, report.

Four layers, mirroring the pipeline:

* **unit** — the jit-safe probe helpers (shared-entity divergence, update
  norms, non-finite counts) against float64 numpy oracles, including the
  consensus property (identical shared rows => exactly zero divergence);
* **grammar** — the ``--alerts`` spec parses/round-trips canonically and
  every rejection restates the grammar (the codec/fault spec contract);
* **monitor** — :class:`repro.core.health.HealthMonitor` fires each rule
  once (latched), attributes the offending client, and drives the
  fail-mode graceful stop without breaking the stream grammar;
* **report** — ``tools/health_report.py`` as a subprocess: exit 0 on a
  healthy stream (sync strictly reduces divergence), exit 1 on fail-level
  alerts or a tampered sync round.
"""
import json
import math
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.health import (
    ALERT_RULES,
    AlertRule,
    HealthMonitor,
    format_alert_spec,
    parse_alert_spec,
)
from repro.core.telemetry import (
    nonfinite_count,
    shared_divergence,
    update_norm,
)
from repro.data import generate_kg, partition_by_relation
from repro.federated.simulation import FederatedConfig, run_federated

ROOT = Path(__file__).resolve().parent.parent


# ----------------------------------------------------- probe numpy oracles
def _np_shared_divergence(rows, gid, valid, num_global):
    """float64 oracle: per-client mean/max L2 distance of each valid shared
    row to the existence-masked cross-client mean of its global entity."""
    rows = rows.astype(np.float64)
    C, k, d = rows.shape
    total = np.zeros((num_global, d))
    cnt = np.zeros(num_global)
    for c in range(C):
        for j in range(k):
            if valid[c, j]:
                total[gid[c, j]] += rows[c, j]
                cnt[gid[c, j]] += 1
    mean = total / np.maximum(cnt, 1.0)[:, None]
    div_mean = np.zeros(C)
    div_max = np.zeros(C)
    for c in range(C):
        dists = [
            np.linalg.norm(rows[c, j] - mean[gid[c, j]])
            for j in range(k) if valid[c, j]
        ]
        if dists:
            div_mean[c] = np.mean(dists)
            div_max[c] = np.max(dists)
    return div_mean, div_max


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_shared_divergence_matches_numpy_oracle(seed):
    rng = np.random.default_rng(seed)
    C, k, d, G = 3, 7, 8, 20
    rows = rng.normal(size=(C, k, d)).astype(np.float32)
    gid = rng.integers(0, G, size=(C, k)).astype(np.int32)
    valid = rng.random((C, k)) < 0.7
    got_mean, got_max = shared_divergence(
        jnp.asarray(rows), jnp.asarray(gid), jnp.asarray(valid), G
    )
    want_mean, want_max = _np_shared_divergence(rows, gid, valid, G)
    np.testing.assert_allclose(np.asarray(got_mean), want_mean,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_max), want_max,
                               rtol=1e-4, atol=1e-5)


def test_divergence_is_exactly_zero_at_consensus():
    """The ISM post-sync property the report's --check-sync leans on: when
    every client holds the SAME row for each shared entity, divergence is
    exactly 0.0 — not approximately.  (Exact because each entity's slots
    are unique per client, so segment counts are powers of two and the
    mean-of-identical-rows division is exact in binary float — the real
    federation's shape: one slot per shared entity per client.)"""
    rng = np.random.default_rng(5)
    C, k, d, G = 2, 6, 8, 10
    table = rng.normal(size=(G, d)).astype(np.float32)
    gid = np.stack([rng.permutation(G)[:k] for _ in range(C)]).astype(np.int32)
    rows = table[gid]  # all clients agree with the global table
    valid = np.ones((C, k), dtype=bool)
    div_mean, div_max = shared_divergence(
        jnp.asarray(rows), jnp.asarray(gid), jnp.asarray(valid), G
    )
    assert float(np.abs(np.asarray(div_mean)).max()) == 0.0
    assert float(np.abs(np.asarray(div_max)).max()) == 0.0


def test_divergence_ignores_invalid_slots():
    """Padding rows (valid=False) contribute to neither the cross-client
    mean nor the distances — a client with NO valid slots reports 0."""
    rows = np.ones((2, 3, 4), dtype=np.float32) * 7.0
    gid = np.zeros((2, 3), dtype=np.int32)
    valid = np.zeros((2, 3), dtype=bool)
    valid[0, 0] = True  # a single live row: consensus with itself
    div_mean, div_max = shared_divergence(
        jnp.asarray(rows), jnp.asarray(gid), jnp.asarray(valid), 5
    )
    np.testing.assert_array_equal(np.asarray(div_mean), [0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(div_max), [0.0, 0.0])


def test_update_norm_matches_numpy_oracle():
    rng = np.random.default_rng(6)
    C, k, d = 3, 5, 8
    new = rng.normal(size=(C, k, d)).astype(np.float32)
    old = rng.normal(size=(C, k, d)).astype(np.float32)
    valid = rng.random((C, k)) < 0.6
    got = np.asarray(update_norm(
        jnp.asarray(new), jnp.asarray(old), jnp.asarray(valid)
    ))
    diff = (new.astype(np.float64) - old) * valid[:, :, None]
    want = np.sqrt((diff * diff).sum(axis=(1, 2)))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_nonfinite_count_masks_padding():
    rows = np.zeros((2, 3, 4), dtype=np.float32)
    rows[0, 0, 0] = np.nan
    rows[0, 1, 1] = np.inf
    rows[1, 2, :] = -np.inf  # padded slot: must not count
    valid = np.array([[True, True, False], [True, True, False]])
    got = np.asarray(nonfinite_count(jnp.asarray(rows), jnp.asarray(valid)))
    np.testing.assert_array_equal(got, [2, 0])


# ------------------------------------------------------------ alert grammar
def test_alert_spec_round_trips_canonically():
    spec = "divergence>0.5;nan;mrr-stall=20;byte-budget=2e9"
    rules = parse_alert_spec(spec)
    assert [r.name for r in rules] == list(ALERT_RULES)
    assert format_alert_spec(rules) == "divergence>0.5;nan;mrr-stall=20;byte-budget=2e+09"
    # canonical form is a fixed point
    assert format_alert_spec(parse_alert_spec(format_alert_spec(rules))) \
        == format_alert_spec(rules)


def test_alert_spec_empty_means_off():
    assert parse_alert_spec("") == ()
    assert parse_alert_spec("  ") == ()


@pytest.mark.parametrize("bad,needle", [
    ("divergence", "positive threshold"),
    ("divergence>-1", "positive threshold"),
    ("divergence>pasta", "bad value"),
    ("nan=1", "takes no value"),
    ("mrr-stall=2.5", "integer round count"),
    ("plasma>3", "unknown alert rule"),
    ("nan;;nan", "empty alert rule"),
    ("nan;nan", "duplicate alert rule"),
])
def test_alert_spec_errors_are_self_describing(bad, needle):
    with pytest.raises(ValueError) as e:
        parse_alert_spec(bad)
    assert needle in str(e.value)
    if needle != "duplicate alert rule":  # duplicates cite the rule, not
        assert "alert spec grammar" in str(e.value)  # the whole grammar


def test_alert_rule_validates_eagerly():
    with pytest.raises(ValueError, match="unknown alert rule"):
        AlertRule("bogus", 1.0)
    with pytest.raises(ValueError, match="positive threshold"):
        AlertRule("byte-budget", 0.0)


# ---------------------------------------------------------- monitor behavior
def _round_event(t, div_mean, nonfinite=(0, 0), cum_bytes=0.0):
    return {"ev": "round", "round": t, "kind": "sparse",
            "div_mean": list(div_mean), "div_max": list(div_mean),
            "upd_norm": [0.0] * len(div_mean),
            "nonfinite": list(nonfinite), "res_mass": [0.0] * len(div_mean),
            "cum_bytes": cum_bytes}


def test_monitor_divergence_latches_and_attributes_client():
    mon = HealthMonitor(parse_alert_spec("divergence>0.5"), mode="warn")
    assert mon.observe(_round_event(0, [0.1, 0.2])) == []
    fired = mon.observe(_round_event(1, [0.1, 0.9]))
    assert len(fired) == 1
    a = fired[0]
    assert a["ev"] == "alert" and a["name"] == "divergence"
    assert a["round"] == 1 and a["level"] == "warn"
    assert "client 1" in a["detail"]
    # latched: a worse violation later does not re-fire
    assert mon.observe(_round_event(2, [2.0, 2.0])) == []
    assert len(mon.fired) == 1
    assert not mon.should_stop()  # warn never stops


def test_monitor_nan_rule_sees_counts_and_nonfinite_floats():
    mon = HealthMonitor(parse_alert_spec("nan"), mode="fail")
    assert mon.observe(_round_event(0, [0.1, 0.1])) == []
    assert mon.observe(_round_event(1, [0.1, 0.1], nonfinite=(3, 0)))
    assert mon.should_stop()
    mon2 = HealthMonitor(parse_alert_spec("nan"), mode="fail")
    assert mon2.observe(_round_event(0, [math.inf, 0.1]))


def test_monitor_byte_budget_and_mrr_stall():
    mon = HealthMonitor(
        parse_alert_spec("byte-budget=1000;mrr-stall=2"), mode="warn"
    )
    assert mon.observe(_round_event(0, [0.0], cum_bytes=999.0)) == []
    assert mon.observe(_round_event(1, [0.0], cum_bytes=1001.0))
    evs = [
        {"ev": "eval", "split": "valid", "round": 0, "mrr": 0.3},
        {"ev": "eval", "split": "valid", "round": 1, "mrr": 0.2},
        {"ev": "eval", "split": "valid", "round": 2, "mrr": 0.25},
        {"ev": "eval", "split": "test", "round": 3, "mrr": 9.9},  # ignored
    ]
    fired = [a for e in evs for a in mon.observe(e)]
    assert [a["name"] for a in fired] == ["mrr-stall"]
    assert "unimproved for 2 rounds" in fired[0]["detail"]


def test_monitor_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown alert mode"):
        HealthMonitor((), mode="explode")


# ------------------------------------------------------- simulation wiring
@pytest.fixture(scope="module")
def health_env():
    kg = generate_kg(num_entities=120, num_relations=8, num_triples=900,
                     seed=1)
    clients = partition_by_relation(kg, 2, seed=0)
    base = dict(method="transe", protocol="feds", dim=8, rounds=5,
                local_epochs=1, batch_size=32, num_negatives=4, lr=5e-3,
                sparsity_p=0.4, sync_interval=2, eval_every=2, patience=99,
                max_eval_triples=30, seed=0)
    return kg, clients, base


def _run(health_env, tmp_path, tag, **overrides):
    kg, clients, base = health_env
    path = tmp_path / f"{tag}.jsonl"
    cfg = FederatedConfig(telemetry=str(path), **dict(base, **overrides))
    res = run_federated(clients, kg.num_entities, cfg)
    with open(path) as f:
        return res, [json.loads(line) for line in f if line.strip()], path


def test_alerts_without_telemetry_is_a_config_error(health_env):
    kg, clients, base = health_env
    cfg = FederatedConfig(alerts="nan", **base)
    with pytest.raises(ValueError, match="telemetry"):
        run_federated(clients, kg.num_entities, cfg)


def test_fail_mode_stops_gracefully_with_intact_stream(health_env, tmp_path):
    """A fail-level alert stops the run at the next eval boundary — early,
    but still ending with a reconciled ledger event (the grammar trace and
    shadow billing survive the abort)."""
    res, events, _ = _run(
        health_env, tmp_path, "failfast",
        alerts="divergence>1e-6", alert_mode="fail",
    )
    assert res.rounds_run < 5  # stopped before the configured horizon
    alerts = [e for e in events if e["ev"] == "alert"]
    assert alerts and alerts[0]["level"] == "fail"
    assert alerts[0]["name"] == "divergence"
    led = events[-1]
    assert led["ev"] == "ledger" and led["reconciled"] is True
    # alert events land immediately after the round that fired them
    idx = events.index(alerts[0])
    assert events[idx - 1]["ev"] == "round"
    assert events[idx - 1]["round"] == alerts[0]["round"]


def test_warn_mode_records_but_never_stops(health_env, tmp_path):
    res, events, _ = _run(
        health_env, tmp_path, "warn",
        alerts="divergence>1e-6", alert_mode="warn",
    )
    assert res.rounds_run == 5
    alerts = [e for e in events if e["ev"] == "alert"]
    assert alerts and all(a["level"] == "warn" for a in alerts)
    # latched: at most one alert per rule for the whole run
    assert len(alerts) == len({a["name"] for a in alerts})


# -------------------------------------------------- health_report subprocess
def _health_report(jsonl_path, *args):
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "health_report.py"),
         str(jsonl_path), *args],
        capture_output=True, text=True, timeout=60,
    )


def test_health_report_passes_on_healthy_run(health_env, tmp_path):
    """Healthy run + high thresholds: no alerts, sync strictly reduces
    divergence, exit 0, and the BENCH record says healthy."""
    _, _, path = _run(
        health_env, tmp_path, "healthy",
        alerts="divergence>100;nan;byte-budget=1e12", alert_mode="fail",
    )
    out_json = tmp_path / "BENCH_health.json"
    res = _health_report(path, "--check-sync", "--json", str(out_json))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "alerts: none fired" in res.stdout
    assert "sync recovery [PASS]" in res.stdout
    rec = json.loads(out_json.read_text())
    assert rec["bench"] == "health_report" and rec["healthy"] is True
    assert any("PASS" in c for c in rec["claims"])


def test_health_report_fails_on_fail_level_alert(health_env, tmp_path):
    _, _, path = _run(
        health_env, tmp_path, "alerting",
        alerts="divergence>1e-6", alert_mode="fail",
    )
    res = _health_report(path)
    assert res.returncode == 1
    assert "divergence" in res.stdout and "fail" in res.stdout


def test_health_report_catches_tampered_sync_round(health_env, tmp_path):
    """--check-sync re-derives the recovery property from the stream: a
    sync round whose divergence did NOT fall below the preceding comm
    round must fail, even with no alerts anywhere."""
    _, events, _ = _run(health_env, tmp_path, "tamper")
    forged = []
    for e in events:
        if e.get("ev") == "round" and e.get("kind") == "sync":
            e = dict(e, div_mean=[9.9 for _ in e["div_mean"]])
        forged.append(e)
    bad = tmp_path / "forged.jsonl"
    bad.write_text("".join(json.dumps(e) + "\n" for e in forged))
    res = _health_report(bad, "--check-sync")
    assert res.returncode == 1
    assert "sync recovery [FAIL]" in res.stdout


def test_health_report_rejects_unparseable_stream(tmp_path):
    bad = tmp_path / "garbage.jsonl"
    bad.write_text('{"ev": "run"}\nnot json\n')
    res = _health_report(bad)
    assert res.returncode != 0
    assert "unparseable" in (res.stdout + res.stderr)
