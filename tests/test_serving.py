"""Continuous-batching engine: batching must not change any request's output."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import init_lm
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), dtype=jnp.float32)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(cfg, params, req):
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=64)
    return eng.run([req])[req.uid]


def test_batched_matches_solo(model):
    cfg, params = model
    reqs = [
        Request("a", prompt=[1, 2, 3], max_new_tokens=6),
        Request("b", prompt=[7, 8], max_new_tokens=4),
        Request("c", prompt=[5, 6, 9, 11], max_new_tokens=5),
        Request("d", prompt=[2], max_new_tokens=3),
        Request("e", prompt=[10, 4], max_new_tokens=6),
    ]
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    batched = eng.run([dataclasses.replace(r) for r in reqs])
    assert set(batched) == {r.uid for r in reqs}
    for r in reqs:
        solo = _solo(cfg, params, dataclasses.replace(r))
        assert batched[r.uid] == solo, (r.uid, batched[r.uid], solo)


def test_continuous_batching_slot_reuse(model):
    cfg, params = model
    # 5 requests through 2 slots forces at least one slot reuse
    reqs = [Request(f"r{i}", prompt=[i + 1, i + 2], max_new_tokens=3)
            for i in range(5)]
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    out = eng.run(reqs)
    assert len(out) == 5
    assert all(len(v) == 3 for v in out.values())


def test_eos_stops_generation(model):
    cfg, params = model
    # discover the first greedy token, then use it as eos
    probe = _solo(cfg, params, Request("p", prompt=[1, 2], max_new_tokens=1))
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=64)
    out = eng.run([Request("q", prompt=[1, 2], max_new_tokens=8, eos_id=probe[0])])
    assert out["q"] == [probe[0]]
