"""Property test: SPMD FedS == host protocol over randomized instances.

Runs several randomized tie-break-free instances in ONE subprocess (4 fake
devices) and asserts elementwise agreement of the updated tables.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np
from repro.core.distributed import make_sharded_feds_round
from repro.core.aggregate import Upload, personalized_aggregate
from repro.core.engine import make_client_mesh
from repro.core.sparsify import change_scores, select_top_k

mesh = make_client_mesh(4, "data")
results = []
for seed in range(5):
    rng = np.random.default_rng(seed)
    C, D = 4, 8 + 4 * seed
    N = 24 + 8 * seed
    K = 4 + seed
    emb = jnp.asarray(rng.normal(size=(C, N, D)), jnp.float32)
    # tie-break-free: each client's top-K rows are a random, possibly
    # overlapping K-subset; priorities are then deterministic per entity.
    hist = np.asarray(emb).copy()
    chosen = []
    for c in range(C):
        idx = rng.choice(N, size=K, replace=False)
        chosen.append(idx)
        hist[c, idx] += 1.0 + rng.random((K, D))
    hist = jnp.asarray(hist)

    rnd = make_sharded_feds_round(mesh, k=K, sync_interval=1000)
    spmd_emb, _ = rnd(emb, hist, jnp.zeros((1,), jnp.int32))

    uploads = []
    for c in range(C):
        idx, _ = select_top_k(change_scores(emb[c], hist[c]), K)
        uploads.append(Upload(client_id=c, entity_ids=np.asarray(idx, np.int64),
                              values=np.asarray(emb[c])[np.asarray(idx)]))
    downs = personalized_aggregate(uploads, [np.arange(N)] * C, K / N,
                                   np.random.default_rng(0))
    host = np.asarray(emb).copy()
    # count candidates per client: if > K the tie-break could differ; the
    # construction keeps candidates <= K whenever priorities are unique.
    ok_instance = True
    for c, d in enumerate(downs):
        if len(d.entity_ids) > K:
            ok_instance = False
        for i, e in enumerate(d.entity_ids.tolist()):
            host[c, e] = (d.agg_values[i] + host[c, e]) / (1 + d.priority[i])
    # only compare when the host selection was unambiguous (<= K candidates)
    cand_counts = []
    for c in range(C):
        others = set()
        for cc in range(C):
            if cc != c:
                others |= set(chosen[cc].tolist())
        cand_counts.append(len(others))
    if max(cand_counts) <= K:
        results.append(float(np.abs(np.asarray(spmd_emb) - host).max()))
print(json.dumps(results))
"""


def test_spmd_randomized_agreement():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _WORKER], capture_output=True,
                         text=True, env=env, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-2000:]
    errs = json.loads(res.stdout.strip().splitlines()[-1])
    # at least some instances are unambiguous; all of those must agree
    for e in errs:
        assert e < 1e-4, errs
