#!/usr/bin/env python3
"""Render the federation's model-health trajectory and alert log.

Input: the flight-recorder JSONL written by ``--telemetry PATH`` — with
the health probes of ``repro.core.telemetry`` (``div_mean`` / ``div_max``
/ ``upd_norm`` / ``nonfinite`` per round event) and any ``alert`` events
the streaming monitor (``repro.core.health``, ``--alerts``) appended.
Output: the shared-entity divergence trajectory around sync boundaries —
the sync-recovery figure the paper's Intermittent Synchronization
Mechanism motivates but never plots — plus the fired-alert log.

This is also the health pipeline's verifier, two ways:

* any **fail-level alert** in the stream makes the report exit non-zero
  (CI gates a healthy run on exit code 0);
* with ``--check-sync``, every sync round must land strictly below the
  immediately preceding comm round's divergence — the recovery property
  ISM predicts — or the report exits non-zero.

Stdlib only (run it anywhere the JSONL lands, no jax needed):

    python tools/health_report.py telemetry.jsonl --check-sync \
        [--json BENCH_health.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict


def load_events(path: str) -> list[dict]:
    events = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError as e:
                raise SystemExit(f"{path}:{i + 1}: unparseable JSONL ({e})")
            if not isinstance(ev, dict) or "ev" not in ev:
                raise SystemExit(f"{path}:{i + 1}: not an event object")
            events.append(ev)
    return events


def _fmt_row(cols, widths):
    return "  ".join(str(c).rjust(w) for c, w in zip(cols, widths))


def _mean(xs) -> float:
    return sum(xs) / len(xs) if xs else 0.0


def divergence_table(rounds: list[dict]) -> list[str]:
    """One line per comm round: the divergence / update-norm / non-finite
    probes, with each sync round annotated with its drop vs the previous
    comm round (the ISM recovery signal)."""
    header = ("round", "kind", "div_mean", "div_max", "upd_norm",
              "nonfin", "sync_drop")
    widths = (5, 6, 9, 9, 9, 6, 10)
    lines = [_fmt_row(header, widths)]
    prev_div = None
    for r in rounds:
        div = _mean(r["div_mean"])
        drop = "-"
        if r["kind"] == "sync" and prev_div is not None:
            drop = f"{prev_div - div:+.4f}"
        lines.append(_fmt_row((
            r["round"], r["kind"], f"{div:.4f}",
            f"{max(r['div_max']):.4f}", f"{_mean(r['upd_norm']):.4f}",
            sum(r["nonfinite"]), drop,
        ), widths))
        prev_div = div
    return lines


def alert_table(alerts: list[dict]) -> list[str]:
    lines = [_fmt_row(("round", "level", "rule", "detail"), (5, 5, 18, 0))]
    for a in alerts:
        lines.append(_fmt_row(
            (a["round"], a["level"], a["rule"], a["detail"]), (5, 5, 18, 0)
        ))
    return lines


def check_sync_recovery(rounds: list[dict]) -> tuple[int, int, list[str]]:
    """(checked, failed, failure details): every sync round must land
    strictly below the previous comm round's mean divergence.  Sync rounds
    with no comm round before them (or a zero-divergence one — nothing to
    recover) are skipped."""
    checked = failed = 0
    details = []
    prev = None
    for r in rounds:
        div = _mean(r["div_mean"])
        if r["kind"] == "sync" and prev is not None and prev[1] > 0.0:
            checked += 1
            if not div < prev[1]:
                failed += 1
                details.append(
                    f"sync round {r['round']}: divergence {div:.6f} did not "
                    f"fall below round {prev[0]}'s {prev[1]:.6f}"
                )
        prev = (r["round"], div)
    return checked, failed, details


def report(events: list[dict], check_sync: bool):
    """Returns (report lines, claim strings, ok)."""
    by = defaultdict(list)
    for ev in events:
        by[ev["ev"]].append(ev)
    lines: list[str] = []
    claims: list[str] = []
    ok = True

    for run in by["run"]:
        lines.append(
            f"run: engine={run['engine']} codec={run['codec']} "
            f"method={run['method']} protocol={run['protocol']} "
            f"clients={run['clients']} dim={run['dim']} "
            f"rounds={run['rounds']}"
        )
    # "none" rounds carry no record (all-zero probes) — only comm rounds
    # tell a health story
    rounds = sorted(
        (r for r in by["round"] if r["kind"] != "none"),
        key=lambda r: r["round"],
    )
    if rounds:
        lines.append("")
        lines.extend(divergence_table(rounds))

    # re-derive severity from the alert events, not from exit-time state:
    # a stream is judged by what it says, even if the monitor is long gone
    alerts = by["alert"]
    lines.append("")
    if alerts:
        lines.extend(alert_table(alerts))
        fails = [a for a in alerts if a["level"] == "fail"]
        tag = "FAIL" if fails else "WARN"
        claims.append(
            f"[{tag}] health: {len(alerts)} alert(s) fired "
            f"({len(fails)} fail-level): "
            + ", ".join(sorted({a["name"] for a in alerts}))
        )
        if fails:
            ok = False
    else:
        lines.append("alerts: none fired")
        claims.append("[PASS] health: no alerts fired")

    if check_sync:
        checked, failed, details = check_sync_recovery(rounds)
        lines.append("")
        if checked == 0:
            lines.append("sync recovery [FAIL]: no sync round follows a "
                         "divergent comm round — nothing to check")
            claims.append("[FAIL] health: sync-recovery check vacuous")
            ok = False
        elif failed:
            lines.append(f"sync recovery [FAIL]: {failed}/{checked} sync "
                         f"round(s) did not reduce divergence")
            lines.extend("  " + d for d in details)
            claims.append(
                f"[FAIL] health: {failed}/{checked} sync round(s) failed "
                f"to reduce shared-entity divergence"
            )
            ok = False
        else:
            lines.append(f"sync recovery [PASS]: all {checked} sync "
                         f"round(s) strictly reduced divergence")
            claims.append(
                f"[PASS] health: every sync round ({checked}) strictly "
                f"reduced shared-entity divergence"
            )
    return lines, claims, ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", help="telemetry JSONL written by --telemetry")
    ap.add_argument("--check-sync", action="store_true",
                    help="fail unless every sync round strictly reduces "
                         "the shared-entity divergence (the ISM recovery "
                         "property)")
    ap.add_argument("--json", default=None,
                    help="also write a BENCH-style JSON record here")
    args = ap.parse_args()
    events = load_events(args.jsonl)
    lines, claims, ok = report(events, args.check_sync)
    print("\n".join(lines))
    if args.json:
        rounds = [e for e in events if e["ev"] == "round"]
        alerts = [e for e in events if e["ev"] == "alert"]
        rec = {
            "bench": "health_report",
            "schema_version": 1,
            "fast": bool(os.environ.get("REPRO_BENCH_FAST")),
            "source": args.jsonl,
            "rounds": len(rounds),
            "alerts": len(alerts),
            "healthy": ok,
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
