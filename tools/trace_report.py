#!/usr/bin/env python3
"""Render a federation flight-recorder JSONL stream into a readable report.

Input: the event stream written by ``--telemetry PATH`` (see
``repro.core.telemetry`` for the grammar: one ``run`` header, one ``round``
event per round, ``eval`` events at boundaries, ``span`` timings for host
stages, and a terminal ``ledger`` event).  Output: a per-round table, a
host-span summary, the eval trajectory, and the run totals.

This is also the telemetry pipeline's verifier: the ``ledger`` event carries
the real ledger totals next to the shadow totals re-billed purely from
device-recorded quantities.  If they disagree — the records misreport what
was transmitted — the report says so and **exits non-zero**, which is the
CI smoke step's assertion.

Stdlib only (run it anywhere the JSONL lands, no jax needed):

    python tools/trace_report.py telemetry.jsonl [--json BENCH_trace.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict


def load_events(path: str) -> list[dict]:
    events = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError as e:
                raise SystemExit(f"{path}:{i + 1}: unparseable JSONL ({e})")
            if not isinstance(ev, dict) or "ev" not in ev:
                raise SystemExit(f"{path}:{i + 1}: not an event object")
            events.append(ev)
    return events


def _fmt_row(cols, widths):
    return "  ".join(str(c).rjust(w) for c, w in zip(cols, widths))


def round_table(rounds: list[dict]) -> list[str]:
    """One line per round: participation, rows/bytes per leg, mean realized
    Top-K overlap fraction, EF residual mass, cache activity."""
    header = ("round", "kind", "part", "up_rows", "dn_rows", "up_MB",
              "dn_MB", "ovl%", "res_mass", "cache h/m/e")
    widths = (5, 6, 4, 7, 7, 7, 7, 5, 8, 11)
    lines = [_fmt_row(header, widths)]
    for r in rounds:
        n_part = sum(r["part"])
        up_rows = sum(r["up_rows"])
        ovl = (
            f"{100.0 * sum(r['overlap']) / up_rows:.0f}"
            if r["kind"] == "sparse" and up_rows else "-"
        )
        cache = "/".join(
            str(r[k])
            for k in ("cache_hits", "cache_misses", "cache_evictions")
        )
        lines.append(_fmt_row((
            r["round"], r["kind"], f"{n_part}/{len(r['part'])}",
            up_rows, sum(r["dn_rows"]),
            f"{sum(r['up_bytes']) / 1e6:.3f}",
            f"{sum(r['dn_bytes']) / 1e6:.3f}",
            ovl, f"{sum(r['res_mass']):.2f}", cache,
        ), widths))
    return lines


def span_table(spans: list[dict]) -> list[str]:
    agg = defaultdict(lambda: [0, 0.0])
    for s in spans:
        agg[s["name"]][0] += 1
        agg[s["name"]][1] += s["dur_s"]
    lines = [_fmt_row(("span", "calls", "total_s", "mean_ms"), (12, 6, 9, 9))]
    for name, (n, tot) in sorted(agg.items(), key=lambda kv: -kv[1][1]):
        lines.append(_fmt_row(
            (name, n, f"{tot:.3f}", f"{1e3 * tot / n:.2f}"), (12, 6, 9, 9)
        ))
    return lines


def eval_table(evals: list[dict]) -> list[str]:
    lines = [_fmt_row(("round", "split", "MRR", "Hits@10", "Mparams"),
                      (5, 6, 7, 8, 9))]
    for e in evals:
        lines.append(_fmt_row((
            e["round"], e["split"], f"{e['mrr']:.4f}", f"{e['hits10']:.4f}",
            f"{e['params_transmitted'] / 1e6:.3f}",
        ), (5, 6, 7, 8, 9)))
    return lines


def report(events: list[dict]) -> tuple[list[str], list[str], bool]:
    """Returns (report lines, claim strings, reconciled)."""
    by = defaultdict(list)
    for ev in events:
        by[ev["ev"]].append(ev)
    lines: list[str] = []
    claims: list[str] = []

    for run in by["run"]:
        lines.append(
            f"run: engine={run['engine']} codec={run['codec']} "
            f"method={run['method']} protocol={run['protocol']} "
            f"clients={run['clients']} dim={run['dim']} "
            f"rounds={run['rounds']}"
        )
    rounds = sorted(by["round"], key=lambda r: r["round"])
    if rounds:
        lines.append("")
        lines.extend(round_table(rounds))
    if by["span"]:
        lines.append("")
        lines.extend(span_table(by["span"]))
    if by["eval"]:
        lines.append("")
        lines.extend(eval_table(by["eval"]))

    reconciled = False
    if not by["ledger"]:
        lines.append("")
        lines.append("ERROR: no terminal 'ledger' event — the run died "
                     "before _finish, or the stream is truncated")
        claims.append("[WARN] trace: missing terminal ledger event")
    else:
        led = by["ledger"][-1]
        # re-derive from the stored totals rather than trusting the flag:
        # a stream whose ledger event was edited after the fact still fails
        reconciled = bool(led["reconciled"]) and (
            led["params_transmitted"] == led["shadow_params"]
            and led["bytes"] == led["shadow_bytes"]
            and led["rounds"] == led["shadow_rounds"]
        )
        part_rounds = [sum(r["part"]) for r in rounds]
        mean_part = (
            sum(part_rounds) / (len(part_rounds) or 1)
        )
        lines.append("")
        lines.append(
            f"totals: {led['rounds']} rounds, "
            f"{led['params_transmitted'] / 1e6:.3f} Mparams, "
            f"{led['bytes'] / 1e6:.3f} MB wire, "
            f"mean participation {mean_part:.2f} clients/round"
        )
        tag = "PASS" if reconciled else "FAIL"
        lines.append(
            f"reconciliation [{tag}]: shadow ledger (re-billed from "
            f"device records) {led['shadow_params'] / 1e6:.3f} Mparams / "
            f"{led['shadow_bytes'] / 1e6:.3f} MB vs real "
            f"{led['params_transmitted'] / 1e6:.3f} Mparams / "
            f"{led['bytes'] / 1e6:.3f} MB"
        )
        claims.append(
            f"[{tag}] trace: round records reconcile with the comm ledger "
            f"bitwise ({led['rounds']} rounds)"
        )
    return lines, claims, reconciled


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl", help="telemetry JSONL written by --telemetry")
    ap.add_argument("--json", default=None,
                    help="also write a BENCH-style JSON record here")
    args = ap.parse_args()
    events = load_events(args.jsonl)
    lines, claims, reconciled = report(events)
    print("\n".join(lines))
    if args.json:
        rounds = [e for e in events if e["ev"] == "round"]
        rec = {
            "bench": "trace_report",
            "schema_version": 1,
            "fast": bool(os.environ.get("REPRO_BENCH_FAST")),
            "source": args.jsonl,
            "rounds": len(rounds),
            "events": len(events),
            "reconciled": reconciled,
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")
    if not reconciled:
        sys.exit(1)


if __name__ == "__main__":
    main()
