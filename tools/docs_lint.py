"""Docs lint: every relative markdown link in README/docs must resolve.

Checks ``[text](target)`` links in README.md, docs/**/*.md, EXPERIMENTS.md,
and ROADMAP.md: external (``http``/``mailto``) and intra-page (``#``)
targets are skipped; everything else must exist on disk relative to the
linking file (anchors stripped).  Exits non-zero listing broken links.

Also checks benchmark-record coverage: every ``BENCH_*.json`` a CI step
produces (parsed from .github/workflows/ci.yml) must be mentioned in
EXPERIMENTS.md alongside its producer script, so the recorded perf
trajectory stays documented as producers are added.

  python tools/docs_lint.py

CI pairs this with ``python -m compileall -q src`` as the docs-lint step.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ROOT = Path(__file__).resolve().parent.parent


def doc_files() -> list[Path]:
    files = [ROOT / "README.md", ROOT / "EXPERIMENTS.md", ROOT / "ROADMAP.md"]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def broken_links() -> list[str]:
    broken = []
    for md in doc_files():
        text = md.read_text()
        # fenced code blocks may contain pseudo-links (e.g. mermaid)
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                broken.append(f"{md.relative_to(ROOT)}: {target}")
    return broken


BENCH_STEP = re.compile(
    r"python\s+benchmarks/(\w+)\.py\s+--json\s+(BENCH_\w+\.json)"
)


def undocumented_benchmarks() -> list[str]:
    """CI-produced BENCH_*.json records that EXPERIMENTS.md never mentions."""
    ci = ROOT / ".github" / "workflows" / "ci.yml"
    exp = ROOT / "EXPERIMENTS.md"
    if not ci.exists() or not exp.exists():
        return []
    text = exp.read_text()
    missing = []
    for script, record in BENCH_STEP.findall(ci.read_text()):
        if record not in text:
            missing.append(f"{record} (benchmarks/{script}.py)")
        elif f"{script}.py" not in text:
            missing.append(f"benchmarks/{script}.py (produces {record})")
    return missing


def main() -> int:
    bad = broken_links()
    for b in bad:
        print(f"BROKEN LINK  {b}")
    undoc = undocumented_benchmarks()
    for u in undoc:
        print(f"UNDOCUMENTED BENCH RECORD  {u} — add it to EXPERIMENTS.md")
    files = len(doc_files())
    if bad or undoc:
        print(f"{len(bad)} broken link(s), {len(undoc)} undocumented "
              f"benchmark record(s) across {files} file(s)")
        return 1
    print(f"docs lint OK ({files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
