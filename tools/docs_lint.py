"""Docs lint: every relative markdown link in README/docs must resolve.

Checks ``[text](target)`` links in README.md, docs/**/*.md, EXPERIMENTS.md,
and ROADMAP.md: external (``http``/``mailto``) and intra-page (``#``)
targets are skipped; everything else must exist on disk relative to the
linking file (anchors stripped).  Exits non-zero listing broken links.

Also checks benchmark-record coverage: every ``BENCH_*.json`` a CI step
produces (parsed from .github/workflows/ci.yml) must be mentioned in
EXPERIMENTS.md alongside its producer script, so the recorded perf
trajectory stays documented as producers are added.

And telemetry schema sync: every field named in the ``ROUND_EVENT_FIELDS``
literal of ``src/repro/core/telemetry.py`` must appear backticked in the
"Telemetry dataflow" section of docs/architecture.md — the recorder can't
grow an undocumented signal.

  python tools/docs_lint.py

CI pairs this with ``python -m compileall -q src`` as the docs-lint step.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ROOT = Path(__file__).resolve().parent.parent


def doc_files() -> list[Path]:
    files = [ROOT / "README.md", ROOT / "EXPERIMENTS.md", ROOT / "ROADMAP.md"]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return [f for f in files if f.exists()]


def broken_links() -> list[str]:
    broken = []
    for md in doc_files():
        text = md.read_text()
        # fenced code blocks may contain pseudo-links (e.g. mermaid)
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                broken.append(f"{md.relative_to(ROOT)}: {target}")
    return broken


# producers live under benchmarks/ or tools/; tools take a positional
# input (e.g. trace_report.py telemetry.jsonl) before --json.  Argument
# whitespace is [ \t] only so the match can't leak across CI steps.
BENCH_STEP = re.compile(
    r"python\s+((?:benchmarks|tools)/\w+\.py)(?:[ \t]+(?!--json)\S+)*"
    r"[ \t]+--json[ \t]+(BENCH_\w+\.json)"
)


def undocumented_benchmarks() -> list[str]:
    """CI-produced BENCH_*.json records that EXPERIMENTS.md never mentions."""
    ci = ROOT / ".github" / "workflows" / "ci.yml"
    exp = ROOT / "EXPERIMENTS.md"
    if not ci.exists() or not exp.exists():
        return []
    text = exp.read_text()
    missing = []
    for script, record in BENCH_STEP.findall(ci.read_text()):
        if record not in text:
            missing.append(f"{record} ({script})")
        elif script.split("/")[-1] not in text:
            missing.append(f"{script} (produces {record})")
    return missing


FIELDS_LITERAL = re.compile(r"ROUND_EVENT_FIELDS\s*=\s*(\([^)]*\))", re.S)
TELEMETRY_HEADING = "## Telemetry dataflow"


def telemetry_schema_drift() -> list[str]:
    """docs/architecture.md's telemetry field table must cover exactly the
    keys the recorder emits — parsed from the ROUND_EVENT_FIELDS literal in
    core/telemetry.py (kept a pure literal so this check needs no jax)."""
    src = ROOT / "src" / "repro" / "core" / "telemetry.py"
    doc = ROOT / "docs" / "architecture.md"
    if not src.exists() or not doc.exists():
        return []
    m = FIELDS_LITERAL.search(src.read_text())
    if not m:
        return ["src/repro/core/telemetry.py: ROUND_EVENT_FIELDS literal "
                "not found (the docs sync check parses it textually)"]
    fields = set(ast.literal_eval(m.group(1)))
    text = doc.read_text()
    if TELEMETRY_HEADING not in text:
        return [f"docs/architecture.md: missing '{TELEMETRY_HEADING}' "
                f"section documenting the round-event schema"]
    section = text.split(TELEMETRY_HEADING, 1)[1].split("\n## ", 1)[0]
    documented = set(re.findall(r"`(\w+)`", section))
    drift = []
    for f in sorted(fields - documented):
        drift.append(f"docs/architecture.md §Telemetry dataflow: round-event "
                     f"field `{f}` is emitted but undocumented")
    return drift


def main() -> int:
    bad = broken_links()
    for b in bad:
        print(f"BROKEN LINK  {b}")
    undoc = undocumented_benchmarks()
    for u in undoc:
        print(f"UNDOCUMENTED BENCH RECORD  {u} — add it to EXPERIMENTS.md")
    drift = telemetry_schema_drift()
    for d in drift:
        print(f"TELEMETRY SCHEMA DRIFT  {d}")
    files = len(doc_files())
    if bad or undoc or drift:
        print(f"{len(bad)} broken link(s), {len(undoc)} undocumented "
              f"benchmark record(s), {len(drift)} schema drift(s) "
              f"across {files} file(s)")
        return 1
    print(f"docs lint OK ({files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
