"""Table IV: FedS vs FedEPL (dimension-reduced FedEP at equal cycle budget).

Paper claim: at the SAME per-cycle transmitted-parameter budget, FedS beats
FedEPL on MRR — full-precision sparse rows > uniformly smaller embeddings.
"""
from benchmarks.common import fedepl_dim, fmt_row, make_config, run_cached


def run(methods=("transe",), client_counts=(3, 5), out=print):
    rows = []
    dim_l = fedepl_dim()
    out(f"\n== Table IV: FedS vs FedEPL (FedEPL dim={dim_l}) ==")
    out(fmt_row(["KGE", "clients", "setting", "MRR", "R@CG"]))
    for method in methods:
        for nc in client_counts:
            feds = run_cached(nc, make_config("feds", method))
            fedepl = run_cached(nc, make_config("fedep", method, dim=dim_l))
            for name, res in (("fedepl", fedepl), ("feds", feds)):
                rows.append({"kge": method, "clients": nc, "setting": name,
                             "mrr": res.test_mrr_cg, "r_cg": res.best_round})
                out(fmt_row([method, nc, name, f"{res.test_mrr_cg:.4f}",
                             res.best_round]))
    return rows


def check_claims(rows) -> list[str]:
    notes = []
    by = {(r["kge"], r["clients"], r["setting"]): r for r in rows}
    for (kge, nc, setting), r in by.items():
        if setting != "feds":
            continue
        l = by[(kge, nc, "fedepl")]
        ok = r["mrr"] >= l["mrr"]
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] {kge}/R{nc}: FedS MRR {r['mrr']:.4f} "
            f"vs FedEPL {l['mrr']:.4f} (paper: FedS higher)"
        )
    return notes
