"""Entity-count scaling benchmark: the host-tiered store vs dense state.

Sweeps the global entity count E while the per-segment working set stays
fixed (``stage_steps * B * (2 + 2*negatives)`` touched rows), driving
:class:`repro.core.store.TieredCycleEngine` through full cycles (sparse /
sync communication included) plus a filtered-ranking eval on the
materialized tables.  This is the "E_max is a config value, not an OOM"
demonstration: the device-resident footprint is the pinned shared prefix
plus the cache, so it is *flat* in E while the dense engines' federation
state (entity table + two Adam moments per client) grows linearly.

Per sweep point we record:

* ``rounds_per_sec`` — full cycles (local epoch + comm round) per second,
* ``peak_device_bytes`` — cache + working-view transients + hist/res, the
  modeled device-resident bytes of the tiered engine (formula-based; on
  this CPU backend there is no per-array allocator telemetry),
* ``dense_state_bytes`` — what :class:`repro.core.state.CycleEngine` would
  pin on device for the same federation (3 copies of ``(C, E_max, D)``
  plus upload history), i.e. "total padded state",
* ``hit_rate`` / ``h2d`` / ``d2h`` — cache behaviour from the store stats.

The headline claim checks ``dense_state_bytes / peak_device_bytes >= 4``
at the top of the sweep: the federation's total padded state is at least
4x the single-shard device capacity the tiered engine actually needs.

``REPRO_BENCH_FAST=1`` shrinks the sweep for CI; ``--full`` extends it to
E = 5M (host tables ~GB — local runs only).  ``--json PATH`` writes the
machine-readable record (CI emits ``BENCH_scale.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.evaluation import BatchedEvaluator
from repro.core.protocol import build_comm_views
from repro.core.store import TieredCycleEngine
from repro.data.partition import ClientData
from repro.federated.client import KGEClient

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

NUM_CLIENTS = 2
DIM = 16 if FAST else 32
BATCH = 128 if FAST else 256
NEGATIVES = 4
TRIPLES = 2_000 if FAST else 4_000  # per client, lockstep
NUM_REL = 4
STAGE_STEPS = 1
SPARSITY = 0.4
EVAL_TRIPLES = 16
KINDS = ("sparse", "sparse", "sync")  # one timed ISM cycle pattern

# Each client holds a small shared block (2% of E — the communicated
# entities) plus a private 30% slice; the rest of the global id space
# belongs to clients this synthetic federation doesn't instantiate.  That
# keeps ns_pad << e_max, which is the regime where tiering pays.
SHARED_FRAC, PRIVATE_FRAC = 0.02, 0.30

SWEEP = [20_000, 120_000] if FAST else [20_000, 120_000, 1_000_000]
FULL_SWEEP = [5_000_000]

_SWEEP_RECORDS: list[dict] = []


def _make_clients(e_global: int, rng):
    """Lockstep synthetic federation over a large global id space."""
    shared = max(64, int(e_global * SHARED_FRAC))
    private = max(256, int(e_global * PRIVATE_FRAC))
    datas = []
    for c in range(NUM_CLIENTS):
        l2g = np.concatenate([
            np.arange(shared),
            shared + c * private + np.arange(private),
        ]).astype(np.int64)
        n_local = len(l2g)

        def triples(n):
            return np.stack(
                [
                    rng.integers(0, n_local, n),
                    rng.integers(0, NUM_REL, n),
                    rng.integers(0, n_local, n),
                ],
                axis=1,
            ).astype(np.int32)

        datas.append(
            ClientData(
                client_id=c,
                train=triples(TRIPLES),
                valid=triples(EVAL_TRIPLES),
                test=triples(EVAL_TRIPLES),
                local_to_global=l2g,
                num_relations=NUM_REL,
            )
        )

    def mk():
        return [
            KGEClient(d, method="transe", dim=DIM, gamma=6.0,
                      batch_size=BATCH, num_negatives=NEGATIVES,
                      lr=1e-3, seed=0)
            for d in datas
        ]

    return datas, mk


def _bench_one(e_global: int, out=print) -> dict:
    rng = np.random.default_rng(e_global)
    datas, mk = _make_clients(e_global, rng)
    views = build_comm_views([d.local_to_global for d in datas], e_global)
    eng = TieredCycleEngine(
        mk(), views, e_global,
        sparsity_p=SPARSITY, local_epochs=1, stage_steps=STAGE_STEPS,
    )
    store, ts = eng.init_state(mk(), seed=0)

    # warm both compiled comm variants + the train-segment body/tail
    ts, _, _ = eng.run_cycle(store, ts, "sparse")
    ts, _, _ = eng.run_cycle(store, ts, "sync")
    t0 = time.perf_counter()
    for kind in KINDS:
        ts, _, loss = eng.run_cycle(store, ts, kind)
    cyc_s = (time.perf_counter() - t0) / len(KINDS)

    t0 = time.perf_counter()
    params = eng.materialize_params(store, ts)
    mat_s = time.perf_counter() - t0

    ev = BatchedEvaluator(
        datas, method="transe", gamma=6.0, e_max=eng.e_max,
        max_triples=EVAL_TRIPLES, splits=("valid",),
        chunk=512 if FAST else 4096,
    )
    block = np.asarray(ev.evaluate(params, "valid"))  # warm (compile)
    t0 = time.perf_counter()
    block = np.asarray(ev.evaluate(params, "valid"))
    eval_s = time.perf_counter() - t0

    row_b = DIM * 4
    c_n, w, ns = NUM_CLIENTS, eng.w, eng.ns_pad
    res_rows = ns if eng.codec.has_residual else 0
    peak_device = (
        store.device_bytes()               # cache: 3 tables x (C, H, D)
        + 3 * c_n * w * row_b              # working-view transients
        + c_n * (ns + res_rows) * row_b    # hist (+ EF residuals)
    )
    dense_state = 3 * c_n * eng.e_max * row_b + c_n * (ns + res_rows) * row_b
    rec = {
        "e_global": e_global,
        "e_max": eng.e_max,
        "ns_pad": ns,
        "w": w,
        "cache_slots": store.h,
        "stage_steps": eng.stage_steps,
        "rounds_per_sec": 1.0 / cyc_s,
        "us_per_round": cyc_s * 1e6,
        "hit_rate": store.hit_rate,
        "evictions": store.stats["evictions"],
        "h2d_bytes": store.stats["h2d_bytes"],
        "d2h_bytes": store.stats["d2h_bytes"],
        "peak_device_bytes": int(peak_device),
        "dense_state_bytes": int(dense_state),
        "state_ratio": dense_state / peak_device,
        "materialize_ms": mat_s * 1e3,
        "eval_ms": eval_s * 1e3,
        "valid_mrr_mean": float(np.mean(block[:, 0])),
        "final_loss_mean": float(np.mean(np.asarray(loss))),
    }
    out(
        f"  E={e_global:>9,}  e_max={rec['e_max']:>9,}  W={w:>7,}"
        f"  {rec['rounds_per_sec']:7.2f} rounds/s"
        f"  device={peak_device / 1e6:8.1f}MB"
        f"  dense={dense_state / 1e6:8.1f}MB"
        f"  ratio={rec['state_ratio']:5.1f}x"
        f"  hit={rec['hit_rate']:.3f}  eval={eval_s * 1e3:7.1f}ms"
    )
    return rec


def run(out=print, sweep=None):
    """Returns ``[(name, us_per_round, derived)]`` rows for run.py."""
    _SWEEP_RECORDS.clear()
    out(f"scale_entities: C={NUM_CLIENTS} D={DIM} B={BATCH} "
        f"triples/client={TRIPLES} stage_steps={STAGE_STEPS} fast={FAST}")
    rows = []
    for e_global in (SWEEP if sweep is None else sweep):
        rec = _bench_one(e_global, out=out)
        _SWEEP_RECORDS.append(rec)
        rows.append((
            f"scale.E{e_global}",
            rec["us_per_round"],
            f"{rec['state_ratio']:.1f}x dense/device hit={rec['hit_rate']:.2f}",
        ))
    return rows


def check_claims(rows) -> list[str]:
    recs = _SWEEP_RECORDS
    if not recs:
        return ["[WARN] scale: no sweep records (run() not called?)"]
    claims = []
    top = recs[-1]
    tag = "PASS" if top["state_ratio"] >= 4.0 else "WARN"
    claims.append(
        f"[{tag}] E={top['e_global']:,}: total padded federation state is "
        f"{top['state_ratio']:.1f}x the tiered device footprint (>= 4x "
        f"single-shard capacity)"
    )
    ok = all(np.isfinite(r["valid_mrr_mean"]) and r["valid_mrr_mean"] > 0
             and np.isfinite(r["final_loss_mean"]) for r in recs)
    claims.append(
        f"[{'PASS' if ok else 'WARN'}] supersteps + filtered eval completed "
        f"at every sweep point (finite losses, MRR > 0)"
    )
    evicting = all(r["evictions"] > 0 for r in recs if r["e_max"] > r["cache_slots"])
    claims.append(
        f"[{'PASS' if evicting else 'WARN'}] cache smaller than the local "
        f"tables actually evicts (tiering exercised, not vacuous)"
    )
    return claims


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    ap.add_argument("--full", action="store_true",
                    help=f"extend the sweep to E={FULL_SWEEP[-1]:,} "
                         f"(host tables ~GB; local runs only)")
    args = ap.parse_args()
    sweep = SWEEP + (FULL_SWEEP if args.full else [])
    rows = run(sweep=sweep)
    claims = check_claims(rows)
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "scale_entities",
            "schema_version": 1,
            "fast": FAST,
            "config": {
                "clients": NUM_CLIENTS, "dim": DIM, "batch": BATCH,
                "negatives": NEGATIVES, "triples": TRIPLES,
                "stage_steps": STAGE_STEPS, "sparsity": SPARSITY,
                "shared_frac": SHARED_FRAC, "private_frac": PRIVATE_FRAC,
            },
            "sweep": _SWEEP_RECORDS,
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
