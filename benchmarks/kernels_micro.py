"""Microbenchmarks of the Pallas kernel ops (CPU: ref/interpret dispatch).

Reports name,us_per_call,derived where derived is the achieved effective
bandwidth (GB/s) for the bandwidth-bound kernels — meaningful relative to
each other on this host, and a smoke check that the jit'd wrappers are not
pathologically slow.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _bench(fn, *args, iters: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(out=print):
    rows = []
    n, d = 8192, 256
    cur = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    hist = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    us = _bench(jax.jit(ops.change_score), cur, hist)
    gbps = 2 * n * d * 4 / (us / 1e6) / 1e9
    rows.append(("kernel.change_score_8192x256", us, f"{gbps:.1f}GB/s"))

    b, neg = 256, 128
    h = jax.random.normal(jax.random.PRNGKey(2), (b, d))
    r = jax.random.normal(jax.random.PRNGKey(3), (b, d))
    t = jax.random.normal(jax.random.PRNGKey(4), (b, neg, d))
    us = _bench(jax.jit(lambda a, bb, c: ops.transe_neg_score(a, bb, c, 8.0)), h, r, t)
    rows.append(("kernel.transe_score_256x128x256", us,
                 f"{b*neg*d*3/ (us/1e6)/1e9:.2f}GFLOP/s-ish"))

    phase = jax.random.normal(jax.random.PRNGKey(5), (b, d // 2))
    us = _bench(jax.jit(lambda a, p, c: ops.rotate_neg_score(a, p, c, 8.0)), h, phase, t)
    rows.append(("kernel.rotate_score_256x128x256", us, "-"))

    agg = jax.random.normal(jax.random.PRNGKey(6), (n, d))
    pri = jnp.ones((n,))
    sign = (jax.random.uniform(jax.random.PRNGKey(7), (n,)) < 0.4).astype(jnp.int8)
    us = _bench(jax.jit(ops.sparse_apply), cur, agg, pri, sign)
    rows.append(("kernel.sparse_apply_8192x256", us,
                 f"{3*n*d*4/(us/1e6)/1e9:.1f}GB/s"))

    for name, us, derived in rows:
        out(f"{name},{us:.1f},{derived}")
    return rows
