"""Registry sweep: every scoring method at FB15k-237 scale, round + eval time.

For each method registered in :mod:`repro.kge.scoring`, one sparse FedS
cycle runs through the fused :class:`repro.core.state.CycleEngine` and one
filtered-ranking eval pass runs through the batched
:class:`repro.core.evaluation.BatchedEvaluator`, at FB15k-237 scale
(E=14541, D=256, C=3, local_epochs=3; ``REPRO_BENCH_FAST=1`` shrinks to a
smoke size).  Reported per method:

* per-round wall time of the fused train+communicate program (the method's
  score/loss pieces compile INSIDE the cycle, so this is the end-to-end cost
  of choosing it),
* per-eval wall time of the compiled candidate scan (family-tag dispatched:
  distance methods through ``dist_cand_score_pallas``, bilinear through the
  matmul-style ``bilinear_cand_score_pallas`` on TPU; exact ref broadcast on
  CPU),
* the family tag and relation-table width the registry prescribes.

Because the sweep iterates the registry, a newly registered method shows up
here (and in ``BENCH_scoring.json``, published by CI) with zero glue.
``--json PATH`` writes the machine-readable record.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.fused_cycle import (  # noqa: E402
    BATCH, DIM, FAST, LOCAL_EPOCHS, NEGATIVES, NUM_CLIENTS, NUM_GLOBAL,
    SPARSITY, TRIPLES, _make_clients,
)
from repro.core.evaluation import BatchedEvaluator  # noqa: E402
from repro.core.state import CycleEngine  # noqa: E402
from repro.kge.scoring import registered_methods  # noqa: E402

EVAL_TRIPLES = 16  # per-client valid triples in the stand-in federation


def run(out=print):
    out(
        f"\n== scoring sweep: 1 fused cycle + 1 batched eval per registered "
        f"method, E={NUM_GLOBAL} D={DIM} C={NUM_CLIENTS} T={TRIPLES} "
        f"B={BATCH} N={NEGATIVES} p={SPARSITY} =="
    )
    iters = 5 if FAST else 3
    rows, records = [], {}
    for method, spec in registered_methods().items():
        rng = np.random.default_rng(0)
        datas, clients, views = _make_clients(rng, method=method)
        engine = CycleEngine(
            clients, views, NUM_GLOBAL, sparsity_p=SPARSITY,
            local_epochs=LOCAL_EPOCHS,
        )
        state = engine.init_state(clients, seed=0)
        state, _, _ = engine.fused_cycle(state, sync=False)  # warm/compile
        jax.block_until_ready(state.arrays.params["entity"])
        best_round = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            state, _, _ = engine.fused_cycle(state, sync=False)
            jax.block_until_ready(state.arrays.params["entity"])
            best_round = min(best_round, time.perf_counter() - t0)

        ev = BatchedEvaluator(
            datas, method=method, gamma=clients[0].gamma, e_max=engine.e_max,
            max_triples=EVAL_TRIPLES, splits=("valid",),
        )
        block = ev.evaluate(state.arrays.params, "valid")  # warm/compile
        best_eval = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            block = ev.evaluate(state.arrays.params, "valid")
            best_eval = min(best_eval, time.perf_counter() - t0)
        count = int(np.asarray(block)[:, 4].sum())

        us_round, us_eval = best_round * 1e6, best_eval * 1e6
        rows.append((f"scoring.{method}", us_round,
                     f"{us_eval:.0f}us/eval [{spec.family}]"))
        records[method] = {
            "family": spec.family,
            "rel_dim": spec.rel_dim(DIM),
            "adversarial": spec.adversarial,
            "us_per_round": us_round,
            "us_per_eval": us_eval,
            "eval_count": count,
        }
    for name, us, derived in rows:
        out(f"{name},{us:.1f},{derived}")
    return rows, records


def check_claims(records):
    notes = []
    missing = sorted(set(registered_methods()) - set(records))
    notes.append(
        f"[{'PASS' if not missing else 'WARN'}] registry sweep covered "
        f"{len(records)}/{len(registered_methods())} registered methods"
        + (f" (missing: {missing})" if missing else "")
    )
    base = records.get("transe")
    for method, rec in records.items():
        ok = (
            np.isfinite(rec["us_per_round"]) and np.isfinite(rec["us_per_eval"])
            and rec["eval_count"] == NUM_CLIENTS * EVAL_TRIPLES
        )
        rel = rec["us_per_round"] / base["us_per_round"] if base else float("nan")
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] method {method} [{rec['family']}]: "
            f"{rel:.2f}x transe round time, full eval count "
            f"{rec['eval_count']} (expect {NUM_CLIENTS * EVAL_TRIPLES})"
        )
    return notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    args = ap.parse_args()
    rows, records = run()
    claims = check_claims(records)
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "scoring",
            "schema_version": 1,
            "fast": FAST,
            "config": {
                "num_global": NUM_GLOBAL, "dim": DIM, "clients": NUM_CLIENTS,
                "local_epochs": LOCAL_EPOCHS, "triples": TRIPLES,
                "batch": BATCH, "negatives": NEGATIVES, "sparsity": SPARSITY,
                "eval_triples": EVAL_TRIPLES,
            },
            "methods": records,
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
