"""Table I: the paper's negative finding — universal precision reduction
(FedE-KD / FedE-SVD) INCREASES total communication.

Metric: total transmitted parameters when first reaching 98% of the FedE
(here: FedEP) convergence MRR, scaled by FedE's own count.  Compression
baselines transmit less per round but need disproportionately more rounds.

Two baselines, two pipelines:

* FedE-KD — the co-distillation host pipeline in ``core/compression.py``
  (model-side compression genuinely needs its own trainer).
* FedE-SVD — runs through the REAL engines since the low-rank truncation
  was absorbed into the ``lowrank`` wire codec: ``feds_nosync`` at
  ``sparsity_p=1.0`` transmits every shared row every round, each row
  truncated to rank ``r`` inside the compiled program (documented delta vs
  the retired numpy pipeline: the codec compresses transmitted embeddings,
  not update deltas — EXPERIMENTS.md §Codecs).
"""
from benchmarks.common import (
    DIM,
    dataset,
    fmt_row,
    make_config,
    params_at_target,
    run_cached,
)
from repro.core.compression import CompressionConfig, run_compression

SVD_COLS = 4  # paper: 8 (dim 256); scaled with the container dim
SVD_RANK = 2  # paper: 5


def _kd_result(nc: int):
    kg, clients = dataset(nc)
    base = make_config("fedep")
    cfg = CompressionConfig(
        strategy="kd", method="transe", dim=DIM,
        kd_low_dim=max(8, int(DIM * 0.75)),  # paper: 192/256
        rounds=base.rounds, local_epochs=base.local_epochs,
        batch_size=base.batch_size, num_negatives=base.num_negatives,
        lr=base.lr, eval_every=base.eval_every, patience=base.patience,
        max_eval_triples=base.max_eval_triples, seed=0,
    )
    return run_compression(clients, kg.num_entities, cfg)


def _svd_result(nc: int):
    # full-exchange shape with per-row low-rank wire compression, through the
    # fused engine (the absorbed Table-I SVD baseline)
    cfg = make_config(
        "feds_nosync", sparsity_p=1.0,
        codec=f"lowrank:cols={SVD_COLS},rank={SVD_RANK}",
    )
    return run_cached(nc, cfg)


def run(client_counts=(3,), out=print):
    rows = []
    out("\n== Table I: total params to reach 98% of FedE MRR@CG (scaled) ==")
    out(fmt_row(["clients", "model", "total params @98%", "ratio vs FedE"]))
    for nc in client_counts:
        fede = run_cached(nc, make_config("fedep"))
        target = 0.98 * fede.val_mrr_cg
        _, fede_params = params_at_target(fede, target)
        out(fmt_row([nc, "FedE(P)", f"{fede_params:.3e}", "1.00x"]))
        rows.append({"clients": nc, "model": "fede", "ratio": 1.0, "reached": True})
        for strategy, result_fn in (("kd", _kd_result), ("svd", _svd_result)):
            res = result_fn(nc)
            _, p = params_at_target(res, target)
            if p is None:  # never reached the target — report at budget end
                p = res.ledger.params_transmitted
                ratio = p / fede_params
                out(fmt_row([nc, f"FedE-{strategy.upper()}",
                             f">{p:.3e}", f">{ratio:.2f}x (never reached)"]))
                rows.append({"clients": nc, "model": strategy, "ratio": ratio,
                             "reached": False})
            else:
                ratio = p / fede_params
                out(fmt_row([nc, f"FedE-{strategy.upper()}", f"{p:.3e}",
                             f"{ratio:.2f}x"]))
                rows.append({"clients": nc, "model": strategy, "ratio": ratio,
                             "reached": True})
    return rows


def check_claims(rows):
    notes = []
    for r in rows:
        if r["model"] == "fede":
            continue
        ok = (r["ratio"] > 1.0) or (not r["reached"])
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] R{r['clients']} FedE-{r['model'].upper()}: "
            f"total-comm ratio {r['ratio']:.2f}x vs FedE "
            f"(paper: 1.28-2.5x, i.e. compression HURTS total cost)"
        )
    return notes
