"""Table I: the paper's negative finding — universal precision reduction
(FedE-KD / FedE-SVD / FedE-SVD+) INCREASES total communication.

Metric: total transmitted parameters when first reaching 98% of the FedE
(here: FedEP) convergence MRR, scaled by FedE's own count.  Compression
baselines transmit less per round but need disproportionately more rounds.
"""
from benchmarks.common import (
    DIM,
    fmt_row,
    make_config,
    params_at_target,
    run_cached,
    dataset,
)
from repro.core.compression import CompressionConfig, run_compression


def _compression_result(nc: int, strategy: str):
    kg, clients = dataset(nc)
    base = make_config("fedep")
    cfg = CompressionConfig(
        strategy=strategy, method="transe", dim=DIM,
        kd_low_dim=max(8, int(DIM * 0.75)),  # paper: 192/256
        svd_cols=4, svd_rank=2,  # paper: cols 8, rank 5 (dim 256)
        rounds=base.rounds, local_epochs=base.local_epochs,
        batch_size=base.batch_size, num_negatives=base.num_negatives,
        lr=base.lr, eval_every=base.eval_every, patience=base.patience,
        max_eval_triples=base.max_eval_triples, seed=0,
    )
    return run_compression(clients, kg.num_entities, cfg)


def run(client_counts=(3,), out=print):
    rows = []
    out("\n== Table I: total params to reach 98% of FedE MRR@CG (scaled) ==")
    out(fmt_row(["clients", "model", "total params @98%", "ratio vs FedE"]))
    for nc in client_counts:
        fede = run_cached(nc, make_config("fedep"))
        target = 0.98 * fede.val_mrr_cg
        _, fede_params = params_at_target(fede, target)
        out(fmt_row([nc, "FedE(P)", f"{fede_params:.3e}", "1.00x"]))
        rows.append({"clients": nc, "model": "fede", "ratio": 1.0, "reached": True})
        for strategy in ("kd", "svd"):
            res = _compression_result(nc, strategy)
            _, p = params_at_target(res, target)
            if p is None:  # never reached the target — report at budget end
                p = res.ledger.params_transmitted
                ratio = p / fede_params
                out(fmt_row([nc, f"FedE-{strategy.upper()}",
                             f">{p:.3e}", f">{ratio:.2f}x (never reached)"]))
                rows.append({"clients": nc, "model": strategy, "ratio": ratio,
                             "reached": False})
            else:
                ratio = p / fede_params
                out(fmt_row([nc, f"FedE-{strategy.upper()}", f"{p:.3e}",
                             f"{ratio:.2f}x"]))
                rows.append({"clients": nc, "model": strategy, "ratio": ratio,
                             "reached": True})
    return rows


def check_claims(rows):
    notes = []
    for r in rows:
        if r["model"] == "fede":
            continue
        ok = (r["ratio"] > 1.0) or (not r["reached"])
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] R{r['clients']} FedE-{r['model'].upper()}: "
            f"total-comm ratio {r['ratio']:.2f}x vs FedE "
            f"(paper: 1.28-2.5x, i.e. compression HURTS total cost)"
        )
    return notes
