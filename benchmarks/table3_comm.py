"""Table III: communication overhead — P@CG / P@99 / P@98 of FedS vs FedEP.

Paper claim: FedS reaches 98/99% of FedEP's converged MRR with ~0.44-0.86x
of the transmitted parameters, and converges (P@CG) at ~0.44-0.76x.
"""
from benchmarks.common import comm_table_row, fmt_row, make_config, run_cached


def run(methods=("transe", "rotate", "complex"), client_counts=(3, 5), out=print):
    from benchmarks.table2_accuracy import _overrides

    rows = []
    out("\n== Table III: communication overhead vs FedEP ==")
    out(fmt_row(["KGE", "clients", "P@CG", "P@99", "P@98"]))
    for method in methods:
        for nc in client_counts:
            ov = _overrides(method, nc)
            fedep = run_cached(nc, make_config("fedep", method))
            feds = run_cached(nc, make_config("feds", method, **ov))
            r = comm_table_row(feds, fedep)
            rows.append({"kge": method, "clients": nc, **r})
            out(fmt_row([method, nc] + [f"{r[k]:.4f}" for k in ("P@CG", "P@99", "P@98")]))
    return rows


def check_claims(rows) -> list[str]:
    notes = []
    for r in rows:
        pcg = r["P@CG"]
        ok = pcg < 1.0
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] {r['kge']}/R{r['clients']}: "
            f"P@CG={pcg:.3f} (<1.0 required; paper 0.44-0.76)"
        )
    return notes
