"""Registry sweep: every wire codec at FB15k-237 scale, bytes + wall time.

For each codec registered in :mod:`repro.core.codecs` (plus an ``ef=1``
variant for codecs that support error feedback), one sparse FedS cycle runs
through the fused :class:`repro.core.state.CycleEngine` at FB15k-237 scale
(E=14541, D=256, C=3, local_epochs=3; ``REPRO_BENCH_FAST=1`` shrinks to a
smoke size).  Reported per codec:

* per-round wall time (the codec's encode/decode runs INSIDE the compiled
  cycle, so this is the end-to-end cost of choosing it),
* wire bytes and Eq.5-style params per round, from the codec's own ledger
  accounting replayed with the measured per-client download counts.

Because the sweep iterates the registry, a newly registered codec shows up
here (and in ``BENCH_codecs.json``, published by CI) with zero glue.
``--json PATH`` writes the machine-readable record.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.fused_cycle import (  # noqa: E402
    BATCH, DIM, FAST, LOCAL_EPOCHS, NEGATIVES, NUM_CLIENTS, NUM_GLOBAL,
    SPARSITY, TRIPLES, _make_clients,
)
from repro.core.codecs import get_codec, registered_codecs  # noqa: E402
from repro.core.state import CycleEngine  # noqa: E402
from repro.federated.comm import CommLedger  # noqa: E402


def sweep_specs() -> list[tuple[str, object]]:
    """(label, codec) for every registered codec + its ef variant if any."""
    out = []
    for name, cls in registered_codecs().items():
        out.append((name, get_codec(name)))
        if any(a.name == "ef" for a in cls.ARGS):
            out.append((f"{name}:ef=1", get_codec(name, ef=True)))
    return out


def _round_ledger(codec, engine, down_counts) -> CommLedger:
    """One sparse round's accounting with the measured download counts."""
    led = CommLedger()
    for v, k_c, dc in zip(engine.views, engine.k_per_client, down_counts):
        codec.log_upload(led, int(k_c), DIM, v.num_shared)
        codec.log_download(led, int(dc), DIM, v.num_shared)
    return led


def run(out=print):
    rng = np.random.default_rng(0)
    _, clients, views = _make_clients(rng)
    out(
        f"\n== codec sweep: 1 sparse cycle/codec through the fused engine, "
        f"E={NUM_GLOBAL} D={DIM} C={NUM_CLIENTS} T={TRIPLES} B={BATCH} "
        f"N={NEGATIVES} p={SPARSITY} =="
    )
    iters = 5 if FAST else 3
    rows, records = [], {}
    for label, codec in sweep_specs():
        engine = CycleEngine(
            clients, views, NUM_GLOBAL, sparsity_p=SPARSITY,
            local_epochs=LOCAL_EPOCHS, codec=codec,
        )
        state = engine.init_state(clients, seed=0)
        state, down, _ = engine.fused_cycle(state, sync=False)  # warm/compile
        jax.block_until_ready(state.arrays.params["entity"])
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            state, down, _ = engine.fused_cycle(state, sync=False)
            jax.block_until_ready(state.arrays.params["entity"])
            best = min(best, time.perf_counter() - t0)
        led = _round_ledger(codec, engine, np.asarray(down))
        us = best * 1e6
        rows.append((f"codecs.{label}", us, f"{led.bytes_int8_signs / 1e6:.3f}MB/rnd"))
        records[label] = {
            "us_per_round": us,
            "bytes_per_round": led.bytes_int8_signs,
            "params_per_round": led.params_transmitted,
        }
    base = records["identity"]["bytes_per_round"]
    for name, us, derived in rows:
        out(f"{name},{us:.1f},{derived}")
    out(f"identity wire baseline: {base / 1e6:.3f} MB/round")
    return rows, records


def check_claims(records):
    base = records["identity"]
    notes = []
    for label, rec in records.items():
        if label == "identity":
            continue
        ratio = rec["bytes_per_round"] / base["bytes_per_round"]
        slowdown = rec["us_per_round"] / base["us_per_round"]
        ok = ratio < 1.0
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] codec {label}: {ratio:.2f}x identity "
            f"wire bytes/round at {slowdown:.2f}x wall time (expect < 1.0x bytes)"
        )
    return notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    args = ap.parse_args()
    rows, records = run()
    claims = check_claims(records)
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "codecs",
            "schema_version": 1,
            "fast": FAST,
            "config": {
                "num_global": NUM_GLOBAL, "dim": DIM, "clients": NUM_CLIENTS,
                "local_epochs": LOCAL_EPOCHS, "triples": TRIPLES,
                "batch": BATCH, "negatives": NEGATIVES, "sparsity": SPARSITY,
            },
            "codecs": records,
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
