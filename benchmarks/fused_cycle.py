"""Microbenchmark: per-cycle latency of the three simulation engines.

One full FedS *cycle* (``local_epochs`` of local training + one sparse
communication round) at FB15k-237 scale (E=14541, D=256, C=3,
local_epochs=3 by default; ``REPRO_BENCH_FAST=1`` shrinks to a smoke size).
Three rows:

* ``cycle.reference`` — per-client ``KGEClient.train_local`` (numpy batch
  stacking per epoch + per-client jit) + the ragged numpy host protocol.
* ``cycle.batched_per_round`` — the pre-PR ``engine="batched"`` simulation
  path: ``train_local`` + RoundEngine with host gather/scatter of every
  client's entity table and a per-round ``np.asarray(down_counts)`` ledger
  sync — exactly what the simulation used to pay per round.
* ``cycle.fused`` — the :class:`repro.core.state.CycleEngine` fused program
  on device-resident :class:`FederationState`: batches pre-sampled on
  device, train scan + communication round as ONE jit, zero per-round host
  transfers of entity tables (down counts stay on device).

Derived column: speedup vs ``cycle.batched_per_round`` (the acceptance bar
is >= 1.5x at full scale).  ``--json PATH`` writes a machine-readable record
(CI emits ``BENCH_cycle.json`` so the perf trajectory is tracked).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import personalized_aggregate
from repro.core.codec import IdentityCodec
from repro.core.engine import RoundEngine
from repro.core.protocol import apply_sparse_download, build_comm_views, sparse_upload
from repro.core.state import CycleEngine
from repro.data.partition import ClientData
from repro.federated.client import KGEClient

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
NUM_GLOBAL = 2000 if FAST else 14541  # FB15k-237 entity count
DIM = 64 if FAST else 256  # paper dim
NUM_CLIENTS = 3  # FB15k-237-R3
LOCAL_EPOCHS = 3  # paper E
SUBSET = 0.6  # per-client entity coverage
SPARSITY = 0.4  # paper p
TRIPLES = 512 if FAST else 1536  # per-client train triples
BATCH = 128 if FAST else 512
NEGATIVES = 8


def _make_clients(rng, method="transe"):
    """FB15k-scale stand-in: random entity subsets + random local triples.

    The benchmark measures latency, not learning, so triples are uniform
    random over each client's local id space (relations global, as in
    ``partition_by_relation`` output).  ``method`` parameterizes the scoring
    method so the registry sweep in benchmarks/scoring.py can reuse this."""
    num_rel = 12
    datas = []
    for c in range(NUM_CLIENTS):
        l2g = np.sort(
            rng.choice(NUM_GLOBAL, size=int(NUM_GLOBAL * SUBSET), replace=False)
        ).astype(np.int32)
        n_local = len(l2g)

        def triples(n):
            return np.stack(
                [
                    rng.integers(0, n_local, n),
                    rng.integers(0, num_rel, n),
                    rng.integers(0, n_local, n),
                ],
                axis=1,
            ).astype(np.int32)

        datas.append(
            ClientData(
                client_id=c,
                train=triples(TRIPLES),
                valid=triples(16),
                test=triples(16),
                local_to_global=l2g,
                num_relations=num_rel,
            )
        )
    clients = [
        KGEClient(
            d, method=method, dim=DIM, batch_size=BATCH,
            num_negatives=NEGATIVES, lr=1e-4, seed=0,
        )
        for d in datas
    ]
    views = build_comm_views([d.local_to_global for d in datas], NUM_GLOBAL)
    return datas, clients, views


def _reference_cycle(clients, views, hists, tie_rng):
    for c in clients:
        c.train_local(LOCAL_EPOCHS)
    uploads = []
    for c, v in zip(clients, views):
        up, hh = sparse_upload(c.params["entity"], hists[v.client_id], v, SPARSITY)
        hists[v.client_id] = hh
        uploads.append(up)
    downs = personalized_aggregate(
        uploads, [v.shared_global for v in views], SPARSITY, tie_rng
    )
    for c, v, d in zip(clients, views, downs):
        c.params["entity"] = apply_sparse_download(
            c.params["entity"], v, d.entity_ids, d.agg_values, d.priority
        )
    jax.block_until_ready([c.params["entity"] for c in clients])


def _legacy_batched_cycle(clients, engine, hist_box, jit_rng):
    """The pre-PR engine="batched" simulation round, verbatim: host training
    + gather/round/scatter host transfers + per-round ledger device sync."""
    for c in clients:
        c.train_local(LOCAL_EPOCHS)
    emb_b = engine.gather([c.params["entity"] for c in clients])
    jitter = jit_rng.random((len(clients), engine.ns_max))
    emb_b, hist_box[0], down = engine.sparse_round(emb_b, hist_box[0], jitter)
    new_tables = engine.scatter(emb_b, [c.params["entity"] for c in clients])
    for c, tab in zip(clients, new_tables):
        c.params["entity"] = tab
    np.asarray(down)  # the old loop's per-round ledger flush forced this sync
    jax.block_until_ready([c.params["entity"] for c in clients])


def run(out=print):
    rng = np.random.default_rng(0)
    _, clients, views = _make_clients(rng)
    ns = [v.num_shared for v in views]
    out(
        f"\n== fused cycle: {LOCAL_EPOCHS} local epochs + 1 sparse round, "
        f"E={NUM_GLOBAL} D={DIM} C={NUM_CLIENTS} Ns={ns} "
        f"T={TRIPLES} B={BATCH} N={NEGATIVES} p={SPARSITY} =="
    )

    # ---- reference: numpy host protocol
    hists = [
        jnp.asarray(np.asarray(c.params["entity"])[v.shared_local])
        for c, v in zip(clients, views)
    ]
    _reference_cycle(clients, views, hists, np.random.default_rng(0))  # warm
    iters_ref = 2 if FAST else 1
    t0 = time.perf_counter()
    for _ in range(iters_ref):
        _reference_cycle(clients, views, hists, np.random.default_rng(0))
    us_ref = (time.perf_counter() - t0) / iters_ref * 1e6

    # ---- pre-PR batched path: host train_local + gather/round/scatter
    engine = RoundEngine(views, NUM_GLOBAL, DIM, SPARSITY, codec=IdentityCodec())
    hist_box = [engine.gather([c.params["entity"] for c in clients])]
    jit_rng = np.random.default_rng(1)
    _legacy_batched_cycle(clients, engine, hist_box, jit_rng)  # warm
    iters = 5 if FAST else 3
    t0 = time.perf_counter()
    for _ in range(iters):
        _legacy_batched_cycle(clients, engine, hist_box, jit_rng)
    us_legacy = (time.perf_counter() - t0) / iters * 1e6

    # ---- fused cycle on device-resident FederationState
    cycle = CycleEngine(
        clients, views, NUM_GLOBAL, sparsity_p=SPARSITY,
        local_epochs=LOCAL_EPOCHS,
    )
    state = cycle.init_state(clients, seed=0)
    state, down, _ = cycle.fused_cycle(state, sync=False)  # warm/compile
    jax.block_until_ready(state.arrays.params["entity"])
    downs = []
    t0 = time.perf_counter()
    for _ in range(iters):
        state, down, _ = cycle.fused_cycle(state, sync=False)
        downs.append(down)  # stays on device — flushed only at eval bounds
        jax.block_until_ready(state.arrays.params["entity"])
    us_fused = (time.perf_counter() - t0) / iters * 1e6
    np.asarray(jnp.stack(downs))  # eval-boundary flush (outside the timing)

    rows = [
        ("cycle.reference", us_ref, f"{us_legacy / us_ref:.2f}x"),
        ("cycle.batched_per_round", us_legacy, "1.00x"),
        ("cycle.fused", us_fused, f"{us_legacy / us_fused:.2f}x"),
    ]
    for name, us, derived in rows:
        out(f"{name},{us:.1f},{derived}")
    return rows


def check_claims(rows):
    by = {r[0]: r[1] for r in rows}
    speedup = by["cycle.batched_per_round"] / by["cycle.fused"]
    ok = speedup >= 1.5
    return [
        f"[{'PASS' if ok else 'WARN'}] fused cycle {speedup:.2f}x vs per-round "
        f"batched path (expect >=1.5x; zero per-round entity-table host "
        f"transfers by construction)"
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    args = ap.parse_args()
    rows = run()
    claims = check_claims(rows)
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "fused_cycle",
            "schema_version": 1,
            "fast": FAST,
            "config": {
                "num_global": NUM_GLOBAL, "dim": DIM, "clients": NUM_CLIENTS,
                "local_epochs": LOCAL_EPOCHS, "triples": TRIPLES,
                "batch": BATCH, "negatives": NEGATIVES, "sparsity": SPARSITY,
            },
            "us_per_cycle": {name: us for name, us, _ in rows},
            "speedup_fused_vs_batched": rows[1][1] / rows[2][1],
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
