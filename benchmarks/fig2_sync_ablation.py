"""Figure 2: Intermittent Synchronization ablation — FedS vs FedS/syn.

Paper claim: FedS (with sync) reaches HIGHER converged accuracy than
FedS/syn (without), even if FedS/syn sometimes converges in fewer rounds.
"""
from benchmarks.common import fmt_row, make_config, run_cached


def run(methods=("transe", "rotate"), out=print):
    rows = []
    out("\n== Fig. 2: sync-mechanism ablation (R3) ==")
    out(fmt_row(["KGE", "setting", "MRR@CG", "R@CG"]))
    for method in methods:
        for proto, label in (("feds", "FedS"), ("feds_nosync", "FedS/syn")):
            res = run_cached(3, make_config(proto, method))
            rows.append({"kge": method, "setting": label,
                         "mrr": res.val_mrr_cg, "r_cg": res.best_round,
                         "curve": res.eval_history})
            out(fmt_row([method, label, f"{res.val_mrr_cg:.4f}", res.best_round]))
    return rows


def check_claims(rows):
    notes = []
    by = {(r["kge"], r["setting"]): r for r in rows}
    for kge in {r["kge"] for r in rows}:
        w, wo = by[(kge, "FedS")], by[(kge, "FedS/syn")]
        ok = w["mrr"] >= wo["mrr"] * 0.98
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] {kge}: FedS {w['mrr']:.4f} vs "
            f"FedS/syn {wo['mrr']:.4f} (paper: FedS converges higher)"
        )
    return notes
