"""Figure 2: Intermittent Synchronization ablation — FedS vs FedS/syn.

Paper claim: FedS (with sync) reaches HIGHER converged accuracy than
FedS/syn (without), even if FedS/syn sometimes converges in fewer rounds.

This run rides the flight recorder's shared-entity divergence probes
(:mod:`repro.core.telemetry`), so the table also shows WHY: FedS's sync
rounds pull the shared rows back to consensus (mean divergence collapses
at sync rounds), while FedS/syn drifts unchecked.  ``--json PATH`` writes
the machine-readable record CI publishes as ``BENCH_fig2.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import (  # noqa: E402
    DIM, FAST, ROUNDS, SYNC_S, fmt_row, make_config, run_with_divergence,
)


def _fmt_div(x) -> str:
    return f"{x:.4f}" if x is not None else "-"


def run(methods=("transe", "rotate"), out=print):
    rows = []
    out("\n== Fig. 2: sync-mechanism ablation (R3) ==")
    out(fmt_row(["KGE", "setting", "MRR@CG", "R@CG", "div_sparse", "div_sync"]))
    for method in methods:
        for proto, label in (("feds", "FedS"), ("feds_nosync", "FedS/syn")):
            res, div = run_with_divergence(3, make_config(proto, method))
            rows.append({"kge": method, "setting": label,
                         "mrr": res.val_mrr_cg, "r_cg": res.best_round,
                         "div_sparse": div["sparse"], "div_sync": div["sync"],
                         "curve": res.eval_history})
            out(fmt_row([method, label, f"{res.val_mrr_cg:.4f}",
                         res.best_round, _fmt_div(div["sparse"]),
                         _fmt_div(div["sync"])]))
    return rows


def check_claims(rows):
    notes = []
    by = {(r["kge"], r["setting"]): r for r in rows}
    for kge in sorted({r["kge"] for r in rows}):
        w, wo = by[(kge, "FedS")], by[(kge, "FedS/syn")]
        ok = w["mrr"] >= wo["mrr"] * 0.98
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] {kge}: FedS {w['mrr']:.4f} vs "
            f"FedS/syn {wo['mrr']:.4f} (paper: FedS converges higher)"
        )
        # the ISM mechanism itself: sync rounds must sit at LOWER
        # shared-entity divergence than the sparse rounds between them
        if w["div_sync"] is not None and w["div_sparse"] is not None:
            ok = w["div_sync"] < w["div_sparse"]
            notes.append(
                f"[{'PASS' if ok else 'WARN'}] {kge}: FedS sync-round "
                f"divergence {w['div_sync']:.4f} < sparse-round "
                f"{w['div_sparse']:.4f} (sync pulls shared entities to "
                f"consensus)"
            )
        else:
            notes.append(
                f"[WARN] {kge}: FedS recorded no divergence probes to check"
            )
    return notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    args = ap.parse_args()
    rows = run()
    claims = check_claims(rows)
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "fig2_sync_ablation",
            "schema_version": 1,
            "fast": FAST,
            "config": {"dim": DIM, "rounds": ROUNDS, "sync_s": SYNC_S},
            "rows": [{k: v for k, v in r.items() if k != "curve"}
                     for r in rows],
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
