"""Table VI: robustness of FedS across batch sizes."""
from benchmarks.common import comm_table_row, fmt_row, make_config, run_cached


def run(batches=(64, 128, 256), out=print):
    rows = []
    out("\n== Table VI: FedS vs FedEP across batch sizes (TransE, R3) ==")
    out(fmt_row(["batch", "setting", "MRR", "P@CG", "P@99", "P@98"]))
    for bs in batches:
        fedep = run_cached(3, make_config("fedep", batch_size=bs))
        feds = run_cached(3, make_config("feds", batch_size=bs))
        r = comm_table_row(feds, fedep)
        rows.append({"batch": bs, "mrr_fedep": fedep.test_mrr_cg,
                     "mrr_feds": feds.test_mrr_cg, **r})
        out(fmt_row([bs, "fedep", f"{fedep.test_mrr_cg:.4f}", "1.0", "1.0", "1.0"]))
        out(fmt_row([bs, "feds", f"{feds.test_mrr_cg:.4f}"]
                    + [f"{r[k]:.3f}" for k in ("P@CG", "P@99", "P@98")]))
    return rows


def check_claims(rows):
    return [
        f"[{'PASS' if r['mrr_feds'] >= 0.9 * r['mrr_fedep'] else 'WARN'}] "
        f"batch={r['batch']}: FedS MRR {r['mrr_feds']:.4f} ~ FedEP {r['mrr_fedep']:.4f}"
        for r in rows
    ]
