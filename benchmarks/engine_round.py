"""Microbenchmark: numpy reference round vs jitted RoundEngine round.

One full sparse FedS communication round (upstream Top-K -> Eq. 3 -> downstream
Top-K -> Eq. 4) at FB15k-237-scale entity counts (E=14541, D=256, C=3 by
default; REPRO_BENCH_FAST=1 shrinks to a smoke size).  Three rows:

* ``engine.reference_round`` — the ragged numpy host protocol
  (``personalized_aggregate`` + per-client apply), the paper-faithful path,
* ``engine.batched_round`` — RoundEngine including host gather/scatter of the
  client tables (what the simulation pays per round),
* ``engine.batched_core`` — the jitted round alone on resident device state
  (what a deployment that keeps state on-device pays).

Derived column: speedup vs the reference round.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import personalized_aggregate
from repro.core.codec import IdentityCodec
from repro.core.engine import RoundEngine
from repro.core.protocol import apply_sparse_download, build_comm_views, sparse_upload

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
NUM_GLOBAL = 2000 if FAST else 14541  # FB15k-237 entity count
DIM = 64 if FAST else 256  # paper dim
NUM_CLIENTS = 3  # FB15k-237-R3
SUBSET = 0.6  # per-client entity coverage
SPARSITY = 0.4  # paper p


def _make_instance(rng):
    l2g = [
        np.sort(
            rng.choice(NUM_GLOBAL, size=int(NUM_GLOBAL * SUBSET), replace=False)
        ).astype(np.int64)
        for _ in range(NUM_CLIENTS)
    ]
    views = build_comm_views(l2g, NUM_GLOBAL)
    tables = [
        jnp.asarray(rng.normal(size=(len(l), DIM)), jnp.float32) for l in l2g
    ]
    hist_tables = [
        t + jnp.asarray(rng.normal(size=t.shape) * 0.5, jnp.float32)
        for t in tables
    ]
    return views, tables, hist_tables


def _reference_round(tables, hists, views, tie_rng):
    uploads, new_hists = [], []
    for t, h, v in zip(tables, hists, views):
        up, hh = sparse_upload(t, h, v, SPARSITY)
        uploads.append(up)
        new_hists.append(hh)
    downs = personalized_aggregate(
        uploads, [v.shared_global for v in views], SPARSITY, tie_rng
    )
    out = [
        apply_sparse_download(t, v, d.entity_ids, d.agg_values, d.priority)
        for t, v, d in zip(tables, views, downs)
    ]
    jax.block_until_ready(out)
    return out, new_hists


def run(out=print):
    rng = np.random.default_rng(0)
    views, tables, hist_tables = _make_instance(rng)
    ns = [v.num_shared for v in views]
    out(f"\n== RoundEngine: one sparse FedS round, E={NUM_GLOBAL} D={DIM} "
        f"C={NUM_CLIENTS} Ns={ns} p={SPARSITY} ==")

    # ---- reference (numpy host protocol)
    hists = [
        jnp.asarray(np.asarray(h)[v.shared_local])
        for h, v in zip(hist_tables, views)
    ]
    _reference_round(tables, hists, views, np.random.default_rng(0))  # warm jits
    iters_ref = 1 if not FAST else 2
    t0 = time.perf_counter()
    for _ in range(iters_ref):
        _reference_round(tables, hists, views, np.random.default_rng(0))
    us_ref = (time.perf_counter() - t0) / iters_ref * 1e6

    # ---- batched engine, including host gather/scatter
    engine = RoundEngine(views, NUM_GLOBAL, DIM, SPARSITY, codec=IdentityCodec())
    hist_b = engine.gather(hist_tables)

    def engine_round():
        emb_b = engine.gather(tables)
        new_emb, new_hist, dc = engine.sparse_round(emb_b, hist_b)
        new_tables = engine.scatter(new_emb, tables)
        jax.block_until_ready((new_tables, new_hist, dc))
        return new_emb

    engine_round()  # warm
    iters_eng = 5
    t0 = time.perf_counter()
    for _ in range(iters_eng):
        engine_round()
    us_eng = (time.perf_counter() - t0) / iters_eng * 1e6

    # ---- jitted core alone (device-resident state)
    emb_b = engine.gather(tables)
    jax.block_until_ready(engine.sparse_round(emb_b, hist_b))
    t0 = time.perf_counter()
    for _ in range(iters_eng):
        jax.block_until_ready(engine.sparse_round(emb_b, hist_b))
    us_core = (time.perf_counter() - t0) / iters_eng * 1e6

    rows = [
        ("engine.reference_round", us_ref, "1.0x"),
        ("engine.batched_round", us_eng, f"{us_ref / us_eng:.1f}x"),
        ("engine.batched_core", us_core, f"{us_ref / us_core:.1f}x"),
    ]
    for name, us, derived in rows:
        out(f"{name},{us:.1f},{derived}")
    return rows


def check_claims(rows):
    by = {r[0]: r[1] for r in rows}
    speedup = by["engine.reference_round"] / by["engine.batched_core"]
    ok = speedup > 3.0
    return [
        f"[{'PASS' if ok else 'WARN'}] jitted engine round {speedup:.1f}x vs "
        f"numpy reference (expect >3x at FB15k-237 scale)"
    ]


if __name__ == "__main__":
    run()
