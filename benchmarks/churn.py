"""Churn sweeps: accuracy and wire bytes under partial participation.

The paper (like its baselines) assumes a perfectly reliable federation.
This benchmark maps what the fault-injection subsystem
(:mod:`repro.core.faults`) costs and buys:

* **participation sweep** — FedS with per-round Bernoulli participation
  ``p_part`` in {1.0, 0.8, 0.6, 0.4}: converged MRR and wire bytes/round.
  Absent clients exchange no bytes (billing happens at send time on the
  ``part`` mask), so bytes/round must fall monotonically with ``p_part`` —
  an exact accounting claim, not a statistical one.
* **sync-interval-under-churn sweep** — at fixed churn (``p_part=0.6`` plus
  upload drops) the ISM sync round is the recovery point that heals
  divergence accumulated while clients were absent; sweeping ``s`` in
  {2, 4, 8} (plus FedS/syn, i.e. never) maps how much recovery frequency
  matters once rounds are unreliable.

Runs the superstep engine on the seeded synthetic KG at benchmark scale
(see benchmarks/common.py; ``REPRO_BENCH_FAST=1`` shrinks everything).
``--json PATH`` writes the machine-readable record CI publishes as
``BENCH_churn.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import (  # noqa: E402
    DIM, FAST, ROUNDS, SYNC_S, fmt_row, make_config, run_with_divergence,
)

PARTICIPATION = (1.0, 0.8, 0.6, 0.4)
SYNC_SWEEP = (2, 4, 8)
CHURN = "p=0.6,drop_up=0.1,seed=11"  # the fixed chaos for the s-sweep
FAULT_SEED = 11


def _bytes_per_round(res) -> float:
    return res.ledger.bytes_int8_signs / max(res.ledger.rounds, 1)


def _fmt_div(x) -> str:
    return f"{x:.4f}" if x is not None else "-"


def run(out=print):
    rows = []
    out(f"\n== churn: participation sweep (TransE, R3, s={SYNC_S}, "
        f"{ROUNDS} rounds) ==")
    out(fmt_row(["p_part", "MRR@CG", "bytes/round", "R@CG", "div_sparse"]))
    for p in PARTICIPATION:
        faults = "" if p >= 1.0 else f"p={p},seed={FAULT_SEED}"
        res, div = run_with_divergence(3, make_config(
            "feds", engine="superstep", faults=faults, patience=99,
        ))
        bpr = _bytes_per_round(res)
        rows.append({"kind": "participation", "value": p,
                     "mrr": res.test_mrr_cg, "bytes_per_round": bpr,
                     "best_round": res.best_round,
                     "div_sparse": div["sparse"], "div_sync": div["sync"]})
        out(fmt_row([p, f"{res.test_mrr_cg:.4f}", f"{bpr / 1e3:.1f}KB",
                     res.best_round, _fmt_div(div["sparse"])]))

    out(f"\n== churn: sync interval under {CHURN!r} ==")
    out(fmt_row(["s", "MRR@CG", "bytes/round", "R@CG", "div_sparse",
                 "div_sync"]))
    sweep = [("feds", s) for s in SYNC_SWEEP] + [("feds_nosync", None)]
    for proto, s in sweep:
        over = {"sync_interval": s} if s is not None else {}
        res, div = run_with_divergence(3, make_config(
            proto, engine="superstep", faults=CHURN, patience=99, **over,
        ))
        label = s if s is not None else "never"
        rows.append({"kind": "sync_under_churn", "value": label,
                     "mrr": res.test_mrr_cg,
                     "bytes_per_round": _bytes_per_round(res),
                     "best_round": res.best_round,
                     "div_sparse": div["sparse"], "div_sync": div["sync"]})
        out(fmt_row([label, f"{res.test_mrr_cg:.4f}",
                     f"{_bytes_per_round(res) / 1e3:.1f}KB", res.best_round,
                     _fmt_div(div["sparse"]), _fmt_div(div["sync"])]))
    return rows


def check_claims(rows):
    notes = []
    part = {r["value"]: r for r in rows if r["kind"] == "participation"}
    full = part[1.0]
    for p in PARTICIPATION[1:]:
        r = part[p]
        # exact: absent clients are never billed, so bytes/round shrink
        ok = r["bytes_per_round"] < full["bytes_per_round"]
        notes.append(
            f"[{'PASS' if ok else 'WARN'}] churn p={p}: "
            f"{r['bytes_per_round'] / full['bytes_per_round']:.2f}x "
            f"all-present wire bytes/round (absent clients bill nothing)"
        )
    r = part[0.6]
    ok = r["mrr"] >= 0.5 * full["mrr"]
    notes.append(
        f"[{'PASS' if ok else 'WARN'}] churn p=0.6 retains "
        f"{r['mrr'] / full['mrr']:.2f}x of all-present MRR "
        f"(graceful degradation, expect >= 0.5x)"
    )
    sync = {r["value"]: r for r in rows if r["kind"] == "sync_under_churn"}
    best_s = max((sync[s]["mrr"] for s in SYNC_SWEEP))
    ok = best_s >= sync["never"]["mrr"] * 0.98
    notes.append(
        f"[{'PASS' if ok else 'WARN'}] sync under churn: best synced MRR "
        f"{best_s:.4f} vs never-sync {sync['never']['mrr']:.4f} "
        f"(sync rounds act as recovery points)"
    )
    # even under churn, every synced schedule's sync rounds must sit below
    # its own sparse rounds on shared-entity divergence (the recovery the
    # second sweep exists to map)
    healed = [s for s in SYNC_SWEEP
              if sync[s]["div_sync"] is not None
              and sync[s]["div_sparse"] is not None
              and sync[s]["div_sync"] < sync[s]["div_sparse"]]
    ok = len(healed) == len(SYNC_SWEEP)
    notes.append(
        f"[{'PASS' if ok else 'WARN'}] sync under churn: "
        f"{len(healed)}/{len(SYNC_SWEEP)} sync intervals show sync-round "
        f"divergence below sparse-round divergence (sync heals drift "
        f"accumulated while clients were absent)"
    )
    return notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write a JSON record here")
    args = ap.parse_args()
    rows = run()
    claims = check_claims(rows)
    for c in claims:
        print(c)
    if args.json:
        rec = {
            "bench": "churn",
            "schema_version": 1,
            "fast": FAST,
            "config": {
                "dim": DIM, "rounds": ROUNDS, "sync_s": SYNC_S,
                "participation": list(PARTICIPATION),
                "sync_sweep": list(SYNC_SWEEP), "churn": CHURN,
            },
            "rows": rows,
            "claims": claims,
        }
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
